"""Percentile reduction over per-scenario sweep results.

Monte-Carlo claims report bands, not point estimates: each swept cell
reduces its scenarios' metrics to p10/p50/p90 (numpy ``percentile`` with
linear interpolation — deterministic for a deterministic batch).  A
scenario that never reaches the target has ``convergence_delay_s=None``;
those are excluded from the band and counted in ``n_failed`` so a cell
that "converges fast, 40% of the time" cannot masquerade as fast.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

import numpy as np

BAND_PS = (10, 50, 90)


def percentile_bands(values: Iterable[Optional[float]],
                     ps: Sequence[int] = BAND_PS) -> Dict:
    """{"p10": ..., "p50": ..., "p90": ..., "n": ..., "n_failed": ...}
    over ``values``; Nones are failures, excluded from the percentiles.
    An all-None (or empty) input yields None bands."""
    vals = [v for v in values if v is not None]
    n_failed = sum(1 for v in values if v is None)
    out: Dict = {"n": len(vals) + n_failed, "n_failed": n_failed}
    if not vals:
        out.update({f"p{p}": None for p in ps})
        return out
    arr = np.asarray(vals, np.float64)
    for p in ps:
        out[f"p{p}"] = float(np.percentile(arr, p))
    return out


def reduce_results(results: Sequence) -> Dict:
    """Band summary over a list of ``driver.ScenarioResult``:
    convergence delay, epochs-to-target, final accuracy, aggregations."""
    return {
        "convergence_delay_s": percentile_bands(
            [r.convergence_delay_s for r in results]),
        "epochs_to_target": percentile_bands(
            [None if r.convergence_delay_s is None else float(r.epochs)
             for r in results]),
        "final_accuracy": percentile_bands(
            [r.final_accuracy for r in results]),
        "aggregations": percentile_bands(
            [float(r.epochs) for r in results]),
    }
