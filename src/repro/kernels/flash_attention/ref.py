"""Pure-jnp oracle for flash_attention (flat layout)."""
import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    Sq, Sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(v.dtype)
