"""Public API: pairwise distances between model pytrees (grouping step)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.pairwise_dist.kernel import pairwise_dist_sq


def pairwise_dist(x, *, squared: bool = False,
                  interpret: Optional[bool] = None):
    """x: (M, N) stacked flat models -> (M, M) L2 (or squared) distances."""
    if interpret is None:
        interpret = default_interpret()
    d2 = pairwise_dist_sq(x, interpret=interpret)
    return d2 if squared else jnp.sqrt(d2)


def dist_to_ref(stack, ref, *, squared: bool = False,
                interpret: Optional[bool] = None):
    """L2 distance of each row of a stacked (M, N) model bank to one (N,)
    reference vector (the grouping step's distance-to-w0, paper Fig. 5b).

    Small M (grouping-scale: a handful of orbits) routes through the
    pairwise kernel by prepending ``ref`` as row 0 of one (M+1, N) pass;
    the kernel's (M+1)^2 Gram work is negligible there.  Larger stacks use
    a direct O(M*N) row-wise reduction instead.
    """
    stack = jnp.asarray(stack, jnp.float32)
    ref = jnp.asarray(ref, jnp.float32)
    if stack.shape[0] > 64:
        d2 = jnp.sum((stack - ref[None, :]) ** 2, axis=1)
        return d2 if squared else jnp.sqrt(d2)
    x = jnp.concatenate([ref[None], stack], axis=0)
    return pairwise_dist(x, squared=squared, interpret=interpret)[0, 1:]


def model_pairwise_dist(models: Sequence, *, interpret: Optional[bool] = None):
    flat = jnp.stack([
        jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                         for l in jax.tree_util.tree_leaves(m)])
        for m in models])
    return pairwise_dist(flat, interpret=interpret)
