"""Walker-delta LEO constellation — circular Kepler orbits (paper §III).

Orbital period  T_o = 2*pi*(R_E + h_o) / v_o,  v_o = sqrt(GM / (R_E + h_o)).
Satellite (o, s) flies at argument-of-latitude
    u(t) = 2*pi*s/N_o + F*2*pi*o/(O*N_o) + n*t        (n = mean motion)
in the plane with RAAN  Omega_o = 2*pi*o/O  and inclination i.  Ground nodes
(GS) and HAPs are Earth-fixed and rotate with the Earth in ECI.

Everything is vectorized numpy; times are seconds since sim start.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

R_EARTH = 6371.0e3          # m
GM = 3.986004418e14         # m^3/s^2
OMEGA_EARTH = 7.2921159e-5  # rad/s
C_LIGHT = 299_792_458.0     # m/s


@dataclasses.dataclass(frozen=True)
class WalkerDelta:
    num_orbits: int
    sats_per_orbit: int
    altitude_m: float = 2000e3
    inclination_deg: float = 80.0
    phasing: int = 1                      # Walker F factor

    @property
    def num_sats(self) -> int:
        return self.num_orbits * self.sats_per_orbit

    @property
    def radius_m(self) -> float:
        return R_EARTH + self.altitude_m

    @property
    def velocity(self) -> float:
        return float(np.sqrt(GM / self.radius_m))

    @property
    def period_s(self) -> float:
        return float(2 * np.pi * self.radius_m / self.velocity)

    @property
    def mean_motion(self) -> float:
        return 2 * np.pi / self.period_s

    def orbit_of(self, sat: int) -> int:
        return sat // self.sats_per_orbit

    def index_in_orbit(self, sat: int) -> int:
        return sat % self.sats_per_orbit

    def orbit_ids(self) -> np.ndarray:
        return np.arange(self.num_sats) // self.sats_per_orbit

    def positions(self, t) -> np.ndarray:
        """ECI positions at time(s) t.  t scalar -> (S,3); t (T,) -> (T,S,3)."""
        t = np.asarray(t, dtype=np.float64)
        scalar = t.ndim == 0
        t = np.atleast_1d(t)
        O, N = self.num_orbits, self.sats_per_orbit
        o = np.repeat(np.arange(O), N)
        s = np.tile(np.arange(N), O)
        raan = 2 * np.pi * o / O
        phase0 = 2 * np.pi * s / N + self.phasing * 2 * np.pi * o / (O * N)
        u = phase0[None, :] + self.mean_motion * t[:, None]     # (T,S)
        inc = np.deg2rad(self.inclination_deg)
        r = self.radius_m
        # in-plane
        xp, yp = r * np.cos(u), r * np.sin(u)
        # rotate by inclination (about x), then RAAN (about z)
        x1, y1, z1 = xp, yp * np.cos(inc), yp * np.sin(inc)
        cosO, sinO = np.cos(raan)[None, :], np.sin(raan)[None, :]
        x = x1 * cosO - y1 * sinO
        y = x1 * sinO + y1 * cosO
        pos = np.stack([x, y, z1], axis=-1)                     # (T,S,3)
        return pos[0] if scalar else pos

    def positions_at(self, sats, t) -> np.ndarray:
        """ECI positions of *specific* satellites at per-satellite times.
        ``sats`` (P,) int, ``t`` scalar or (P,) -> (P, 3).  Unlike
        ``positions`` this never materializes the full constellation, so
        per-satellite timing paths stay O(P)."""
        sats = np.atleast_1d(np.asarray(sats, dtype=np.int64))
        t = np.broadcast_to(np.asarray(t, dtype=np.float64), sats.shape)
        O, N = self.num_orbits, self.sats_per_orbit
        o, s = sats // N, sats % N
        raan = 2 * np.pi * o / O
        phase0 = 2 * np.pi * s / N + self.phasing * 2 * np.pi * o / (O * N)
        u = phase0 + self.mean_motion * t
        inc = np.deg2rad(self.inclination_deg)
        r = self.radius_m
        xp, yp = r * np.cos(u), r * np.sin(u)
        x1, y1, z1 = xp, yp * np.cos(inc), yp * np.sin(inc)
        cosO, sinO = np.cos(raan), np.sin(raan)
        return np.stack([x1 * cosO - y1 * sinO, x1 * sinO + y1 * cosO, z1],
                        axis=-1)


@dataclasses.dataclass(frozen=True)
class GroundNode:
    """A GS (altitude ~0) or HAP (stratosphere, ~20 km) fixed over a location."""
    name: str
    lat_deg: float
    lon_deg: float
    altitude_m: float = 0.0
    min_elevation_deg: float = 10.0
    kind: str = "gs"                      # gs | hap

    def position(self, t) -> np.ndarray:
        """ECI position at time(s) t (Earth-fixed point rotating with Earth)."""
        t = np.asarray(t, dtype=np.float64)
        scalar = t.ndim == 0
        t = np.atleast_1d(t)
        lat, lon = np.deg2rad(self.lat_deg), np.deg2rad(self.lon_deg)
        r = R_EARTH + self.altitude_m
        theta = lon + OMEGA_EARTH * t                           # (T,)
        x = r * np.cos(lat) * np.cos(theta)
        y = r * np.cos(lat) * np.sin(theta)
        z = np.full_like(theta, r * np.sin(lat))
        pos = np.stack([x, y, z], axis=-1)
        return pos[0] if scalar else pos


# paper §V-A locations
ROLLA = (37.95, -91.77)
PORTLAND = (45.52, -122.68)
NORTH_POLE = (90.0, 0.0)


def paper_constellation() -> WalkerDelta:
    """40 satellites over 5 orbits at 2000 km, 80 deg inclination (§V-A)."""
    return WalkerDelta(num_orbits=5, sats_per_orbit=8,
                       altitude_m=2000e3, inclination_deg=80.0)


def make_ps_nodes(scenario: str) -> List[GroundNode]:
    """'gs' | 'hap' | 'twohap' | 'gs-np' (ideal-setup baselines), plus the
    parametric 'hapring:N' mega-constellation scenario: N >= 1 HAPs at
    20 km spread evenly in longitude at the Rolla latitude, forming a
    P = N parameter-server ring (§IV-A ring-of-stars at scale)."""
    if scenario.startswith("hapring:"):
        n = int(scenario.split(":", 1)[1])
        if n < 1:
            raise ValueError(scenario)
        lat, lon0 = ROLLA
        return [GroundNode(f"HAP-ring{k}", lat,
                           ((lon0 + 360.0 * k / n + 180.0) % 360.0) - 180.0,
                           20e3, kind="hap")
                for k in range(n)]
    if scenario == "gs":
        return [GroundNode("GS-Rolla", *ROLLA, 0.0)]
    if scenario == "hap":
        return [GroundNode("HAP-Rolla", *ROLLA, 20e3, kind="hap")]
    if scenario == "twohap":
        return [GroundNode("HAP-Rolla", *ROLLA, 20e3, kind="hap"),
                GroundNode("HAP-Portland", *PORTLAND, 20e3, kind="hap")]
    if scenario == "gs-np":
        return [GroundNode("GS-NorthPole", *NORTH_POLE, 0.0)]
    raise ValueError(scenario)
