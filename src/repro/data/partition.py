"""Federated data partitioning across satellites (paper §V-A).

IID: shuffle and split evenly — every satellite sees all 10 classes.
non-IID (the paper's setting): satellites of two orbits hold four classes,
satellites of the other three orbits hold the remaining six classes.
A Dirichlet partitioner is included for broader ablations.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def iid_partition(labels: np.ndarray, num_clients: int, seed: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(order, num_clients)]


def paper_noniid_partition(labels: np.ndarray, orbits: Sequence[int],
                           seed: int, *, num_classes: int = 10,
                           split_classes: int = 4,
                           low_orbits: int = 2) -> List[np.ndarray]:
    """``orbits[i]`` = orbit id of satellite i.  Satellites in the first
    ``low_orbits`` orbits draw from classes [0, split_classes); the rest draw
    from [split_classes, num_classes) — the paper's 4/6 class split."""
    rng = np.random.default_rng(seed)
    orbits = np.asarray(orbits)
    group_a = np.flatnonzero(np.isin(labels, np.arange(split_classes)))
    group_b = np.flatnonzero(np.isin(labels, np.arange(split_classes, num_classes)))
    rng.shuffle(group_a)
    rng.shuffle(group_b)
    sats_a = np.flatnonzero(orbits < low_orbits)
    sats_b = np.flatnonzero(orbits >= low_orbits)
    out: List[np.ndarray] = [None] * len(orbits)   # type: ignore[list-item]
    for sats, pool in ((sats_a, group_a), (sats_b, group_b)):
        chunks = np.array_split(pool, max(len(sats), 1))
        for s, c in zip(sats, chunks):
            out[int(s)] = np.sort(c)
    return out


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float,
                        seed: int, num_classes: int = 10) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx_by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    shares = rng.dirichlet([alpha] * num_clients, size=num_classes)
    client_idx: List[list] = [[] for _ in range(num_clients)]
    for c, idx in enumerate(idx_by_class):
        cuts = (np.cumsum(shares[c])[:-1] * len(idx)).astype(int)
        for cid, part in enumerate(np.split(idx, cuts)):
            client_idx[cid].extend(part.tolist())
    return [np.sort(np.array(ci, dtype=np.int64)) for ci in client_idx]
