from repro.kernels.chunk_scan import ops, ref
from repro.kernels.chunk_scan.ops import chunk_scan
