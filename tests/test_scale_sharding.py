"""Layer-4 scale proof: the (C, N) ModelBank provably shards (DESIGN.md §14).

Everything before this test only *type-checked* bank sharding on the
identity mesh (one device -> every NamedSharding is trivially satisfied).
Here a subprocess forces an 8-device CPU backend so the S=10^4-class bank
actually splits: each device must own C/8 participant rows, the sharded
contraction must reduce over a genuinely distributed C axis, and the
fused epoch program at C=16384 must (a) keep its bank on the documented
``bank_sharding`` layout and (b) stay numerically identical to the
single-logical-device run.

Subprocess because jax locks the device count at first init — the same
pattern as ``test_epoch_step.py``'s 4-device case, scaled up.
"""
import os
import subprocess
import sys
import textwrap

SCALE_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.epoch_step import (EpochStepProgram, bank_sharding,
                                       sharded_contract)
    from repro.core.modelbank import FlatSpec, flatten_tree
    from repro.launch.mesh import make_data_mesh
    from repro.launch.sharding import replicated

    assert len(jax.devices()) == 8
    mesh = make_data_mesh()
    assert dict(zip(mesh.axis_names, mesh.devices.shape))["data"] == 8

    # ---- the bank really splits: 16384 rows -> 2048 per device ----------
    C, N = 16384, 32
    rng = np.random.default_rng(0)
    bank_host = rng.standard_normal((C, N)).astype(np.float32)
    bank = jax.device_put(bank_host, bank_sharding(mesh))
    shards = bank.addressable_shards
    assert len(shards) == 8
    assert {s.device for s in shards} == set(jax.devices())
    for s in shards:
        assert s.data.shape == (C // 8, N), s.data.shape
    np.testing.assert_array_equal(np.asarray(bank), bank_host)

    # ---- sharded contraction reduces over the distributed C axis --------
    w = jax.device_put(rng.random(C).astype(np.float32),
                       jax.sharding.NamedSharding(mesh, P("data")))
    out = sharded_contract(w, bank, mesh)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(w) @ bank_host,
                               atol=1e-3, rtol=1e-4)

    # ---- fused epoch program at mega-constellation capacity -------------
    w0 = {"w": np.arange(24, dtype=np.float32).reshape(4, 6),
          "b": np.ones(8, np.float32)}
    spec = FlatSpec.of(w0)

    def train_fn(params, inputs, ids, seed):
        flat = flatten_tree(params)
        offs = ((ids * 37 + seed.astype(jnp.int32)) % 11
                - 5).astype(jnp.float32) * 0.01
        stack = flat[None, :] * 0.9 + offs[:, None] + inputs[:, None]
        return stack, offs

    cap, K = 8, 2
    ids = np.arange(C, dtype=np.int32)
    inputs = np.linspace(0.0, 1.0, C).astype(np.float32)
    wv = (np.linspace(0.1, 0.2, C) / C).astype(np.float32)
    wc = np.zeros(cap, np.float32)
    dw_row = np.full(C, 1.0 / C, np.float32)
    dw_seg = np.repeat(np.arange(K), C // K).astype(np.int32)
    dwc = np.zeros((K, cap), np.float32)

    outs = {}
    for name, m in (("single", None), ("mesh", mesh)):
        prog = EpochStepProgram(spec, train_fn, mesh=m)
        w_flat = spec.flatten(w0)
        carry = jnp.zeros((cap, spec.num_params), jnp.float32)
        ref = jnp.zeros(spec.num_params)
        new_w, stack, dists, losses = prog.step(
            w_flat, carry, jnp.asarray(inputs), ids, 7, wv, wc, 0.5,
            dw_row, dw_seg, K, 0, dwc, ref)
        assert stack.shape == (C, spec.num_params)
        outs[name] = (np.asarray(new_w), np.asarray(dists))
        if name == "mesh":
            assert stack.sharding.is_equivalent_to(bank_sharding(mesh),
                                                   stack.ndim), stack.sharding
            per_dev = {s.device: s.data.shape for s in
                       stack.addressable_shards}
            assert len(per_dev) == 8
            assert all(sh == (C // 8, spec.num_params)
                       for sh in per_dev.values()), per_dev
    np.testing.assert_allclose(outs["single"][0], outs["mesh"][0], atol=1e-5)
    np.testing.assert_allclose(outs["single"][1], outs["mesh"][1], atol=1e-5)
    print("SCALE-SHARD-OK")
""")


def test_bank_shards_at_scale_on_8_devices():
    here = os.path.dirname(__file__)
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(here, "..", "src"), here]))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCALE_SHARD_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SCALE-SHARD-OK" in proc.stdout
