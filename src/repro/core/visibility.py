"""Visibility: satellite<->ground elevation gating and inter-satellite LoS.

The paper's link condition (§III-B): a satellite n and PS g can communicate
iff the elevation of n above g's local horizon is >= the minimum elevation
angle.  ``VisibilityTimeline`` precomputes the boolean visibility grid over
the whole simulation horizon (vectorized — 3 days at dt=10 s for 40 sats x
2 PSs is ~52k x 40 x 2 bools) and answers next-visible queries in O(1)-ish.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.constellation import GroundNode, R_EARTH, WalkerDelta

ATMOSPHERE_MARGIN_M = 80e3   # ISL grazing margin above the surface


def elevation_deg(sat_pos: np.ndarray, gnd_pos: np.ndarray) -> np.ndarray:
    """Elevation of satellite(s) above ground node's horizon, degrees.
    Broadcasts over leading dims; last dim is xyz."""
    d = sat_pos - gnd_pos
    dn = np.linalg.norm(d, axis=-1)
    gn = np.linalg.norm(gnd_pos, axis=-1)
    sin_el = np.sum(d * gnd_pos, axis=-1) / np.maximum(dn * gn, 1e-9)
    return np.rad2deg(np.arcsin(np.clip(sin_el, -1.0, 1.0)))


def horizon_dip_deg(altitude_m: float) -> float:
    """Geometric horizon dip for an elevated observer: arccos(R/(R+h)).
    ~4.5 deg at 20 km — the physical reason a HAP sees more satellites than
    a GS at the same nominal minimum elevation (paper §I/§III)."""
    if altitude_m <= 0:
        return 0.0
    return float(np.rad2deg(np.arccos(R_EARTH / (R_EARTH + altitude_m))))


def is_visible(sat_pos, node: GroundNode, node_pos) -> np.ndarray:
    eff_min = node.min_elevation_deg - horizon_dip_deg(node.altitude_m)
    return elevation_deg(sat_pos, node_pos) >= eff_min


def sat_los(p1: np.ndarray, p2: np.ndarray,
            margin_m: float = ATMOSPHERE_MARGIN_M) -> np.ndarray:
    """Inter-satellite line-of-sight: True if the segment p1-p2 clears the
    Earth (+margin).  Broadcasts over leading dims."""
    d = p2 - p1
    dd = np.sum(d * d, axis=-1)
    t = -np.sum(p1 * d, axis=-1) / np.maximum(dd, 1e-9)
    t = np.clip(t, 0.0, 1.0)
    closest = p1 + t[..., None] * d
    return np.linalg.norm(closest, axis=-1) >= (R_EARTH + margin_m)


@dataclasses.dataclass
class VisibilityTimeline:
    """Precomputed sat x PS visibility over [0, duration] at step dt."""
    constellation: WalkerDelta
    nodes: List[GroundNode]
    duration_s: float
    dt_s: float = 10.0

    def __post_init__(self):
        self.times = np.arange(0.0, self.duration_s + self.dt_s, self.dt_s)
        sat_pos = self.constellation.positions(self.times)      # (T,S,3)
        self.grid = np.zeros((len(self.times), self.constellation.num_sats,
                              len(self.nodes)), dtype=bool)
        self._sat_pos = sat_pos
        for j, node in enumerate(self.nodes):
            npos = node.position(self.times)[:, None, :]        # (T,1,3)
            self.grid[:, :, j] = is_visible(sat_pos, node, npos)

    # ---- queries ----------------------------------------------------------

    def _ti(self, t: float) -> int:
        return int(np.clip(round(t / self.dt_s), 0, len(self.times) - 1))

    def visible(self, t: float) -> np.ndarray:
        """(S, P) bool at time t."""
        return self.grid[self._ti(t)]

    def visible_sats(self, t: float, node_idx: int) -> np.ndarray:
        return np.flatnonzero(self.grid[self._ti(t), :, node_idx])

    def next_visible_time(self, sat: int, t: float,
                          node_idx: Optional[int] = None) -> Optional[float]:
        """Earliest time >= t when ``sat`` sees any PS (or a specific one).
        None if never within the horizon."""
        ti = self._ti(t)
        col = (self.grid[ti:, sat, :].any(axis=-1) if node_idx is None
               else self.grid[ti:, sat, node_idx])
        hits = np.flatnonzero(col)
        if len(hits) == 0:
            return None
        return float(self.times[ti + hits[0]])

    def _next_visible_grid(self) -> np.ndarray:
        """(T, S) int32: for each (time step, sat), the earliest row >= t
        where the satellite sees any PS (== T when never again).  Built once
        by a reverse running-minimum over the visibility grid and cached —
        it turns every next-visible query into one fancy-index lookup."""
        if not hasattr(self, "_nxt"):
            T = self.grid.shape[0]
            any_ps = self.grid.any(axis=2)                      # (T, S)
            idx = np.where(any_ps, np.arange(T, dtype=np.int32)[:, None],
                           np.int32(T))
            self._nxt = np.minimum.accumulate(idx[::-1], axis=0)[::-1]
        return self._nxt

    def next_visible_after(self, sats, t):
        """Vectorized ``next_visible_time`` over (sat, per-sat time) pairs.
        Returns (times (P,), first-visible PS (P,)) with inf / -1 where a
        satellite is never visible again within the horizon."""
        sats = np.atleast_1d(np.asarray(sats, dtype=np.int64))
        t = np.broadcast_to(np.asarray(t, dtype=np.float64), sats.shape)
        ti = np.clip(np.round(t / self.dt_s).astype(np.int64), 0,
                     len(self.times) - 1)
        row = self._next_visible_grid()[ti, sats]
        ok = row < self.grid.shape[0]
        rowc = np.minimum(row, self.grid.shape[0] - 1)
        times = np.where(ok, self.times[rowc], np.inf)
        ps = np.where(ok, np.argmax(self.grid[rowc, sats, :], axis=1), -1)
        return times, ps

    def next_orbit_visible(self, orbit_sats: Sequence[int], t: float):
        """Earliest (time, sat) at/after t when any satellite of an orbit sees
        any PS.  Returns (None, None) if never."""
        ti = self._ti(t)
        sub = self.grid[ti:][:, list(orbit_sats), :].any(axis=-1)   # (T', n)
        rows = np.flatnonzero(sub.any(axis=1))
        if len(rows) == 0:
            return None, None
        row = rows[0]
        sat_local = int(np.flatnonzero(sub[row])[0])
        return float(self.times[ti + row]), int(list(orbit_sats)[sat_local])

    def visibility_fraction(self, sat: int) -> float:
        return float(self.grid[:, sat, :].any(axis=-1).mean())
