"""Unified model API over every assigned architecture family.

    init_params(key, cfg)                        -> params pytree
    apply(params, cfg, batch, ...)               -> (logits, aux)     # train/prefill
    init_cache(cfg, batch, cache_len, dtype)     -> cache pytree      # decode
    decode_step(params, cfg, cache, tokens, ...) -> (logits, cache)
    train_loss(params, cfg, batch, ...)          -> (loss, metrics)
    analytic_param_count(cfg, active_only)       -> int
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as MB
from repro.models import rwkv as RW
from repro.models import transformer as TF

MOE_AUX_WEIGHT = 0.01


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    if cfg.family == "ssm":
        ks = jax.random.split(key, 3)
        return {
            "embed": L.init_embedding(ks[0], cfg),
            "final_norm": jnp.ones((cfg.d_model,)),
            "layers": TF._stacked_init(
                functools.partial(RW.init_layer, cfg=cfg), ks[1], cfg.num_layers),
        }
    if cfg.family == "hybrid":
        G = cfg.num_layers // cfg.attn_every
        ks = jax.random.split(key, 4)
        mamba_keys = jax.random.split(ks[1], G * cfg.attn_every)
        stacked = jax.vmap(lambda k: MB.init_layer(k, cfg))(mamba_keys)
        stacked = jax.tree.map(
            lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), stacked)
        return {
            "embed": L.init_embedding(ks[0], cfg),
            "final_norm": jnp.ones((cfg.d_model,)),
            "mamba": stacked,
            "shared": MB.init_shared_attn(ks[2], cfg),
        }
    return TF.init_params(key, cfg)


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def apply(params, cfg: ModelConfig, batch, *, window: int = 0, impl: str = "xla",
          q_chunks: int = 1):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "ssm":
        x, _ = TF._embed_inputs(params, cfg, batch, dtype)
        B, S = x.shape[:2]
        state = RW.init_state(cfg, B, dtype)
        scan_impl = "jnp" if impl == "xla" else impl

        def body(x, inp):
            lp, st = inp
            x, st2 = RW.block(lp, cfg, x, st, impl=scan_impl)
            return x, st2
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, (params["layers"], state))
        x = L.rms_norm(x, params["final_norm"])
        return L.unembed(params["embed"], cfg, x), jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        x, positions = TF._embed_inputs(params, cfg, batch, dtype)
        B, S = x.shape[:2]
        G = cfg.num_layers // cfg.attn_every
        state = MB.init_state(cfg, cfg.num_layers, B, dtype)
        state = jax.tree.map(
            lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), state)
        shared = params["shared"]

        def group_body(x, inp):
            mp_g, st_g = inp
            x, _ = MB.shared_attn_block(shared, cfg, x, positions, None,
                                        window=window)

            def mamba_body(x, inp2):
                lp, st = inp2
                x, st2 = MB.block(lp, cfg, x, st,
                                  impl="jnp" if impl == "xla" else impl)
                return x, st2
            x, st2 = jax.lax.scan(mamba_body, x, (mp_g, st_g))
            return x, st2
        if cfg.remat:
            group_body = jax.checkpoint(group_body, prevent_cse=False)
        x, _ = jax.lax.scan(group_body, x, (params["mamba"], state))
        x = L.rms_norm(x, params["final_norm"])
        return L.unembed(params["embed"], cfg, x), jnp.zeros((), jnp.float32)

    return TF.forward(params, cfg, batch, window=window, impl=impl,
                      q_chunks=q_chunks)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    if cfg.family == "ssm":
        return RW.init_state(cfg, batch, dtype)
    if cfg.family == "hybrid":
        G = cfg.num_layers // cfg.attn_every
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        st = MB.init_state(cfg, cfg.num_layers, batch, dtype)
        st = jax.tree.map(lambda a: a.reshape((G, cfg.attn_every) + a.shape[1:]), st)
        return {
            "mamba": st,
            "attn_k": jnp.zeros((G, batch, cache_len, KV, hd), dtype),
            "attn_v": jnp.zeros((G, batch, cache_len, KV, hd), dtype),
            "index": jnp.zeros((), jnp.int32),
        }
    return TF.init_cache(cfg, batch, cache_len, dtype)


def decode_step(params, cfg: ModelConfig, cache, tokens, *, window: int = 0):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "ssm":
        x = L.embed(params["embed"], cfg, tokens, dtype)

        def body(x, inp):
            lp, st = inp
            x, st2 = RW.block(lp, cfg, x, st)
            return x, st2
        x, new_state = jax.lax.scan(body, x, (params["layers"], cache))
        x = L.rms_norm(x, params["final_norm"])
        return L.unembed(params["embed"], cfg, x), new_state

    if cfg.family == "hybrid":
        x = L.embed(params["embed"], cfg, tokens, dtype)
        idx = cache["index"]
        shared = params["shared"]

        def group_body(x, inp):
            mp_g, st_g, kc, vc = inp
            attn_cache = {"k": kc, "v": vc, "index": idx}
            x, new_attn = MB.shared_attn_block(shared, cfg, x, None, attn_cache,
                                               window=window)

            def mamba_body(x, inp2):
                lp, st = inp2
                x, st2 = MB.block(lp, cfg, x, st)
                return x, st2
            x, st2 = jax.lax.scan(mamba_body, x, (mp_g, st_g))
            return x, (st2, new_attn["k"], new_attn["v"])
        x, (new_st, new_k, new_v) = jax.lax.scan(
            group_body, x, (params["mamba"], cache["mamba"],
                            cache["attn_k"], cache["attn_v"]))
        x = L.rms_norm(x, params["final_norm"])
        new_cache = {"mamba": new_st, "attn_k": new_k, "attn_v": new_v,
                     "index": idx + 1}
        return L.unembed(params["embed"], cfg, x), new_cache

    return TF.decode_step(params, cfg, cache, tokens, window=window)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def _ce(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)


def train_loss(params, cfg: ModelConfig, batch, *, window: int = 0,
               impl: str = "xla", q_chunks: int = 1):
    logits, aux = apply(params, cfg, batch, window=window, impl=impl,
                        q_chunks=q_chunks)
    if cfg.family == "audio":
        loss = _ce(logits, batch["labels"], batch.get("mask"))
    elif cfg.family == "vlm":
        P = batch["prefix_embeds"].shape[1]
        text_logits = logits[:, P:]
        loss = _ce(text_logits[:, :-1], batch["tokens"][:, 1:])
    else:
        loss = _ce(logits[:, :-1], batch["tokens"][:, 1:])
    total = loss + MOE_AUX_WEIGHT * aux
    return total, {"ce": loss, "aux": aux}


# --------------------------------------------------------------------------
# parameter counting (exact, via eval_shape — no allocation)
# --------------------------------------------------------------------------

def _count(cfg: ModelConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree_util.tree_leaves(shapes))


def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    if active_only and cfg.is_moe:
        cfg = cfg.replace(num_experts=cfg.top_k)
    return _count(cfg)
