from repro.kernels.pairwise_dist import ops, ref
from repro.kernels.pairwise_dist.ops import pairwise_dist, model_pairwise_dist
