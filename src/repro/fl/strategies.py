"""FL-Satcom strategies: AsyncFLEO and the paper's baselines (§II, §V-A).

Each strategy is a declarative spec consumed by ``repro.core.simulator``:

=================  ====== ======= ========== ============ =====================
strategy           sync   ISL     grouping   aggregation  PS placement
=================  ====== ======= ========== ============ =====================
asyncfleo-gs       no     yes     yes        asyncfleo    GS, arbitrary (Rolla)
asyncfleo-hap      no     yes     yes        asyncfleo    1 HAP, arbitrary
asyncfleo-twohap   no     yes     yes        asyncfleo    2 HAPs (ring)
fedavg / fedisl    yes    yes     no         fedavg       GS, arbitrary
fedisl-ideal       yes    yes     no         fedavg       GS at the North Pole
fedsat             no     no      no         per-arrival  GS at the North Pole
fedspace           no     no      no         interval     GS, arbitrary
fedhap             yes    yes     no         fedavg       1 HAP
fedasync           no     yes     no         per-arrival  GS, arbitrary
asyncfleo-pipelined no    yes     yes        asyncfleo    GS, 3 rounds in flight
=================  ====== ======= ========== ============ =====================

FedSpace's real scheduler optimizes the schedule from uploaded raw-data
fractions (which AsyncFLEO criticizes); we emulate its idle-vs-staleness
trade-off with a fixed-interval staleness-weighted aggregation (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    name: str
    sync: bool
    use_isl: bool
    grouping: bool
    agg_mode: str                    # asyncfleo | fedavg | per_arrival | interval
    ps_scenario: str                 # gs | hap | twohap | gs-np
    interval_s: float = 1800.0       # for agg_mode == interval
    num_groups: int = 3
    strict_paper_eq14: bool = False
    use_agg_kernel: bool = False     # route eq. 14 through the Pallas kernel
    # event-runtime trigger policy (sched/policies.py): "" derives it from
    # sync/agg_mode — sync -> barrier, per_arrival -> FedAsync, else the
    # AsyncFLEO idle-timeout window
    sched_policy: str = ""
    # pipelined event runtime (sched/runtime.py, DESIGN.md §8): how many
    # rounds may be in flight at once (1 = the single-round loop,
    # bit-identical to the epoch loop) and which sink-handoff policy
    # picks the next source/sink PS ("" -> the §IV-B3 ring role swap;
    # "next_contact" -> earliest-next-contact from the contact plan)
    max_in_flight: int = 1
    handoff_policy: str = ""
    # per-divergence-group trigger deadlines for the AsyncFLEO policy:
    # ((group_id, window_s), ...) pairs (group -1 = not-yet-grouped
    # orbits); empty keeps the single global agg_timeout_s window
    group_timeouts: tuple = ()
    # finite per-PS link capacity (sched/contacts.ContentionModel,
    # DESIGN.md §9): how many model transfers a PS can send (and,
    # separately, receive) in parallel — concurrent transfers at the same
    # PS beyond this serialize FIFO, including transfers from different
    # in-flight rounds.  None = infinite parallelism with no contention
    # state at all, bit-identical to the pre-contention semantics (the
    # parity default)
    ps_channels: Optional[int] = None


STRATEGIES = {
    "asyncfleo-gs": StrategySpec("asyncfleo-gs", False, True, True,
                                 "asyncfleo", "gs"),
    "asyncfleo-hap": StrategySpec("asyncfleo-hap", False, True, True,
                                  "asyncfleo", "hap"),
    "asyncfleo-twohap": StrategySpec("asyncfleo-twohap", False, True, True,
                                     "asyncfleo", "twohap"),
    "fedisl": StrategySpec("fedisl", True, True, False, "fedavg", "gs"),
    "fedisl-ideal": StrategySpec("fedisl-ideal", True, True, False,
                                 "fedavg", "gs-np"),
    "fedsat": StrategySpec("fedsat", False, False, False,
                           "per_arrival", "gs-np"),
    "fedspace": StrategySpec("fedspace", False, False, False,
                             "interval", "gs"),
    "fedhap": StrategySpec("fedhap", True, True, False, "fedavg", "hap"),
    # FedAsync-style baseline: immediate per-arrival aggregation at a GS
    # PS, full ISL relay — only meaningfully different from fedsat under
    # the event-driven runtime, where every MODEL_ARRIVAL triggers its own
    # aggregation instead of a batched window
    "fedasync": StrategySpec("fedasync", False, True, False,
                             "per_arrival", "gs", sched_policy="per_arrival"),
    # pipelined AsyncFLEO (DESIGN.md §8): same physics and PS placement
    # as asyncfleo-gs, but the event runtime keeps up to 3 rounds in
    # flight and opens each from the contact-plan-chosen PS — the
    # head-to-head row that isolates what overlap buys
    "asyncfleo-pipelined": StrategySpec("asyncfleo-pipelined", False, True,
                                        True, "asyncfleo", "gs",
                                        max_in_flight=3,
                                        handoff_policy="next_contact"),
}


def get_strategy(name: str) -> StrategySpec:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; available: {sorted(STRATEGIES)}")
    return STRATEGIES[name]
