"""Observability layer (DESIGN.md §12): structured tracing, the metric
registry behind ``runtime.stats``, Perfetto/JSONL export, and fused-
dispatch profiling.  Everything here is strictly read-only with respect
to simulation state — ``tracer=None`` / ``profiler=None`` runs are
bit-identical and pay nothing."""
from repro.obs.export import (add_runtime_tracks, export_chrome,
                              export_jsonl, validate_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                               StatsView)
from repro.obs.profile import DispatchProfiler
from repro.obs.trace import NULL_TRACER, Instant, NullTracer, Span, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span", "Instant",
    "Counter", "Gauge", "Histogram", "MetricRegistry", "StatsView",
    "DispatchProfiler",
    "export_chrome", "export_jsonl", "validate_chrome_trace",
    "add_runtime_tracks",
]
