"""Render EXPERIMENTS.md sections (markdown tables) from benchmark artifacts.

    PYTHONPATH=src python -m benchmarks.report            # prints to stdout
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import load_and_analyze, roofline_terms

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def _fmt(x, nd=4):
    if x is None:
        return "—"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) < 1e-4 or abs(x) >= 1e6:
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def roofline_table(path: str) -> str:
    rows = load_and_analyze([path])
    out = ["| arch | shape | chips | compute (s) | memory (s) | collective (s) "
           "| dominant | MODEL_FLOPs/HLO ratio | bound (s/step) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skip | — | {r['reason'][:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | "
            f"{_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} | "
            f"{_fmt(r['collective_s'])} | **{r['dominant']}** | "
            f"{_fmt(r['useful_ratio'], 3)} | {_fmt(r['step_time_bound_s'])} |")
    return "\n".join(out)


def dryrun_table(path: str) -> str:
    with open(path) as f:
        data = json.load(f)
    out = ["| arch | shape | compile (s) | HLO flops | collective bytes | "
           "arg bytes/dev (GB) | temp bytes/dev (GB) |",
           "|---|---|---|---|---|---|---|"]
    for e in data:
        if e.get("skipped"):
            out.append(f"| {e['arch']} | {e['shape']} | — | — | — | skip | skip |")
            continue
        if "error" in e:
            out.append(f"| {e['arch']} | {e['shape']} | FAIL | — | — | — | — |")
            continue
        mem = e.get("memory", {})
        arg = (mem.get("argument_size_bytes") or 0) / e["num_devices"] / 2**30
        tmp = (mem.get("temp_size_bytes") or 0) / e["num_devices"] / 2**30
        out.append(
            f"| {e['arch']} | {e['shape']} | {e['compile_s']} | "
            f"{e['flops']:.3e} | {e['collective_bytes']['total']:.3e} | "
            f"{arg:.2f} | {tmp:.2f} |")
    return "\n".join(out)


def hillclimb_row(path: str, label: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    e = data[0] if isinstance(data, list) else data
    r = roofline_terms(e)
    mem = e.get("memory", {})
    r["label"] = label
    r["temp_gb_dev"] = (mem.get("temp_size_bytes") or 0) / e["num_devices"] / 2**30
    r["arg_gb_dev"] = (mem.get("argument_size_bytes") or 0) / e["num_devices"] / 2**30
    return r


def hillclimb_table(entries) -> str:
    out = ["| iteration | compute (s) | memory (s) | collective (s) | "
           "collective bytes | temp GB/dev | arg GB/dev | Δ dominant |",
           "|---|---|---|---|---|---|---|---|"]
    prev = None
    for label, path in entries:
        try:
            r = hillclimb_row(path, label)
        except FileNotFoundError:
            continue
        dom = r["dominant"] + "_s"
        delta = ""
        if prev is not None and prev.get(dom):
            delta = f"{(r[dom] - prev[dom]) / prev[dom] * 100:+.1f}%"
        out.append(
            f"| {label} | {_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} | "
            f"{_fmt(r['collective_s'])} | {r['collective_bytes']:.3e} | "
            f"{r['temp_gb_dev']:.2f} | {r['arg_gb_dev']:.2f} | {delta} |")
        prev = r
    return "\n".join(out)


def main():
    sp = os.path.join(ART, "dryrun_base_singlepod.json")
    mp = os.path.join(ART, "dryrun_base_multipod.json")
    if os.path.exists(sp):
        print("## Roofline — single pod (16x16 = 256 chips), baseline rules\n")
        print(roofline_table(sp))
    if os.path.exists(mp):
        print("\n## Roofline — multi-pod (2x16x16 = 512 chips), baseline rules\n")
        print(roofline_table(mp))
    if os.path.exists(sp):
        print("\n## Dry-run artifacts (single pod)\n")
        print(dryrun_table(sp))

    for name, base_shape, entries in [
        ("HC1: deepseek-v2-236b prefill_32k", ("deepseek-v2-236b", "prefill_32k"), [
            ("it1 q_chunks=8", os.path.join(ART, "hc1_it1_qchunks8.json")),
            ("it2 +capacity_factor=1.0", os.path.join(ART, "hc1_it2_cf1.json")),
            ("it3 q_chunks=16", os.path.join(ART, "hc1_it3_qchunks16.json")),
        ]),
        ("HC2: kimi-k2-1t-a32b train_4k", ("kimi-k2-1t-a32b", "train_4k"), [
            ("it1 fsdp rules", os.path.join(ART, "hc2_it1_fsdp.json")),
            ("it2 +donate", os.path.join(ART, "hc2_it2_donate.json")),
            ("it3 +q_chunks=4", os.path.join(ART, "hc2_it3_qchunks.json")),
            ("it4 +no-remat", os.path.join(ART, "hc2_it4_noremat.json")),
        ]),
        ("HC3: qwen3-4b train_4k", ("qwen3-4b", "train_4k"), [
            ("it1 no-remat", os.path.join(ART, "hc3_it1_noremat.json")),
            ("it2 +q_chunks=4", os.path.join(ART, "hc3_it2_qchunks.json")),
            ("it3 +fsdp+donate", os.path.join(ART, "hc3_it3_fsdp.json")),
        ]),
    ]:
        if not any(os.path.exists(p) for _, p in entries):
            continue
        print(f"\n## {name}\n")
        # baseline row from the campaign artifact
        base_entries = [("baseline", None)]
        with open(sp) as f:
            for e in json.load(f):
                if (e.get("arch"), e.get("shape")) == base_shape:
                    import tempfile
                    tf = tempfile.NamedTemporaryFile("w", suffix=".json",
                                                     delete=False)
                    json.dump(e, tf)
                    tf.close()
                    base_entries = [("baseline (paper-faithful)", tf.name)]
        print(hillclimb_table(base_entries + entries))


if __name__ == "__main__":
    main()
