"""Event-driven asynchronous FL runtime (DESIGN.md §7; the pipelined
multi-round model is §8).

`core/simulator.py`'s epoch loop advances simulated time one aggregation
window at a time — enough to reproduce accuracy curves, but it hard-codes
*when* the server aggregates.  The paper's headline claim (22x lower
convergence delay than synchronous FL) is a statement about trigger
policy, so this module runs the same physics and the same fused device
program under a priority-queue event loop instead:

    SINK_HANDOFF -> round opens: the handoff policy (sched/policies.py)
      picks the source/sink PS pair — the ring role swap, or the
      contact-plan-driven earliest-next-contact HAP — and the contact
      plan + propagation model give every satellite its global-model
      receive time; TRAIN_DONE events are scheduled at receive +
      train_time.
    TRAIN_DONE -> the satellite's local model enters the uplink relay; a
      MODEL_ARRIVAL is scheduled at its sink arrival time.
    MODEL_ARRIVAL / TRIGGER_TIMEOUT -> the strategy's trigger policy
      (sched/policies.py) decides when to aggregate: AsyncFLEO's idle
      window (optionally one deadline per divergence group), the sync
      barrier, or FedAsync per-arrival.
    trigger -> ALL arrivals ready at the instant batch into ONE fused
      `core/epoch_step.py` dispatch (training + grouping distances +
      aggregation contraction), so async semantics cost no extra device
      round-trips; stragglers carry over device-resident exactly as in the
      epoch loop.

**Pipelining** (DESIGN.md §8): with ``StrategySpec.max_in_flight > 1``
the runtime keeps a SET of in-flight rounds keyed by round id instead of
one.  While round k's models are still propagating, a *speculative*
SINK_HANDOFF (scheduled by the handoff policy's ``next_open_time``, by
default round k's first expected arrival) may open round k+1 from a
contact-plan-chosen source, recruiting only satellites that are not
still training for an earlier round (the overlap invariant).  Every
event carries its round id, so MODEL_ARRIVALs commit into the right
round; an arrival addressed to an already-closed round was carried over
at that round's commit and re-enters aggregation through the successor
round's stale set — `FLSimulation._fused_commit` stamps it with its
origin round's epoch, so eq. 13's staleness discount sees exactly the
paper's semantics.  Commits land in event-time order against the single
global model; ``max_in_flight=1`` (the default) collapses to the
single-round loop bit-for-bit.

**Link contention** (DESIGN.md §9): with ``StrategySpec.ps_channels``
set, the contact plan carries a `ContentionModel` — per-PS transmit and
receive pools of that many parallel channels — and every round open
(downlink) and uplink the runtime times through the plan consults AND
updates the pools, so transfers at the same PS serialize across
overlapping rounds.  A speculative open that aborts rolls its grants
back (`ContentionModel.snapshot`/``restore``); ``contention_stats()``
exposes grants, queue-wait totals and per-PS utilization.
``ps_channels=None`` (default) attaches no model at all — bit-identical
to the uncontended runtime.

**Faults** (DESIGN.md §10): with ``SimConfig.fault_model`` set, each
sat->PS model transfer draws a deterministic Bernoulli loss
(`sched/faults.FaultModel.transfer_fails`, keyed on (seed, sat, round,
attempt)).  A lost transfer fires TRANSFER_FAILED at its would-be
arrival instant; the handler re-times the retransmission after an
exponential backoff through the contact plan — a fresh rx-channel grant,
so retries contend for the same finite ``ps_channels`` — and bounds the
chain at ``max_retries`` before dropping the update entirely
(``dropped_after_max_retries``).  A retry whose grant can never complete
(unreachable sink / past the horizon) is rolled back through the same
snapshot/restore machinery as aborted speculative opens.  Dropping
shrinks the round's expected set, and the trigger policy's
``on_expected_drop`` hook keeps barrier/window rounds from hanging on
transfers that will never land.  ``fault_model=None`` (default) skips
every check — bit-identical to the fault-free runtime.

**Degradation & recovery** (DESIGN.md §11): the FaultModel's §11 axes
extend the runtime with recovery semantics.  *PS outages*: the compiled
`OutageSchedule` (masked into the visibility grid at construction)
schedules a PS_DOWN/PS_UP event pair per dark window; PS_DOWN fails
over every open round sunk at the dead PS to the handoff policy's
replacement (ring-next-live by default), and an in-flight MODEL_ARRIVAL
that pops at a sink dark at its arrival instant re-routes along the HAP
ring to the next live PS — re-timed by the ring relay delay and charged
a fresh §9 rx grant (snapshot/restore rollback on infeasible re-times).
During a *total* outage, arrivals hold at the ring edge until the first
recovery, round opens and triggers defer to it, and a trigger with no
recovery inside the horizon commits anyway (the horizon clamp) so
starved rounds terminate instead of hanging.  *Energy budgets*: per-sat
`EnergyState` batteries drain at recruitment (training energy) and at
every transmit attempt; a depleted satellite defers its uplink to the
first affordable instant (or drops past the horizon), and retries pay
transmit energy too.  *Adaptive backoff*: with
``FaultModel.adaptive_backoff`` the retry delay is AIMD — additive
increase on each failure scaled by the sink rx pool's observed mean
queue wait (capped at ``retry_backoff_cap_s``), halved on a successful
retry — replacing the blind exponential; chosen delays land in the
bounded ``backoff_delays_s`` histogram (``stats["backoff_delays_s"]``
renders its count/sum/min/max/p50/p95/p99 summary).  A conservation
ledger
(``arrivals_expected`` / ``arrivals_committed`` + the ``dropped_*``
counters) pins that every expected arrival is committed, dropped, or
still pending — across reroutes, deferrals and retries
(tests/test_property.py).  Every §11 axis at its default attaches no
state and is bit-identical to the §10 runtime.

The runtime owns no model math: it drives `FLSimulation._fused_commit`
(the epoch loop's post-trigger tail), so under the AsyncFLEO policy its
aggregation instants, weights and dispatch counts are *identical* to the
epoch loop — tests/test_sched.py pins that parity on a degenerate
(always-visible) contact plan — while the sync-barrier and per-arrival
policies express the baselines the epoch loop could only approximate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.modelbank import gather_rows
from repro.obs.metrics import MetricRegistry, StatsView
from repro.obs.trace import (EV_ARRIVAL, EV_COMMIT, EV_DISPATCH, EV_DROP,
                             EV_ENERGY_DEFER, EV_FAILOVER, EV_PS_DOWN,
                             EV_PS_UP, EV_REROUTE, EV_TRANSFER_FAILED,
                             EV_TRANSFER_RETRY, EV_TRIGGER, NULL_TRACER,
                             SPAN_RECRUIT, SPAN_ROUND, SPAN_TRANSFERS,
                             SPAN_TRIGGER)
from repro.sched.contacts import ContactPlan
from repro.sched.events import Event, EventKind, EventQueue
from repro.sched.policies import make_handoff_policy, make_policy

# the ``runtime.stats`` key set, in its historical order — the StatsView
# compatibility contract: same keys, same values, same JSON shape, backed
# by the obs/metrics registry instead of an ad-hoc dict (DESIGN.md §12)
STAT_COUNTER_KEYS = (
    "rounds_opened", "max_rounds_in_flight",
    "pipelined_opens", "cross_round_adoptions",
    "closed_round_arrivals",
    # fault/retry telemetry (zero-filled so benchmark rows always carry
    # the keys): failed attempts, rescheduled retransmissions, updates
    # dropped after max_retries, updates dropped because the retry could
    # never complete, and contention-shrunk trigger windows
    "transfers_failed", "transfer_retries",
    "dropped_after_max_retries", "dropped_unreachable",
    "shrunk_windows",
    # outage / failover telemetry (DESIGN.md §11): arrivals rerouted off
    # a dark sink, sink role failovers of open rounds, updates dropped
    # because no PS recovered inside the horizon, and opens/triggers/
    # arrivals deferred to a recovery
    "rerouted_arrivals", "sink_failovers",
    "dropped_outage", "outage_deferrals",
    # energy telemetry (§11): deferred uplinks, recruits skipped for an
    # empty battery, updates dropped as never affordable
    "energy_deferrals", "energy_skipped_recruits",
    "dropped_energy",
    # fault-aware participant selection skips (§11)
    "fault_aware_skips",
    # conservation ledger (§11): every expected arrival ends up committed
    # (used or adopted-from-carry), in a dropped_* bucket, or still
    # pending at run end — tests/test_property.py pins the identity
    # across reroute/defer/retry paths
    "arrivals_expected", "arrivals_committed")

# AIMD backoff delays actually applied (adaptive_backoff) — a bounded
# histogram (count/sum/min/max/p50/p95/p99 in the compat view), not the
# unbounded list it used to be
STAT_HISTOGRAM_KEYS = ("backoff_delays_s",)


@dataclasses.dataclass
class RoundState:
    """Mutable per-round bookkeeping the event handlers share."""
    idx: int
    beta: int                       # global epoch counter at round start
    t_start: float
    source: int
    sink: int
    participants: List[int]
    ids_np: np.ndarray              # padded participant ids (bank order)
    expected: List[tuple]           # sorted finite (t_arr, sat, row)
    arr_time: Dict[int, float]      # bank row -> sink arrival time
    arrived_count: int = 0
    trigger_scheduled: Optional[float] = None
    committed: bool = False         # fused training dispatch consumed
    closed: bool = False            # roles handed off; ignore stale events
    group_first: Dict[int, float] = dataclasses.field(default_factory=dict)
    # the sink the round's arrival times were computed against at open —
    # ``sink`` may fail over to a live PS mid-flight (DESIGN.md §11),
    # but already-timed arrivals stay addressed here and reroute lazily
    # at their pop instant when this PS is (still) dark
    open_sink: int = -1
    # open tracer span handle for the round's lifetime (obs/trace.py);
    # -1 when untraced
    span: int = -1


class EventDrivenRuntime:
    """Priority-queue driver over an ``FLSimulation``'s compute machinery.

    ``fls`` supplies physics (contact plan, propagation), strategy spec and
    the fused-epoch commit path; ``policy`` defaults to the strategy's
    (`sched/policies.make_policy`), and the handoff policy + pipeline depth
    come from ``StrategySpec.handoff_policy`` / ``max_in_flight``.  ``run``
    returns the same ``EpochRecord`` history as ``FLSimulation.run`` — one
    record per aggregation — so downstream analysis (``convergence_time``)
    is shared.  ``stats`` exposes pipeline telemetry: rounds opened, the
    peak number of rounds in flight, speculative opens, and carried-
    straggler adoptions across round boundaries.
    """

    def __init__(self, fls, policy=None, plan: Optional[ContactPlan] = None,
                 tracer=None):
        self.fls = fls
        self.sim = fls.sim
        self.spec = fls.spec
        # observability (DESIGN.md §12): the tracer records the round
        # lifecycle read-only — an explicit argument wins, else
        # SimConfig.tracer, else the strict no-op NULL_TRACER so every
        # call site below is unconditional and untraced runs pay nothing
        self.tracer = (tracer if tracer is not None
                       else getattr(fls.sim, "tracer", None)) or NULL_TRACER
        self.policy = policy or make_policy(fls.spec)
        self.handoff = make_handoff_policy(fls.spec)
        self.max_in_flight = max(1, int(getattr(fls.spec,
                                                "max_in_flight", 1)))
        self.plan = plan or fls.plan
        self.events = EventQueue()
        self.rounds: Dict[int, RoundState] = {}
        self.history: List = []
        self.beta = 0
        self._round_seq = 0
        self._stop = False
        # training occupancy per satellite (the §8 overlap invariant:
        # a satellite trains for at most one in-flight round at a time)
        self._busy_until = np.zeros(self.plan.num_sats)
        # fault layer (DESIGN.md §10): the FaultModel lives on the
        # simulation config; None short-circuits every check
        self.fault = getattr(fls, "fault", None)
        # compiled PS outage schedule (DESIGN.md §11); None without any
        # outage config — not a single query is made
        self._outages = getattr(fls, "_outages", None)
        # per-sat battery state ((re)built in run()); None = energy off
        self.energy = None
        # AIMD retry-delay state for FaultModel.adaptive_backoff
        self._retry_delay_s = 0.0
        # telemetry: one metric registry per runtime is the single
        # backing store (DESIGN.md §12); ``stats`` is the historical dict
        # surface as a live MutableMapping view over it — existing
        # ``stats[k] += 1`` call sites (here and in sched/policies.py)
        # keep working unchanged, and the two can never drift
        self.metrics = MetricRegistry()
        self.stats: StatsView = StatsView(
            self.metrics, counter_keys=STAT_COUNTER_KEYS,
            histogram_keys=STAT_HISTOGRAM_KEYS)

    # ---- lifecycle ---------------------------------------------------------

    def run(self, w0, max_epochs: int = 30,
            target_accuracy: Optional[float] = None):
        fls = self.fls
        self.bits, prog, _stacked = fls._init_run(w0)
        if prog is None:
            raise ValueError(
                "the event-driven runtime reuses the fused epoch program as "
                "its compute engine; the trainer must expose the fused-epoch "
                "protocol (epoch_train_fn + epoch_inputs) and SimConfig must "
                "keep use_model_bank/use_fused_step enabled")
        self.prog = prog
        self.max_epochs = max_epochs
        self.target = target_accuracy
        self.lazy_eval = (target_accuracy is None
                          and hasattr(fls.evaluator, "eval_async"))
        self.history = []
        self.beta = 0
        self._stop = False
        self._busy_until[:] = 0.0
        self._retry_delay_s = (float(self.fault.retry_backoff_s)
                               if self.fault is not None else 0.0)
        if self.fault is not None and self.fault.has_energy:
            # fresh battery state per run (mirrors _init_run's pool reset)
            from repro.sched.faults import EnergyState
            self.energy = EnergyState(self.fault, self.plan.num_sats)
        if self._outages is not None:
            # one PS_DOWN / PS_UP pair per dark window (DESIGN.md §11);
            # recovery decisions query the pure schedule, so these events
            # carry the *reactive* semantics (failover sweeps) + telemetry
            for p, s, e in self._outages.events():
                if s < self.sim.duration_s:
                    self.events.push(Event(s, EventKind.PS_DOWN, -1, ps=p))
                if e < self.sim.duration_s:
                    self.events.push(Event(e, EventKind.PS_UP, -1, ps=p))
        self._start_round(0.0, source=0)
        handlers = {
            EventKind.TRAIN_DONE: self._on_train_done,
            EventKind.MODEL_ARRIVAL: self._on_arrival,
            EventKind.TRIGGER_TIMEOUT: self._on_trigger,
            EventKind.SINK_HANDOFF: self._on_handoff,
            EventKind.TRANSFER_FAILED: self._on_transfer_failed,
            EventKind.PS_DOWN: self._on_ps_down,
            EventKind.PS_UP: self._on_ps_up,
        }
        tracer = self.tracer
        t_last = 0.0
        # batched pops (DESIGN.md §14): same-(time, kind, round) runs —
        # the MODEL_ARRIVAL floods a mega-constellation trigger produces —
        # drain as one batch through a vectorized handler tail instead of
        # one Python heap pop + handler dispatch per satellite.  The
        # run's events are exactly the pops the sequential loop would do
        # consecutively (nothing else can sort between them), and the
        # batch handlers reproduce the per-event push order, so sequence
        # numbers and histories stay bit-identical
        while self.events and not self._stop:
            evs = self.events.pop_batch()
            if tracer.enabled:
                t_last = max(t_last, evs[0].time)
            if len(evs) == 1:
                handlers[evs[0].kind](evs[0])
            elif evs[0].kind == EventKind.TRAIN_DONE:
                self._on_train_done_batch(evs)
            elif evs[0].kind == EventKind.MODEL_ARRIVAL:
                self._on_arrival_batch(evs)
            else:
                h = handlers[evs[0].kind]
                for ev in evs:
                    if self._stop:
                        break
                    h(ev)
        # finalize the timeline: rounds still alive at the horizon close
        # at the last processed instant so every opened span exports
        tracer.close_open_spans(t_last)
        fls._resolve_pending_dists()       # leave grouping state complete
        with fls._seg("eval"):
            for rec in self.history:       # block once, at finalize time
                rec.accuracy = float(rec.accuracy)
        return self.history

    # ---- round opening -----------------------------------------------------

    def _open_count(self) -> int:
        return sum(1 for r in self.rounds.values() if not r.closed)

    def contention_stats(self) -> Optional[Dict]:
        """Per-PS link-capacity telemetry (None without a ContentionModel,
        i.e. ``StrategySpec.ps_channels=None``): channel grants, FIFO
        queue-wait totals and per-PS utilization for the transmit and
        receive pools (DESIGN.md §9) — round opens and uplinks consult
        and update this occupancy through the shared contact plan."""
        ctn = self.plan.contention
        return None if ctn is None else ctn.stats(self.sim.duration_s)

    def group_of_sat(self, sat: int) -> int:
        """Divergence group of a satellite's orbit (-1 = not yet grouped)
        — the per-group deadline lookup (DESIGN.md §8)."""
        if sat < 0:
            return -1
        self.fls._resolve_pending_dists()       # grouping-state read next
        g = self.fls.grouping.group_of(int(self.fls.orbit_ids[sat]))
        return -1 if g is None else int(g)

    def _start_round(self, t: float, source: int, sink: Optional[int] = None,
                     *, pipelined: bool = False) -> Optional[RoundState]:
        fls, sim = self.fls, self.sim
        if t >= sim.duration_s or self.beta >= self.max_epochs:
            return None
        if sink is None:
            sink = fls.topo.sink_of(source)
        if self._outages is not None:
            # PS roles must be live at open (DESIGN.md §11): a dark
            # source/sink is replaced by the nearest live ring PS; with
            # EVERY PS dark the open defers to the first recovery (a
            # round_idx=-1 SINK_HANDOFF that _on_handoff restarts)
            if self._outages.down_at(source, t):
                alt = self._next_live_ps(source, t)
                if alt is None:
                    t_up = self._outages.next_any_up(t)
                    if t < t_up < sim.duration_s:
                        self.stats["outage_deferrals"] += 1
                        self.events.push(Event(t_up, EventKind.SINK_HANDOFF,
                                               -1, sat=source,
                                               pipelined=pipelined))
                    return None
                source = alt
            if self._outages.down_at(sink, t):
                alt = self._next_live_ps(sink, t)
                sink = alt if alt is not None else source
        # timing a round consumes channel grants when a ContentionModel is
        # attached (DESIGN.md §9); if the open aborts below, roll the
        # grants back so a round that never ran leaves no occupancy behind
        ctn = self.plan.contention
        snap = ctn.snapshot() if ctn is not None else None
        esnap = self.energy.snapshot() if self.energy is not None else None
        with fls._seg("timing"):
            recv = fls._downlink(t, self.bits, source)
        participants = [s for s in range(self.plan.num_sats)
                        if np.isfinite(recv[s])]
        if self.max_in_flight > 1:
            # §8 overlap invariant: a satellite still training for an
            # earlier in-flight round sits this downlink out and joins a
            # later round instead (single-round mode keeps the epoch
            # loop's recruit-everyone semantics for parity)
            participants = [s for s in participants
                            if self._busy_until[s] <= recv[s]]
        if (participants and self.fault is not None
                and getattr(self.spec, "fault_aware_selection", False)):
            # fault-aware participant selection (DESIGN.md §11): skip
            # satellites whose eclipse covers the expected uplink
            # instant, or whose uplink would land in a total PS outage —
            # the model would only wait out the dark window anyway
            fm = self.fault
            tt = np.broadcast_to(
                np.asarray(fls._train_times(participants), np.float64),
                (len(participants),))
            keep = []
            for k, s in enumerate(participants):
                t_up = float(recv[s]) + float(tt[k])
                ok = fm.sat_available_at(s, t_up, self.plan.num_sats)
                if ok and self._outages is not None:
                    ok = not self._outages.all_down_at(t_up)
                if ok:
                    keep.append(s)
                else:
                    self.stats["fault_aware_skips"] += 1
            participants = keep
        if self.energy is not None and participants:
            # training costs energy at the recruit's receive instant
            # (DESIGN.md §11): a satellite that cannot afford it sits the
            # round out and recharges instead
            keep = []
            for s in participants:
                if self.energy.try_drain(s, float(recv[s]),
                                         self.energy.train_j):
                    keep.append(s)
                else:
                    self.stats["energy_skipped_recruits"] += 1
            participants = keep
        ids_np = np.zeros(0, np.int32)
        expected: List[tuple] = []
        arr_time: Dict[int, float] = {}
        t_done = np.zeros(0)
        if participants:
            with fls._seg("timing"):
                # the SAME timing math as the epoch loop, by construction
                ids_np, t_done, t_arr, expected = fls._arrival_times(
                    participants, recv, self.bits, sink)
            arr_time = {k: float(t_arr[k])
                        for k in range(len(participants))}
        if pipelined and not expected:
            if snap is not None:
                ctn.restore(snap)
            if esnap is not None:
                self.energy.restore(esnap)
            return None     # nobody free to train: the retry in
            #                 _on_handoff (or the close handoff) covers it
        if not expected and not fls._pend_meta:
            if snap is not None:
                ctn.restore(snap)
            if esnap is not None:
                self.energy.restore(esnap)
            return None                     # constellation drained: halt
        rnd = RoundState(self._round_seq, self.beta, t, source, sink,
                         participants, ids_np, expected, arr_time)
        rnd.open_sink = sink
        self._round_seq += 1
        self.rounds[rnd.idx] = rnd
        self.stats["rounds_opened"] += 1
        self.stats["arrivals_expected"] += len(expected)
        self.stats["pipelined_opens"] += int(pipelined)
        self.stats["max_rounds_in_flight"] = max(
            self.stats["max_rounds_in_flight"], self._open_count())
        if self.tracer.enabled:
            # the round's lifecycle track (DESIGN.md §12): one open-ended
            # span for the whole round plus the two phase spans whose
            # bounds are known at open — recruit (downlink: open -> last
            # participant's receive) and transfers (uplink: first
            # TRAIN_DONE -> last expected sink arrival; retries and
            # reroutes that move arrivals show up as instants)
            track = f"round {rnd.idx}"
            rnd.span = self.tracer.begin(
                SPAN_ROUND, t, track=track, source=int(source),
                sink=int(sink), participants=len(participants),
                pipelined=bool(pipelined), epoch=int(rnd.beta))
            if participants:
                self.tracer.span(
                    SPAN_RECRUIT, t,
                    max(float(recv[s]) for s in participants), track=track,
                    participants=len(participants))
            if expected:
                self.tracer.span(
                    SPAN_TRANSFERS, float(np.min(t_done)),
                    float(expected[-1][0]), track=track,
                    expected=len(expected))
        for k, s in enumerate(participants):
            td = float(t_done[k])
            self._busy_until[s] = max(self._busy_until[s], td)
            self.events.push(Event(td, EventKind.TRAIN_DONE,
                                   rnd.idx, sat=s, row=k))
        deadline = self.policy.round_deadline(self, rnd)
        if deadline is not None:
            rnd.trigger_scheduled = deadline
            self.events.push(Event(deadline, EventKind.TRIGGER_TIMEOUT,
                                   rnd.idx))
        if self.max_in_flight > 1 and self._open_count() < self.max_in_flight:
            # speculatively extend the pipeline: the handoff policy says
            # when a successor may open while this round is in flight
            t_next = self.handoff.next_open_time(self, rnd)
            if t_next is not None and t < t_next < sim.duration_s:
                self.events.push(Event(t_next, EventKind.SINK_HANDOFF,
                                       rnd.idx, pipelined=True))
        return rnd

    # ---- handlers ----------------------------------------------------------

    def _on_train_done(self, ev: Event) -> None:
        # the model is transmitted regardless of whether its round is
        # still open — a closed round's arrival fires as an event and is
        # routed to the carried-straggler path in _on_arrival
        rnd = self.rounds[ev.round_idx]
        ta = rnd.arr_time.get(ev.row)
        if ta is None or not np.isfinite(ta):
            return
        if self.energy is not None and not self.energy.try_drain(
                ev.sat, ev.time, self.energy.tx_j):
            # depleted battery: the uplink defers to the first affordable
            # instant instead of transmitting now (DESIGN.md §11)
            self._defer_uplink(rnd, ev, ta)
            return
        fm = self.fault
        if (fm is not None and fm.has_loss
                and fm.transfer_fails(ev.sat, rnd.idx, 0,
                                      ps=rnd.open_sink, t=ta)):
            # the transfer is lost in flight: the failure surfaces at the
            # would-be arrival instant (the sink notices a missing /
            # corrupt update only when it was due), DESIGN.md §10
            self.events.push(Event(ta, EventKind.TRANSFER_FAILED, rnd.idx,
                                   sat=ev.sat, row=ev.row, ps=rnd.open_sink))
            return
        self.events.push(Event(ta, EventKind.MODEL_ARRIVAL, rnd.idx,
                               sat=ev.sat, row=ev.row, ps=rnd.open_sink))

    def _on_train_done_batch(self, evs: List[Event]) -> None:
        """Batched TRAIN_DONE run (same time + round, DESIGN.md §14).
        With energy or loss faults active the per-event handler runs
        one-at-a-time (those paths draw per-sat state in event order);
        otherwise every member just converts to its MODEL_ARRIVAL push —
        one bulk ``push_many`` with per-event order preserved, which is
        exactly the sequential loop's push sequence."""
        if self.energy is not None or (self.fault is not None
                                       and self.fault.has_loss):
            for ev in evs:
                self._on_train_done(ev)
            return
        rnd = self.rounds[evs[0].round_idx]
        out = []
        for ev in evs:
            ta = rnd.arr_time.get(ev.row)
            if ta is None or not np.isfinite(ta):
                continue
            out.append(Event(ta, EventKind.MODEL_ARRIVAL, rnd.idx,
                             sat=ev.sat, row=ev.row, ps=rnd.open_sink))
        self.events.push_many(out)

    def _on_arrival_batch(self, evs: List[Event]) -> None:
        """Batched MODEL_ARRIVAL run (same time + round, DESIGN.md §14):
        one closed-round check, one ``policy.on_arrival_batch`` call, one
        trigger-application tail — instead of 10^4 per-event handler
        invocations.  Outage reroutes, tracing, and adaptive backoff keep
        the per-event path (they mutate per-event state mid-run)."""
        if (self._outages is not None or self.tracer.enabled
                or (self.fault is not None and self.fault.adaptive_backoff)):
            for ev in evs:
                self._on_arrival(ev)
            return
        rnd = self.rounds[evs[0].round_idx]
        if rnd.closed:
            self.stats["closed_round_arrivals"] += len(evs)
            return
        t = evs[0].time
        batch_fn = getattr(self.policy, "on_arrival_batch", None)
        if batch_fn is None:
            # custom policy without the batch protocol: stay exactly
            # sequential (its on_arrival may read trigger_scheduled
            # between arrivals)
            for ev in evs:
                self._on_arrival(ev)
            return
        trigs = batch_fn(self, rnd, t, [ev.sat for ev in evs])
        # the sequential loop's per-arrival tail, applied in run order:
        # the earliest trigger wins the schedule, every non-None trigger
        # still pushes (identical TRIGGER_TIMEOUT sequence numbers)
        for trig in trigs:
            if trig is not None:
                if (rnd.trigger_scheduled is None
                        or trig < rnd.trigger_scheduled):
                    rnd.trigger_scheduled = trig
                self.events.push(Event(trig, EventKind.TRIGGER_TIMEOUT,
                                       rnd.idx))

    def _on_arrival(self, ev: Event) -> None:
        rnd = self.rounds[ev.round_idx]
        if (self._outages is not None and ev.ps >= 0
                and self._outages.down_at(ev.ps, ev.time)):
            # the sink this arrival was timed against is dark at the
            # arrival instant: ring failover (DESIGN.md §11)
            self._reroute_arrival(rnd, ev)
            return
        fm = self.fault
        if ev.attempt > 0 and fm is not None and fm.adaptive_backoff:
            # AIMD multiplicative decrease: a retry landed, halve the
            # delay back toward the base (DESIGN.md §11)
            self._retry_delay_s = max(fm.retry_backoff_s,
                                      self._retry_delay_s / 2.0)
        if self.tracer.enabled:
            self.tracer.instant(EV_ARRIVAL, ev.time,
                                track=f"round {ev.round_idx}",
                                sat=int(ev.sat), ps=int(ev.ps),
                                attempt=int(ev.attempt),
                                closed_round=rnd.closed)
        if rnd.closed:
            # the round committed before this model landed: its row was
            # carried over (device-resident) at commit time and re-enters
            # through a successor round's stale set (DESIGN.md §8)
            self.stats["closed_round_arrivals"] += 1
            return
        rnd.arrived_count += 1
        trig = self.policy.on_arrival(self, rnd, ev.time, sat=ev.sat)
        if trig is not None:
            if rnd.trigger_scheduled is None or trig < rnd.trigger_scheduled:
                rnd.trigger_scheduled = trig
            self.events.push(Event(trig, EventKind.TRIGGER_TIMEOUT, rnd.idx))

    def _on_trigger(self, ev: Event) -> None:
        rnd = self.rounds[ev.round_idx]
        if rnd.closed:
            return              # duplicate deadline (barrier already fired)
        if self._outages is not None and self._outages.all_down_at(ev.time):
            # no PS can aggregate right now: push the trigger to the
            # first recovery — or, when no PS recovers inside the
            # horizon, fall through and commit anyway so a starved round
            # terminates (the total-outage horizon clamp, DESIGN.md §11)
            t_up = self._outages.next_any_up(ev.time)
            if ev.time < t_up < self.sim.duration_s:
                self.stats["outage_deferrals"] += 1
                rnd.trigger_scheduled = t_up
                self.events.push(Event(t_up, EventKind.TRIGGER_TIMEOUT,
                                       rnd.idx))
                return
        t_agg, used, late = self.policy.split(self, rnd, ev.time)
        pend = [ta for (ta, _s, _ep) in self.fls._pend_meta]
        if not used and not any(ta <= t_agg for ta in pend):
            if not rnd.committed and rnd.participants:
                # sync stall with EVERY arrival late: commit the training
                # dispatch anyway — all rows carry over as stragglers and
                # a 0-model epoch is recorded, exactly as the epoch loop
                # does for the same configuration
                self._commit(rnd, t_agg, used, late)
                return
            t_next = min(pend) if pend else None
            if (t_next is not None and not rnd.committed
                    and not rnd.expected
                    and t_next < self.sim.duration_s
                    and t_next > ev.time):
                # idle round: nothing trains and every carried straggler
                # is still in flight — re-open the round at the earliest
                # landing so the next trigger's window covers it (the
                # epoch loop instead busy-waits timeout-sized epochs).
                # Stragglers past the horizon are dropped, like the epoch
                # loop's `t >= duration` break, so this always terminates.
                rnd.t_start = t_next
                self.events.push(Event(t_next, EventKind.TRIGGER_TIMEOUT,
                                       rnd.idx))
                return
            self._maybe_close(rnd, ev.time)    # spurious: nothing to commit
            return
        self._commit(rnd, t_agg, used, late)

    # ---- outages, failover & energy (DESIGN.md §11) ------------------------

    def _next_live_ps(self, ps: int, t: float) -> Optional[int]:
        """Nearest live PS on the HAP ring at instant ``t``, by ring
        distance from ``ps`` (ties toward increasing id, matching
        ``Topology.ring_path``); None when every PS is dark."""
        H = self.fls.topo.num_ps
        for d in sorted(range(1, H), key=lambda d: (min(d, H - d), d)):
            cand = (ps + d) % H
            if not self._outages.down_at(cand, t):
                return cand
        return None

    def _on_ps_down(self, ev: Event) -> None:
        # reactive failover sweep: every open round sunk at the dead PS
        # asks its handoff policy for a live replacement sink; arrivals
        # already timed against the old sink reroute lazily at pop time
        if self.tracer.enabled:
            self.tracer.instant(EV_PS_DOWN, ev.time, track=f"ps {ev.ps}",
                                ps=int(ev.ps))
        for rnd in self.rounds.values():
            if rnd.closed or rnd.sink != ev.ps:
                continue
            new_sink = self.handoff.failover_sink(self, rnd, ev.time)
            if new_sink is not None and new_sink != rnd.sink:
                old_sink = rnd.sink
                rnd.sink = new_sink
                self.stats["sink_failovers"] += 1
                if self.tracer.enabled:
                    self.tracer.instant(EV_FAILOVER, ev.time,
                                        track=f"round {rnd.idx}",
                                        old_sink=int(old_sink),
                                        new_sink=int(new_sink))

    def _on_ps_up(self, ev: Event) -> None:
        # recovery needs no sweep: deferred opens/triggers/arrivals were
        # re-scheduled at this instant when they hit the outage, and
        # every outage decision queries the pure OutageSchedule — the
        # event marks the trace-visible recovery boundary
        if self.tracer.enabled:
            self.tracer.instant(EV_PS_UP, ev.time, track=f"ps {ev.ps}",
                                ps=int(ev.ps))

    def _reroute_arrival(self, rnd: RoundState, ev: Event) -> None:
        """An arrival popped at a sink that is dark at its arrival
        instant: relay it along the HAP ring to the next live PS
        (DESIGN.md §11) — re-timed by the ring relay delay and charged a
        fresh §9 rx grant — or hold it at the ring edge until the first
        recovery when EVERY PS is dark (dropping only when none recovers
        inside the horizon)."""
        o = self._outages
        loc = self._locate_transfer(rnd, ev.row, ev.sat, ev.time)
        if loc is None:
            return          # adopted by a same-instant commit: moot
        if not o.down_at(rnd.sink, ev.time):
            target = rnd.sink       # the round already failed over there
        else:
            target = self._next_live_ps(ev.ps, ev.time)
        if target is None:
            # total outage: hold until the first recovery, then re-check
            t_up = o.next_any_up(ev.time)
            if not ev.time < t_up < self.sim.duration_s:
                self.stats["dropped_outage"] += 1
                self._retire_transfer(rnd, loc, ev.row, ev.time,
                                      reason="outage")
                return
            self.stats["outage_deferrals"] += 1
            self._move_transfer(rnd, loc, ev.row, ev.sat, t_up)
            self.events.push(Event(t_up, EventKind.MODEL_ARRIVAL, rnd.idx,
                                   sat=ev.sat, row=ev.row,
                                   attempt=ev.attempt, ps=ev.ps))
            return
        ctn = self.plan.contention
        snap = ctn.snapshot() if ctn is not None else None
        with self.fls._seg("timing"):
            new_ta = self.plan.reroute_times(
                ev.ps, target, ev.time, self.bits,
                avoid=o.down_set(ev.time) - {ev.ps, target})
        if not np.isfinite(new_ta) or new_ta >= self.sim.duration_s:
            # both ring arcs blocked by other dark PSs, or the relay
            # lands past the horizon: roll the grant back and drop
            if snap is not None:
                ctn.restore(snap)
            self.stats["dropped_outage"] += 1
            self._retire_transfer(rnd, loc, ev.row, ev.time,
                                  reason="outage")
            return
        self.stats["rerouted_arrivals"] += 1
        if self.tracer.enabled:
            self.tracer.instant(EV_REROUTE, ev.time,
                                track=f"round {rnd.idx}", sat=int(ev.sat),
                                ps_from=int(ev.ps), ps_to=int(target),
                                t_arrival=float(new_ta))
        self._move_transfer(rnd, loc, ev.row, ev.sat, new_ta)
        self.events.push(Event(new_ta, EventKind.MODEL_ARRIVAL, rnd.idx,
                               sat=ev.sat, row=ev.row,
                               attempt=ev.attempt, ps=target))

    def _defer_uplink(self, rnd: RoundState, ev: Event,
                      ta_old: float) -> None:
        """A depleted satellite's uplink waits for its battery: re-time
        the transfer from the first instant the transmit energy is
        affordable, or drop it when that never happens inside the
        horizon (DESIGN.md §11)."""
        en = self.energy
        loc = self._locate_transfer(rnd, ev.row, ev.sat, ta_old)
        if loc is None:
            return
        t_aff = en.time_to_afford(ev.sat, ev.time, en.tx_j)
        if t_aff is None or t_aff >= self.sim.duration_s:
            self.stats["dropped_energy"] += 1
            self._retire_transfer(rnd, loc, ev.row, ev.time,
                                  reason="energy")
            return
        ctn = self.plan.contention
        snap = ctn.snapshot() if ctn is not None else None
        with self.fls._seg("timing"):
            t_arr, _haps = self.plan.uplink_times(
                [ev.sat], [t_aff], self.bits, rnd.sink)
        new_ta = float(t_arr[0])
        if not np.isfinite(new_ta) or new_ta >= self.sim.duration_s:
            if snap is not None:
                ctn.restore(snap)
            self.stats["dropped_energy"] += 1
            self._retire_transfer(rnd, loc, ev.row, ev.time,
                                  reason="energy")
            return
        en.try_drain(ev.sat, t_aff, en.tx_j)    # affordable by construction
        self.stats["energy_deferrals"] += 1
        if self.tracer.enabled:
            self.tracer.instant(EV_ENERGY_DEFER, ev.time,
                                track=f"round {rnd.idx}", sat=int(ev.sat),
                                t_affordable=float(t_aff),
                                t_arrival=float(new_ta))
        self._move_transfer(rnd, loc, ev.row, ev.sat, new_ta)
        fm = self.fault
        kind = (EventKind.TRANSFER_FAILED
                if (fm.has_loss
                    and fm.transfer_fails(ev.sat, rnd.idx, 0,
                                          ps=rnd.sink, t=new_ta))
                else EventKind.MODEL_ARRIVAL)
        self.events.push(Event(new_ta, kind, rnd.idx, sat=ev.sat,
                               row=ev.row, ps=rnd.sink))

    # ---- lossy transfers: retry / backoff / drop (DESIGN.md §10) -----------

    def _locate_transfer(self, rnd: RoundState, row: int, sat: int,
                         ta: float):
        """Where an in-flight transfer's bookkeeping lives at failure
        time: ("expected", i) while its round is uncommitted, ("pend", i)
        after a commit carried it as a straggler, or None when a commit
        tied at exactly the failure instant already adopted it (the model
        made it into an aggregation — the failure is moot)."""
        if not rnd.committed:
            for i, a in enumerate(rnd.expected):
                if a[2] == row:
                    return ("expected", i)
            return None
        for i, (pta, ps, _ep) in enumerate(self.fls._pend_meta):
            if ps == sat and pta == ta:
                return ("pend", i)
        return None

    def _move_transfer(self, rnd: RoundState, loc, row: int, sat: int,
                       new_ta: float) -> None:
        """Re-time a pending transfer to its retry arrival instant."""
        kind, i = loc
        if kind == "expected":
            rnd.expected[i] = (new_ta, sat, row)
            rnd.expected.sort(key=lambda a: a[0])
            rnd.arr_time[row] = new_ta
        else:
            pta, ps, ep = self.fls._pend_meta[i]
            self.fls._pend_meta[i] = (new_ta, ps, ep)

    def _retire_transfer(self, rnd: RoundState, loc, row: int,
                         t: float, reason: str = "") -> None:
        """Drop an update whose transfer can never complete: remove its
        bookkeeping (the carried device row too — _pend_dev rows are
        indexed parallel to _pend_meta) and let the trigger policy rescue
        a round that now waits on nothing."""
        fls = self.fls
        if self.tracer.enabled:
            self.tracer.instant(EV_DROP, t, track=f"round {rnd.idx}",
                                row=int(row), reason=reason)
        kind, i = loc
        if kind == "pend":
            keep = [j for j in range(len(fls._pend_meta)) if j != i]
            fls._pend_meta = [fls._pend_meta[j] for j in keep]
            fls._pend_dev = (gather_rows(fls._pend_dev,
                                         np.asarray(keep, np.int32))
                             if keep else None)
        rnd.expected = [a for a in rnd.expected if a[2] != row]
        rnd.arr_time.pop(row, None)
        hook = getattr(self.policy, "on_expected_drop", None)
        trig = hook(self, rnd, t) if hook is not None else None
        if trig is not None and not rnd.closed:
            if rnd.trigger_scheduled is None or trig < rnd.trigger_scheduled:
                rnd.trigger_scheduled = trig
            self.events.push(Event(trig, EventKind.TRIGGER_TIMEOUT, rnd.idx))
        self._maybe_close(rnd, t)

    def _on_transfer_failed(self, ev: Event) -> None:
        fm = self.fault
        rnd = self.rounds[ev.round_idx]
        self.stats["transfers_failed"] += 1
        if self.tracer.enabled:
            self.tracer.instant(EV_TRANSFER_FAILED, ev.time,
                                track=f"round {ev.round_idx}",
                                sat=int(ev.sat), attempt=int(ev.attempt),
                                ps=int(ev.ps))
        loc = self._locate_transfer(rnd, ev.row, ev.sat, ev.time)
        if loc is None:
            return          # adopted by a same-instant commit: chain ends
        attempt = ev.attempt + 1
        new_ta = np.inf
        snap = None
        ctn = self.plan.contention
        if attempt <= fm.max_retries:
            if fm.adaptive_backoff:
                # AIMD additive increase (DESIGN.md §11): the step is the
                # sink rx pool's observed mean queue wait (at least the
                # configured base), capped at retry_backoff_cap_s; the
                # applied delays land in stats["backoff_delays_s"]
                delay = self._retry_delay_s
                wait = 0.0
                if ctn is not None and ctn.rx.grants:
                    wait = ctn.rx.queue_wait_s / ctn.rx.grants
                self._retry_delay_s = min(
                    fm.retry_backoff_cap_s,
                    self._retry_delay_s + max(fm.retry_backoff_s, wait))
                # bounded histogram, not an unbounded list: the compat
                # view renders count/sum/min/max/p50/p95/p99
                self.metrics.observe("backoff_delays_s", float(delay))
            else:
                delay = fm.retry_delay_s(ev.attempt)
            t_retry = ev.time + delay
            if self.energy is not None:
                # retransmissions pay transmit energy too: wait for the
                # battery when depleted, drop when it never recovers
                t_aff = self.energy.time_to_afford(ev.sat, t_retry,
                                                   self.energy.tx_j)
                if t_aff is None:
                    self.stats["dropped_energy"] += 1
                    self._retire_transfer(rnd, loc, ev.row, ev.time,
                                          reason="energy")
                    return
                t_retry = max(t_retry, t_aff)
            if t_retry < self.sim.duration_s:
                # the retransmission re-enters the shared channel pools: a
                # fresh uplink (and rx grant) from the backoff instant
                snap = ctn.snapshot() if ctn is not None else None
                with self.fls._seg("timing"):
                    t_arr, _haps = self.plan.uplink_times(
                        [ev.sat], [t_retry], self.bits, rnd.sink)
                new_ta = float(t_arr[0])
        else:
            self.stats["dropped_after_max_retries"] += 1
            self._retire_transfer(rnd, loc, ev.row, ev.time,
                                  reason="max_retries")
            return
        if not np.isfinite(new_ta) or new_ta >= self.sim.duration_s:
            # unreachable sink or a landing past the horizon: the transfer
            # will never happen, so its channel grant is rolled back (no
            # occupancy ghosts — the same contract as aborted speculative
            # opens) and the update is dropped
            if snap is not None:
                ctn.restore(snap)
            self.stats["dropped_unreachable"] += 1
            self._retire_transfer(rnd, loc, ev.row, ev.time,
                                  reason="unreachable")
            return
        self.stats["transfer_retries"] += 1
        if self.tracer.enabled:
            self.tracer.instant(EV_TRANSFER_RETRY, ev.time,
                                track=f"round {rnd.idx}", sat=int(ev.sat),
                                attempt=int(attempt),
                                delay_s=float(delay),
                                t_arrival=float(new_ta))
        if self.energy is not None:
            self.energy.try_drain(ev.sat, t_retry, self.energy.tx_j)
        self._move_transfer(rnd, loc, ev.row, ev.sat, new_ta)
        kind = (EventKind.TRANSFER_FAILED
                if fm.transfer_fails(ev.sat, rnd.idx, attempt,
                                     ps=rnd.sink, t=new_ta)
                else EventKind.MODEL_ARRIVAL)
        self.events.push(Event(new_ta, kind, rnd.idx, sat=ev.sat,
                               row=ev.row, attempt=attempt, ps=rnd.sink))

    def _on_handoff(self, ev: Event) -> None:
        # the round stays registered: stale TRAIN_DONE / MODEL_ARRIVAL
        # events for it may still be queued and look their round up
        rnd = self.rounds.get(ev.round_idx)
        if rnd is None:
            # a round open deferred through a total PS outage
            # (DESIGN.md §11, round_idx=-1): restart it from the recorded
            # source at the recovery instant
            if self._open_count() < self.max_in_flight:
                self._start_round(ev.time, max(ev.sat, 0),
                                  pipelined=ev.pipelined)
            return
        if self._open_count() >= self.max_in_flight:
            return              # pipeline full; a close will refill it
        source, sink = self.handoff.next_round(self, rnd, ev.time)
        opened = self._start_round(ev.time, source, sink,
                                   pipelined=ev.pipelined)
        if opened is None and ev.pipelined:
            # every eligible satellite is busy: retry when the next one
            # frees up (strictly later + horizon-guarded, so this
            # terminates)
            busy = self._busy_until[self._busy_until > ev.time]
            if busy.size:
                t_retry = float(busy.min())
                if ev.time < t_retry < self.sim.duration_s:
                    self.events.push(Event(t_retry, EventKind.SINK_HANDOFF,
                                           ev.round_idx, pipelined=True))

    # ---- commit ------------------------------------------------------------

    def _commit(self, rnd: RoundState, t_agg: float, used, late) -> None:
        fls, spec = self.fls, self.spec
        participants = rnd.participants if not rnd.committed else []
        ids_np = rnd.ids_np if not rnd.committed else np.zeros(0, np.int32)
        # adoption telemetry: cross_round counts only stragglers that
        # originated in ANOTHER round (FedAsync drains its own round's
        # carried rows — epoch stamp equal to rnd.beta — which is not a
        # round boundary); the total adopted count feeds the §11
        # conservation ledger alongside the rows used directly
        adopted = cross = 0
        for (ta, _s, ep) in fls._pend_meta:
            if ta <= t_agg:
                adopted += 1
                cross += int(ep != rnd.beta)
        self.stats["cross_round_adoptions"] += cross
        self.stats["arrivals_committed"] += len(used) + adopted
        prof = getattr(self.prog, "profiler", None)
        if prof is not None:
            # dispatches-per-trigger attribution (obs/profile.py): the
            # fused commit below issues 1 (fused) or 2 (fallback) device
            # programs for this one aggregation trigger
            prof.trigger()
        # scenario-batched sweeps (DESIGN.md §13): this runtime is one of
        # several whose dispatches multiplex through a shared
        # DispatchBatcher; its profiler counts *physical* programs, so
        # every scenario's trigger feeds the shared denominator
        dispatcher = getattr(self.sim, "dispatcher", None)
        bprof = getattr(dispatcher, "profiler", None)
        if bprof is not None:
            bprof.trigger()
        t_trigger = t_agg
        out = fls._fused_commit(self.prog, self.beta, ids_np, participants,
                                t_agg, used, late, train_epoch=rnd.beta)
        rnd.committed = True
        t_agg, metas, info, _losses = out
        if spec.agg_mode == "interval":
            t_agg = max(t_agg, rnd.t_start + spec.interval_s)
        if self.tracer.enabled:
            # the trigger/collection window: first used arrival -> the
            # aggregation instant, then the commit boundary instants
            track = f"round {rnd.idx}"
            t0 = min((a[0] for a in used), default=t_trigger)
            self.tracer.span(SPAN_TRIGGER, t0, t_agg, track=track,
                             used=len(used), late=len(late),
                             adopted=adopted)
            self.tracer.instant(EV_TRIGGER, t_trigger, track=track,
                                epoch=int(self.beta))
            self.tracer.instant(EV_DISPATCH, t_agg, track=track,
                                epoch=int(self.beta),
                                participants=len(participants))
            self.tracer.instant(EV_COMMIT, t_agg, track=track,
                                epoch=int(self.beta), used=len(used),
                                late=len(late), adopted=adopted)
        w_tree = (fls._spec.unflatten(fls._w_flat)
                  if fls.evaluator is not None else None)
        acc = fls._record_epoch(self.history, self.beta, t_agg, metas, info,
                                self.lazy_eval, w_tree)
        self.beta += 1
        if self.target is not None and acc >= self.target:
            self._stop = True
            return
        if self.beta >= self.max_epochs:
            self._stop = True
            return
        self._maybe_close(rnd, t_agg)

    def _maybe_close(self, rnd: RoundState, t: float) -> None:
        if not rnd.closed and rnd.committed and \
                self.policy.round_complete(rnd):
            rnd.closed = True
            if rnd.span >= 0:
                self.tracer.end(rnd.span, t)
            self.events.push(Event(t, EventKind.SINK_HANDOFF, rnd.idx))
