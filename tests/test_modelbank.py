"""Stacked (ModelBank) vs legacy pytree parity for the server hot path.

Every aggregation and grouping entry point must produce allclose results
whether models arrive as host pytrees or as one device-resident (C, N)
stack — including the strict_paper_eq14 and stale-only-group branches and
the segmented (multi-matrix) simulator path.
"""
import numpy as np
import pytest

from repro.core.aggregation import (SatelliteMeta, asyncfleo_aggregate,
                                    combine_stacked, dedup, dedup_indices,
                                    fedavg, weighted_sum)
from repro.core.grouping import GroupingState, partial_global_model
from repro.core.modelbank import FlatSpec, ModelBank


def _models(vals):
    rng = np.random.default_rng(0)
    out = []
    for v in vals:
        out.append({"w": np.full((3, 4), v, np.float32),
                    "b": np.full((5,), -v, np.float32),
                    "nested": {"k": (v * rng.standard_normal(7)).astype(np.float32)}})
    return out


def _meta(sid, size=100.0, epoch=0, ts=0.0):
    return SatelliteMeta(sid, size, (0.0, 0.0), ts, epoch)


def _assert_tree_close(a, b, atol=1e-5):
    import jax
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


# ---- FlatSpec / ModelBank roundtrips --------------------------------------

def test_flatspec_roundtrip():
    m = _models([1.5])[0]
    spec = FlatSpec.of(m)
    flat = spec.flatten(m)
    assert flat.shape == (spec.num_params,)
    _assert_tree_close(spec.unflatten(flat), m, atol=0)
    _assert_tree_close(spec.unflatten_host(flat), m, atol=0)


def test_modelbank_roundtrip_and_select():
    models = _models([0.0, 1.0, 2.0, 3.0])
    bank = ModelBank.from_pytrees(models)
    assert len(bank) == 4
    back = bank.to_pytrees()
    for m, b in zip(models, back):
        _assert_tree_close(m, b, atol=0)
    sub = bank.select([3, 1])
    _assert_tree_close(sub.pytree(0), models[3], atol=0)
    _assert_tree_close(sub.pytree(1), models[1], atol=0)


def test_spec_cache_reuse():
    a, b = _models([1.0, 2.0])
    assert FlatSpec.of(a) is FlatSpec.of(b)


# ---- aggregation parity ----------------------------------------------------

def test_fedavg_parity():
    models = _models([0.0, 1.0, 5.0])
    sizes = [100, 300, 50]
    bank = ModelBank.from_pytrees(models)
    legacy = fedavg(models, sizes)
    stacked = bank.spec.unflatten(fedavg(bank, sizes))
    _assert_tree_close(legacy, stacked)


def test_weighted_sum_parity_with_base():
    models = _models([2.0, -1.0])
    base = _models([7.0])[0]
    bank = ModelBank.from_pytrees(models)
    legacy = weighted_sum(models, [0.3, 0.4], base=base, base_weight=0.3)
    stacked = bank.spec.unflatten(
        weighted_sum(bank, [0.3, 0.4], base=base, base_weight=0.3))
    _assert_tree_close(legacy, stacked)


def test_weighted_sum_kernel_parity():
    models = _models([2.0, -1.0, 0.5])
    base = _models([7.0])[0]
    bank = ModelBank.from_pytrees(models)
    legacy = weighted_sum(models, [0.3, 0.4, 0.1], base=base, base_weight=0.2)
    stacked = bank.spec.unflatten(
        weighted_sum(bank, [0.3, 0.4, 0.1], base=base, base_weight=0.2,
                     use_kernel=True))
    _assert_tree_close(legacy, stacked)


@pytest.mark.parametrize("strict", [False, True])
def test_asyncfleo_parity_mixed_freshness(strict):
    models = _models([1.0, 3.0, -2.0, 0.5, 4.0])
    metas = [_meta(0, 100, epoch=5), _meta(1, 200, epoch=5),
             _meta(2, 150, epoch=2), _meta(3, 50, epoch=1),
             _meta(4, 120, epoch=5)]
    groups = {0: [0, 2], 1: [1, 4], 2: [3]}   # group 2 is stale-only
    w_prev = _models([0.25])[0]
    bank = ModelBank.from_pytrees(models)
    legacy, info_l = asyncfleo_aggregate(w_prev, groups, models, metas, beta=5,
                                         strict_paper_eq14=strict)
    flat, info_s = asyncfleo_aggregate(w_prev, groups, bank, metas, beta=5,
                                       strict_paper_eq14=strict)
    assert info_l == info_s
    assert info_l["stale_groups"] == 1
    _assert_tree_close(legacy, bank.spec.unflatten(flat))


def test_asyncfleo_parity_stale_only():
    models = _models([2.0, -1.0])
    metas = [_meta(0, 100, epoch=1), _meta(1, 50, epoch=2)]
    w_prev = _models([5.0])[0]
    bank = ModelBank.from_pytrees(models)
    legacy, info_l = asyncfleo_aggregate(w_prev, {0: [0, 1]}, models, metas,
                                         beta=6)
    flat, info_s = asyncfleo_aggregate(w_prev, {0: [0, 1]}, bank, metas,
                                       beta=6)
    assert info_l == info_s
    assert 0.0 < info_l["gamma"] < 1.0
    _assert_tree_close(legacy, bank.spec.unflatten(flat))


def test_dedup_parity():
    models = _models([1.0, 2.0, 3.0])
    metas = [_meta(7, ts=1.0), _meta(7, ts=5.0), _meta(8, ts=2.0)]
    bank = ModelBank.from_pytrees(models)
    m_l, t_l = dedup(models, metas)
    b_s, t_s = dedup(bank, metas)
    assert [m.sat_id for m in t_l] == [m.sat_id for m in t_s]
    assert dedup_indices(metas) == [1, 2]
    for i in range(len(m_l)):
        _assert_tree_close(m_l[i], b_s.pytree(i), atol=0)


def test_combine_stacked_kernel_parity():
    models = _models([1.0, -2.0, 0.5])
    weights = np.array([0.2, 0.3, 0.1], np.float32)
    base = _models([4.0])[0]
    bank = ModelBank.from_pytrees(models)
    bflat = bank.spec.flatten(base)
    xla = combine_stacked([(bank.stack, weights)], bflat, 0.4)
    pallas = combine_stacked([(bank.stack, weights)], bflat, 0.4,
                             use_kernel=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas), atol=1e-5)
    # split across two segments, kernel-chained
    a, b = ModelBank.from_pytrees(models[:1]), ModelBank.from_pytrees(models[1:])
    pallas2 = combine_stacked([(a.stack, weights[:1]), (b.stack, weights[1:])],
                              bflat, 0.4, use_kernel=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas2),
                               atol=1e-5)


def test_pad_ids_empty():
    from repro.fl.client import _pad_ids
    ids, n = _pad_ids([])
    assert n == 0 and len(ids) == 0


def test_combine_stacked_segments_match_single_bank():
    """Models split over two device matrices combine identically to one."""
    models = _models([1.0, -2.0, 0.5, 3.0])
    weights = np.array([0.1, 0.2, 0.3, 0.15])
    base = _models([4.0])[0]
    bank = ModelBank.from_pytrees(models)
    whole = weighted_sum(bank, weights, base=base, base_weight=0.25)
    a = ModelBank.from_pytrees(models[:2])
    b = ModelBank.from_pytrees(models[2:])
    split = combine_stacked([(a.stack, weights[:2]), (b.stack, weights[2:])],
                            bank.spec.flatten(base), 0.25)
    np.testing.assert_allclose(np.asarray(whole), np.asarray(split),
                               atol=1e-5)


# ---- grouping parity -------------------------------------------------------

def test_partial_global_model_parity():
    models = _models([0.0, 1.0, 4.0])
    sizes = [1.0, 3.0, 2.0]
    bank = ModelBank.from_pytrees(models)
    legacy = partial_global_model(models, sizes)
    flat = partial_global_model(bank, sizes)
    _assert_tree_close(legacy, bank.spec.unflatten(flat))


def test_observe_orbit_parity():
    w0 = _models([0.0])[0]
    models = _models([0.1, 0.2, 5.0, 5.2, 9.0, 9.1])
    sizes = [1.0] * 6
    orbit_rows = {0: [0, 1], 1: [2, 3], 2: [4, 5]}
    bank = ModelBank.from_pytrees(models)

    gs_l = GroupingState(num_groups=2)
    gs_l.set_reference(w0)
    gs_s = GroupingState(num_groups=2)
    gs_s.set_reference(w0)
    for orbit, rows in orbit_rows.items():
        gl = gs_l.observe_orbit(orbit, [models[j] for j in rows],
                                [sizes[j] for j in rows])
        st = gs_s.observe_orbit(orbit, bank.select(rows),
                                [sizes[j] for j in rows])
        assert gl == st
    for o, d in gs_l.distances.items():
        assert gs_s.distances[o] == pytest.approx(d, rel=1e-5)
    assert gs_l.groups == gs_s.groups


def test_observe_orbits_batched_matches_sequential():
    w0 = _models([0.0])[0]
    models = _models([0.1, 0.2, 5.0, 5.2, 9.0, 9.1])
    bank = ModelBank.from_pytrees(models)
    sizes = [1.0, 2.0, 1.0, 1.0, 3.0, 1.0]
    orbit_rows = {0: [0, 1], 1: [2, 3], 2: [4, 5]}

    gs_seq = GroupingState(num_groups=2)
    gs_seq.set_reference(w0)
    seq = {o: gs_seq.observe_orbit(o, [models[j] for j in rows],
                                   [sizes[j] for j in rows])
           for o, rows in orbit_rows.items()}
    gs_b = GroupingState(num_groups=2)
    gs_b.set_reference(w0)
    batched = gs_b.observe_orbits(orbit_rows, bank, sizes)
    assert seq == batched
    assert gs_seq.groups == gs_b.groups

    # multi-segment form (models split across two matrices) agrees too
    gs_m = GroupingState(num_groups=2)
    gs_m.set_reference(w0)
    a = ModelBank.from_pytrees(models[:4])
    b = ModelBank.from_pytrees(models[4:])
    rows_a = [0, 1, 2, 3, -1, -1]
    rows_b = [-1, -1, -1, -1, 0, 1]
    multi = gs_m.observe_orbits_multi(orbit_rows,
                                      [(a.stack, rows_a), (b.stack, rows_b)],
                                      sizes)
    assert multi == seq
    assert gs_m.groups == gs_seq.groups


# ---- simulator end-to-end parity ------------------------------------------

class _TinyTrainer:
    """Deterministic stacked/legacy trainer: model + per-sat offset."""

    def __init__(self, w0):
        self.spec = FlatSpec.of(w0)

    def data_size(self, sat):
        return 100 + (sat % 5) * 10

    def train_many_stacked(self, sats, params, seed):
        import jax.numpy as jnp
        flat = self.spec.flatten(params)
        offs = jnp.asarray([(s * 37 + seed) % 11 - 5 for s in sats],
                           jnp.float32) * 0.01
        stack = flat[None, :] * 0.9 + offs[:, None]
        return ModelBank(self.spec, stack), np.zeros(len(sats))

    def train_many(self, sats, params, seed):
        bank, losses = self.train_many_stacked(sats, params, seed)
        return bank.to_pytrees(), losses


@pytest.mark.parametrize("name", ["asyncfleo-twohap", "fedhap", "fedsat",
                                  "fedspace"])
def test_simulation_stacked_matches_legacy(name):
    from repro.core import FLSimulation, SimConfig
    from repro.fl import get_strategy

    w0 = {"w": np.zeros((6,), np.float32), "b": np.ones((3,), np.float32)}
    histories = {}
    for use_bank in (False, True):
        sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                        use_model_bank=use_bank)
        fls = FLSimulation(get_strategy(name), _TinyTrainer(w0),
                           None, sim)
        hist = fls.run(w0, max_epochs=3)
        histories[use_bank] = [(r.epoch, round(r.time_s, 6), r.num_models,
                                round(r.gamma, 6), r.stale_groups)
                               for r in hist]
    assert histories[False] == histories[True]


def test_simulation_stacked_final_model_matches_legacy():
    from repro.core import FLSimulation, SimConfig
    from repro.fl import get_strategy

    w0 = {"w": np.full((6,), 0.5, np.float32), "b": np.ones((3,), np.float32)}
    evals = {}
    for use_bank in (False, True):
        seen = []

        def evaluator(params, seen=seen):
            seen.append(np.concatenate(
                [np.ravel(np.asarray(l)) for l in
                 (params["w"], params["b"])]))
            return 0.0

        sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                        use_model_bank=use_bank)
        fls = FLSimulation(get_strategy("asyncfleo-twohap"),
                           _TinyTrainer(w0), evaluator, sim)
        fls.run(w0, max_epochs=3)
        evals[use_bank] = seen
    assert len(evals[False]) == len(evals[True]) > 0
    for a, b in zip(evals[False], evals[True]):
        np.testing.assert_allclose(a, b, atol=1e-5)
