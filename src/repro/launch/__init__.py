from repro.launch.mesh import (
    make_production_mesh, make_host_mesh,
    PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK, ICI_LINKS,
)
