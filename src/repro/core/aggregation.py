"""Model aggregation (paper §IV-C2, Algorithm 2, eqs. 13-14) + FedAvg (eq. 4).

Selection: per group, keep *fresh* models (metadata.epoch == current beta) and
discard stale ones — unless a group has only stale models, in which case its
models participate with the staleness discount gamma (eq. 13):

    gamma = sum_n (D_n / D) * (k_n / beta)

Update (eq. 14):  w^{beta+1} = (1 - gamma) w^beta + sum_n p_n w_n, with
per-model weights p_n ∝ D_n * (k_n/beta) normalized to sum to gamma.  The
literal eq. 14 multiplies every selected model by the scalar gamma, which is
not convex for >1 model; ``strict_paper_eq14=True`` reproduces it anyway
(DESIGN.md §3 records this interpretation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass
class SatelliteMeta:
    """Metadata tuple <ID, size, loc, ts, epoch> (paper §IV-C1)."""
    sat_id: int
    size: float                   # training-data size D_n
    loc: tuple                    # angular coordinates (for next-visit calc)
    ts: float                     # timestamp of transmission
    epoch: int                    # last global epoch this sat's model joined

    def is_fresh(self, beta: int) -> bool:
        return self.epoch >= beta


def dedup(models: List, metas: List[SatelliteMeta]):
    """Filter duplicates (a satellite visible to >1 HAP at once, §IV-C1):
    keep the most recent timestamp per satellite id."""
    best: Dict[int, int] = {}
    for i, m in enumerate(metas):
        j = best.get(m.sat_id)
        if j is None or metas[j].ts < m.ts:
            best[m.sat_id] = i
    keep = sorted(best.values())
    return [models[i] for i in keep], [metas[i] for i in keep]


def weighted_sum(models: Sequence, weights: Sequence[float], base=None,
                 base_weight: float = 0.0, *, use_kernel: bool = False):
    """w = base_weight * base + sum_i weights_i * models_i  (pytree math).
    ``use_kernel`` routes the reduction through the Pallas fed_agg kernel."""
    if use_kernel:
        from repro.kernels.fed_agg import ops as agg_ops
        return agg_ops.fed_agg_pytree(models, np.asarray(weights, np.float32),
                                      base, base_weight)
    ws = [float(w) for w in weights]

    def comb(*leaves):
        acc = sum(w * np.asarray(l, dtype=np.float32) for w, l in zip(ws, leaves))
        return acc
    out = jax.tree.map(comb, *models)
    if base is not None and base_weight != 0.0:
        out = jax.tree.map(lambda b, o: base_weight * np.asarray(b, np.float32) + o,
                           base, out)
    elif base is not None:
        pass
    return out


def fedavg(models: Sequence, sizes: Sequence[float], *, use_kernel=False):
    """Synchronous FedAvg (eq. 4)."""
    total = float(sum(sizes))
    return weighted_sum(models, [s / total for s in sizes], use_kernel=use_kernel)


def staleness_gamma(metas: Sequence[SatelliteMeta], total_data: float,
                    beta: int) -> float:
    """eq. (13) over the selected (stale) models."""
    if beta <= 0:
        return 1.0
    g = sum((m.size / total_data) * (max(m.epoch, 0) / beta) for m in metas)
    return float(np.clip(g, 0.0, 1.0))


def asyncfleo_aggregate(w_prev, groups: Dict[int, List[int]], models: List,
                        metas: List[SatelliteMeta], beta: int, *,
                        strict_paper_eq14: bool = False,
                        min_gamma: float = 0.1,
                        use_kernel: bool = False):
    """Algorithm 2 lines 12-17.

    ``groups``: group id -> indices into models/metas.
    Returns (w_new, info dict).
    """
    selected: List[int] = []
    stale_only_groups = 0
    for gi, idxs in groups.items():
        fresh = [i for i in idxs if metas[i].is_fresh(beta)]
        if fresh:
            selected.extend(fresh)          # discard the group's stale models
        else:
            selected.extend(idxs)           # stale-only group joins, discounted
            stale_only_groups += 1
    if not selected:
        return w_prev, {"gamma": 0.0, "selected": 0, "stale_groups": 0}

    total_data = sum(metas[i].size for i in selected)
    sel_metas = [metas[i] for i in selected]
    sel_models = [models[i] for i in selected]

    all_fresh = all(m.is_fresh(beta) for m in sel_metas)
    if all_fresh:
        gamma = 1.0                          # pure data-weighted FedAvg step
        raw = np.array([m.size for m in sel_metas], np.float64)
    else:
        gamma = max(staleness_gamma(sel_metas, total_data, beta), min_gamma)
        raw = np.array([m.size * (max(m.epoch, 0) / max(beta, 1) if not m.is_fresh(beta) else 1.0)
                        for m in sel_metas], np.float64)
        if raw.sum() <= 0.0:                 # all k_n == 0: size-weight instead
            raw = np.array([m.size for m in sel_metas], np.float64)

    if strict_paper_eq14:
        weights = np.full(len(sel_models), gamma)
    else:
        weights = gamma * raw / raw.sum()

    w_new = weighted_sum(sel_models, weights, base=w_prev,
                         base_weight=1.0 - gamma, use_kernel=use_kernel)
    info = {"gamma": gamma, "selected": len(selected),
            "stale_groups": stale_only_groups}
    return w_new, info
