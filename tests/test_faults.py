"""Fault-injection / heterogeneity suite (sched/faults.FaultModel,
DESIGN.md §10) and the staleness-function zoo (core/aggregation).

Covers: FaultModel + StrategySpec construction validation, the
off-switch bit-parity contract (fault_model=None == FaultModel() ==
the PR-5 semantics — the CI-pinned gate), seeded determinism of the
fault schedule, compute-rate heterogeneity (stretched TRAIN_DONE times,
epoch-loop-vs-runtime parity preserved), eclipse availability masking,
lossy transfers with bounded retry/backoff (retry telemetry, drop after
max retries, termination under total loss, barrier rescue on drops, the
epoch loop refusing loss), the staleness zoo's eq13-default parity, and
the contention-aware trigger-window shrink.

The §11 degradation-and-recovery axes (DESIGN.md §11): Gilbert–Elliott
burst loss (off-switch draw parity, window correlation, long-run rate),
PS outage schedules (compile/merge/point queries, grid masking,
end-to-end ring failover with rerouted arrivals, the total-outage
horizon clamp), per-sat energy budgets (closed-form battery unit tests,
deferred uplinks, skipped recruits, the never-binding-budget parity),
fault-aware participant selection (default-off parity) and AIMD
adaptive retry backoff (delays surfaced in runtime.stats, capped).
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core import aggregation as agg
from repro.core.aggregation import (SatelliteMeta, STALENESS_FNS,
                                    asyncfleo_weights, staleness_factor)
from repro.core.links import LinkModel
from repro.fl import get_strategy
from repro.fl.strategies import StrategySpec, _STALENESS_FNS
from repro.sched import (EnergyState, EventDrivenRuntime, FaultModel,
                         OutageSchedule)
from repro.sched.policies import AsyncFLEOPolicy, make_policy

from test_epoch_step import TinyFusedTrainer, W0

SIMKW = dict(duration_s=86400.0, train_time_s=300.0,
             use_model_bank=True, use_fused_step=True)
SLOW = LinkModel(rate_bps=10.0)          # 288-bit W0 -> 28.8 s per transfer


def _sim(name, event_driven, *, spec_kw=None, **kw):
    cfg = SimConfig(event_driven=event_driven, **{**SIMKW, **kw})
    spec = get_strategy(name)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    return FLSimulation(spec, TinyFusedTrainer(W0), None, cfg)


def _rows(hist):
    return [(r.epoch, round(r.time_s, 6), r.num_models,
             round(r.gamma, 6), r.stale_groups) for r in hist]


# ---- construction validation ------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(seed=-1), dict(loss_prob=1.5), dict(loss_prob=-0.1),
    dict(max_retries=-1), dict(retry_backoff_s=0.0),
    dict(eclipse_fraction=1.0), dict(eclipse_fraction=-0.2),
    dict(eclipse_period_s=0.0), dict(compute_rate_spread=-1.0),
    dict(compute_rates=()), dict(compute_rates=(1.0, 0.0)),
    # §11 axes
    dict(burst_len_s=-1.0), dict(loss_prob_bad=1.5),
    dict(loss_prob_good=-0.1),
    dict(ps_outages=((0, 10.0, 5.0),)), dict(ps_outages=((0, -1.0, 5.0),)),
    dict(ps_outages=((-1, 0.0, 5.0),)), dict(ps_outages=("bad",)),
    dict(ps_outage_fraction=1.0), dict(ps_outage_period_s=0.0),
    dict(battery_j=0.0), dict(train_energy_j=-1.0), dict(tx_energy_j=-1.0),
    dict(recharge_w=-0.5), dict(initial_charge=1.5),
    dict(retry_backoff_cap_s=10.0),      # below retry_backoff_s
])
def test_fault_model_validation(kw):
    with pytest.raises(ValueError):
        FaultModel(**kw)


@pytest.mark.parametrize("kw", [
    dict(ps_channels=0), dict(ps_channels=-3), dict(max_in_flight=0),
    dict(group_timeouts=("bad",)), dict(group_timeouts=((0,),)),
    dict(group_timeouts=((0, -5.0),)), dict(group_timeouts=((0.5, 10.0),)),
    dict(staleness_fn="nope"), dict(agg_mode="typo"),
    dict(interval_s=0.0), dict(num_groups=0),
    dict(rx_backlog_threshold_s=-1.0), dict(rx_backlog_window_scale=0.0),
    dict(rx_backlog_window_scale=1.5),
])
def test_spec_validation_rejects(kw):
    """Malformed specs fail at construction with a clear ValueError, not
    deep in the runtime."""
    base = get_strategy("asyncfleo-gs")
    with pytest.raises(ValueError):
        dataclasses.replace(base, **kw)


def test_spec_validation_accepts_valid():
    spec = dataclasses.replace(
        get_strategy("asyncfleo-gs"), ps_channels=4, max_in_flight=3,
        group_timeouts=((-1, 900.0), (0, 1200.0)), staleness_fn="poly",
        rx_backlog_threshold_s=0.0, rx_backlog_window_scale=0.25)
    assert spec.ps_channels == 4


def test_staleness_fns_tables_in_sync():
    """strategies.py validates against a literal mirror of the canonical
    aggregation table (kept import-light) — they must not drift."""
    assert _STALENESS_FNS == STALENESS_FNS


# ---- staleness-function zoo -------------------------------------------------

def test_staleness_factor_zoo():
    # eq13: k_n / beta
    assert staleness_factor("eq13", 10, 7) == pytest.approx(0.7)
    assert staleness_factor("eq13", 10, -1) == 0.0       # never joined
    # constant: no mitigation
    assert staleness_factor("constant", 10, 0) == 1.0
    # hinge: flat 1 up to the breakpoint, then 1/(a*(d-b))
    assert staleness_factor("hinge", 6, 0) == 1.0        # d = 6 = b
    assert staleness_factor("hinge", 7, 0) == pytest.approx(1 / 10.0)
    assert staleness_factor("hinge", 16, 0) == pytest.approx(1 / 100.0)
    # poly: (1+d)^-a
    assert staleness_factor("poly", 0, 0) == 1.0
    assert staleness_factor("poly", 3, 0) == pytest.approx(0.5)
    # all zoo members give a fresh model (d=0) full weight and decay
    # monotonically with the gap
    for fn in ("constant", "hinge", "poly"):
        assert staleness_factor(fn, 5, 5) == 1.0
        vals = [staleness_factor(fn, b, 0) for b in range(0, 20)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))
    with pytest.raises(ValueError):
        staleness_factor("nope", 1, 0)


def _metas():
    return [SatelliteMeta(0, 100.0, (0, 0), 10.0, 5),     # fresh at beta=5
            SatelliteMeta(1, 100.0, (0, 0), 11.0, 2),     # stale
            SatelliteMeta(2, 50.0, (0, 0), 12.0, 0)]      # very stale


def test_asyncfleo_weights_staleness_fn():
    # per-model groups so the stale ones survive Alg. 2 selection (a
    # group with a fresh member discards its stale members)
    groups = {0: [0], 1: [1], 2: [2]}
    # eq13 explicitly == eq13 by default (the byte-identical contract)
    d0 = asyncfleo_weights(groups, _metas(), 5)
    d1 = asyncfleo_weights(groups, _metas(), 5, staleness_fn="eq13")
    np.testing.assert_array_equal(d0[1], d1[1])
    assert d0[2] == d1[2]
    # a zoo member changes the stale weighting but stays convex
    sel, w, gamma, info = asyncfleo_weights(groups, _metas(), 5,
                                            staleness_fn="poly")
    assert sel == [0, 1, 2]
    assert 0.0 < gamma <= 1.0
    assert w.sum() == pytest.approx(gamma)
    assert not np.allclose(w, d0[1])
    # constant == no mitigation: stale models keep pure size weights
    _, wc, gc, _ = asyncfleo_weights(groups, _metas(), 5,
                                     staleness_fn="constant")
    np.testing.assert_allclose(wc, gc * np.array([100, 100, 50.0]) / 250.0)


def test_staleness_fn_threads_through_simulation():
    """StrategySpec.staleness_fn reaches the committed gamma; eq13 (the
    default) is bit-identical to a spec that never heard of the field."""
    a = _sim("asyncfleo-twohap", True)
    b = _sim("asyncfleo-twohap", True, spec_kw=dict(staleness_fn="eq13"))
    c = _sim("asyncfleo-twohap", True, spec_kw=dict(staleness_fn="poly"))
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    hc = c.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))
    assert len(hc) == len(ha)        # the zoo member still runs to length


# ---- off-switch bit-parity (the CI-pinned contract) -------------------------

def test_fault_model_none_attaches_no_state():
    fls = _sim("asyncfleo-twohap", True)
    assert fls.fault is None and fls._train_scale is None


def test_null_fault_model_bit_identical():
    """fault_model=None and an all-off FaultModel() take identical code
    paths: same histories, same weights, under both drivers."""
    fm = FaultModel()
    assert fm.is_null
    for ed in (False, True):
        a = _sim("asyncfleo-twohap", ed)
        b = _sim("asyncfleo-twohap", ed, fault_model=fm)
        ha = a.run(W0, max_epochs=5)
        hb = b.run(W0, max_epochs=5)
        assert _rows(ha) == _rows(hb)
        np.testing.assert_array_equal(np.asarray(a._w_flat),
                                      np.asarray(b._w_flat))
        assert a._fused_prog.dispatches == b._fused_prog.dispatches


# ---- compute-rate heterogeneity ---------------------------------------------

def test_train_time_scale_shapes():
    fm = FaultModel(compute_rate_spread=2.0)
    s = fm.train_time_scale(40)
    assert s.shape == (40,) and (s >= 1.0).all() and (s <= 3.0).all()
    assert s.max() > 1.0
    np.testing.assert_array_equal(s, fm.train_time_scale(40))  # seeded
    assert FaultModel(compute_rate_spread=0.0).train_time_scale(40) is None
    ex = FaultModel(compute_rates=(1.0, 2.0, 3.0))
    np.testing.assert_array_equal(ex.train_time_scale(3), [1.0, 2.0, 3.0])
    # a length mismatch raises in BOTH directions — a longer table used
    # to silently truncate, masking a mis-sized scenario
    with pytest.raises(ValueError):
        ex.train_time_scale(5)           # fewer rates than satellites
    with pytest.raises(ValueError):
        ex.train_time_scale(2)           # more rates than satellites


def test_compute_spread_changes_timing_keeps_driver_parity():
    """Heterogeneous compute stretches TRAIN_DONE times (the history
    moves), but the epoch loop and the event runtime still agree exactly
    — both route through the ONE shared `_train_times`."""
    fm = FaultModel(compute_rate_spread=1.5, eclipse_fraction=0.2)
    base = _sim("asyncfleo-twohap", True).run(W0, max_epochs=4)
    a = _sim("asyncfleo-twohap", False, fault_model=fm)
    b = _sim("asyncfleo-twohap", True, fault_model=fm)
    ha = a.run(W0, max_epochs=4)
    hb = b.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches
    assert _rows(hb) != _rows(base)      # the faults actually bite


# ---- eclipse availability ---------------------------------------------------

def test_eclipse_masks_visibility():
    fm = FaultModel(eclipse_fraction=0.3)
    base = _sim("asyncfleo-twohap", True)
    ecl = _sim("asyncfleo-twohap", True, fault_model=fm)
    assert ecl.timeline.grid.sum() < base.timeline.grid.sum()
    # deterministic: same seed -> same mask
    ecl2 = _sim("asyncfleo-twohap", True, fault_model=fm)
    np.testing.assert_array_equal(ecl.timeline.grid, ecl2.timeline.grid)
    # availability_mask itself: each sat dark for ~the configured fraction
    mask = fm.availability_mask(np.arange(0.0, 54000.0, 10.0), 8)
    dark = 1.0 - mask.mean(axis=0)
    np.testing.assert_allclose(dark, 0.3, atol=0.02)
    assert FaultModel().availability_mask(np.zeros(3), 4) is None


# ---- lossy transfers: retry / backoff / drop --------------------------------

def test_transfer_fails_deterministic_schedule():
    fm = FaultModel(loss_prob=0.4)
    draws = [fm.transfer_fails(s, r, a)
             for s in range(8) for r in range(4) for a in range(3)]
    draws2 = [fm.transfer_fails(s, r, a)
              for s in range(8) for r in range(4) for a in range(3)]
    assert draws == draws2 and any(draws) and not all(draws)
    # keyed draws: a different seed gives a different schedule
    fm2 = FaultModel(seed=7, loss_prob=0.4)
    assert draws != [fm2.transfer_fails(s, r, a)
                     for s in range(8) for r in range(4) for a in range(3)]
    assert FaultModel(loss_prob=0.0).transfer_fails(0, 0, 0) is False
    assert FaultModel(loss_prob=1.0).transfer_fails(0, 0, 0) is True
    assert fm.retry_delay_s(0) == pytest.approx(120.0)
    assert fm.retry_delay_s(3) == pytest.approx(960.0)


def test_lossy_transfers_retry_and_recover():
    """30% loss with generous retries: failures and retransmissions show
    up in the telemetry, every epoch still commits, and the whole run is
    reproducible (the seeded schedule is independent of event order)."""
    fm = FaultModel(loss_prob=0.3, max_retries=5, retry_backoff_s=60.0)
    a = _sim("asyncfleo-twohap", True, fault_model=fm)
    rt = EventDrivenRuntime(a)
    ha = rt.run(W0, max_epochs=5)
    assert len(ha) == 5
    assert rt.stats["transfers_failed"] > 0
    assert rt.stats["transfer_retries"] > 0
    assert rt.events.counts["TRANSFER_FAILED"] == rt.stats["transfers_failed"]
    b = _sim("asyncfleo-twohap", True, fault_model=fm)
    rtb = EventDrivenRuntime(b)
    hb = rtb.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    assert rt.stats == rtb.stats
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))


def test_total_loss_drops_after_max_retries_and_terminates():
    """loss_prob=1: every chain burns its retries and drops; rounds
    resolve as 0-model commits (the on_expected_drop rescue) instead of
    hanging, and the run terminates at max_epochs."""
    fm = FaultModel(loss_prob=1.0, max_retries=1, retry_backoff_s=60.0)
    fls = _sim("asyncfleo-twohap", True, fault_model=fm)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=4)
    assert [r.num_models for r in hist] == [0, 0, 0, 0]
    assert rt.stats["dropped_after_max_retries"] > 0
    # every failed transfer either retried or dropped — nothing leaks
    assert rt.stats["transfers_failed"] == (
        rt.stats["transfer_retries"]
        + rt.stats["dropped_after_max_retries"]
        + rt.stats["dropped_unreachable"])


def test_sync_barrier_rescued_on_drops():
    """A barrier round whose transfers all drop must not stall until
    sync_stall_s — on_expected_drop fires the trigger as soon as nothing
    is left in flight."""
    fm = FaultModel(loss_prob=1.0, max_retries=0)
    fls = _sim("fedisl", True, fault_model=fm)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=3)
    assert len(hist) == 3
    assert all(r.num_models == 0 for r in hist)
    assert rt.stats["dropped_after_max_retries"] > 0


def test_partial_loss_fewer_models_than_baseline():
    fm = FaultModel(loss_prob=0.5, max_retries=1, retry_backoff_s=600.0)
    base = _sim("asyncfleo-twohap", True).run(W0, max_epochs=4)
    rt = EventDrivenRuntime(_sim("asyncfleo-twohap", True, fault_model=fm))
    hist = rt.run(W0, max_epochs=4)
    n_base = sum(r.num_models for r in base)
    n_fault = sum(r.num_models for r in hist)
    assert 0 < n_fault < n_base
    assert rt.stats["dropped_after_max_retries"] > 0


def test_loss_requires_event_runtime():
    fm = FaultModel(loss_prob=0.2)
    fls = _sim("asyncfleo-twohap", False, fault_model=fm)
    with pytest.raises(ValueError, match="event-driven"):
        fls.run(W0, max_epochs=2)


def test_retries_reenter_channel_pools():
    """With finite ps_channels, retransmissions charge fresh rx grants:
    the lossy run books strictly more rx grants than the loss-free run
    of the same scenario."""
    kw = dict(link=SLOW, spec_kw=dict(ps_channels=2))
    a = _sim("asyncfleo-twohap", True, **kw)
    ra = EventDrivenRuntime(a)
    ra.run(W0, max_epochs=4)
    fm = FaultModel(loss_prob=0.4, max_retries=4, retry_backoff_s=60.0)
    b = _sim("asyncfleo-twohap", True, fault_model=fm, **kw)
    rb = EventDrivenRuntime(b)
    rb.run(W0, max_epochs=4)
    assert rb.stats["transfer_retries"] > 0
    assert (rb.contention_stats()["rx"]["grants"]
            > ra.contention_stats()["rx"]["grants"])


# ---- contention-aware trigger windows (off by default) ----------------------

def test_window_shrink_unit():
    """Backlog above the threshold scales the window; below leaves it
    untouched; threshold None is the bit-identical off switch."""
    fls = _sim("asyncfleo-twohap", True,
               spec_kw=dict(ps_channels=1, rx_backlog_threshold_s=10.0,
                            rx_backlog_window_scale=0.5))
    rt = EventDrivenRuntime(fls)
    pol = rt.policy
    assert isinstance(pol, AsyncFLEOPolicy)
    assert pol.rx_backlog_threshold_s == 10.0
    rnd = SimpleNamespace(sink=0, t_start=0.0, trigger_scheduled=None,
                          expected=[(1.0, 0, 0)], group_first={})
    w = rt.sim.agg_timeout_s
    assert pol.on_arrival(rt, rnd, 100.0) == pytest.approx(100.0 + w)
    fls.plan.contention.grant_rx(0, 50.0, 500.0)    # load the rx pool
    rnd.trigger_scheduled = None
    assert pol.on_arrival(rt, rnd, 100.0) == pytest.approx(100.0 + 0.5 * w)
    assert rt.stats["shrunk_windows"] == 1
    # default spec: the field stays None and split delegates to _trigger
    off = make_policy(get_strategy("asyncfleo-gs"))
    assert off.rx_backlog_threshold_s is None


def test_window_shrink_end_to_end():
    """Shrink enabled under heavy contention: the run completes, commits
    earlier-or-equal windows, and counts the shrinks."""
    base = _sim("asyncfleo-twohap", True, link=SLOW,
                spec_kw=dict(ps_channels=1))
    hb = base.run(W0, max_epochs=4)
    tight = _sim("asyncfleo-twohap", True, link=SLOW,
                 spec_kw=dict(ps_channels=1, rx_backlog_threshold_s=0.0,
                              rx_backlog_window_scale=0.25))
    rt = EventDrivenRuntime(tight)
    ht = rt.run(W0, max_epochs=4)
    assert len(ht) == 4
    assert rt.stats["shrunk_windows"] > 0
    assert ht[0].time_s <= hb[0].time_s    # first window can only shrink


# ---- correlated / bursty loss (Gilbert–Elliott, §11) ------------------------

def test_burst_off_switch_keeps_iid_draws():
    """burst_len_s=0 (the default) keeps the i.i.d. key: ps/t are
    ignored, so the schedule is byte-identical to the historical 3-arg
    call regardless of where or when the attempt happens."""
    fm = FaultModel(loss_prob=0.4)
    assert not fm.has_burst and fm.has_loss
    for s in range(6):
        for r in range(3):
            for a in range(3):
                assert (fm.transfer_fails(s, r, a)
                        == fm.transfer_fails(s, r, a, ps=1, t=43210.9))


def test_burst_windows_correlate_failures():
    fm = FaultModel(loss_prob=0.3, burst_len_s=600.0)
    assert fm.has_burst and fm.has_loss and not fm.is_null
    # window state is a pure keyed draw: constant inside one window,
    # identical on re-query (independent of query order)
    assert (fm.in_bad_window(0, 0, 0.0) == fm.in_bad_window(0, 0, 100.0)
            == fm.in_bad_window(0, 0, 599.9))
    fwd = [fm.in_bad_window(0, 0, w * 600.0) for w in range(50)]
    rev = [fm.in_bad_window(0, 0, w * 600.0) for w in reversed(range(50))]
    assert fwd == rev[::-1]
    # default bad/good probs (1.0 / 0.0): an attempt's fate IS the
    # window state — retries inside the same burst all fail
    for t in np.arange(0.0, 30000.0, 137.0):
        assert fm.transfer_fails(0, 7, 2, ps=0, t=t) == \
            fm.in_bad_window(0, 0, t)
    # the long-run bad fraction tracks loss_prob (stationary rate match)
    bad = np.mean([fm.in_bad_window(s, p, w * 600.0 + 1.0)
                   for s in range(4) for p in range(2) for w in range(300)])
    assert abs(bad - 0.3) < 0.04
    # distinct (sat, ps) links fade independently
    assert ([fm.in_bad_window(0, 0, w * 600.0) for w in range(100)]
            != [fm.in_bad_window(0, 1, w * 600.0) for w in range(100)])


def test_burst_loss_end_to_end_deterministic():
    """A bursty channel run commits every epoch, shows failures in the
    telemetry, and is bit-reproducible (the GE schedule is pure)."""
    fm = FaultModel(loss_prob=0.3, burst_len_s=1800.0, max_retries=4,
                    retry_backoff_s=60.0)
    a = _sim("asyncfleo-twohap", True, fault_model=fm)
    ra = EventDrivenRuntime(a)
    ha = ra.run(W0, max_epochs=4)
    assert len(ha) == 4
    assert ra.stats["transfers_failed"] > 0
    b = _sim("asyncfleo-twohap", True, fault_model=fm)
    rb = EventDrivenRuntime(b)
    hb = rb.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    assert ra.stats == rb.stats
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))


# ---- PS outages & ring failover (§11) ---------------------------------------

def test_outage_schedule_queries():
    fm = FaultModel(ps_outages=((0, 100.0, 200.0), (0, 150.0, 300.0),
                                (1, 120.0, 140.0)))
    assert fm.has_outages and not fm.is_null
    sched = OutageSchedule(fm.outage_intervals(2, 1000.0), 2)
    # overlapping intervals merge; events() is the PS_DOWN/PS_UP source
    assert sched.events() == [(0, 100.0, 300.0), (1, 120.0, 140.0)]
    # half-open [start, end): down AT start, up again AT end
    assert sched.down_at(0, 100.0) and sched.down_at(0, 299.9)
    assert not sched.down_at(0, 99.9) and not sched.down_at(0, 300.0)
    assert sched.next_up(0, 150.0) == 300.0
    assert sched.next_up(1, 20.0) == 20.0            # already up
    assert sched.all_down_at(130.0) and not sched.all_down_at(150.0)
    assert sched.next_any_up(130.0) == 140.0         # PS 1 recovers first
    assert sched.down_set(130.0) == {0, 1}
    # a PS index beyond the topology fails at compile time, like
    # compute_rates at train_time_scale time
    with pytest.raises(ValueError):
        fm.outage_intervals(1, 1000.0)
    # horizon clipping drops or trims out-of-range windows
    assert fm.outage_intervals(2, 110.0) == ((0, 100.0, 110.0),)


def test_outage_fraction_masks_grid():
    fm = FaultModel(ps_outage_fraction=0.3)
    base = _sim("asyncfleo-twohap", True)
    out = _sim("asyncfleo-twohap", True, fault_model=fm)
    assert out.timeline.grid.sum() < base.timeline.grid.sum()
    out2 = _sim("asyncfleo-twohap", True, fault_model=fm)   # seeded
    np.testing.assert_array_equal(out.timeline.grid, out2.timeline.grid)
    # the periodic windows keep each PS dark for ~the configured fraction
    mask = fm.outage_mask(np.arange(0.0, 86400.0, 10.0), 2, 86400.0)
    np.testing.assert_allclose(mask.mean(axis=0), 0.7, atol=0.02)
    assert FaultModel().outage_mask(np.zeros(3), 2, 100.0) is None


def test_ps_outage_failover_end_to_end():
    """One of the two ring HAPs dark for a contiguous 30% of the horizon:
    open rounds fail their sink over to the survivor, arrivals timed
    against the dark PS reroute along the ring, every epoch still
    commits, and the whole run is bit-reproducible."""
    fm = FaultModel(ps_outages=((0, 2000.0, 27920.0),))
    a = _sim("asyncfleo-twohap", True, fault_model=fm)
    ra = EventDrivenRuntime(a)
    ha = ra.run(W0, max_epochs=6)
    assert len(ha) == 6
    assert ra.events.counts["PS_DOWN"] == 1
    assert ra.events.counts["PS_UP"] == 1
    assert ra.stats["sink_failovers"] > 0      # PS_DOWN swept the open round
    assert ra.stats["rerouted_arrivals"] > 0   # in-flight arrivals relayed
    b = _sim("asyncfleo-twohap", True, fault_model=fm)
    rb = EventDrivenRuntime(b)
    hb = rb.run(W0, max_epochs=6)
    assert _rows(ha) == _rows(hb)
    assert ra.stats == rb.stats
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))


def test_total_outage_horizon_clamp_commits():
    """EVERY PS dark through the end of the horizon: deferred triggers
    can find no recovery inside the run, so the clamp commits the
    starved rounds anyway and the run terminates."""
    fm = FaultModel(ps_outages=((0, 40000.0, 86400.0),
                                (1, 40000.0, 86400.0)))
    fls = _sim("asyncfleo-twohap", True, fault_model=fm)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=6)
    assert len(hist) >= 1                  # terminated, nothing hangs
    assert all(np.isfinite(r.time_s) for r in hist)


def test_outage_energy_require_event_runtime():
    """The epoch loop cannot express failover or deferred uplinks; it
    must refuse instead of silently ignoring the configured axis."""
    for fm in (FaultModel(ps_outage_fraction=0.2),
               FaultModel(battery_j=100.0)):
        fls = _sim("asyncfleo-twohap", False, fault_model=fm)
        with pytest.raises(ValueError, match="event-driven"):
            fls.run(W0, max_epochs=2)


# ---- energy budgets (§11) ---------------------------------------------------

def test_energy_state_unit():
    fm = FaultModel(battery_j=100.0, train_energy_j=60.0, tx_energy_j=10.0,
                    recharge_w=0.5, initial_charge=0.5)
    assert fm.has_energy and not fm.is_null
    es = EnergyState(fm, 2)
    assert es.level(0, 0.0) == pytest.approx(50.0)
    assert not es.try_drain(0, 0.0, 60.0)            # can't afford yet
    # deficit 10 J at 0.5 W -> affordable 20 s later (closed form)
    assert es.time_to_afford(0, 0.0, 60.0) == pytest.approx(20.0)
    assert es.try_drain(0, 20.0, 60.0)
    assert es.level(0, 20.0) == pytest.approx(0.0)
    assert es.time_to_afford(0, 20.0, 200.0) is None  # above capacity
    assert es.level(1, 1000.0) == pytest.approx(100.0)   # capped at battery_j
    # snapshot/restore mirrors the §9 channel-pool rollback
    snap = es.snapshot()
    assert es.try_drain(1, 1000.0, 10.0)
    es.restore(snap)
    assert es.level(1, 1000.0) == pytest.approx(100.0)
    # zero recharge: a depleted battery never recovers
    es0 = EnergyState(FaultModel(battery_j=100.0, recharge_w=0.0,
                                 initial_charge=0.0), 1)
    assert es0.time_to_afford(0, 0.0, 5.0) is None
    # eclipse scales the mean-field recharge rate (sunlit duty cycle)
    ec = EnergyState(FaultModel(battery_j=1.0, recharge_w=2.0,
                                eclipse_fraction=0.5), 1)
    assert ec.rate_w == pytest.approx(1.0)


def test_energy_budget_defers_and_recovers():
    """A never-binding battery changes nothing; a tight one forces
    deferred uplinks / skipped recruits (telemetry) while the run still
    commits and reproduces."""
    hb = _sim("asyncfleo-twohap", True).run(W0, max_epochs=4)
    ample = _sim("asyncfleo-twohap", True,
                 fault_model=FaultModel(battery_j=1e9))
    ra = EventDrivenRuntime(ample)
    ha = ra.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    assert (ra.stats["energy_deferrals"] + ra.stats["dropped_energy"]
            + ra.stats["energy_skipped_recruits"]) == 0
    tight = FaultModel(battery_j=60.0, train_energy_j=50.0, tx_energy_j=20.0,
                       recharge_w=0.05, initial_charge=1.0)
    b = _sim("asyncfleo-twohap", True, fault_model=tight)
    rb = EventDrivenRuntime(b)
    hbt = rb.run(W0, max_epochs=4)
    assert len(hbt) >= 1
    assert (rb.stats["energy_deferrals"] + rb.stats["dropped_energy"]
            + rb.stats["energy_skipped_recruits"]) > 0
    c = _sim("asyncfleo-twohap", True, fault_model=tight)
    rc = EventDrivenRuntime(c)
    hc = rc.run(W0, max_epochs=4)
    assert _rows(hbt) == _rows(hc) and rb.stats == rc.stats


# ---- fault-aware participant selection (§11, off by default) ----------------

def test_fault_aware_selection_flag():
    fm = FaultModel(eclipse_fraction=0.4)
    # off (the default): no recruit is ever skipped for fault forecasts
    a = _sim("asyncfleo-twohap", True, fault_model=fm)
    ra = EventDrivenRuntime(a)
    ha = ra.run(W0, max_epochs=4)
    assert ra.stats["fault_aware_skips"] == 0
    # on: recruits whose uplink instant lands in eclipse are skipped
    b = _sim("asyncfleo-twohap", True, fault_model=fm,
             spec_kw=dict(fault_aware_selection=True))
    rb = EventDrivenRuntime(b)
    hbt = rb.run(W0, max_epochs=4)
    assert len(hbt) == 4
    assert rb.stats["fault_aware_skips"] > 0
    # the flag without a fault model consults nothing: bit-identical
    base = _sim("asyncfleo-twohap", True).run(W0, max_epochs=4)
    c = _sim("asyncfleo-twohap", True,
             spec_kw=dict(fault_aware_selection=True))
    assert _rows(c.run(W0, max_epochs=4)) == _rows(base)


# ---- adaptive retry backoff (AIMD, §11) -------------------------------------

def test_adaptive_backoff_applied_and_capped():
    fm = FaultModel(loss_prob=0.6, max_retries=6, retry_backoff_s=60.0,
                    adaptive_backoff=True, retry_backoff_cap_s=240.0)
    fls = _sim("asyncfleo-twohap", True, fault_model=fm)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=4)
    assert len(hist) == 4
    # the applied delays live in a bounded histogram (obs/metrics.py);
    # the stats view renders its count/sum/min/max/percentile summary
    delays = rt.stats["backoff_delays_s"]
    assert delays["count"] > 0 and rt.stats["transfer_retries"] > 0
    # every applied delay sits in [base, cap]; additive increase under
    # sustained loss actually moves it off the base
    assert delays["min"] >= 60.0 and delays["max"] <= 240.0
    assert delays["max"] > 60.0
    assert delays["min"] <= delays["p50"] <= delays["max"]
    # the default (adaptive_backoff=False) keeps the blind exponential:
    # no delays are recorded at all
    off = dataclasses.replace(fm, adaptive_backoff=False)
    fls2 = _sim("asyncfleo-twohap", True, fault_model=off)
    rt2 = EventDrivenRuntime(fls2)
    rt2.run(W0, max_epochs=4)
    assert rt2.stats["backoff_delays_s"]["count"] == 0
    assert rt2.stats["transfers_failed"] > 0
