import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh and extract roofline inputs — no real allocation (ShapeDtypeStructs).

MUST be run as its own process (the XLA flag above is read at first jax
init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--rules base|fsdp] [--out out.json]

Exit code 0 = lower+compile succeeded; the JSON artifact carries
cost_analysis, memory_analysis, and parsed collective traffic for
benchmarks/roofline.py.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape, applicable, SHAPES, ARCHS
from repro.launch import sharding as sh
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (cache_specs, input_specs, opt_state_specs,
                                param_specs)
from repro.launch.steps import (cache_len_for, make_decode_step,
                                make_prefill_step, make_train_step,
                                make_optimizer, window_for)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               rules_name: str = "base", donate: bool = False,
               remat: bool = True, verbose: bool = True,
               q_chunks: int = 1, capacity_factor: float = None) -> dict:
    cfg = get_config(arch).replace(remat=remat)
    if capacity_factor is not None:
        cfg = cfg.replace(moe_capacity_factor=capacity_factor)
    shape = get_shape(shape_name)
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "encoder-only has no decode step (DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = sh.RULE_SETS[rules_name]
    window = window_for(cfg, shape)

    p_spec = param_specs(cfg)
    p_shard = sh.tree_shardings(p_spec, mesh, rules)
    batch = input_specs(cfg, shape)
    b_shard = sh.batch_shardings(batch, mesh, rules)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            o_spec = opt_state_specs(cfg, p_spec)
            o_shard = sh.tree_shardings(o_spec, mesh, rules)
            step = make_train_step(cfg, make_optimizer(), window=window,
                                   q_chunks=q_chunks)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(p_spec, o_spec, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, window=window, q_chunks=q_chunks)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_spec, batch)
        else:                                            # decode / serve_step
            c_spec = cache_specs(cfg, shape)
            c_shard = sh.tree_shardings(c_spec, mesh, rules)
            step = make_decode_step(cfg, window=window)
            jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(p_spec, c_spec, batch)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_dev = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "rules": rules_name, "mesh_shape": list(mesh.devices.shape),
        "num_devices": int(n_dev),
        "window": window,
        "q_chunks": q_chunks,
        "capacity_factor": cfg.moe_capacity_factor,
        "remat": remat,
        "cache_len": cache_len_for(cfg, shape) if shape.kind == "decode" else 0,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "params": int(ARCHS[arch].param_count()),
        "active_params": int(ARCHS[arch].active_param_count()),
        "hlo_bytes": len(hlo),
        "skipped": False,
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items() if k != "memory"},
                         indent=None), flush=True)
        print("memory_analysis:", result["memory"], flush=True)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS) + ["all"])
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="base", choices=sorted(sh.RULE_SETS))
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--q-chunks", type=int, default=1)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = sorted(SHAPES) if args.shape == "all" else [args.shape]
    results = []
    failures = 0
    for a in archs:
        for s in shapes:
            try:
                results.append(dryrun_one(a, s, multi_pod=args.multi_pod,
                                          rules_name=args.rules,
                                          donate=args.donate,
                                          remat=not args.no_remat,
                                          q_chunks=args.q_chunks,
                                          capacity_factor=args.capacity_factor))
            except Exception as e:          # a dry-run failure is a bug
                failures += 1
                results.append({"arch": a, "shape": s, "error": repr(e)[:500],
                                "skipped": False})
                print(f"FAIL {a} {s}: {e}", file=sys.stderr, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
