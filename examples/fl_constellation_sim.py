"""Strategy comparison driver (paper Table II / Fig. 6, configurable).

    PYTHONPATH=src python examples/fl_constellation_sim.py \
        --schemes asyncfleo-hap fedhap --epochs 8 --iid

Runs the simulation for each scheme on the same data and prints
accuracy-vs-simulated-time CSV curves — the paper's Fig. 6.

``--event-driven`` swaps the epoch loop for the event-driven async
scheduler (`repro.sched`): the same constellation is compiled into a
contact plan, each scheme runs under its trigger policy (AsyncFLEO idle
window / sync barrier / FedAsync per-arrival, see DESIGN.md §7), and the
compiled plan's window statistics are printed alongside the curves:

    PYTHONPATH=src python examples/fl_constellation_sim.py \
        --schemes asyncfleo-hap fedasync fedisl --event-driven

``--max-in-flight N`` (N > 1) additionally pipelines every scheme's
rounds — up to N overlapping rounds in flight per the DESIGN.md §8
round model (the ``asyncfleo-pipelined`` scheme ships with depth 3 and
the contact-plan handoff built in):

    PYTHONPATH=src python examples/fl_constellation_sim.py \
        --schemes asyncfleo-pipelined asyncfleo-gs --event-driven

The fault / heterogeneity flags (DESIGN.md §10) inject failures into
every scheme: ``--dropout`` makes each uplink transfer fail with that
probability (retried with exponential backoff; forces --event-driven),
``--compute-spread`` stretches each satellite's training time by a
seeded per-sat multiplier in [1, 1+spread], ``--eclipse-fraction``
blacks out each satellite for that fraction of a phase-shifted orbital
period, and ``--staleness-fn`` swaps eq. 13's staleness discount for a
FedAsync-family alternative:

    PYTHONPATH=src python examples/fl_constellation_sim.py \
        --schemes asyncfleo-gs fedisl --event-driven \
        --dropout 0.2 --compute-spread 1.0 --staleness-fn poly
"""
import argparse
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro.configs import MNIST_CNN
from repro.core import (FLSimulation, SimConfig, convergence_time,
                        paper_constellation)
from repro.data import (class_conditional_images, iid_partition,
                        paper_noniid_partition)
from repro.fl import Evaluator, ImageClassifierPool, get_strategy, STRATEGIES
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schemes", nargs="+", default=["asyncfleo-hap", "fedhap"],
                    choices=sorted(STRATEGIES))
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--target", type=float, default=0.75)
    ap.add_argument("--days", type=float, default=3.0)
    ap.add_argument("--event-driven", action="store_true",
                    help="drive each scheme with the async event scheduler "
                         "(contact plan + trigger policies) instead of the "
                         "epoch loop")
    ap.add_argument("--max-in-flight", type=int, default=0,
                    help="override every scheme's pipeline depth (rounds "
                         "in flight, DESIGN.md §8); 0 keeps each "
                         "strategy's own setting, >1 implies "
                         "--event-driven")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-transfer loss probability (retried with "
                         "exponential backoff, DESIGN.md §10); >0 implies "
                         "--event-driven")
    ap.add_argument("--compute-spread", type=float, default=0.0,
                    help="per-sat compute heterogeneity: training time "
                         "stretched by a seeded multiplier in "
                         "[1, 1+spread]")
    ap.add_argument("--eclipse-fraction", type=float, default=0.0,
                    help="fraction of each (phase-shifted) orbital period "
                         "a satellite is unavailable")
    ap.add_argument("--staleness-fn", default="eq13",
                    choices=["eq13", "constant", "hinge", "poly"],
                    help="staleness discount: the paper's eq. 13 or a "
                         "FedAsync-family alternative")
    args = ap.parse_args()
    if args.max_in_flight > 1 or args.dropout > 0.0:
        args.event_driven = True

    fault = None
    if args.dropout or args.compute_spread or args.eclipse_fraction:
        from repro.sched import FaultModel
        fault = FaultModel(loss_prob=args.dropout,
                           compute_rate_spread=args.compute_spread,
                           eclipse_fraction=args.eclipse_fraction)

    cfg = dataclasses.replace(MNIST_CNN, conv_channels=(8, 16))
    const = paper_constellation()
    imgs, labs = class_conditional_images(0, 4000, separation=0.8)
    ti, tl = class_conditional_images(99, 1000, separation=0.8)
    shards = (iid_partition(labs, const.num_sats, 0) if args.iid
              else paper_noniid_partition(labs, const.orbit_ids(), 0))
    pool = ImageClassifierPool(cfg, imgs, labs, shards, local_iters=30)
    ev = Evaluator(cfg, ti, tl)
    w0 = jax.device_get(cnn.init_params(jax.random.PRNGKey(0), cfg))

    print("scheme,epoch,sim_time_h,accuracy,num_models,gamma")
    summary = []
    for name in args.schemes:
        spec = get_strategy(name)
        if args.max_in_flight:
            spec = dataclasses.replace(spec,
                                       max_in_flight=args.max_in_flight)
        if args.staleness_fn != "eq13":
            spec = dataclasses.replace(spec,
                                       staleness_fn=args.staleness_fn)
        sim = FLSimulation(spec, pool, ev,
                           SimConfig(duration_s=args.days * 86400.0,
                                     event_driven=args.event_driven,
                                     fault_model=fault))
        if args.event_driven:
            s = sim.plan.summary()
            print(f"# {name}: contact plan — {s['num_windows']} windows, "
                  f"coverage {s['coverage_fraction']:.3f}, "
                  f"mean window {s['mean_window_s']:.0f}s")
        if args.event_driven and fault is not None:
            # drive the runtime directly so the retry telemetry is visible
            from repro.sched import EventDrivenRuntime
            rt = EventDrivenRuntime(sim)
            hist = rt.run(w0, max_epochs=args.epochs)
            st = rt.stats
            print(f"# {name}: faults — transfers failed "
                  f"{st['transfers_failed']}, retried "
                  f"{st['transfer_retries']}, dropped "
                  f"{st['dropped_after_max_retries'] + st['dropped_unreachable']}")
        else:
            hist = sim.run(w0, max_epochs=args.epochs)
        for r in hist:
            print(f"{name},{r.epoch},{r.time_s/3600:.3f},{r.accuracy:.4f},"
                  f"{r.num_models},{r.gamma:.3f}")
        conv = convergence_time(hist, args.target)
        summary.append((name, max(r.accuracy for r in hist),
                        conv / 3600 if conv else None))
    print("\n# scheme,best_acc,conv_time_h(target=%.2f)" % args.target)
    for name, acc, conv in summary:
        print(f"# {name},{acc:.4f},{conv if conv else 'n/a'}")


if __name__ == "__main__":
    main()
