import numpy as np
import pytest

from repro.core.grouping import (GroupingState, flatten_model, group_by_gaps,
                                 model_distance, partial_global_model)


def _model(val, shape=(4, 3)):
    return {"w": np.full(shape, val, np.float32), "b": np.full((3,), val, np.float32)}


def test_flatten_and_distance():
    m = _model(1.0)
    ref = flatten_model(_model(0.0))
    assert model_distance(m, ref) == pytest.approx(np.sqrt(15.0))


def test_partial_global_model_weighted():
    pm = partial_global_model([_model(0.0), _model(1.0)], [1.0, 3.0])
    np.testing.assert_allclose(pm["w"], 0.75)


def test_group_by_gaps_three_clusters():
    d = {0: 1.0, 1: 1.1, 2: 5.0, 3: 5.2, 4: 9.0, 5: 9.3, 6: 1.05, 7: 9.1}
    groups = group_by_gaps(d, num_groups=3)
    assert len(groups) == 3
    sets = [set(g) for g in groups]
    assert {0, 1, 6} in sets and {2, 3} in sets and {4, 5, 7} in sets


def test_group_by_gaps_fewer_orbits_than_groups():
    groups = group_by_gaps({0: 1.0, 1: 2.0}, num_groups=3)
    assert len(groups) == 2


def test_grouping_state_incremental():
    gs = GroupingState(num_groups=2)
    gs.set_reference(_model(0.0))
    # first two orbits: one near, one far
    g0 = gs.observe_orbit(0, [_model(0.1)], [1.0])
    g1 = gs.observe_orbit(1, [_model(5.0)], [1.0])
    assert g0 != g1 or len(gs.groups) == 1
    # known orbit keeps its group
    assert gs.observe_orbit(0, [_model(99.0)], [1.0]) == g0
    # new orbit near orbit 1's distance joins orbit 1's group
    g2 = gs.observe_orbit(2, [_model(5.1)], [1.0])
    assert g2 == gs.group_of(1)
    assert gs.all_grouped(3)


def test_grouping_deterministic():
    d = {i: float(v) for i, v in enumerate([3, 1, 4, 1.5, 9, 2.6, 5.8])}
    assert group_by_gaps(d, 3) == group_by_gaps(dict(reversed(list(d.items()))), 3)


def test_observe_orbits_multi_matches_single_stack():
    """Models split across two device matrices (the epoch's training
    bank + a carried-stragglers matrix, with -1 sentinel rows) must
    yield the SAME distances and group assignments as the one-stack
    ``observe_orbits`` over the concatenated models."""
    import jax.numpy as jnp
    from repro.core.modelbank import FlatSpec, ModelBank

    models = [_model(v) for v in (0.2, 0.4, 5.0, 7.0)]
    sizes = [1.0, 3.0, 1.0, 1.0]
    orbit_indices = {10: [0, 1], 11: [2, 3]}
    spec = FlatSpec.of(models[0])
    flats = np.stack([np.asarray(flatten_model(m)) for m in models])

    ref = GroupingState(num_groups=2)
    ref.set_reference(_model(0.0))
    expected = ref.observe_orbits(orbit_indices,
                                  ModelBank(spec, jnp.asarray(flats)),
                                  sizes)

    # models 0, 2 live in segment A (rows 0, 1); models 1, 3 in B
    seg_a = jnp.asarray(flats[[0, 2]])
    seg_b = jnp.asarray(flats[[1, 3]])
    rows_a = [0, -1, 1, -1]
    rows_b = [-1, 0, -1, 1]
    gs = GroupingState(num_groups=2)
    gs.set_reference(_model(0.0))
    got = gs.observe_orbits_multi(orbit_indices,
                                  [(seg_a, rows_a), (seg_b, rows_b)],
                                  sizes)
    assert got == expected
    assert gs.distances == pytest.approx(ref.distances)
    # a None / empty segment contributes nothing rather than crashing
    gs2 = GroupingState(num_groups=2)
    gs2.set_reference(_model(0.0))
    got2 = gs2.observe_orbits_multi(
        orbit_indices,
        [(None, rows_a), (seg_a, rows_a), (seg_b, rows_b)], sizes)
    assert got2 == expected


def test_observe_orbits_multi_known_orbits_skip_device_work():
    gs = GroupingState(num_groups=2)
    gs.set_reference(_model(0.0))
    gs.observe_orbit(5, [_model(1.0)], [1.0])
    # all orbits known: no segments touched at all (stack=None is fine)
    out = gs.observe_orbits_multi({5: [0]}, [(None, [-1])], [1.0])
    assert out == {5: gs.group_of(5)}
