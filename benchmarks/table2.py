"""Table II + Fig. 6: AsyncFLEO vs SOTA baselines on non-IID MNIST-like data
with the CNN model.  Reports per-scheme best accuracy, convergence time to
the target accuracy, and the speedup ratio over the slowest sync baseline —
the paper's headline "22x faster, +40% accuracy" claims.
"""
from __future__ import annotations

from repro.benchmarks_io import emit
from benchmarks.common import make_setup, run_strategy
from repro.core import convergence_time

SCHEMES = ["fedisl", "fedisl-ideal", "fedsat", "fedspace", "fedhap",
           "asyncfleo-gs", "asyncfleo-hap", "asyncfleo-twohap"]
TARGET = 0.75          # convergence target (relative; see EXPERIMENTS.md)


def run(max_epochs: int = 16, schemes=None):
    pool, ev, w0 = make_setup("mnist", "cnn", iid=False)
    rows = []
    curves = []
    for name in (schemes or SCHEMES):
        res = run_strategy(name, pool, ev, w0, max_epochs=max_epochs)
        conv = convergence_time(res["history"], TARGET)
        rows.append({
            "scheme": name,
            "best_acc": round(res["best_acc"], 4),
            "conv_time_h": round(conv / 3600, 2) if conv else None,
            "epochs": len(res["history"]),
            "wall_s": round(res["wall_s"], 1),
        })
        for r in res["history"]:
            curves.append((name, r.epoch, round(r.time_s / 3600, 3),
                           round(r.accuracy, 4)))
    # speedups vs slowest converged sync baseline
    sync_times = [r["conv_time_h"] for r in rows
                  if r["scheme"] in ("fedisl", "fedhap", "fedisl-ideal")
                  and r["conv_time_h"]]
    ours = [r["conv_time_h"] for r in rows
            if r["scheme"].startswith("asyncfleo") and r["conv_time_h"]]
    speedup = (max(sync_times) / min(ours)) if sync_times and ours else None
    return {"rows": rows, "curves": curves, "speedup_vs_slowest_sync": speedup}


def main():
    out = run()
    print("scheme,best_acc,conv_time_h,epochs,wall_s")
    for r in out["rows"]:
        print(f"{r['scheme']},{r['best_acc']},{r['conv_time_h']},"
              f"{r['epochs']},{r['wall_s']}")
    print(f"# speedup_vs_slowest_sync,{out['speedup_vs_slowest_sync']}")
    emit("table2", out)
    return out


if __name__ == "__main__":
    main()
