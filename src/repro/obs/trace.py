"""Structured round-lifecycle tracing (DESIGN.md §12).

The event runtime (`sched/runtime.py`) can answer *what* happened — a
flat counter dict — but not *when*: where did the 0.59x pipelining
inversion's wall-clock go, which failover path delayed round k, how long
did a round sit in its trigger window.  A :class:`Tracer` records the
per-round lifecycle as **spans** (durations in simulated seconds on a
named track) and **instant events** (points with structured args), into
a plain in-memory buffer that `obs/export.py` turns into Chrome
trace-event JSON (loadable in Perfetto / chrome://tracing) or JSONL.

Span taxonomy (one track per round, ``"round <idx>"``):

* ``round``          — open -> close (roles handed off);
* ``recruit``        — downlink phase: open -> last participant's
  global-model receive instant;
* ``transfers``      — uplink phase: first TRAIN_DONE -> last expected
  sink arrival (retries/reroutes move arrivals; the instants record it);
* ``trigger_window`` — first *used* arrival -> the aggregation instant.

Per-PS tracks (``"ps <p>"``, synthesized at export time by
`obs/export.add_runtime_tracks`): ``channel_busy`` spans per reserved
tx/rx channel interval (DESIGN.md §9 pools) and ``outage`` spans per
dark window (§11).

Instant names mirror the runtime's event/telemetry vocabulary:
``MODEL_ARRIVAL``, ``TRANSFER_FAILED`` / ``TRANSFER_RETRY``,
``PS_DOWN`` / ``PS_UP``, ``FAILOVER``, ``REROUTE``,
``ENERGY_DEFERRAL``, ``DROP``, ``TRIGGER`` / ``DISPATCH`` / ``COMMIT``,
``WINDOW_SHRUNK``.

**The null-tracer parity invariant**: tracing is strictly read-only —
a traced run and a ``tracer=None`` run produce bit-identical histories
and weights (pinned in tests/test_obs.py and CI-gated by
``sched_bench.py --trace-out``).  ``tracer=None`` resolves to the
module-level :data:`NULL_TRACER`, whose every method is a no-op and
whose ``enabled`` flag lets hot paths skip building args entirely, so
untraced runs pay nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# ---- span taxonomy ----------------------------------------------------------

SPAN_ROUND = "round"
SPAN_RECRUIT = "recruit"
SPAN_TRANSFERS = "transfers"
SPAN_TRIGGER = "trigger_window"
SPAN_CHANNEL = "channel_busy"
SPAN_OUTAGE = "outage"

# ---- instant-event names ----------------------------------------------------

EV_ARRIVAL = "MODEL_ARRIVAL"
EV_TRANSFER_FAILED = "TRANSFER_FAILED"
EV_TRANSFER_RETRY = "TRANSFER_RETRY"
EV_PS_DOWN = "PS_DOWN"
EV_PS_UP = "PS_UP"
EV_FAILOVER = "FAILOVER"
EV_REROUTE = "REROUTE"
EV_ENERGY_DEFER = "ENERGY_DEFERRAL"
EV_DROP = "DROP"
EV_TRIGGER = "TRIGGER"
EV_DISPATCH = "DISPATCH"
EV_COMMIT = "COMMIT"
EV_WINDOW_SHRUNK = "WINDOW_SHRUNK"


@dataclasses.dataclass
class Span:
    """One closed duration on a track; times are simulated seconds."""
    name: str
    track: str
    t_start: float
    t_end: float
    args: Dict

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclasses.dataclass
class Instant:
    """One point event on a track; time is simulated seconds."""
    name: str
    track: str
    t: float
    args: Dict


class Tracer:
    """In-memory span/instant recorder.

    ``begin``/``end`` bracket long-lived spans by handle (a round may
    stay open across thousands of events); ``span`` records an already-
    closed duration in one call; ``instant`` records a point.  Buffers
    are plain lists — exporters iterate ``spans`` / ``instants``
    directly, and ``close_open_spans`` finalizes whatever is still open
    at run end (rounds alive at the horizon)."""

    enabled = True

    def __init__(self):
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self._open: Dict[int, Tuple[str, str, float, Dict]] = {}
        self._next_handle = 0

    # ---- recording ---------------------------------------------------------

    def begin(self, name: str, t: float, track: str = "main",
              **args) -> int:
        """Open a span; returns the handle ``end`` closes it by."""
        h = self._next_handle
        self._next_handle += 1
        self._open[h] = (name, track, float(t), dict(args))
        return h

    def end(self, handle: int, t: float, **args) -> None:
        """Close an open span (unknown/already-closed handles are
        ignored, so callers never need to track liveness)."""
        ent = self._open.pop(handle, None)
        if ent is None:
            return
        name, track, t0, a = ent
        a.update(args)
        self.spans.append(Span(name, track, t0, max(float(t), t0), a))

    def span(self, name: str, t_start: float, t_end: float,
             track: str = "main", **args) -> None:
        t0 = float(t_start)
        self.spans.append(Span(name, track, t0, max(float(t_end), t0),
                               dict(args)))

    def instant(self, name: str, t: float, track: str = "main",
                **args) -> None:
        self.instants.append(Instant(name, track, float(t), dict(args)))

    # ---- lifecycle ---------------------------------------------------------

    def close_open_spans(self, t: float) -> None:
        """Finalize every still-open span at instant ``t`` (clamped so a
        span never ends before it starts) — called at run end so rounds
        alive at the horizon still export."""
        for h in sorted(self._open):
            self.end(h, t)

    def tracks(self) -> List[str]:
        """All track names, in first-appearance order."""
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.track)
        for i in self.instants:
            seen.setdefault(i.track)
        for (_n, track, _t, _a) in self._open.values():
            seen.setdefault(track)
        return list(seen)

    def clear(self) -> None:
        self.spans.clear()
        self.instants.clear()
        self._open.clear()


class NullTracer:
    """The strict no-op tracer: every method returns immediately and
    records nothing, and ``enabled`` is False so hot paths can skip arg
    construction.  ``tracer=None`` everywhere resolves to the shared
    :data:`NULL_TRACER` — the bit-parity/overhead-free contract."""

    enabled = False
    __slots__ = ()

    def begin(self, *a, **kw) -> int:
        return -1

    def end(self, *a, **kw) -> None:
        pass

    def span(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def close_open_spans(self, *a, **kw) -> None:
        pass

    def tracks(self):
        return []

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
