"""The paper's own FL client models: small CNN and MLP (AsyncFLEO §V-A).

These are the models the paper trains on MNIST/CIFAR-10 across 40 satellites.
They are not ModelConfig transformers; they live in ``repro.models.cnn``.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SmallNetConfig:
    name: str
    kind: str                 # cnn | mlp
    image_size: int = 28
    channels: int = 1
    num_classes: int = 10
    hidden: int = 128
    conv_channels: tuple = (16, 32)


MNIST_CNN = SmallNetConfig("mnist-cnn", "cnn", 28, 1)
MNIST_MLP = SmallNetConfig("mnist-mlp", "mlp", 28, 1, hidden=256)
CIFAR_CNN = SmallNetConfig("cifar-cnn", "cnn", 32, 3, conv_channels=(32, 64))
CIFAR_MLP = SmallNetConfig("cifar-mlp", "mlp", 32, 3, hidden=256)
