"""Synthetic datasets (offline container — no MNIST/CIFAR downloads).

``class_conditional_images`` builds an MNIST/CIFAR-like classification task:
each class c has a smooth prototype image; samples are prototype + structured
noise.  The ``separation`` knob controls achievable accuracy so the paper's
qualitative orderings (CNN > MLP, IID > non-IID) are reproducible.  Token
streams for LLM-scale federated pretraining come from a synthetic Zipf-Markov
source.
"""
from __future__ import annotations

import numpy as np


def _prototypes(rng, num_classes, size, channels, smooth=3):
    protos = rng.standard_normal((num_classes, size, size, channels))
    # cheap smoothing -> spatially-correlated "digit-like" blobs, which gives
    # conv nets a genuine edge over MLPs.
    for _ in range(smooth):
        protos = (protos
                  + np.roll(protos, 1, 1) + np.roll(protos, -1, 1)
                  + np.roll(protos, 1, 2) + np.roll(protos, -1, 2)) / 5.0
    protos -= protos.mean(axis=(1, 2, 3), keepdims=True)
    protos /= protos.std(axis=(1, 2, 3), keepdims=True) + 1e-9
    return protos


def class_conditional_images(seed: int, num_samples: int, *, num_classes=10,
                             size=28, channels=1, separation=1.6,
                             noise_smooth=1, proto_seed: int = 1234):
    """Returns (images (N,H,W,C) float32 in [0,1], labels (N,) int32).

    ``proto_seed`` fixes the class prototypes independently of the sample
    seed so train/test splits (different seeds) share the same task."""
    rng = np.random.default_rng(seed)
    protos = _prototypes(np.random.default_rng(proto_seed), num_classes,
                         size, channels)
    labels = rng.integers(0, num_classes, size=num_samples)
    noise = rng.standard_normal((num_samples, size, size, channels))
    for _ in range(noise_smooth):
        noise = (noise + np.roll(noise, 1, 1) + np.roll(noise, 1, 2)) / 3.0
    x = separation * protos[labels] + noise
    x = (x - x.min()) / (x.max() - x.min() + 1e-9)
    return x.astype(np.float32), labels.astype(np.int32)


def token_stream(seed: int, num_tokens: int, vocab_size: int,
                 *, zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed token stream with a light Markov structure."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    base = rng.choice(vocab_size, size=num_tokens, p=probs)
    # Markov flavor: with prob .3 repeat previous token's neighborhood
    rep = rng.random(num_tokens) < 0.3
    shifted = np.roll(base, 1) + rng.integers(0, 7, num_tokens)
    out = np.where(rep, shifted % vocab_size, base)
    return out.astype(np.int32)


def batches(images, labels, batch_size: int, seed: int):
    """Infinite shuffled batch generator."""
    rng = np.random.default_rng(seed)
    n = len(labels)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = order[i:i + batch_size]
            yield images[sel], labels[sel]
