"""Ring-of-stars topology (paper §IV-A).

HAP layer: the HAPs form a ring (each talks to its two neighbors via IHL);
one is *source*, one *sink* (roles swap every global epoch).  Each HAP also
runs a star with its currently visible satellites.  SAT layer: satellites of
one orbit form an ISL ring (adjacent neighbors only — cross-orbit links are
excluded because of Doppler, §IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.constellation import GroundNode, WalkerDelta
from repro.core.visibility import VisibilityTimeline


@dataclasses.dataclass
class RingOfStars:
    constellation: WalkerDelta
    nodes: List[GroundNode]
    timeline: VisibilityTimeline

    # ---- HAP ring ----------------------------------------------------------

    @property
    def num_ps(self) -> int:
        return len(self.nodes)

    def ring_hops(self, src: int, dst: int) -> int:
        """Hops along the HAP ring from src to dst (shorter direction —
        the relay floods both ways)."""
        H = self.num_ps
        d = abs(dst - src)
        return min(d, H - d)

    def sink_of(self, source: int) -> int:
        """Sink = HAP farthest from the source on the ring (§IV-B1)."""
        H = self.num_ps
        return (source + H // 2) % H if H > 1 else source

    def ring_path(self, src: int, dst: int) -> List[int]:
        """HAP ids along the shorter ring arc src -> dst, endpoints
        included (ties broken toward increasing id)."""
        H = self.num_ps
        fwd = (dst - src) % H
        step, hops = (1, fwd) if fwd <= H - fwd else (-1, H - fwd)
        return [(src + i * step) % H for i in range(hops + 1)]

    def ring_path_via(self, src: int, dst: int,
                      avoid=()) -> Optional[List[int]]:
        """Like ``ring_path`` but routing around the ``avoid`` HAPs
        (e.g. PSs inside an outage window, DESIGN.md §11): the shorter
        arc when its interior is clear, else the other arc, else None
        (both arcs blocked — src/dst endpoints are never checked)."""
        H = self.num_ps
        fwd = (dst - src) % H
        step, hops = (1, fwd) if fwd <= H - fwd else (-1, H - fwd)
        arcs = [[(src + i * step) % H for i in range(hops + 1)]]
        if 0 < fwd < H and hops < H:
            arcs.append([(src - i * step) % H for i in range(H - hops + 1)])
        for path in arcs:
            if not any(p in avoid for p in path[1:-1]):
                return path
        return None

    def ihl_distance(self, a: int, b: int, t):
        """HAP a <-> b distance; ``t`` may be scalar or an array of times."""
        d = np.linalg.norm(self.nodes[a].position(t)
                           - self.nodes[b].position(t), axis=-1)
        return float(d) if np.ndim(t) == 0 else d

    # ---- stars --------------------------------------------------------------

    def star_members(self, ps: int, t: float) -> np.ndarray:
        return self.timeline.visible_sats(t, ps)

    def visible_ps_of(self, sat: int, t: float) -> List[int]:
        return list(np.flatnonzero(self.timeline.visible(t)[sat]))

    # ---- SAT-layer ISL ring --------------------------------------------------

    def orbit_sats(self, orbit: int) -> np.ndarray:
        N = self.constellation.sats_per_orbit
        return np.arange(orbit * N, (orbit + 1) * N)

    def isl_neighbors(self, sat: int) -> Tuple[int, int]:
        N = self.constellation.sats_per_orbit
        o, s = divmod(sat, N)
        return o * N + (s - 1) % N, o * N + (s + 1) % N

    def isl_ring_distance(self, a: int, b: int) -> int:
        """Hops along the intra-orbit ring (two-front relay => shorter arc).
        Satellites on different orbits are unreachable (returns a big int)."""
        N = self.constellation.sats_per_orbit
        if a // N != b // N:
            return 10 ** 9
        d = abs(a % N - b % N)
        return min(d, N - d)

    def isl_ring_distance_matrix(self) -> np.ndarray:
        """(N, N) intra-orbit hop distances — identical for every orbit."""
        N = self.constellation.sats_per_orbit
        d = np.abs(np.arange(N)[:, None] - np.arange(N)[None, :])
        return np.minimum(d, N - d)

    def isl_chord_m(self) -> float:
        """Distance between ring-adjacent satellites (constant for circular
        equally-spaced orbits)."""
        N = self.constellation.sats_per_orbit
        return float(2 * self.constellation.radius_m * np.sin(np.pi / N))

    def sat_ps_distance(self, sat: int, ps: int, t: float) -> float:
        sp = self.constellation.positions(t)[sat]
        return float(np.linalg.norm(sp - self.nodes[ps].position(t)))

    def sat_ps_distances(self, sats, ps: int, t) -> np.ndarray:
        """Distances of the given satellites to one PS; ``t`` scalar or
        per-satellite (P,).  Vectorized — no full-constellation positions."""
        sats = np.atleast_1d(np.asarray(sats, dtype=np.int64))
        t_arr = np.broadcast_to(np.asarray(t, dtype=np.float64), sats.shape)
        sp = self.constellation.positions_at(sats, t_arr)       # (P,3)
        gp = self.nodes[ps].position(t_arr)                     # (P,3)
        return np.linalg.norm(sp - np.atleast_2d(gp), axis=-1)
