"""Pure-jnp oracle for pairwise_dist."""
import jax.numpy as jnp


def pairwise_dist_sq_ref(x):
    x = x.astype(jnp.float32)
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sum(diff * diff, axis=-1)
