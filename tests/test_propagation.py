import numpy as np
import pytest

from repro.core.constellation import make_ps_nodes, paper_constellation
from repro.core.links import LinkModel
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline

BITS = 1e6 * 32


@pytest.fixture(scope="module", params=["hap", "twohap"])
def prop(request):
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes(request.param), 86400.0, 10.0)
    topo = RingOfStars(c, tl.nodes, tl)
    return PropagationModel(topo, LinkModel())


def test_downlink_times_causal(prop):
    recv = prop.downlink_times(0.0, BITS, source=0)
    finite = recv[np.isfinite(recv)]
    assert len(finite) > 0
    assert (finite >= 0.0).all()
    # visible satellites receive before the invisible ones they relay to
    vis0 = prop.topo.timeline.visible(0.0)[:, 0]
    if vis0.any() and (~vis0).any():
        assert recv[vis0].min() <= recv[~vis0].min() + 1e9


def test_downlink_relay_bounds(prop):
    """A satellite reached via k ISL hops receives exactly k hop-delays after
    its seed when its orbit has a visible seed at t0."""
    topo = prop.topo
    recv = prop.downlink_times(0.0, BITS, source=0)
    hop = prop.isl_hop_delay(BITS)
    for orbit in range(topo.constellation.num_orbits):
        sats = topo.orbit_sats(orbit)
        rs = recv[sats]
        if not np.isfinite(rs).all():
            continue
        # max spread within an orbit <= (N/2 hops) * hop delay + direct spread
        assert rs.max() - rs.min() <= 4 * hop + 60.0


def test_uplink_after_done(prop):
    t_done = 1000.0
    for sat in range(0, 40, 7):
        t_arr, hap = prop.uplink(sat, t_done, BITS, sink=0)
        if np.isfinite(t_arr):
            assert t_arr > t_done
            assert 0 <= hap < prop.topo.num_ps


def test_uplink_visible_faster_than_invisible(prop):
    """Satellites visible at t_done upload sooner (no waiting)."""
    tl = prop.topo.timeline
    t = 0.0
    vis = tl.visible(t).any(axis=1)
    if vis.any() and (~vis).any():
        t_vis, _ = prop.uplink(int(np.flatnonzero(vis)[0]), t, BITS, 0)
        # the visible satellite's arrival is prompt (< 10 min)
        assert t_vis - t < 600.0


def test_hap_receive_times_ring(prop):
    ht = prop.hap_receive_times(0.0, BITS, source=0)
    assert ht[0] == 0.0
    if len(ht) > 1:
        assert (ht[1:] > 0).all()


def _four_hap_prop():
    from repro.core.constellation import GroundNode
    c = paper_constellation()
    nodes = [GroundNode(f"HAP-{i}", 20.0 + 10.0 * i, -100.0 + 25.0 * i,
                        20e3, kind="hap") for i in range(4)]
    tl = VisibilityTimeline(c, nodes, 43200.0, 10.0)
    topo = RingOfStars(c, nodes, tl)
    return PropagationModel(topo, LinkModel())


def test_hap_receive_times_multi_hop_accumulates_ring_pairs():
    """Regression: a HAP k hops away accumulates the delays of the k
    successive ring pairs on the walked path, not k x the endpoint-pair
    delay (hand-computed for a 4-HAP ring)."""
    prop = _four_hap_prop()
    link, topo = prop.link, prop.topo
    ht = prop.hap_receive_times(0.0, BITS, source=0)

    # one hop: 0 -> 1 and 0 -> 3 (both directions of the ring)
    assert ht[1] == pytest.approx(link.total_delay(BITS,
                                                   topo.ihl_distance(0, 1, 0.0)))
    assert ht[3] == pytest.approx(link.total_delay(BITS,
                                                   topo.ihl_distance(0, 3, 0.0)))
    # two hops: 0 -> 1 -> 2, second hop evaluated at the first's arrival
    d1 = link.total_delay(BITS, topo.ihl_distance(0, 1, 0.0))
    d2 = link.total_delay(BITS, topo.ihl_distance(1, 2, d1))
    assert ht[2] == pytest.approx(d1 + d2)
    # the old bug doubled the direct 0->2 delay instead
    wrong = 2 * link.total_delay(BITS, topo.ihl_distance(0, 2, 0.0))
    assert ht[2] != pytest.approx(wrong)


def test_ring_path_shorter_arc():
    prop = _four_hap_prop()
    assert prop.topo.ring_path(0, 2) == [0, 1, 2]
    assert prop.topo.ring_path(0, 3) == [0, 3]
    assert prop.topo.ring_path(3, 1) == [3, 0, 1]
    assert prop.topo.ring_path(2, 2) == [2]


def _uplink_reference(prop, sat, t_done, bits, sink):
    """Independent per-satellite reimplementation of the Alg. 1 uplink
    rules (direct / relay / wait + HAP ring walk), for parity against the
    vectorized ``uplink_many``."""
    topo = prop.topo
    tl = topo.timeline
    hop = prop.isl_hop_delay(bits)

    def to_sink(t_at, h):
        H = topo.num_ps
        fwd = (sink - h) % H
        step = 1 if fwd <= H - fwd else -1
        cur, t = h, t_at
        while cur != sink:
            nxt = (cur + step) % H
            t += prop.link.total_delay(bits, topo.ihl_distance(cur, nxt, t))
            cur = nxt
        return t

    vis = topo.visible_ps_of(sat, t_done)
    if vis:
        h = vis[0]
        return to_sink(t_done + prop.sat_ps_delay(bits, sat, h, t_done), h), h
    sats = topo.orbit_sats(topo.constellation.orbit_of(sat))
    now_vis = [s for s in sats if topo.visible_ps_of(s, t_done)]
    if now_vis:
        s_star = min(now_vis, key=lambda s: topo.isl_ring_distance(sat, s))
        t_arrive = t_done + topo.isl_ring_distance(sat, s_star) * hop
        h = topo.visible_ps_of(s_star, t_done)[0]
        return to_sink(t_arrive
                       + prop.sat_ps_delay(bits, s_star, h, t_arrive), h), h
    t_vis, s_star = tl.next_orbit_visible(sats, t_done)
    if t_vis is None:
        return np.inf, -1
    t_ready = max(t_done + topo.isl_ring_distance(sat, s_star) * hop, t_vis)
    vis2 = topo.visible_ps_of(s_star, t_vis)
    h = vis2[0] if vis2 else 0
    return to_sink(t_ready + prop.sat_ps_delay(bits, s_star, h, t_ready), h), h


def test_uplink_many_matches_loop_reference(prop):
    sats = np.arange(0, 40, 3)
    t_done = 600.0 + 120.0 * np.arange(len(sats))
    out, haps = prop.uplink_many(sats, t_done, BITS, sink=0)
    for i, s in enumerate(sats):
        t_ref, h_ref = _uplink_reference(prop, int(s), float(t_done[i]),
                                         BITS, 0)
        if np.isfinite(t_ref):
            assert out[i] == pytest.approx(t_ref)
            assert haps[i] == h_ref
        else:
            assert not np.isfinite(out[i])


def test_uplink_many_matches_reference_four_haps():
    """Multi-hop sink relay: 4-HAP ring exercises ring walks of length 2."""
    prop = _four_hap_prop()
    sats = np.arange(0, 40, 5)
    t_done = np.full(len(sats), 900.0)
    out, haps = prop.uplink_many(sats, t_done, BITS, sink=2)
    for i, s in enumerate(sats):
        t_ref, h_ref = _uplink_reference(prop, int(s), 900.0, BITS, 2)
        if np.isfinite(t_ref):
            assert out[i] == pytest.approx(t_ref)
            assert haps[i] == h_ref


def test_downlink_times_matches_loop_reference(prop):
    """The vectorized min-plus relay equals a brute-force per-satellite
    reference implementing Alg. 1 directly."""
    topo = prop.topo
    recv = prop.downlink_times(0.0, BITS, source=0)
    hap_t = prop.hap_receive_times(0.0, BITS, source=0)
    S = topo.constellation.num_sats
    ref = np.full(S, np.inf)
    for h in range(topo.num_ps):
        for sat in topo.star_members(h, hap_t[h]):
            cand = hap_t[h] + prop.sat_ps_delay(BITS, sat, h, hap_t[h])
            ref[sat] = min(ref[sat], cand)
    hop = prop.isl_hop_delay(BITS)
    for orbit in range(topo.constellation.num_orbits):
        sats = topo.orbit_sats(orbit)
        seeds = [s for s in sats if np.isfinite(ref[s])]
        if not seeds:
            continue                     # fallback branch covered elsewhere
        for sat in sats:
            best = ref[sat]
            for seed in seeds:
                best = min(best, ref[seed]
                           + topo.isl_ring_distance(seed, sat) * hop)
            ref[sat] = best
    finite = np.isfinite(ref)
    np.testing.assert_allclose(recv[finite], ref[finite], rtol=1e-9)


def test_next_visible_after_matches_scalar(prop):
    tl = prop.topo.timeline
    sats = np.arange(0, 40, 5)
    t = 1000.0 + 500.0 * np.arange(len(sats))
    times, ps = tl.next_visible_after(sats, t)
    for i, s in enumerate(sats):
        tv = tl.next_visible_time(int(s), float(t[i]))
        if tv is None:
            assert not np.isfinite(times[i])
        else:
            assert times[i] == pytest.approx(tv)
