"""``shard_map`` across jax versions.

The top-level ``jax.shard_map`` (with its ``check_vma`` kwarg) landed after
the 0.4.x series; on older jax the same transform lives at
``jax.experimental.shard_map.shard_map`` with the kwarg spelled
``check_rep``.  Every shard_map call site in this repo goes through this
wrapper so the code runs on both.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
