"""Fig. 7: AsyncFLEO on MNIST-like data across settings —
IID/non-IID x CNN/MLP x GS/HAP/two-HAP.  Emits accuracy-vs-time curves."""
from __future__ import annotations

from benchmarks.common import make_setup, run_strategy
from repro.benchmarks_io import emit

FULL_SETTINGS = [(iid, model, scen)
                 for iid in (True, False)
                 for model in ("cnn", "mlp")
                 for scen in ("asyncfleo-gs", "asyncfleo-hap",
                              "asyncfleo-twohap")]

QUICK_SETTINGS = [
    (True, "cnn", "asyncfleo-hap"),
    (False, "cnn", "asyncfleo-hap"),
    (False, "mlp", "asyncfleo-hap"),
]


def run(dataset: str = "mnist", quick: bool = True, max_epochs: int = 12):
    settings = QUICK_SETTINGS if quick else FULL_SETTINGS
    rows, curves = [], []
    cache = {}
    for iid, model, scen in settings:
        key = (iid, model)
        if key not in cache:
            cache[key] = make_setup(dataset, model, iid=iid)
        pool, ev, w0 = cache[key]
        res = run_strategy(scen, pool, ev, w0, max_epochs=max_epochs)
        rows.append({"iid": iid, "model": model, "scheme": scen,
                     "best_acc": round(res["best_acc"], 4),
                     "final_time_h": round(res["final_time_h"], 2)})
        for r in res["history"]:
            curves.append((f"{'iid' if iid else 'noniid'}-{model}-{scen}",
                           r.epoch, round(r.time_s / 3600, 3),
                           round(r.accuracy, 4)))
    return {"rows": rows, "curves": curves, "dataset": dataset}


def main(dataset="mnist", quick=True):
    out = run(dataset, quick=quick)
    print("iid,model,scheme,best_acc,final_time_h")
    for r in out["rows"]:
        print(f"{r['iid']},{r['model']},{r['scheme']},{r['best_acc']},"
              f"{r['final_time_h']}")
    emit(f"fig7_{dataset}" if dataset == "mnist" else f"fig8_{dataset}", out)
    return out


if __name__ == "__main__":
    main(quick=False)
