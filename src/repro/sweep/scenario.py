"""Scenario specifications and the grid/draw compiler (DESIGN.md §13).

A *scenario* is one full federated-learning simulation: a seed, a
constellation geometry, a link rate, a trigger policy (via the strategy
table) and a staleness function, plus the simulation horizon knobs.  The
sweep engine (`sweep/driver.py`) runs *batches* of scenarios with their
fused epoch dispatches multiplexed into shared device programs
(`sweep/batch.py`), so a Monte-Carlo sweep of hundreds of configs costs a
handful of batched dispatches instead of hundreds of sequential runs.

Two compilers produce scenario batches:

* ``grid(**axes)`` — the cartesian product of explicit axis values
  (deterministic order: axes sorted by name, rightmost axis fastest);
* ``draw(n, axes, seed)`` — ``n`` independent draws, one value per axis
  per scenario, from a seeded ``numpy`` Generator (reproducible; the
  draw spec is what benchmark rows record).

Every axis must name a ``ScenarioSpec`` field; unknown axes raise at
compile time, not at run time inside a worker thread.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One simulation configuration, batchable alongside others.

    Geometry fields default to ``None`` = the paper constellation
    (``core.constellation.paper_constellation``); setting ``num_orbits``
    and ``sats_per_orbit`` builds an explicit WalkerDelta shell instead.
    ``strategy`` picks the trigger policy / aggregation rule from the
    ``fl.strategies`` table; ``staleness_fn`` / ``ps_channels`` /
    ``max_in_flight`` override that spec's fields when not None.
    """
    seed: int = 0
    strategy: str = "asyncfleo-gs"
    # geometry (None, None -> paper constellation)
    num_orbits: Optional[int] = None
    sats_per_orbit: Optional[int] = None
    altitude_m: float = 2000e3
    inclination_deg: float = 80.0
    # link + policy knobs
    rate_bps: float = 16e6
    staleness_fn: Optional[str] = None
    ps_channels: Optional[int] = None
    max_in_flight: Optional[int] = None
    # horizon
    duration_s: float = 86400.0
    dt_s: float = 60.0
    train_time_s: float = 300.0
    agg_timeout_s: float = 1500.0

    def geometry_key(self) -> tuple:
        """Hashable geometry identity (constellation cache key)."""
        return (self.num_orbits, self.sats_per_orbit, self.altitude_m,
                self.inclination_deg, self.duration_s, self.dt_s)


_FIELDS = {f.name for f in dataclasses.fields(ScenarioSpec)}


def _check_axes(axes: Dict[str, Sequence]) -> None:
    unknown = sorted(set(axes) - _FIELDS)
    if unknown:
        raise ValueError(f"unknown scenario axes {unknown}; "
                         f"valid fields: {sorted(_FIELDS)}")
    for name, vals in axes.items():
        if not len(list(vals)):
            raise ValueError(f"scenario axis {name!r} has no values")


def grid(base: Optional[ScenarioSpec] = None, **axes) -> List[ScenarioSpec]:
    """Cartesian product of axis values over ``base`` (axes sorted by
    name; the last-sorted axis varies fastest — deterministic order)."""
    _check_axes(axes)
    base = base or ScenarioSpec()
    names = sorted(axes)
    out = []
    for combo in itertools.product(*(list(axes[n]) for n in names)):
        out.append(dataclasses.replace(base, **dict(zip(names, combo))))
    return out


def draw(n: int, axes: Dict[str, Sequence], seed: int = 0,
         base: Optional[ScenarioSpec] = None) -> List[ScenarioSpec]:
    """``n`` scenarios with each axis drawn independently and uniformly
    from its value list by a seeded generator — the Monte-Carlo
    counterpart of :func:`grid`.  Same (axes, seed, n) -> same batch."""
    _check_axes(axes)
    if n <= 0:
        raise ValueError("draw needs n >= 1")
    base = base or ScenarioSpec()
    rng = np.random.default_rng(seed)
    names = sorted(axes)
    out = []
    for _ in range(n):
        picks = {name: list(axes[name])[int(rng.integers(len(list(axes[name]))))]
                 for name in names}
        out.append(dataclasses.replace(base, **picks))
    return out


def draw_spec(axes: Dict[str, Sequence], seed: int, n: int) -> Dict:
    """JSON-serializable record of a draw (what bench rows store)."""
    return {"kind": "draw", "n": int(n), "seed": int(seed),
            "axes": {k: list(v) for k, v in sorted(axes.items())}}
