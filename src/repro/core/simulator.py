"""Discrete-event FL simulation over LEO trajectories (paper §V).

The simulator advances *simulated* time (seconds over a 3-day horizon) while
running *real* JAX training for every satellite's local model.  Per global
epoch beta:

  1. downlink  — Alg. 1 timing gives each satellite its receive time of
     w^beta (ring-of-stars + ISL relay for strategies that have ISL; plain
     next-visibility otherwise);
  2. train     — each satellite trains for J local iterations (real SGD),
     finishing ``train_time_s`` later in simulated time;
  3. uplink    — arrival time of each local model at the sink PS;
  4. aggregate — strategy-dependent trigger and rule (AsyncFLEO grouping +
     staleness discounting; FedAvg barrier; per-arrival; fixed interval);
  5. evaluate  — test accuracy of the new global model at the trigger time.

The output is a history of (sim_time_s, epoch, accuracy, ...) rows, from
which convergence time (time to reach a target accuracy) is read — the
paper's Table II / Fig. 6 quantities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import SatelliteMeta
from repro.core.constellation import (WalkerDelta, make_ps_nodes,
                                      paper_constellation)
from repro.core.grouping import GroupingState
from repro.core.links import LinkModel, model_bits
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline
from repro.fl.strategies import StrategySpec


@dataclasses.dataclass
class SimConfig:
    duration_s: float = 3 * 86400.0
    dt_s: float = 10.0
    train_time_s: float = 600.0        # on-board local-training wall time
    agg_timeout_s: float = 1500.0      # async collection window per epoch
    min_models: int = 2                # never aggregate on fewer arrivals
    eval_fn: Optional[object] = None   # params -> accuracy
    seed: int = 0
    sync_stall_s: float = 86400.0      # cap a sync round at this (stragglers)
    link: Optional[LinkModel] = None   # None -> paper Table I RF (16 Mb/s)


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    time_s: float
    accuracy: float
    num_models: int
    gamma: float
    stale_groups: int


class FLSimulation:
    def __init__(self, spec: StrategySpec, trainer, evaluator,
                 sim: SimConfig, constellation: Optional[WalkerDelta] = None):
        self.spec = spec
        self.trainer = trainer
        self.evaluator = evaluator
        self.sim = sim
        self.constellation = constellation or paper_constellation()
        self.nodes = make_ps_nodes(spec.ps_scenario)
        self.timeline = VisibilityTimeline(self.constellation, self.nodes,
                                           sim.duration_s, sim.dt_s)
        self.topo = RingOfStars(self.constellation, self.nodes, self.timeline)
        self.prop = PropagationModel(self.topo, sim.link or LinkModel())
        self.grouping = GroupingState(num_groups=spec.num_groups)
        self.orbit_ids = self.constellation.orbit_ids()
        # persistent per-satellite bookkeeping
        self.last_epoch_included: Dict[int, int] = {}
        self.pending: List[tuple] = []    # (arrival_t, sat, params, trained_from_epoch)

    # ------------------------------------------------------------------

    def _downlink(self, t0: float, bits: float, source: int) -> np.ndarray:
        if self.spec.use_isl:
            return self.prop.downlink_times(t0, bits, source)
        # no ISL: each satellite waits for direct visibility
        S = self.constellation.num_sats
        recv = np.full(S, np.inf)
        for s in range(S):
            tv = self.timeline.next_visible_time(s, t0)
            if tv is not None:
                ps = self.topo.visible_ps_of(s, tv)
                h = ps[0] if ps else 0
                recv[s] = tv + self.prop.sat_ps_delay(bits, s, h, tv)
        return recv

    def _uplink(self, sat: int, t_done: float, bits: float, sink: int):
        if self.spec.use_isl:
            return self.prop.uplink(sat, t_done, bits, sink)
        tv = self.timeline.next_visible_time(sat, t_done)
        if tv is None:
            return np.inf, -1
        ps = self.topo.visible_ps_of(sat, tv)
        h = ps[0] if ps else 0
        return tv + self.prop.sat_ps_delay(bits, sat, h, tv), h

    # ------------------------------------------------------------------

    def run(self, w0, max_epochs: int = 30,
            target_accuracy: Optional[float] = None) -> List[EpochRecord]:
        sim, spec = self.sim, self.spec
        bits = model_bits(w0)
        self.grouping.set_reference(w0)
        w = w0
        t = 0.0
        source = 0
        history: List[EpochRecord] = []
        S = self.constellation.num_sats

        for beta in range(max_epochs):
            if t >= sim.duration_s:
                break
            sink = self.topo.sink_of(source)
            recv = self._downlink(t, bits, source)

            # local training (real JAX, one batched call) + uplink timing
            participants = [s for s in range(S) if np.isfinite(recv[s])]
            trained, _losses = (self.trainer.train_many(
                participants, w, seed=sim.seed * 1000 + beta)
                if participants else ([], []))
            arrivals = []                       # (t_arr, sat, params)
            for s, params_s in zip(participants, trained):
                t_done = recv[s] + sim.train_time_s
                t_arr, _hap = self._uplink(s, t_done, bits, sink)
                if np.isfinite(t_arr):
                    arrivals.append((t_arr, s, params_s))
            arrivals.sort(key=lambda a: a[0])
            if not arrivals and not self.pending:
                break

            # ---- aggregation trigger --------------------------------------
            if spec.sync:
                t_agg = min(arrivals[-1][0] if arrivals else t,
                            t + sim.sync_stall_s)
                used = [a for a in arrivals if a[0] <= t_agg]
                late = [a for a in arrivals if a[0] > t_agg]
            else:
                t_first = arrivals[0][0] if arrivals else t
                t_agg = min(t_first + sim.agg_timeout_s, sim.duration_s)
                used = [a for a in arrivals if a[0] <= t_agg]
                if len(used) < sim.min_models:
                    used = arrivals[: sim.min_models]
                    t_agg = used[-1][0] if used else t_agg
                late = [a for a in arrivals if a[0] > t_agg]

            # models stuck from previous epochs arrive as stale candidates
            carried = [(ta, s, p, ep) for (ta, s, p, ep) in self.pending
                       if ta <= t_agg]
            self.pending = [x for x in self.pending if x[0] > t_agg]
            self.pending.extend((ta, s, p, beta) for (ta, s, p) in late)

            models, metas = [], []
            for (ta, s, p) in used:
                models.append(p)
                metas.append(SatelliteMeta(s, self.trainer.data_size(s),
                                           loc=(0.0, 0.0), ts=ta, epoch=beta))
            for (ta, s, p, ep) in carried:
                models.append(p)
                metas.append(SatelliteMeta(s, self.trainer.data_size(s),
                                           loc=(0.0, 0.0), ts=ta, epoch=ep))
            models, metas = agg.dedup(models, metas)

            # ---- aggregate -------------------------------------------------
            info = {"gamma": 1.0, "stale_groups": 0}
            if spec.agg_mode == "fedavg":
                w = agg.fedavg(models, [m.size for m in metas],
                               use_kernel=spec.use_agg_kernel)
            elif spec.agg_mode == "per_arrival":
                for m_i, meta in zip(models, metas):
                    alpha = 0.5 / (1.0 + max(beta - meta.epoch, 0))
                    w = agg.weighted_sum([m_i], [alpha], base=w,
                                         base_weight=1.0 - alpha)
            elif spec.agg_mode == "interval":
                total = sum(m.size for m in metas)
                raw = np.array([m.size * (1.0 / (1.0 + max(beta - m.epoch, 0)))
                                for m in metas])
                gam = float(np.clip(raw.sum() / max(total, 1e-9), 0.2, 1.0))
                w = agg.weighted_sum(models, gam * raw / raw.sum(), base=w,
                                     base_weight=1.0 - gam)
                t_agg = max(t_agg, t + spec.interval_s)
                info["gamma"] = gam
            else:                                        # asyncfleo (Alg. 2)
                groups: Dict[int, List[int]] = {}
                if not spec.grouping:                    # ablation: one group
                    groups[0] = list(range(len(metas)))
                else:
                    for i, meta in enumerate(metas):
                        orbit = int(self.orbit_ids[meta.sat_id])
                        same_orbit = [j for j, mm in enumerate(metas)
                                      if int(self.orbit_ids[mm.sat_id]) == orbit]
                        gi = self.grouping.observe_orbit(
                            orbit, [models[j] for j in same_orbit],
                            [metas[j].size for j in same_orbit])
                        groups.setdefault(gi, [])
                        if i not in groups[gi]:
                            groups[gi].append(i)
                w, info = agg.asyncfleo_aggregate(
                    w, groups, models, metas, beta,
                    strict_paper_eq14=spec.strict_paper_eq14,
                    use_kernel=spec.use_agg_kernel)

            for meta in metas:
                self.last_epoch_included[meta.sat_id] = beta

            acc = float(self.evaluator(w)) if self.evaluator else float("nan")
            history.append(EpochRecord(beta, t_agg, acc, len(models),
                                       float(info.get("gamma", 1.0)),
                                       int(info.get("stale_groups", 0))))
            t = t_agg
            source, sink = sink, source            # §IV-B3 role swap
            if target_accuracy is not None and acc >= target_accuracy:
                break
        return history


def convergence_time(history: List[EpochRecord], target: float) -> Optional[float]:
    for rec in history:
        if rec.accuracy >= target:
            return rec.time_s
    return None
