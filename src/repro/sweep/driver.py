"""The batched sweep driver: N scenarios, a handful of device programs.

``run_scenarios(specs)`` builds one full simulation per
:class:`~repro.sweep.scenario.ScenarioSpec` (strategy table lookup +
overrides, WalkerDelta geometry or the paper constellation, LinkModel at
the swept rate, seeded SimConfig), then runs them either

* **sequentially** (``batched=False``) — the exact pre-existing
  event-driven runtime path, one scenario after another; or
* **batched** (the default) — every scenario's runtime on its own worker
  thread with all fused epoch dispatches multiplexed through one shared
  :class:`~repro.sweep.batch.DispatchBatcher` on the calling thread.

The two paths are bit-identical per scenario (histories, weights,
logical dispatch counts) under ``mode="exact"`` — the differential
contract ``tests/test_sweep.py`` pins.  Results come back in spec order.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import FLSimulation, SimConfig, convergence_time
from repro.core.constellation import WalkerDelta
from repro.core.links import LinkModel
from repro.fl.strategies import get_strategy
from repro.sched import EventDrivenRuntime
from repro.sweep.batch import DispatchBatcher
from repro.sweep.scenario import ScenarioSpec
from repro.sweep.testbed import (ConvergingTrainer, MeanDistanceEvaluator,
                                 make_model)


@dataclasses.dataclass
class ScenarioResult:
    spec: ScenarioSpec
    history: list                       # EpochRecord rows
    final_weights: np.ndarray           # forced flat weights
    dispatches: int                     # logical fused dispatches
    fallback_dispatches: int
    convergence_delay_s: Optional[float]
    final_accuracy: Optional[float]
    epochs: int
    stats: Dict


def _build(spec: ScenarioSpec, w0, trainer, evaluator, dispatcher,
           const_cache: Dict):
    strat = get_strategy(spec.strategy)
    kw = {}
    if spec.staleness_fn is not None:
        kw["staleness_fn"] = spec.staleness_fn
    if spec.ps_channels is not None:
        kw["ps_channels"] = spec.ps_channels
    if spec.max_in_flight is not None:
        kw["max_in_flight"] = spec.max_in_flight
    if kw:
        strat = dataclasses.replace(strat, **kw)
    const = None
    if spec.num_orbits is not None:
        gkey = spec.geometry_key()
        const = const_cache.get(gkey)
        if const is None:
            const = const_cache[gkey] = WalkerDelta(
                num_orbits=spec.num_orbits,
                sats_per_orbit=spec.sats_per_orbit or 8,
                altitude_m=spec.altitude_m,
                inclination_deg=spec.inclination_deg)
    sim = SimConfig(duration_s=spec.duration_s, dt_s=spec.dt_s,
                    train_time_s=spec.train_time_s,
                    agg_timeout_s=spec.agg_timeout_s, seed=spec.seed,
                    link=LinkModel(rate_bps=spec.rate_bps),
                    event_driven=True, dispatcher=dispatcher)
    fls = FLSimulation(strat, trainer, evaluator, sim, constellation=const)
    return fls, EventDrivenRuntime(fls)


def run_scenarios(specs: Sequence[ScenarioSpec], w0=None, *,
                  batched: bool = True, mode: str = "exact",
                  max_epochs: int = 30,
                  target_accuracy: Optional[float] = None,
                  trainer_factory: Optional[Callable] = None,
                  evaluator_factory: Optional[Callable] = None,
                  profiler=None,
                  batcher: Optional[DispatchBatcher] = None
                  ) -> List[ScenarioResult]:
    """Run every scenario; return :class:`ScenarioResult` in spec order.

    ``trainer_factory(w0)`` / ``evaluator_factory()`` default to ONE
    shared ``ConvergingTrainer`` / ``MeanDistanceEvaluator`` — sharing
    the (stateless) trainer shares its jitted program cache across
    scenarios, and its ``scenario_batch_key`` is what lets the batcher
    group them.  Pass ``batcher`` to inspect physical-dispatch telemetry
    after the run (``batcher.summary()``); ``profiler`` (a PR 8
    ``DispatchProfiler``) records per-physical-dispatch timing.
    """
    w0 = w0 if w0 is not None else make_model()
    if trainer_factory is None:
        shared = ConvergingTrainer(w0)
        trainer_factory = lambda _w0: shared        # noqa: E731
    if evaluator_factory is None:
        evaluator_factory = MeanDistanceEvaluator
    if batcher is None and batched:
        batcher = DispatchBatcher(mode=mode, profiler=profiler)
    const_cache: Dict = {}
    builds = [_build(s, w0, trainer_factory(w0), evaluator_factory(),
                     batcher if batched else None, const_cache)
              for s in specs]
    # pre-warm the shared program cache on this thread so concurrent
    # _init_run calls hit the cache instead of racing to populate it
    from repro.core.epoch_step import make_epoch_program
    for fls, _rt in builds:
        make_epoch_program(fls.trainer, w0, mesh=fls.sim.mesh,
                           use_kernel=fls.spec.use_agg_kernel)

    histories: List = [None] * len(specs)
    errors: List = [None] * len(specs)
    counts: List = [None] * len(specs)  # sequential per-scenario deltas

    def _finish(i: int) -> ScenarioResult:
        fls, rt = builds[i]
        hist = histories[i] or []
        conv = (convergence_time(hist, target_accuracy)
                if target_accuracy is not None else None)
        if counts[i] is not None:
            disp, fb = counts[i]
        else:                           # batched: the proxy counts
            prog = fls._fused_prog      # per-scenario logical dispatches
            disp = int(getattr(prog, "dispatches", 0))
            fb = int(getattr(prog, "fallback_dispatches", 0))
        return ScenarioResult(
            spec=specs[i], history=hist,
            final_weights=np.asarray(fls._w_flat),
            dispatches=disp, fallback_dispatches=fb,
            convergence_delay_s=conv,
            final_accuracy=(float(hist[-1].accuracy) if hist else None),
            epochs=len(hist), stats=dict(rt.stats))

    if not batched:
        # a shared trainer shares one program (and its counters) across
        # scenarios, so per-scenario dispatch counts are deltas
        for i, (fls, rt) in enumerate(builds):
            prog = make_epoch_program(fls.trainer, w0, mesh=fls.sim.mesh,
                                      use_kernel=fls.spec.use_agg_kernel)
            d0 = ((prog.dispatches, prog.fallback_dispatches)
                  if prog is not None else (0, 0))
            histories[i] = rt.run(w0, max_epochs=max_epochs,
                                  target_accuracy=target_accuracy)
            counts[i] = (((prog.dispatches - d0[0]),
                          (prog.fallback_dispatches - d0[1]))
                         if prog is not None else (0, 0))
        return [_finish(i) for i in range(len(specs))]

    def _worker(i: int) -> None:
        try:
            histories[i] = builds[i][1].run(
                w0, max_epochs=max_epochs,
                target_accuracy=target_accuracy)
        except BaseException as e:      # surfaced after drain
            errors[i] = e
        finally:
            batcher.finish()

    threads = []
    for i in range(len(specs)):
        batcher.register()
        t = threading.Thread(target=_worker, args=(i,),
                             name=f"scenario-{i}", daemon=True)
        threads.append(t)
    for t in threads:
        t.start()
    batcher.drain()
    for t in threads:
        t.join()
    for i, err in enumerate(errors):
        if err is not None:
            raise RuntimeError(
                f"scenario {i} ({specs[i]!r}) failed") from err
    return [_finish(i) for i in range(len(specs))]
