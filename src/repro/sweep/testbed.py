"""The deterministic fused-protocol trainer/evaluator the scheduler
benchmarks and the sweep engine share.

These lived in ``benchmarks/sched_bench.py`` since PR 3; the sweep
engine needs them importable (``repro.sweep.testbed``), and the batched
driver needs the trainer to declare a ``scenario_batch_key`` — the
equivalence class under which different scenarios' epoch dispatches may
share one physical program.  Two trainers with equal keys MUST run
identical device math (same ``epoch_train_fn`` graph for the same
inputs); the DispatchBatcher executes a whole group through one of their
programs.  Trainers without the attribute (key ``None``) always run
solo — correct, just unbatched.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.modelbank import FlatSpec, flatten_tree


def make_model(key_seed: int = 0, width: int = 64):
    rng = np.random.default_rng(key_seed)
    return {
        "w1": rng.standard_normal((width, width)).astype(np.float32) * 0.0,
        "w2": rng.standard_normal((width, width)).astype(np.float32) * 0.0,
        "b": np.zeros((width,), np.float32),
    }


class ConvergingTrainer:
    """Deterministic fused-protocol trainer: every local step moves the
    model halfway toward the all-ones optimum (plus a zero-mean per-sat
    perturbation), so accuracy-vs-epoch is identical across policies and
    the measured difference is PURE scheduling delay."""

    def __init__(self, w0, rate: float = 0.5, jitter: float = 1e-3):
        self.spec = FlatSpec.of(w0)
        self._rate = rate
        self._jitter = jitter
        # scenarios whose trainers share this key run identical device
        # math, so their epoch dispatches may be batched together
        self.scenario_batch_key = ("converging", float(rate), float(jitter))

    def data_size(self, sat: int) -> int:
        return 100 + (sat % 7) * 10

    def epoch_inputs(self, ids_np):
        return None

    def epoch_train_fn(self):
        rate, jitter = self._rate, self._jitter

        def _fn(params, inputs, ids, seed):
            flat = flatten_tree(params)
            # zero-mean per-(sat, seed) jitter: cancels in aggregation up
            # to weighting differences, so policies stay comparable
            phase = ((ids * 37 + seed.astype(jnp.int32)) % 13
                     - 6).astype(jnp.float32) * jitter
            stack = (flat[None, :] * (1.0 - rate) + rate
                     + phase[:, None])
            return stack, jnp.zeros(ids.shape[0])
        return _fn

    def train_many_stacked(self, sats, params, seed):   # stacked protocol
        from repro.core.modelbank import ModelBank, pad_bucket_ids
        ids, n = pad_bucket_ids(list(sats))
        fn = self.epoch_train_fn()
        stack, _ = fn(params, None, jnp.asarray(ids),
                      jnp.uint32(np.uint32(seed)))
        return ModelBank(self.spec, stack[:n]), np.zeros(n)


class MeanDistanceEvaluator:
    """acc = 1 - mean|w - 1| (clipped): 0 at w0 = zeros, 1 at the optimum."""

    def __call__(self, params) -> float:
        flat = np.asarray(flatten_tree(params))
        return 1.0 - min(1.0, float(np.mean(np.abs(flat - 1.0))))
