"""Discrete-event primitives for the async FL runtime (DESIGN.md §7-§8).

Seven event kinds drive a federated round (FLGo's ``system_simulator``
separates virtual-clock state the same way):

* ``TRAIN_DONE``     — a satellite finished its J local iterations;
* ``MODEL_ARRIVAL``  — a local model reached the sink PS (after the
  uplink relay chain);
* ``TRIGGER_TIMEOUT``— a policy-scheduled aggregation deadline fired
  (AsyncFLEO's idle timeout, the sync barrier's straggler stall, a
  per-divergence-group deadline — DESIGN.md §8);
* ``SINK_HANDOFF``   — open the next round.  Pushed when a round closes
  (PS roles swap, §IV-B3) and, in pipelined mode, *speculatively* while
  a round is still in flight (``pipelined=True``) so up to
  ``max_in_flight`` rounds overlap (DESIGN.md §8);
* ``TRANSFER_FAILED``— a sat->PS model transfer was lost in flight
  (FaultModel Bernoulli draw, DESIGN.md §10).  Fires at the would-be
  arrival instant; the handler re-times the retransmission with
  exponential backoff through the contact plan (a fresh rx-channel
  grant) up to ``FaultModel.max_retries`` attempts, then drops the
  update.  ``attempt`` counts the failures so far in the chain;
* ``PS_DOWN`` / ``PS_UP`` — a parameter server enters / leaves a
  FaultModel outage window (DESIGN.md §11).  ``ps`` names the server;
  ``round_idx`` is -1 (outages are not addressed to a round).  PS_DOWN
  triggers ring failover of every open round sunk at the dead PS; the
  schedule itself is queried purely (``OutageSchedule``), so PS_UP is
  telemetry plus a wake-up point for deferred work.

Every event carries the ``round_idx`` it is addressed to, so with
several rounds in flight a ``MODEL_ARRIVAL`` always commits into the
round that scheduled it; arrivals addressed to an already-closed round
are ignored here and reach the successor round through the simulator's
carried-straggler set instead (§8 late-arrival semantics).

``EventQueue`` is a plain binary heap keyed on (time, sequence) — the
sequence number makes same-instant pops deterministic (FIFO), which the
runtime-vs-epoch-loop parity tests rely on.  Events are immutable;
handlers look up mutable round state on the runtime by ``round_idx``.

**Batched pops** (DESIGN.md §14): ``pop_batch`` drains the maximal FIFO
run of events sharing (time, kind, round_idx) at the heap top — the
shape a mega-constellation trigger produces (10^4 MODEL_ARRIVALs in one
dt-slice) — so the runtime touches Python round state once per run, not
once per satellite.  Batching is bit-exact by construction: any event a
run member's handler pushes has time >= t and a sequence number greater
than every remaining run member's (those were pushed earlier), so it
can never pop before the rest of the run; and since pops don't consume
sequence numbers, every push gets the same sequence number it would
have gotten one-at-a-time.  Histories are therefore identical to the
unbatched loop (the tier-1 parity pins).
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Dict, List, Optional


class EventKind(enum.IntEnum):
    TRAIN_DONE = 0
    MODEL_ARRIVAL = 1
    TRIGGER_TIMEOUT = 2
    SINK_HANDOFF = 3
    TRANSFER_FAILED = 4
    PS_DOWN = 5
    PS_UP = 6


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence.  ``sat`` / ``row`` are payload for the
    training/arrival kinds (``row`` is the satellite's row in the round's
    padded training bank); -1 where not applicable.  ``pipelined`` marks
    a speculative ``SINK_HANDOFF`` that tries to extend the pipeline
    while its round is still in flight — the handler drops it when the
    pipeline is already at ``max_in_flight`` (DESIGN.md §8)."""
    time: float
    kind: EventKind
    round_idx: int
    sat: int = -1
    row: int = -1
    pipelined: bool = False
    # failed attempts so far in a lossy-transfer retry chain: attempt=k
    # on MODEL_ARRIVAL / TRANSFER_FAILED means this is retransmission k
    attempt: int = 0
    # the PS this event is addressed to: the outage server on
    # PS_DOWN/PS_UP, the sink the arrival was *timed against* on
    # MODEL_ARRIVAL/TRANSFER_FAILED (so a pop can detect "timed to a
    # now-dead sink" and reroute, DESIGN.md §11); -1 where not applicable
    ps: int = -1

    def __post_init__(self):
        assert self.time == self.time, "event time must not be NaN"


class EventQueue:
    """Min-heap of events ordered by (time, push sequence)."""

    def __init__(self):
        self._heap: List = []
        self._seq = 0
        self.counts: Dict[str, int] = {k.name: 0 for k in EventKind}

    def push(self, ev: Event) -> None:
        self.counts[ev.kind.name] += 1
        heapq.heappush(self._heap, (ev.time, self._seq, ev))
        self._seq += 1

    def push_many(self, evs: List[Event]) -> None:
        """Bulk push preserving per-event FIFO order: event i of ``evs``
        gets the exact sequence number it would get from ``push`` calls
        in the same order."""
        for ev in evs:
            self.push(ev)

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def pop_batch(self) -> List[Event]:
        """Pop the maximal run of events sharing (time, kind, round_idx)
        with the heap top, in FIFO (sequence) order.  Always returns at
        least one event; a single-element list degrades to ``pop``."""
        t0, _seq, ev = heapq.heappop(self._heap)
        out = [ev]
        heap = self._heap
        while heap and heap[0][0] == t0:
            nxt = heap[0][2]
            if nxt.kind != ev.kind or nxt.round_idx != ev.round_idx:
                break
            out.append(heapq.heappop(heap)[2])
        return out

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
