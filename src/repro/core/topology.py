"""Ring-of-stars topology (paper §IV-A).

HAP layer: the HAPs form a ring (each talks to its two neighbors via IHL);
one is *source*, one *sink* (roles swap every global epoch).  Each HAP also
runs a star with its currently visible satellites.  SAT layer: satellites of
one orbit form an ISL ring (adjacent neighbors only — cross-orbit links are
excluded because of Doppler, §IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.constellation import GroundNode, WalkerDelta
from repro.core.visibility import VisibilityTimeline


@dataclasses.dataclass
class RingOfStars:
    constellation: WalkerDelta
    nodes: List[GroundNode]
    timeline: VisibilityTimeline

    # ---- HAP ring ----------------------------------------------------------

    @property
    def num_ps(self) -> int:
        return len(self.nodes)

    def ring_hops(self, src: int, dst: int) -> int:
        """Hops along the HAP ring from src to dst (shorter direction —
        the relay floods both ways)."""
        H = self.num_ps
        d = abs(dst - src)
        return min(d, H - d)

    def sink_of(self, source: int) -> int:
        """Sink = HAP farthest from the source on the ring (§IV-B1)."""
        H = self.num_ps
        return (source + H // 2) % H if H > 1 else source

    def ihl_distance(self, a: int, b: int, t: float) -> float:
        return float(np.linalg.norm(self.nodes[a].position(t)
                                    - self.nodes[b].position(t)))

    # ---- stars --------------------------------------------------------------

    def star_members(self, ps: int, t: float) -> np.ndarray:
        return self.timeline.visible_sats(t, ps)

    def visible_ps_of(self, sat: int, t: float) -> List[int]:
        return list(np.flatnonzero(self.timeline.visible(t)[sat]))

    # ---- SAT-layer ISL ring --------------------------------------------------

    def orbit_sats(self, orbit: int) -> np.ndarray:
        N = self.constellation.sats_per_orbit
        return np.arange(orbit * N, (orbit + 1) * N)

    def isl_neighbors(self, sat: int) -> Tuple[int, int]:
        N = self.constellation.sats_per_orbit
        o, s = divmod(sat, N)
        return o * N + (s - 1) % N, o * N + (s + 1) % N

    def isl_ring_distance(self, a: int, b: int) -> int:
        """Hops along the intra-orbit ring (two-front relay => shorter arc).
        Satellites on different orbits are unreachable (returns a big int)."""
        N = self.constellation.sats_per_orbit
        if a // N != b // N:
            return 10 ** 9
        d = abs(a % N - b % N)
        return min(d, N - d)

    def isl_chord_m(self) -> float:
        """Distance between ring-adjacent satellites (constant for circular
        equally-spaced orbits)."""
        N = self.constellation.sats_per_orbit
        return float(2 * self.constellation.radius_m * np.sin(np.pi / N))

    def sat_ps_distance(self, sat: int, ps: int, t: float) -> float:
        sp = self.constellation.positions(t)[sat]
        return float(np.linalg.norm(sp - self.nodes[ps].position(t)))
