"""Satellite grouping by model-weight divergence (paper §IV-C1, Fig. 5).

The PS cannot see data (FL), so data-distribution similarity is inferred from
model weights: per orbit, a *partial global model* S'_o = data-size-weighted
average of that orbit's received local models; its Euclidean distance to the
*initial* global model w0 (largest divergence happens in epoch 1, giving the
sharpest differentiation) places the orbit on a 1-D axis; orbits with similar
distances form a group.  Later epochs assign new orbits to the group whose
members' mean distance is closest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np


def flatten_model(model) -> np.ndarray:
    return np.concatenate([np.asarray(l, dtype=np.float32).ravel()
                           for l in jax.tree_util.tree_leaves(model)])


def model_distance(model, ref_flat: np.ndarray) -> float:
    """|| flat(model) - flat(w0) ||_2."""
    return float(np.linalg.norm(flatten_model(model) - ref_flat))


def partial_global_model(models: Sequence, sizes: Sequence[float]):
    """Data-size-weighted average of one orbit's local models (Fig. 5a)."""
    total = float(sum(sizes))
    ws = [s / total for s in sizes]
    return jax.tree.map(
        lambda *leaves: sum(w * np.asarray(l, dtype=np.float32)
                            for w, l in zip(ws, leaves)),
        *models)


def group_by_gaps(distances: Dict[int, float], num_groups: int = 3) -> List[List[int]]:
    """1-D clustering: sort orbit distances, split at the (num_groups-1)
    largest gaps.  Deterministic; matches the paper's 'similar Euclidean
    distances are grouped together'."""
    orbits = sorted(distances, key=lambda o: distances[o])
    if len(orbits) <= num_groups:
        return [[o] for o in orbits]
    vals = np.array([distances[o] for o in orbits])
    gaps = np.diff(vals)
    cuts = np.sort(np.argsort(gaps)[::-1][: num_groups - 1])
    groups, start = [], 0
    for c in cuts:
        groups.append(orbits[start:c + 1])
        start = c + 1
    groups.append(orbits[start:])
    return groups


@dataclasses.dataclass
class GroupingState:
    """Incremental grouping maintained by the sink HAP."""
    ref_flat: Optional[np.ndarray] = None          # flat(w0)
    distances: Dict[int, float] = dataclasses.field(default_factory=dict)
    groups: List[List[int]] = dataclasses.field(default_factory=list)
    num_groups: int = 3

    def set_reference(self, w0) -> None:
        self.ref_flat = flatten_model(w0)

    def group_of(self, orbit: int) -> Optional[int]:
        for gi, g in enumerate(self.groups):
            if orbit in g:
                return gi
        return None

    def observe_orbit(self, orbit: int, models: Sequence, sizes: Sequence[float]) -> int:
        """Ingest an orbit's freshly received models; returns its group id.
        First sighting computes the partial-model distance; known orbits keep
        their stored group (paper: 'directly assigned to the associated
        group')."""
        gi = self.group_of(orbit)
        if gi is not None:
            return gi
        assert self.ref_flat is not None, "set_reference(w0) first"
        pm = partial_global_model(models, sizes)
        d = model_distance(pm, self.ref_flat)
        self.distances[orbit] = d
        if len(self.groups) < self.num_groups:
            # still building the grouping (paper: first epoch(s)) — recluster
            # over every orbit distance seen so far so early arrivals don't
            # freeze a degenerate single group.
            self.groups = group_by_gaps(self.distances, self.num_groups)
            return self.group_of(orbit)                     # type: ignore
        # grouping established: assign to nearest group by mean distance
        means = [np.mean([self.distances[o] for o in g if o in self.distances])
                 if any(o in self.distances for o in g) else np.inf
                 for g in self.groups]
        gi = int(np.argmin([abs(d - m) for m in means]))
        self.groups[gi].append(orbit)
        return gi

    def regroup(self) -> None:
        """Re-run the gap clustering over all seen orbits (end of an epoch
        where new orbits appeared)."""
        if self.distances:
            self.groups = group_by_gaps(self.distances, self.num_groups)

    def all_grouped(self, num_orbits: int) -> bool:
        return sum(len(g) for g in self.groups) >= num_orbits
