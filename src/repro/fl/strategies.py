"""FL-Satcom strategies: AsyncFLEO and the paper's baselines (§II, §V-A).

Each strategy is a declarative spec consumed by ``repro.core.simulator``:

=================  ====== ======= ========== ============ =====================
strategy           sync   ISL     grouping   aggregation  PS placement
=================  ====== ======= ========== ============ =====================
asyncfleo-gs       no     yes     yes        asyncfleo    GS, arbitrary (Rolla)
asyncfleo-hap      no     yes     yes        asyncfleo    1 HAP, arbitrary
asyncfleo-twohap   no     yes     yes        asyncfleo    2 HAPs (ring)
fedavg / fedisl    yes    yes     no         fedavg       GS, arbitrary
fedisl-ideal       yes    yes     no         fedavg       GS at the North Pole
fedsat             no     no      no         per-arrival  GS at the North Pole
fedspace           no     no      no         interval     GS, arbitrary
fedhap             yes    yes     no         fedavg       1 HAP
fedasync           no     yes     no         per-arrival  GS, arbitrary
=================  ====== ======= ========== ============ =====================

FedSpace's real scheduler optimizes the schedule from uploaded raw-data
fractions (which AsyncFLEO criticizes); we emulate its idle-vs-staleness
trade-off with a fixed-interval staleness-weighted aggregation (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    name: str
    sync: bool
    use_isl: bool
    grouping: bool
    agg_mode: str                    # asyncfleo | fedavg | per_arrival | interval
    ps_scenario: str                 # gs | hap | twohap | gs-np
    interval_s: float = 1800.0       # for agg_mode == interval
    num_groups: int = 3
    strict_paper_eq14: bool = False
    use_agg_kernel: bool = False     # route eq. 14 through the Pallas kernel
    # event-runtime trigger policy (sched/policies.py): "" derives it from
    # sync/agg_mode — sync -> barrier, per_arrival -> FedAsync, else the
    # AsyncFLEO idle-timeout window
    sched_policy: str = ""


STRATEGIES = {
    "asyncfleo-gs": StrategySpec("asyncfleo-gs", False, True, True,
                                 "asyncfleo", "gs"),
    "asyncfleo-hap": StrategySpec("asyncfleo-hap", False, True, True,
                                  "asyncfleo", "hap"),
    "asyncfleo-twohap": StrategySpec("asyncfleo-twohap", False, True, True,
                                     "asyncfleo", "twohap"),
    "fedisl": StrategySpec("fedisl", True, True, False, "fedavg", "gs"),
    "fedisl-ideal": StrategySpec("fedisl-ideal", True, True, False,
                                 "fedavg", "gs-np"),
    "fedsat": StrategySpec("fedsat", False, False, False,
                           "per_arrival", "gs-np"),
    "fedspace": StrategySpec("fedspace", False, False, False,
                             "interval", "gs"),
    "fedhap": StrategySpec("fedhap", True, True, False, "fedavg", "hap"),
    # FedAsync-style baseline: immediate per-arrival aggregation at a GS
    # PS, full ISL relay — only meaningfully different from fedsat under
    # the event-driven runtime, where every MODEL_ARRIVAL triggers its own
    # aggregation instead of a batched window
    "fedasync": StrategySpec("fedasync", False, True, False,
                             "per_arrival", "gs", sched_policy="per_arrival"),
}


def get_strategy(name: str) -> StrategySpec:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; available: {sorted(STRATEGIES)}")
    return STRATEGIES[name]
