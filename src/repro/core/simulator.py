"""Discrete-event FL simulation over LEO trajectories (paper §V).

The simulator advances *simulated* time (seconds over a 3-day horizon) while
running *real* JAX training for every satellite's local model.  Per global
epoch beta:

  1. downlink  — Alg. 1 timing gives each satellite its receive time of
     w^beta (ring-of-stars + ISL relay for strategies that have ISL; plain
     next-visibility otherwise);
  2. train     — each satellite trains for J local iterations (real SGD),
     finishing ``train_time_s`` later in simulated time;
  3. uplink    — arrival time of each local model at the sink PS;
  4. aggregate — strategy-dependent trigger and rule (AsyncFLEO grouping +
     staleness discounting; FedAvg barrier; per-arrival; fixed interval);
  5. evaluate  — test accuracy of the new global model at the trigger time.

Three trainer paths, fastest first (DESIGN.md §2/§6):

* **fused** — trainers exposing the fused-epoch protocol
  (``epoch_train_fn`` + ``epoch_inputs``) run steps 2-4 as ONE donated
  jitted device program per epoch (``core/epoch_step.py``): propagation
  timing and all per-model weight metadata math happen on host *before*
  the dispatch, training/grouping-distances/aggregation happen inside it.
  Losses stay lazy device arrays the simulator never forces, and accuracy
  values are blocked on only when the history is finalized.  Carried
  stragglers live in a small device matrix re-gathered per epoch (never
  donated twice).
* **stacked** — trainers with ``train_many_stacked`` keep local models as
  one device-resident (C, N) stack through grouping and aggregation but
  issue separate (still fused per-segment) dispatches.
* **legacy** — pytree trainers (e.g. test stubs) take the seed's
  host-pytree path.

The output is a history of (sim_time_s, epoch, accuracy, ...) rows, from
which convergence time (time to reach a target accuracy) is read — the
paper's Table II / Fig. 6 quantities.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import SatelliteMeta
from repro.core.constellation import (WalkerDelta, make_ps_nodes,
                                      paper_constellation)
from repro.core.grouping import (GroupingState, segment_partial_inputs,
                                 segment_weight_matrix)
from repro.core.links import LinkModel, model_bits
from repro.core.modelbank import FlatSpec, gather_rows, pad_bucket_ids
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline
from repro.fl.strategies import StrategySpec


@dataclasses.dataclass
class SimConfig:
    duration_s: float = 3 * 86400.0
    dt_s: float = 10.0
    train_time_s: float = 600.0        # on-board local-training wall time
    agg_timeout_s: float = 1500.0      # async collection window per epoch
    min_models: int = 2                # never aggregate on fewer arrivals
    eval_fn: Optional[object] = None   # params -> accuracy
    seed: int = 0
    sync_stall_s: float = 86400.0      # cap a sync round at this (stragglers)
    link: Optional[LinkModel] = None   # None -> paper Table I RF (16 Mb/s)
    use_model_bank: bool = True        # stacked path when trainer supports it
    use_fused_step: bool = True        # one donated program/epoch (DESIGN §6)
    mesh: Optional[object] = None      # jax Mesh with a "data" axis, or None
    event_driven: bool = False         # run() delegates to sched.runtime
    # pluggable fault/heterogeneity layer (sched/faults.FaultModel,
    # DESIGN.md §10): per-sat compute-rate multipliers, eclipse
    # availability windows, lossy sat->PS transfers with bounded
    # retry/backoff.  None attaches NO fault state at all — bit-identical
    # to the fault-free simulator (the parity contract)
    fault_model: Optional[object] = None
    # observability (obs/, DESIGN.md §12): a `obs.Tracer` records the
    # event runtime's round lifecycle; a `obs.DispatchProfiler` times the
    # fused program's host dispatches.  Both are strictly read-only —
    # None (the defaults) attaches nothing and stays bit-identical
    tracer: Optional[object] = None
    profiler: Optional[object] = None
    # scenario-batched sweeps (sweep/batch.DispatchBatcher, DESIGN.md
    # §13): when set, `_init_run` wraps the fused program in the
    # batcher's proxy so this simulation's epoch dispatches multiplex
    # into shared device programs with the sweep's other scenarios.
    # None (the default) attaches nothing — the sequential path is
    # untouched (the batched-vs-sequential parity contract)
    dispatcher: Optional[object] = None
    # contact-plan geometry backend (DESIGN.md §14): "dense" precomputes
    # the (T, S, P) visibility grid; "sparse" compiles per-(sat, PS)
    # window segments coarse-to-fine and answers every query by bisect —
    # O(windows) memory, required at mega-constellation scale.  Sparse is
    # pinned bit-identical to dense (windows, queries, runtime histories)
    # but cannot host fault grid-masks (eclipse/outage masks mutate the
    # dense grid in place), so those combinations raise at construction
    visibility: str = "dense"


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    time_s: float
    accuracy: float
    num_models: int
    gamma: float
    stale_groups: int


def split_min_models(arrivals, t_agg: float, min_models: int):
    """(t_agg, used, late) partition of SORTED arrivals at ``t_agg`` with
    the ``min_models`` backstop: when fewer than ``min_models`` arrivals
    land inside the window, the first ``min_models`` are aggregated anyway
    and ``t_agg`` moves to the last of them.

    ``used`` is always a *prefix* of the sorted arrivals and ``late`` the
    exact remainder, so ``used + late == arrivals`` holds on every branch
    — in particular, arrivals *tied* at the backstop's ``t_agg`` beyond
    the ``min_models`` slice are carried as late, never dropped (the
    conservation property tests/test_property.py pins).  ONE shared
    implementation for `FLSimulation._trigger` and the per-group
    `sched/policies.AsyncFLEOPolicy.split` — neither may fork it.
    """
    used = [a for a in arrivals if a[0] <= t_agg]
    if len(used) < min_models:
        used = arrivals[:min_models]
        t_agg = used[-1][0] if used else t_agg
    return t_agg, used, arrivals[len(used):]


class FLSimulation:
    def __init__(self, spec: StrategySpec, trainer, evaluator,
                 sim: SimConfig, constellation: Optional[WalkerDelta] = None):
        self.spec = spec
        self.trainer = trainer
        self.evaluator = evaluator
        self.sim = sim
        self.constellation = constellation or paper_constellation()
        self.nodes = make_ps_nodes(spec.ps_scenario)
        visibility = getattr(sim, "visibility", "dense")
        if visibility == "sparse":
            from repro.core.visibility import SparseVisibilityTimeline
            self.timeline = SparseVisibilityTimeline(
                self.constellation, self.nodes, sim.duration_s, sim.dt_s)
        elif visibility == "dense":
            self.timeline = VisibilityTimeline(
                self.constellation, self.nodes, sim.duration_s, sim.dt_s)
        else:
            raise ValueError(f"visibility must be dense|sparse: {visibility}")
        # fault/heterogeneity layer (DESIGN.md §10): eclipse windows mask
        # the visibility grid BEFORE anything derives state from it, so
        # contact windows, downlink stars, relay seeds and uplinks all
        # route around dark satellites with no special cases; the per-sat
        # training-time scale is applied in _train_times (None = scalar
        # math, bit-identical to the fault-free path)
        self.fault = getattr(sim, "fault_model", None)
        self._train_scale = None
        self._outages = None
        if self.fault is not None:
            S = self.constellation.num_sats
            self._train_scale = self.fault.train_time_scale(S)
            mask = self.fault.availability_mask(self.timeline.times, S)
            if mask is not None:
                if visibility == "sparse":
                    raise ValueError(
                        "sparse visibility cannot host eclipse/outage "
                        "grid-masks — use visibility='dense' with this "
                        "fault model")
                self.timeline.grid &= mask[:, :, None]
            # PS outage windows (DESIGN.md §11) mask the PS axis the same
            # way — a dark parameter server has no satellite contacts —
            # and the compiled OutageSchedule drives the event runtime's
            # ring-failover recovery.  No outage config -> no schedule,
            # no grid mutation at all (the off-switch contract)
            omask = self.fault.outage_mask(self.timeline.times,
                                           len(self.nodes), sim.duration_s)
            if omask is not None:
                from repro.sched.faults import OutageSchedule
                if visibility == "sparse":
                    raise ValueError(
                        "sparse visibility cannot host eclipse/outage "
                        "grid-masks — use visibility='dense' with this "
                        "fault model")
                self.timeline.grid &= omask[:, None, :]
                self._outages = OutageSchedule(
                    self.fault.outage_intervals(len(self.nodes),
                                                sim.duration_s),
                    len(self.nodes))
        self.topo = RingOfStars(self.constellation, self.nodes, self.timeline)
        self.prop = PropagationModel(self.topo, sim.link or LinkModel())
        # the compiled contact plan owns the downlink/uplink timing rules
        # (including the use_isl switch) and is shared with the
        # event-driven runtime; lazy import keeps core <-> sched acyclic
        from repro.sched.contacts import ContactPlan, ContentionModel
        self.plan = ContactPlan(self.constellation, self.nodes,
                                self.timeline, self.topo, self.prop,
                                use_isl=spec.use_isl)
        if getattr(spec, "ps_channels", None) is not None:
            # finite per-PS link capacity (DESIGN.md §9): every sat<->PS
            # model transfer serializes over spec.ps_channels parallel
            # channels; None keeps infinite parallelism with NO contention
            # state at all (the parity default)
            self.plan.contention = ContentionModel(len(self.nodes),
                                                   int(spec.ps_channels))
        self.grouping = GroupingState(num_groups=spec.num_groups)
        self.orbit_ids = self.constellation.orbit_ids()
        # persistent per-satellite bookkeeping
        self.last_epoch_included: Dict[int, int] = {}
        # legacy path: (arrival_t, sat, host pytree, trained_from_epoch)
        self.pending: List[tuple] = []
        # stacked + fused paths: stragglers live in a small DEVICE matrix
        # (O(late) rows, not O(S)) so nothing blocks — they re-enter
        # aggregation as one fused term
        self._pend_dev = None                            # (L, N) device
        self._pend_meta: List[tuple] = []      # (arrival_t, sat, epoch)
        self._spec = None              # FlatSpec of the stacked/fused path
        self._fused_prog = None        # EpochStepProgram (fused path)
        # fused path: distances of newly seen orbits are fetched lazily —
        # (new_orbits, device dists, block map, block size), resolved at
        # the next grouping read so the next epoch's host timing overlaps
        # the device stream instead of draining it
        self._dist_pending = None
        # wall-time attribution per host-side section (bench breakdown)
        self.segment_seconds: Dict[str, float] = {
            k: 0.0 for k in ("timing", "train", "step", "agg", "group",
                             "carry", "eval")}

    @contextlib.contextmanager
    def _seg(self, key: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.segment_seconds[key] += time.perf_counter() - t0

    # ------------------------------------------------------------------

    def _downlink(self, t0: float, bits: float, source: int) -> np.ndarray:
        # timing rules live on the compiled contact plan (sched/contacts.py)
        return self.plan.downlink_times(t0, bits, source)

    def _uplink_many(self, sats, t_done, bits: float, sink: int):
        return self.plan.uplink_times(sats, t_done, bits, sink)

    def _train_times(self, participants):
        """Per-participant local-training durations.  Homogeneous fleets
        get the scalar ``train_time_s`` (bit-identical to the fault-free
        arithmetic); under a FaultModel compute-rate spread each
        satellite's duration is stretched by its multiplier, which is how
        heterogeneity reaches every TRAIN_DONE instant of both drivers."""
        if self._train_scale is None:
            return self.sim.train_time_s
        return (self.sim.train_time_s
                * self._train_scale[np.asarray(participants, np.int64)])

    def _combine(self, segments, weights, base_flat, base_weight: float):
        """Map metas-indexed ``weights`` onto per-segment weight vectors and
        run the fused stacked combination (host bookkeeping + one
        contraction per segment)."""
        terms = []
        for stack, rows in segments:
            if stack is None or stack.shape[0] == 0:
                continue
            terms.append((stack,
                          agg.scatter_weights(rows, weights, stack.shape[0])))
        out = agg.combine_stacked(terms, base_flat, base_weight,
                                  use_kernel=self.spec.use_agg_kernel)
        return base_flat if out is None else out

    # ---- shared per-epoch host metadata ------------------------------

    def _trigger(self, arrivals, t: float):
        """Aggregation trigger: (t_agg, used, late) from sorted arrivals.
        ``used`` is a prefix of ``arrivals`` and ``late`` the exact
        remainder (``used + late == arrivals`` — no drops, even on tied
        arrival times)."""
        sim, spec = self.sim, self.spec
        if spec.sync:
            # barrier: last expected arrival, capped by the straggler
            # stall AND the simulation horizon — a barrier round must not
            # commit an epoch past the end of the simulation
            t_agg = min(arrivals[-1][0] if arrivals else t,
                        t + sim.sync_stall_s, sim.duration_s)
            used = [a for a in arrivals if a[0] <= t_agg]
            return t_agg, used, arrivals[len(used):]
        t_first = arrivals[0][0] if arrivals else t
        t_agg = min(t_first + sim.agg_timeout_s, sim.duration_s)
        return split_min_models(arrivals, t_agg, sim.min_models)

    def _mode_weights(self, metas: List[SatelliteMeta], beta: int,
                      groups: Optional[Dict[int, List[int]]]):
        """Per-model weight vector + base weight for the epoch update
        (:func:`repro.core.aggregation.epoch_weight_vector`)."""
        return agg.epoch_weight_vector(
            self.spec.agg_mode, metas, beta, groups,
            strict_paper_eq14=self.spec.strict_paper_eq14,
            staleness_fn=getattr(self.spec, "staleness_fn", "eq13"))

    @staticmethod
    def _blocked_layout(new_orbits, orbit_indices, bank_rows, n_rows: int,
                        kpad: int):
        """Detect whether every new orbit's bank rows sit in one distinct
        contiguous block of ``n_rows // kpad`` rows (the common
        full-participation layout).  Returns (block size m, orbit-index ->
        block map); (0, {}) when the layout is irregular and the program
        must fall back to the dense one-hot GEMM.  The blocked einsum is
        O(C*N) instead of O(K*C*N) — see DESIGN.md §6."""
        if not kpad or not n_rows or n_rows % kpad:
            return 0, {}
        mb = n_rows // kpad
        block_of: Dict[int, int] = {}
        used = set()
        homeless = []
        for k, o in enumerate(new_orbits):
            blocks = {bank_rows[j] // mb for j in orbit_indices[o]
                      if bank_rows[j] >= 0}
            if len(blocks) > 1:
                return 0, {}
            if blocks:
                b = blocks.pop()
                if b in used:
                    return 0, {}
                block_of[k] = b
                used.add(b)
            else:
                homeless.append(k)      # carry-only orbit: any free block
        free = (b for b in range(kpad) if b not in used)
        for k in homeless:
            b = next(free, None)
            if b is None:
                return 0, {}
            block_of[k] = b
        return mb, block_of

    def _resolve_pending_dists(self) -> None:
        """Fetch + record the previous epoch's new-orbit distances.  MUST
        run before any grouping-state read (``group_of`` / ``groups``)."""
        pend = self._dist_pending
        if pend is None:
            return
        self._dist_pending = None
        new_orbits, dists, block_of, blocked_m = pend
        with self._seg("group"):
            ds_full = np.asarray(dists)          # tiny (kpad,) transfer
            if blocked_m:
                ds = ds_full[[block_of[k] for k in range(len(new_orbits))]]
            else:
                ds = ds_full[:len(new_orbits)]
            self.grouping.assign_distances(new_orbits, ds)

    def _carried_split(self, t_agg: float):
        """Indices of pending stragglers that arrived (<= t_agg) vs kept."""
        c_idx = [i for i, (ta, _s, _ep) in enumerate(self._pend_meta)
                 if ta <= t_agg]
        k_idx = [i for i in range(len(self._pend_meta)) if i not in c_idx]
        return c_idx, k_idx

    # ---- fused path (one donated program per epoch, DESIGN.md §6) ----

    def _arrival_times(self, participants, recv, bits, sink):
        """Participant timing for one round: padded bank ids, per-row
        training-done times, raw per-row sink arrival times, and the
        sorted finite (t_arr, sat, row) arrival triples.  ONE shared
        implementation for the epoch loop and the event runtime — their
        parity contract (tests/test_sched.py) depends on identical
        timing math, so neither may fork this."""
        ids_np, _n = pad_bucket_ids(participants)
        t_done = recv[participants] + self._train_times(participants)
        t_arr, _haps = self._uplink_many(participants, t_done, bits, sink)
        arrivals = [(float(t_arr[k]), s, k)
                    for k, s in enumerate(participants)
                    if np.isfinite(t_arr[k])]
        arrivals.sort(key=lambda a: a[0])
        return ids_np, t_done, t_arr, arrivals

    def _fused_epoch(self, prog, beta, participants, recv, t, bits, sink):
        """One epoch-loop iteration on the fused path: propagation timing
        and the `_trigger` split happen here, everything after the trigger
        is the shared `_fused_commit` (which the event-driven runtime calls
        directly with policy-chosen trigger instants)."""
        # all host work happens BEFORE the dispatch: propagation timing,
        # trigger, straggler bookkeeping, weight-vector metadata math
        arrivals = []
        ids_np = np.zeros(0, np.int32)
        if participants:
            with self._seg("timing"):
                ids_np, _td, _ta, arrivals = self._arrival_times(
                    participants, recv, bits, sink)
        if not arrivals and not self._pend_meta:
            return None
        t_agg, used, late = self._trigger(arrivals, t)
        return self._fused_commit(prog, beta, ids_np, participants, t_agg,
                                  used, late)

    def _fused_commit(self, prog, beta, ids_np, participants, t_agg, used,
                      late, train_epoch: Optional[int] = None):
        """Post-trigger tail of a fused epoch: metas/carry bookkeeping,
        grouping metadata, weight vectors, the ONE donated dispatch, and
        the straggler carry-over.  ``used``/``late`` are (t_arr, sat, bank
        row) triples split at ``t_agg`` — by `_trigger` on the epoch loop,
        by a trigger policy in the event runtime (`sched/runtime.py`).

        ``train_epoch`` names the round the commit belongs to: the global
        epoch counter when the round's downlink left the source (defaults
        to ``beta``, the epoch-loop case where rounds never overlap).
        With the pipelined runtime (DESIGN.md §8) a round may commit
        after later-opened rounds advanced ``beta``; its models — used
        AND late-carried — are stamped with ``train_epoch``, so eq. 13's
        staleness discount and Alg. 2's fresh/stale selection see the
        model version the round actually started from."""
        from repro.core.epoch_step import carry_capacity, next_pow2

        sim, spec = self.sim, self.spec
        if train_epoch is None:
            train_epoch = beta
        # the RNG seed stays keyed on the commit-time counter: commits are
        # serialized so beta is unique per training dispatch, while two
        # overlapping pipelined rounds can share a train_epoch (and must
        # NOT draw identical minibatch streams)
        seed = sim.seed * 1000 + beta
        self._spec = prog.spec
        N = prog.spec.num_params
        c_idx, k_idx = self._carried_split(t_agg)

        metas = [SatelliteMeta(s, self.trainer.data_size(s),
                               loc=(0.0, 0.0), ts=ta, epoch=train_epoch)
                 for (ta, s, _k) in used]
        metas += [SatelliteMeta(s, self.trainer.data_size(s),
                                loc=(0.0, 0.0), ts=ta, epoch=ep)
                  for (ta, s, ep) in (self._pend_meta[i] for i in c_idx)]
        bank_rows = [k for (_, _, k) in used] + [-1] * len(c_idx)
        carry_rows = [-1] * len(used) + list(range(len(c_idx)))
        keep = agg.dedup_indices(metas)
        if len(keep) < len(metas):
            metas = [metas[i] for i in keep]
            bank_rows = [bank_rows[i] for i in keep]
            carry_rows = [carry_rows[i] for i in keep]

        # carried stragglers: a small padded device matrix (pad rows repeat
        # row 0 and carry zero weight); rebuilt from _pend_dev every epoch
        # so the program may freely consume (donate) its buffer
        cap = carry_capacity(len(c_idx))
        if c_idx:
            gids = np.asarray(c_idx + [c_idx[0]] * (cap - len(c_idx)),
                              np.int32)
            carry = gather_rows(self._pend_dev, gids)
        else:
            carry = jnp.zeros((cap, N), jnp.float32)

        # groups + new-orbit partial-model inputs (host metadata): the
        # program computes distances as an O(C*N) segment-sum, so the host
        # ships per-row weights + segment ids, not a (K, C) matrix
        groups = None
        new_orbits: List[int] = []
        orbit_indices: Dict[int, List[int]] = {}
        kpad, blocked_m = 0, 0
        block_of: Dict[int, int] = {}
        dw_row = np.zeros(len(ids_np), np.float32)
        dw_seg = np.zeros(len(ids_np), np.int32)
        dw_carry = np.zeros((0, cap), np.float32)
        fallback = False
        if spec.agg_mode == "asyncfleo" and not spec.grouping:
            groups = {0: list(range(len(metas)))}
        elif spec.agg_mode == "asyncfleo":
            self._resolve_pending_dists()        # state read follows
            for i, meta in enumerate(metas):
                orbit_indices.setdefault(
                    int(self.orbit_ids[meta.sat_id]), []).append(i)
            known = {o: self.grouping.group_of(o) for o in orbit_indices}
            new_orbits = [o for o, g in known.items() if g is None]
            if new_orbits:
                sizes = [m.size for m in metas]
                totals = {o: float(sum(sizes[j] for j in orbit_indices[o]))
                          for o in new_orbits}
                kpad = next_pow2(len(new_orbits))
                dw_row, dw_seg = segment_partial_inputs(
                    new_orbits, orbit_indices, bank_rows, sizes, totals,
                    len(ids_np), kpad)
                carry_w = segment_weight_matrix(
                    new_orbits, orbit_indices, carry_rows, sizes, totals,
                    cap)
                blocked_m, block_of = self._blocked_layout(
                    new_orbits, orbit_indices, bank_rows, len(ids_np),
                    kpad)
                dw_carry = np.zeros((kpad, cap), np.float32)
                if blocked_m:
                    for k in range(len(new_orbits)):
                        dw_carry[block_of[k]] = carry_w[k]
                else:
                    dw_carry[:len(new_orbits)] = carry_w
            any_stale = any(not m.is_fresh(beta) for m in metas)
            # group membership only moves weights through which *stale*
            # models survive selection; with everything fresh the weights
            # are group-independent, so provisional singleton groups keep
            # the epoch at one dispatch.  A new orbit arriving while stale
            # models are pending is the one case where the weight vector
            # depends on this epoch's distances -> two dispatches.
            fallback = bool(new_orbits) and any_stale
            groups = {}
            provisional = -1
            for o, idxs in orbit_indices.items():
                gi = known[o]
                if gi is None:
                    gi = provisional
                    provisional -= 1
                groups.setdefault(gi, []).extend(idxs)

        if not participants:
            # nothing trained this epoch: no program — one eager fused
            # combine over the carried-stragglers matrix only
            return self._fused_no_train(beta, metas, carry, carry_rows,
                                        c_idx, k_idx, new_orbits,
                                        orbit_indices, groups, t_agg)

        with self._seg("agg"):
            if fallback:
                wv_bank = np.zeros(len(ids_np), np.float32)
                wv_carry = np.zeros(cap, np.float32)
                base_w, info = 1.0, None
            else:
                ws, base_w, info = self._mode_weights(metas, beta, groups)
                wv_bank = agg.scatter_weights(bank_rows, ws, len(ids_np))
                wv_carry = agg.scatter_weights(carry_rows, ws, cap)

        with self._seg("step"):
            inputs = self.trainer.epoch_inputs(ids_np)
            new_w, stack, dists, losses = prog.step(
                self._w_flat, carry, inputs, ids_np, seed,
                wv_bank, wv_carry, base_w, dw_row, dw_seg, kpad,
                blocked_m, dw_carry, self.grouping._ref_device(),
                fallback=fallback)

        if new_orbits:
            # don't block here: the fetch resolves at the next grouping
            # read, letting the next epoch's host work overlap the stream
            self._dist_pending = (new_orbits, dists, block_of, blocked_m)

        if fallback:
            self._resolve_pending_dists()        # weights need the groups
            with self._seg("agg"):
                groups = {}
                for o, idxs in orbit_indices.items():
                    gi = self.grouping.group_of(o)
                    groups.setdefault(gi, []).extend(idxs)
                ws, base_w, info = self._mode_weights(metas, beta, groups)
                out = agg.combine_stacked(
                    [(stack, agg.scatter_weights(bank_rows, ws,
                                                 len(ids_np))),
                     (carry if c_idx else None,
                      agg.scatter_weights(carry_rows, ws, cap))],
                    new_w, base_w, use_kernel=spec.use_agg_kernel)
                new_w = out if out is not None else new_w

        # retire carried stragglers, enqueue this epoch's late rows —
        # all lazy device gathers, nothing blocks
        with self._seg("carry"):
            kept_meta = [self._pend_meta[i] for i in k_idx]
            kept_dev = (gather_rows(self._pend_dev,
                                    np.asarray(k_idx, np.int32))
                        if k_idx else None)
            if late:
                late_ids = np.asarray([k for (_, _, k) in late], np.int32)
                late_dev = gather_rows(stack, late_ids)
                kept_dev = (late_dev if kept_dev is None
                            else jnp.concatenate([kept_dev, late_dev]))
                kept_meta += [(ta, s, train_epoch) for (ta, s, _k) in late]
            self._pend_dev, self._pend_meta = kept_dev, kept_meta

        self._w_flat = new_w
        return t_agg, metas, info, losses

    def _fused_no_train(self, beta, metas, carry, carry_rows, c_idx, k_idx,
                        new_orbits, orbit_indices, groups, t_agg):
        """Fused-path epoch with no participants: carried stragglers only."""
        spec = self.spec
        if new_orbits:
            with self._seg("group"):
                sizes = [m.size for m in metas]
                totals = {o: float(sum(sizes[j] for j in orbit_indices[o]))
                          for o in new_orbits}
                dw = segment_weight_matrix(new_orbits, orbit_indices,
                                           carry_rows, sizes, totals,
                                           carry.shape[0])
                pm = jnp.asarray(dw) @ carry
                ds = np.asarray(jnp.linalg.norm(
                    pm - self.grouping._ref_device()[None, :], axis=1))
                self.grouping.assign_distances(new_orbits, ds)
            if spec.agg_mode == "asyncfleo" and spec.grouping:
                groups = {}
                for o, idxs in orbit_indices.items():
                    groups.setdefault(self.grouping.group_of(o),
                                      []).extend(idxs)
        with self._seg("agg"):
            ws, base_w, info = self._mode_weights(metas, beta, groups)
            out = agg.combine_stacked(
                [(carry, agg.scatter_weights(carry_rows, ws,
                                             carry.shape[0]))],
                self._w_flat, base_w, use_kernel=spec.use_agg_kernel)
            if out is not None:
                self._w_flat = out
        kept_meta = [self._pend_meta[i] for i in k_idx]
        kept_dev = (gather_rows(self._pend_dev, np.asarray(k_idx, np.int32))
                    if k_idx else None)
        self._pend_dev, self._pend_meta = kept_dev, kept_meta
        return t_agg, metas, info, None

    # ---- stacked path (device-resident bank, chained dispatches) -----

    def _stacked_epoch(self, beta, participants, recv, t, bits, sink,
                       w_tree):
        sim, spec = self.sim, self.spec
        bank = None
        arrivals = []
        if participants:
            with self._seg("train"):
                bank, _losses = self.trainer.train_many_stacked(
                    participants, w_tree, seed=sim.seed * 1000 + beta)
                self._spec = bank.spec
            with self._seg("timing"):
                t_done = recv[participants] + self._train_times(participants)
                t_arr_vec, _haps = self._uplink_many(participants, t_done,
                                                     bits, sink)
            arrivals = [(float(t_arr_vec[k]), s, k)
                        for k, s in enumerate(participants)
                        if np.isfinite(t_arr_vec[k])]
            arrivals.sort(key=lambda a: a[0])
        if not arrivals and not self._pend_meta:
            return None
        t_agg, used, late = self._trigger(arrivals, t)
        c_idx, k_idx = self._carried_split(t_agg)

        metas = [SatelliteMeta(s, self.trainer.data_size(s),
                               loc=(0.0, 0.0), ts=ta, epoch=beta)
                 for (ta, s, _k) in used]
        metas += [SatelliteMeta(s, self.trainer.data_size(s),
                                loc=(0.0, 0.0), ts=ta, epoch=ep)
                  for (ta, s, ep) in (self._pend_meta[i] for i in c_idx)]
        # row bookkeeping instead of row gathers: metas index j maps to a
        # row of the intact epoch bank or the carried matrix
        bank_rows = [k for (_, _, k) in used] + [-1] * len(c_idx)
        carry_rows = [-1] * len(used) + list(range(len(c_idx)))
        with self._seg("carry"):
            carry_seg = (gather_rows(self._pend_dev,
                                     np.asarray(c_idx, np.int32))
                         if c_idx else None)
            # retire carried stragglers, enqueue this epoch's late rows —
            # all lazy device gathers, O(late) rows; the old path staged
            # them in a host matrix (a (late, N) device->host->device
            # round-trip per epoch that an accelerator host can't hide)
            keep_dev = (gather_rows(self._pend_dev,
                                    np.asarray(k_idx, np.int32))
                        if k_idx else None)
            keep_meta = [self._pend_meta[i] for i in k_idx]
            if late:
                late_ids = np.asarray([k for (_, _, k) in late], np.int32)
                late_dev = gather_rows(bank.stack, late_ids)
                keep_dev = (late_dev if keep_dev is None else
                            jnp.concatenate([keep_dev, late_dev]))
                keep_meta += [(ta, s, beta) for (ta, s, _k) in late]
            self._pend_dev, self._pend_meta = keep_dev, keep_meta

        keep = agg.dedup_indices(metas)
        if len(keep) < len(metas):
            metas = [metas[i] for i in keep]
            bank_rows = [bank_rows[i] for i in keep]
            carry_rows = [carry_rows[i] for i in keep]
        carry_dev = (carry_seg
                     if carry_seg is not None
                     and any(r >= 0 for r in carry_rows) else None)
        segments = [(bank.stack if bank is not None else None, bank_rows),
                    (carry_dev, carry_rows)]

        # guard: a trainer that never ran leaves _spec unset — fall back
        # to the pytree base's own structure instead of crashing
        if self._spec is None:
            self._spec = FlatSpec.of(w_tree)
        if self._w_flat is None:
            self._w_flat = self._spec.flatten(w_tree)

        groups: Optional[Dict[int, List[int]]] = None
        if spec.agg_mode == "asyncfleo":
            if not spec.grouping:                    # ablation: one group
                groups = {0: list(range(len(metas)))}
            else:
                with self._seg("group"):
                    # batched: all new-orbit partial models + distances in
                    # fused per-segment contractions over the bank
                    orbit_indices: Dict[int, List[int]] = {}
                    for i, meta in enumerate(metas):
                        orbit_indices.setdefault(
                            int(self.orbit_ids[meta.sat_id]), []).append(i)
                    orbit_group = self.grouping.observe_orbits_multi(
                        orbit_indices, segments, [m.size for m in metas])
                    groups = {}
                    for i, meta in enumerate(metas):
                        gi = orbit_group[int(self.orbit_ids[meta.sat_id])]
                        groups.setdefault(gi, []).append(i)

        with self._seg("agg"):
            # per-model weights are host metadata math; the tensor update
            # is a couple of fused per-segment contractions (epoch bank +
            # carried stragglers), no row copies
            ws, base_w, info = self._mode_weights(metas, beta, groups)
            w_new = self._combine(segments, ws, self._w_flat, base_w)
            self._w_flat = (w_new if getattr(w_new, "ndim", None) == 1
                            else self._spec.flatten(w_new))
        return t_agg, metas, info, None

    # ---- legacy path (host pytrees, the seed's semantics) ------------

    def _legacy_epoch(self, beta, participants, recv, t, bits, sink,
                      w_tree):
        sim, spec = self.sim, self.spec
        arrivals = []
        if participants:
            with self._seg("train"):
                trained, _losses = self.trainer.train_many(
                    participants, w_tree, seed=sim.seed * 1000 + beta)
            with self._seg("timing"):
                t_done = recv[participants] + self._train_times(participants)
                t_arr_vec, _haps = self._uplink_many(participants, t_done,
                                                     bits, sink)
            arrivals = [(float(t_arr_vec[k]), s, p)
                        for k, (s, p)
                        in enumerate(zip(participants, trained))
                        if np.isfinite(t_arr_vec[k])]
            arrivals.sort(key=lambda a: a[0])
        if not arrivals and not self.pending:
            return None
        t_agg, used, late = self._trigger(arrivals, t)

        metas = [SatelliteMeta(s, self.trainer.data_size(s),
                               loc=(0.0, 0.0), ts=ta, epoch=beta)
                 for (ta, s, _p) in used]
        carried = [(ta, s, p, ep) for (ta, s, p, ep) in self.pending
                   if ta <= t_agg]
        self.pending = [x for x in self.pending if x[0] > t_agg]
        self.pending.extend((ta, s, p, beta) for (ta, s, p) in late)
        metas += [SatelliteMeta(s, self.trainer.data_size(s),
                                loc=(0.0, 0.0), ts=ta, epoch=ep)
                  for (ta, s, _p, ep) in carried]
        models = ([p for (_, _, p) in used]
                  + [p for (_, _, p, _) in carried])
        models, metas = agg.dedup(models, metas)
        base = w_tree

        info = {"gamma": 1.0, "stale_groups": 0}
        with self._seg("agg"):
            if spec.agg_mode == "fedavg":
                w_new = agg.fedavg(models, [m.size for m in metas],
                                   use_kernel=spec.use_agg_kernel)
            elif spec.agg_mode == "per_arrival":
                w_new = base
                for m_i, meta in zip(models, metas):
                    alpha = 0.5 / (1.0 + max(beta - meta.epoch, 0))
                    w_new = agg.weighted_sum([m_i], [alpha], base=w_new,
                                             base_weight=1.0 - alpha)
            elif spec.agg_mode == "interval":
                total = sum(m.size for m in metas)
                raw = np.array([m.size / (1.0 + max(beta - m.epoch, 0))
                                for m in metas])
                gam = float(np.clip(raw.sum() / max(total, 1e-9), 0.2, 1.0))
                w_new = agg.weighted_sum(models, gam * raw / raw.sum(),
                                         base=base, base_weight=1.0 - gam)
                info["gamma"] = gam
            else:                                    # asyncfleo (Alg. 2)
                groups: Dict[int, List[int]] = {}
                if not spec.grouping:                # ablation: one group
                    groups[0] = list(range(len(metas)))
                else:
                    for i, meta in enumerate(metas):
                        orbit = int(self.orbit_ids[meta.sat_id])
                        gi = self.grouping.group_of(orbit)
                        if gi is None:     # first sighting: distance to w0
                            same_orbit = [j for j, mm in enumerate(metas)
                                          if int(self.orbit_ids[mm.sat_id])
                                          == orbit]
                            gi = self.grouping.observe_orbit(
                                orbit, [models[j] for j in same_orbit],
                                [metas[j].size for j in same_orbit])
                        groups.setdefault(gi, [])
                        if i not in groups[gi]:
                            groups[gi].append(i)
                w_new, info = agg.asyncfleo_aggregate(
                    base, groups, models, metas, beta,
                    strict_paper_eq14=spec.strict_paper_eq14,
                    use_kernel=spec.use_agg_kernel)
        return t_agg, metas, info, w_new

    # ------------------------------------------------------------------

    def _init_run(self, w0):
        """Shared run-state reset for the epoch loop and the event-driven
        runtime.  Returns (model bits, fused program or None, stacked?)."""
        bits = model_bits(w0)
        self.grouping.set_reference(w0)
        if self.plan.contention is not None:
            self.plan.contention.reset()   # channel pools are per-run state
        stacked = self.sim.use_model_bank and hasattr(self.trainer,
                                                      "train_many_stacked")
        fused = None
        if stacked and self.sim.use_fused_step:
            from repro.core.epoch_step import make_epoch_program
            fused = make_epoch_program(self.trainer, w0, mesh=self.sim.mesh,
                                       use_kernel=self.spec.use_agg_kernel)
            if fused is not None:
                # dispatch profiling hook (obs/profile.py); programs are
                # cached on the trainer, so (re)set it every run — None
                # detaches a previous run's profiler
                fused.profiler = getattr(self.sim, "profiler", None)
                dispatcher = getattr(self.sim, "dispatcher", None)
                if dispatcher is not None:
                    # scenario-batched sweep (DESIGN.md §13): route this
                    # run's dispatches through the shared batcher; the
                    # proxy keeps step()'s exact surface and counters
                    fused = dispatcher.wrap(
                        fused, key=getattr(self.trainer,
                                           "scenario_batch_key", None))
        self._fused_prog = fused
        self._w_flat = None               # flat device view (stacked/fused)
        self._dist_pending = None
        if stacked:
            self._spec = self._spec or FlatSpec.of(w0)
            self._w_flat = self._spec.flatten(w0)
        return bits, fused, stacked

    def _record_epoch(self, history: List[EpochRecord], beta: int,
                      t_agg: float, metas, info, lazy_eval: bool, w_tree):
        """Evaluate + append one epoch's history row (shared by the epoch
        loop and the event runtime so the records stay bit-identical).
        Returns the recorded accuracy (a lazy device scalar when
        ``lazy_eval``)."""
        for meta in metas:
            self.last_epoch_included[meta.sat_id] = beta
        with self._seg("eval"):
            if self.evaluator is None:
                acc = float("nan")
            elif lazy_eval:
                acc = self.evaluator.eval_async(w_tree)  # lazy device
            else:
                acc = float(self.evaluator(w_tree))
        history.append(EpochRecord(beta, t_agg, acc, len(metas),
                                   float(info.get("gamma", 1.0)),
                                   int(info.get("stale_groups", 0))))
        return acc

    def run(self, w0, max_epochs: int = 30,
            target_accuracy: Optional[float] = None) -> List[EpochRecord]:
        sim, spec = self.sim, self.spec
        if sim.event_driven:
            # the event-driven async runtime replaces the epoch loop as
            # the top-level driver (DESIGN.md §7)
            from repro.sched.runtime import EventDrivenRuntime
            return EventDrivenRuntime(self).run(
                w0, max_epochs, target_accuracy=target_accuracy)
        if self.fault is not None and self.fault.has_loss:
            raise ValueError(
                "FaultModel transfer loss (loss_prob > 0 or burst_len_s "
                "> 0) requires the event-driven runtime "
                "(SimConfig.event_driven=True): the epoch loop cannot "
                "express TRANSFER_FAILED retry chains")
        if self.fault is not None and (self.fault.has_outages
                                       or self.fault.has_energy):
            raise ValueError(
                "FaultModel PS outages / energy budgets require the "
                "event-driven runtime (SimConfig.event_driven=True): the "
                "epoch loop cannot express ring failover or deferred "
                "uplinks (DESIGN.md §11)")
        bits, fused, stacked = self._init_run(w0)
        w_tree = w0                       # pytree view (trainer/evaluator)
        t = 0.0
        source = 0
        history: List[EpochRecord] = []
        S = self.constellation.num_sats
        lazy_eval = (target_accuracy is None
                     and hasattr(self.evaluator, "eval_async"))

        for beta in range(max_epochs):
            if t >= sim.duration_s:
                break
            sink = self.topo.sink_of(source)
            with self._seg("timing"):
                recv = self._downlink(t, bits, source)
            participants = [s for s in range(S) if np.isfinite(recv[s])]

            if fused is not None:
                out = self._fused_epoch(fused, beta, participants, recv, t,
                                        bits, sink)
            elif stacked:
                out = self._stacked_epoch(beta, participants, recv, t,
                                          bits, sink, w_tree)
            else:
                out = self._legacy_epoch(beta, participants, recv, t,
                                         bits, sink, w_tree)
            if out is None:
                break
            t_agg, metas, info, extra = out
            if fused is not None:
                # the fused path trains from w_flat directly: the pytree
                # view only feeds the evaluator
                if self.evaluator is not None:
                    w_tree = self._spec.unflatten(self._w_flat)  # lazy
            elif stacked:
                w_tree = self._spec.unflatten(self._w_flat)  # device, lazy
            else:
                w_tree = extra
            if spec.agg_mode == "interval":
                t_agg = max(t_agg, t + spec.interval_s)

            acc = self._record_epoch(history, beta, t_agg, metas, info,
                                     lazy_eval, w_tree)
            t = t_agg
            source, sink = sink, source            # §IV-B3 role swap
            if target_accuracy is not None and acc >= target_accuracy:
                break
        self._resolve_pending_dists()        # leave grouping state complete
        with self._seg("eval"):
            for rec in history:              # block once, at finalize time
                rec.accuracy = float(rec.accuracy)
        return history


def convergence_time(history: List[EpochRecord], target: float) -> Optional[float]:
    for rec in history:
        if rec.accuracy >= target:
            return rec.time_s
    return None
