"""Event-driven scheduler subsystem (sched/, DESIGN.md §7-§8).

Covers: contact-plan compilation (RLE windows reconstruct the visibility
grid, delays, summary/export), the runtime-vs-epoch-loop parity contract
(degenerate all-visible plan AND the real paper constellation: aggregated
weights within atol 1e-5 and the same fused-dispatch count), the sync
barrier and FedAsync per-arrival policies, policy selection via
fl/strategies, the convergence-delay ordering the paper claims
(async < sync on the same constellation), and the pipelined multi-round
model (§8): overlapping rounds in flight, closed-round arrivals landing
in the successor's stale set, contact-plan handoff, per-group trigger
deadlines, and ``max_in_flight=1`` staying bit-identical to the epoch
loop.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core.modelbank import flatten_tree
from repro.fl import get_strategy
from repro.sched import (ContactPlan, EventDrivenRuntime, EventKind,
                         RoundState, make_handoff_policy, make_policy)
from repro.sched.policies import (AsyncFLEOPolicy, FedAsyncPolicy,
                                  NextContactHandoff, RingHandoff,
                                  SyncBarrierPolicy)

from test_epoch_step import TinyFusedTrainer, W0, _staged_downlink

SIMKW = dict(duration_s=86400.0, train_time_s=300.0,
             use_model_bank=True, use_fused_step=True)


def _sim(name, event_driven, *, spec_kw=None, **kw):
    cfg = SimConfig(event_driven=event_driven, **{**SIMKW, **kw})
    spec = get_strategy(name)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    return FLSimulation(spec, TinyFusedTrainer(W0), None, cfg)


def _rows(hist):
    return [(r.epoch, round(r.time_s, 6), r.num_models,
             round(r.gamma, 6), r.stale_groups) for r in hist]


# ---- contact-plan compilation ---------------------------------------------

def test_contact_windows_reconstruct_grid():
    fls = _sim("asyncfleo-twohap", False)
    plan = fls.plan
    tl = fls.timeline
    rebuilt = np.zeros_like(tl.grid)
    for w in plan.windows():
        i0 = int(round(w.t_start / tl.dt_s))
        i1 = int(round(w.t_end / tl.dt_s))
        assert w.t_end > w.t_start
        assert w.delay_s >= 0.0
        rebuilt[i0:i1, w.sat, w.node] = True
    np.testing.assert_array_equal(rebuilt, tl.grid)


def test_contact_plan_summary_and_export():
    fls = _sim("asyncfleo-hap", False)
    plan = ContactPlan.compile(fls.constellation, fls.nodes,
                               duration_s=6 * 3600.0, dt_s=30.0)
    s = plan.summary()
    assert s["num_windows"] == len(plan.to_dicts()) > 0
    assert 0.0 < s["coverage_fraction"] < 1.0
    assert not s["is_degenerate"]
    assert plan.isl_hop_delay(0.0) > 0.0
    d = plan.to_dicts()[0]
    assert set(d) == {"sat", "node", "t_start", "t_end", "delay_s"}


def test_next_contact_matches_timeline():
    fls = _sim("asyncfleo-twohap", False)
    tv, ps = fls.plan.next_contact([0, 7, 23], 1234.0)
    tv2, ps2 = fls.timeline.next_visible_after([0, 7, 23], 1234.0)
    np.testing.assert_array_equal(tv, tv2)
    np.testing.assert_array_equal(ps, ps2)
    t_any = fls.plan.next_any_contact(0.0)
    assert t_any is not None and t_any >= 0.0


# ---- runtime vs epoch-loop parity -----------------------------------------

def _degenerate(fls):
    """All sats always visible — the acceptance-criteria contact plan."""
    fls.timeline.grid[:] = True
    assert fls.plan.is_degenerate
    return fls


def test_parity_degenerate_plan_asyncfleo():
    """The acceptance contract: under an all-visible plan and the AsyncFLEO
    policy the event runtime reproduces the fused epoch loop's aggregated
    weights (atol 1e-5) with the SAME fused-dispatch count."""
    a = _degenerate(_sim("asyncfleo-twohap", False))
    b = _degenerate(_sim("asyncfleo-twohap", True))
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches == len(ha)
    assert a._fused_prog.fallback_dispatches == \
        b._fused_prog.fallback_dispatches


@pytest.mark.parametrize("name", ["asyncfleo-twohap", "asyncfleo-hap",
                                  "fedhap", "fedisl"])
def test_parity_real_constellation(name):
    """Same contract on the real paper constellation (async idle-timeout
    and sync barrier policies both delegate their split to _trigger)."""
    a, b = _sim(name, False), _sim(name, True)
    ha = a.run(W0, max_epochs=4)
    hb = b.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches


def test_parity_with_stragglers():
    """A tight collection window forces late arrivals: the runtime's
    straggler carry-over must match the epoch loop's."""
    a = _sim("asyncfleo-twohap", False, agg_timeout_s=120.0)
    b = _sim("asyncfleo-twohap", True, agg_timeout_s=120.0)
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)


def test_parity_sync_stall_all_late():
    """A sync stall shorter than every uplink: the barrier round must
    still consume its training dispatch (0-model epoch, all rows carried)
    instead of silently dropping the round — and match the epoch loop."""
    for stall in (350.0, 900.0):
        a = _sim("fedhap", False, sync_stall_s=stall)
        b = _sim("fedhap", True, sync_stall_s=stall)
        ha = a.run(W0, max_epochs=4)
        hb = b.run(W0, max_epochs=4)
        assert _rows(ha) == _rows(hb), f"stall={stall}"
        np.testing.assert_allclose(np.asarray(a._w_flat),
                                   np.asarray(b._w_flat), atol=1e-5)


def test_idle_round_sleeps_until_straggler_lands():
    """A round with no participants and a straggler hours out must wake
    at the straggler's landing (not re-arm the same trigger forever) and
    aggregate it."""
    fls = _sim("asyncfleo-twohap", True)
    row = (np.asarray(flatten_tree(W0)) + 1.0)[None, :]
    ta = 50000.0                        # far beyond t_start + agg_timeout
    fls._pend_meta = [(ta, 3, 0)]
    fls._pend_dev = jnp.asarray(row.astype(np.float32))
    _staged_downlink(fls, [()])         # nobody is ever visible
    hist = fls.run(W0, max_epochs=3)
    assert len(hist) == 1
    assert hist[0].num_models == 1
    assert hist[0].time_s >= ta


def test_idle_round_drops_past_horizon_straggler():
    """A pending straggler landing after the horizon is dropped (the
    epoch loop's `t >= duration` break) — the run terminates cleanly."""
    fls = _sim("asyncfleo-twohap", True)
    row = (np.asarray(flatten_tree(W0)) + 1.0)[None, :]
    fls._pend_meta = [(SIMKW["duration_s"] + 100.0, 3, 0)]
    fls._pend_dev = jnp.asarray(row.astype(np.float32))
    _staged_downlink(fls, [()])
    hist = fls.run(W0, max_epochs=3)
    assert hist == []


def test_runtime_event_counts_and_rounds():
    fls = _sim("asyncfleo-twohap", True)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=3)
    assert len(hist) == 3
    c = rt.events.counts
    # every participant trains once per round; every finite arrival fires
    assert c[EventKind.TRAIN_DONE.name] >= c[EventKind.MODEL_ARRIVAL.name]
    assert c[EventKind.MODEL_ARRIVAL.name] > 0
    assert c[EventKind.TRIGGER_TIMEOUT.name] >= len(hist)
    assert c[EventKind.SINK_HANDOFF.name] >= len(hist) - 1


def test_runtime_requires_fused_trainer():
    class LegacyOnly:
        def data_size(self, sat):
            return 1

        def train_many(self, sats, params, seed):
            return [params for _ in sats], np.zeros(len(sats))

    cfg = SimConfig(event_driven=True, **SIMKW)
    fls = FLSimulation(get_strategy("asyncfleo-twohap"), LegacyOnly(),
                       None, cfg)
    with pytest.raises(ValueError, match="fused"):
        fls.run(W0, max_epochs=2)


def test_runtime_target_accuracy_stops_early():
    def ev(params):
        flat = np.concatenate([np.ravel(np.asarray(params["w"])),
                               np.ravel(np.asarray(params["b"]))])
        return 1.0 - min(1.0, float(np.mean(np.abs(flat - 1.0))))

    class Converging(TinyFusedTrainer):
        def epoch_train_fn(self):
            def _fn(params, inputs, ids, seed):
                flat = flatten_tree(params)
                stack = (flat[None, :] * 0.5 + 0.5
                         + 0.0 * ids[:, None].astype(np.float32))
                return stack, np.zeros(ids.shape[0])
            return _fn

    cfg = SimConfig(event_driven=True, **SIMKW)
    fls = FLSimulation(get_strategy("asyncfleo-twohap"), Converging(W0),
                       ev, cfg)
    hist = fls.run(W0, max_epochs=20, target_accuracy=0.9)
    assert hist[-1].accuracy >= 0.9
    assert len(hist) < 20


# ---- policies --------------------------------------------------------------

def test_policy_selection_via_strategies():
    assert isinstance(make_policy(get_strategy("asyncfleo-hap")),
                      AsyncFLEOPolicy)
    assert isinstance(make_policy(get_strategy("fedhap")),
                      SyncBarrierPolicy)
    assert isinstance(make_policy(get_strategy("fedisl")),
                      SyncBarrierPolicy)
    assert isinstance(make_policy(get_strategy("fedasync")),
                      FedAsyncPolicy)
    assert isinstance(make_policy(get_strategy("fedsat")),
                      FedAsyncPolicy)
    with pytest.raises(KeyError):
        make_policy(get_strategy("fedhap"), name="nope")


def test_fedasync_per_arrival_aggregation():
    """FedAsync: every arrival triggers its own aggregation — many small
    commits per round, but still only ONE fused training dispatch."""
    fls = _sim("fedasync", True)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=6)
    assert len(hist) == 6
    # per-arrival commits are small (one or a few simultaneous arrivals)
    assert max(r.num_models for r in hist) <= 4
    times = [r.time_s for r in hist]
    assert all(b >= a for a, b in zip(times, times[1:]))
    # the first commit consumed the round's single training dispatch; the
    # later per-arrival commits drained the carried matrix eagerly
    assert fls._fused_prog.dispatches < len(hist)


def test_sync_barrier_fires_on_last_arrival():
    """The barrier commits exactly when the last expected model lands (not
    at the stall deadline) when every satellite reports in time."""
    fls = _sim("fedhap", True)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=2)
    assert len(hist) == 2
    assert all(r.num_models == fls.constellation.num_sats for r in hist)
    assert hist[0].time_s < SIMKW["duration_s"]


# ---- pipelined multi-round runtime (DESIGN.md §8) --------------------------

PIPE_KW = dict(max_in_flight=3, handoff_policy="next_contact")


def test_pipelined_rounds_overlap():
    """With max_in_flight=3 the runtime actually keeps several rounds in
    flight at once, commits stay in event-time order, and staleness
    discounting kicks in for rounds that committed after a later-opened
    round advanced the epoch counter."""
    fls = _sim("asyncfleo-twohap", True, spec_kw=PIPE_KW)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=8)
    assert len(hist) == 8
    assert rt.stats["max_rounds_in_flight"] >= 2
    assert rt.stats["pipelined_opens"] >= 1
    times = [r.time_s for r in hist]
    assert all(b >= a for a, b in zip(times, times[1:]))
    # at least one commit belonged to a round opened before an earlier
    # commit advanced beta -> its models were stale -> gamma < 1
    assert any(r.gamma < 1.0 for r in hist)


def test_pipelined_reaches_epoch_count_sooner():
    """The acceptance ordering: the pipelined runtime fits the same
    number of aggregations into strictly less simulated time than the
    single-round runtime on the same constellation."""
    h1 = _sim("asyncfleo-twohap", True).run(W0, max_epochs=8)
    hp = _sim("asyncfleo-twohap", True, spec_kw=PIPE_KW).run(
        W0, max_epochs=8)
    assert len(h1) == len(hp) == 8
    assert hp[-1].time_s < h1[-1].time_s


def test_closed_round_arrival_lands_in_successor_stale_set():
    """An arrival addressed to an already-closed round must not be lost:
    its MODEL_ARRIVAL still fires (and is counted), its row was carried
    device-resident at commit time, and a successor round's commit
    adopts it (the §8 late-arrival semantics)."""
    fls = _sim("asyncfleo-twohap", True,
               spec_kw=dict(max_in_flight=2, handoff_policy="next_contact"))
    rt = EventDrivenRuntime(fls)
    # round 0 recruits all 40 sats; one orbit's uplink only lands at the
    # next pass (~13.9k s simulated), so run far enough to adopt it
    hist = rt.run(W0, max_epochs=30)
    assert len(hist) >= 2
    # arrivals fired after their round closed...
    assert rt.stats["closed_round_arrivals"] > 0
    # ...and carried stragglers were adopted by later rounds' commits
    assert rt.stats["cross_round_adoptions"] > 0
    # the adopted models were stamped with their origin round's epoch,
    # so at least one adopting commit saw stale models (gamma < 1)
    assert any(r.gamma < 1.0 for r in hist)


def test_max_in_flight_one_parity_with_epoch_loop():
    """Explicit max_in_flight=1 (+ the ring handoff default) must stay
    bit-identical to the fused epoch loop — the §8 backward-compat
    contract on top of the PR 3 parity tests."""
    one = dict(max_in_flight=1, handoff_policy="")
    a = _sim("asyncfleo-twohap", False, spec_kw=one)
    b = _sim("asyncfleo-twohap", True, spec_kw=one)
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches
    rt_stats_free = EventDrivenRuntime(_sim("asyncfleo-twohap", True,
                                            spec_kw=one))
    rt_stats_free.run(W0, max_epochs=3)
    assert rt_stats_free.stats["pipelined_opens"] == 0
    assert rt_stats_free.stats["max_rounds_in_flight"] == 1


def test_handoff_policy_selection_and_next_contact():
    assert isinstance(make_handoff_policy(get_strategy("asyncfleo-hap")),
                      RingHandoff)
    spec = get_strategy("asyncfleo-pipelined")
    assert spec.max_in_flight == 3
    assert isinstance(make_handoff_policy(spec), NextContactHandoff)
    with pytest.raises(KeyError):
        make_handoff_policy(spec, name="nope")
    # the contact-plan query behind NextContactHandoff: per-PS earliest
    # any-sat contact, consistent with the compiled windows
    fls = _sim("asyncfleo-twohap", False)
    tv = fls.plan.next_contact_by_node(0.0)
    assert tv.shape == (2,)
    for p in range(2):
        wins = [w.t_start for w in fls.plan.windows() if w.node == p]
        if np.isfinite(tv[p]) and wins:
            assert tv[p] <= min(w for w in wins if w >= 0.0) + fls.plan.timeline.dt_s
    # pipelined rounds route through it end to end
    fls2 = _sim("asyncfleo-twohap", True, spec_kw=PIPE_KW)
    rt = EventDrivenRuntime(fls2)
    rt.run(W0, max_epochs=4)
    assert {r.source for r in rt.rounds.values()} <= {0, 1}


def test_per_group_deadlines_commit_earlier():
    """Per-divergence-group trigger windows (§8): shrinking every group's
    window below agg_timeout_s commits the first round strictly earlier
    than the global-window default."""
    tight = tuple((g, 60.0) for g in (-1, 0, 1, 2))
    a = _sim("asyncfleo-twohap", True)
    b = _sim("asyncfleo-twohap", True, spec_kw=dict(group_timeouts=tight))
    pol = make_policy(b.spec)
    assert isinstance(pol, AsyncFLEOPolicy)
    assert pol.group_timeouts == dict(tight)
    ha = a.run(W0, max_epochs=2)
    hb = b.run(W0, max_epochs=2)
    assert hb[0].time_s < ha[0].time_s


# ---- trigger-split bugfix regressions (ISSUE 5) ----------------------------

def test_min_models_backstop_keeps_tied_arrivals():
    """dt-grid-quantized uplink times make exact ties common: arrivals
    tied at the backstop's t_agg beyond the min_models slice must be
    carried as late, never dropped (pre-fix, `late = [a > t_agg]` lost
    them — the model vanished from the simulation)."""
    fls = _sim("asyncfleo-twohap", False)
    assert fls.sim.min_models == 2
    dt = fls.sim.dt_s
    arrivals = [(0.0, 0, 0)] + [(500 * dt, s, s) for s in (1, 2, 3)]
    t_agg, used, late = fls._trigger(arrivals, 0.0)
    assert t_agg == 500 * dt              # the backstop moved the instant
    assert used == arrivals[:2]
    assert late == arrivals[2:]           # the tied arrivals are carried
    assert used + late == arrivals


def test_per_group_split_keeps_tied_arrivals():
    """The per-group AsyncFLEO split routes through the SAME shared
    min_models helper as `_trigger` (it used to re-implement it with the
    same tied-arrival drop)."""
    fls = _sim("asyncfleo-twohap", True)
    rt = EventDrivenRuntime(fls)
    pol = AsyncFLEOPolicy(group_timeouts={0: 60.0})
    dt = fls.sim.dt_s
    arrivals = [(0.0, 0, 0)] + [(500 * dt, s, s) for s in (1, 2, 3)]
    rnd = RoundState(0, 0, 0.0, 0, 0, [0, 1, 2, 3], np.zeros(0, np.int32),
                     arrivals, {})
    t_agg, used, late = pol.split(rt, rnd, 60.0)
    assert t_agg == 500 * dt
    assert used == arrivals[:2] and late == arrivals[2:]
    assert used + late == arrivals


def test_sync_round_deadline_clamped_to_horizon():
    """A barrier round whose every arrival lands past a short horizon
    must commit AT the horizon, not at the unclamped arrival/stall
    instant (pre-fix the epoch was recorded past the end of the
    simulation)."""
    fls = _sim("fedhap", True, duration_s=550.0, train_time_s=600.0)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=3)
    assert hist                           # the barrier round still commits
    assert all(r.time_s <= fls.sim.duration_s for r in hist)
    # and the policy-level stall deadline itself is horizon-clamped, like
    # the AsyncFLEO / FedAsync deadlines
    pol = SyncBarrierPolicy()
    rnd = RoundState(0, 0, 500.0, 0, 0, [0], np.zeros(0, np.int32),
                     [(600.0, 0, 0)], {})
    assert pol.round_deadline(rt, rnd) == fls.sim.duration_s


# ---- the paper's headline ordering ----------------------------------------

def test_async_convergence_delay_beats_sync():
    """Same constellation, same trainer: the AsyncFLEO policy reaches the
    same epoch count in strictly less simulated time than the sync
    barrier — the paper's Table II quantity, now runnable head-to-head."""
    h_async = _sim("asyncfleo-gs", True).run(W0, max_epochs=3)
    h_sync = _sim("fedisl", True).run(W0, max_epochs=3)
    assert h_async[-1].time_s < h_sync[-1].time_s
