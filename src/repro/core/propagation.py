"""Model propagation timing (paper §IV-B, Algorithm 1).

Downlink: the source HAP relays the global model around the HAP ring; every
HAP broadcasts to its visible satellites; visible satellites relay along the
intra-orbit ISL ring (two fronts, ceasing where they meet), so invisible
satellites start training with minimal delay.  Orbits with *no* visible
satellite wait for their next pass.

Uplink: a trained local model goes straight up if its satellite sees a HAP,
else it relays along the ring toward the nearest (eventually-)visible
orbit-mate; received sets are relayed along the HAP ring to the sink.

This module converts those rules into per-satellite receive/arrival *times*
(simulated seconds), which is everything the discrete-event simulator needs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.links import LinkModel
from repro.core.topology import RingOfStars


@dataclasses.dataclass
class PropagationModel:
    topo: RingOfStars
    link: LinkModel

    # ---- primitive hop delays ----------------------------------------------

    def isl_hop_delay(self, bits: float) -> float:
        return self.link.total_delay(bits, self.topo.isl_chord_m())

    def ihl_hop_delay(self, bits: float, a: int, b: int, t: float) -> float:
        return self.link.total_delay(bits, self.topo.ihl_distance(a, b, t))

    def sat_ps_delay(self, bits: float, sat: int, ps: int, t: float) -> float:
        return self.link.total_delay(bits, self.topo.sat_ps_distance(sat, ps, t))

    # ---- downlink (Alg. 1 lines 2-10) ---------------------------------------

    def hap_receive_times(self, t0: float, bits: float, source: int) -> np.ndarray:
        """Time each HAP holds the global model after the ring relay."""
        H = self.topo.num_ps
        out = np.full(H, t0)
        for h in range(H):
            hops = self.topo.ring_hops(source, h)
            delay = 0.0
            for step in range(hops):     # accumulate per-hop IHL delays
                delay += self.ihl_hop_delay(bits, source, h, t0)
            out[h] = t0 + delay
        return out

    def downlink_times(self, t0: float, bits: float, source: int = 0) -> np.ndarray:
        """Per-satellite time of receiving the global model (Alg. 1)."""
        topo = self.topo
        S = topo.constellation.num_sats
        recv = np.full(S, np.inf)
        hap_t = self.hap_receive_times(t0, bits, source)

        # star broadcast from each HAP to its visible satellites
        for h in range(topo.num_ps):
            for sat in topo.star_members(h, hap_t[h]):
                cand = hap_t[h] + self.sat_ps_delay(bits, sat, h, hap_t[h])
                recv[sat] = min(recv[sat], cand)

        # intra-orbit ISL relay from the seeded (visible) satellites
        hop = self.isl_hop_delay(bits)
        for orbit in range(topo.constellation.num_orbits):
            sats = topo.orbit_sats(orbit)
            seeds = [s for s in sats if np.isfinite(recv[s])]
            if not seeds:
                # no visible satellite now: wait for the orbit's next pass
                t_vis, seed = topo.timeline.next_orbit_visible(sats, t0)
                if t_vis is None:
                    continue             # never visible within horizon
                ps = topo.visible_ps_of(seed, t_vis)
                ps0 = ps[0] if ps else 0
                recv[seed] = (max(t_vis, hap_t[ps0])
                              + self.sat_ps_delay(bits, seed, ps0, t_vis))
                seeds = [seed]
            for sat in sats:
                best = recv[sat]
                for seed in seeds:
                    d = topo.isl_ring_distance(seed, sat)
                    best = min(best, recv[seed] + d * hop)
                recv[sat] = best
        return recv

    # ---- uplink (Alg. 1 lines 11-22) ----------------------------------------

    def uplink(self, sat: int, t_done: float, bits: float,
               sink: int) -> Tuple[float, int]:
        """Arrival time of sat's local model at the *sink* HAP, and the HAP
        that first received it."""
        topo = self.topo
        tl = topo.timeline
        hop = self.isl_hop_delay(bits)

        def to_sink(t_at_hap: float, h: int) -> float:
            hops = topo.ring_hops(h, sink)
            return t_at_hap + hops * self.ihl_hop_delay(bits, h, sink, t_at_hap)

        # direct
        vis = topo.visible_ps_of(sat, t_done)
        if vis:
            h = vis[0]
            t_at = t_done + self.sat_ps_delay(bits, sat, h, t_done)
            return to_sink(t_at, h), h

        # relay toward a currently visible orbit-mate
        sats = topo.orbit_sats(topo.constellation.orbit_of(sat))
        now_vis = [s for s in sats if topo.visible_ps_of(s, t_done)]
        if now_vis:
            s_star = min(now_vis, key=lambda s: topo.isl_ring_distance(sat, s))
            d = topo.isl_ring_distance(sat, s_star)
            t_arrive = t_done + d * hop
            h = topo.visible_ps_of(s_star, t_done)[0]
            t_at = t_arrive + self.sat_ps_delay(bits, s_star, h, t_arrive)
            return to_sink(t_at, h), h

        # wait for the orbit's next visibility; the relay pre-positions
        t_vis, s_star = tl.next_orbit_visible(sats, t_done)
        if t_vis is None:
            return np.inf, -1
        d = topo.isl_ring_distance(sat, s_star)
        t_ready = max(t_done + d * hop, t_vis)
        vis2 = topo.visible_ps_of(s_star, t_vis)
        h = vis2[0] if vis2 else 0
        t_at = t_ready + self.sat_ps_delay(bits, s_star, h, t_ready)
        return to_sink(t_at, h), h
