"""Mamba2 (SSD) block and the Zamba2 hybrid wiring [arXiv:2411.15242].

Mamba2 block: in_proj -> (z | x | B | C | dt), causal depthwise conv over
(x,B,C), SSD linear recurrence with scalar-per-head decay
``a_t = exp(-softplus(dt_t + dt_bias) * exp(A_log))``, D skip, silu(z) gating,
RMSNorm, out_proj.  The SSD scan maps onto ``repro.models.scan_ops`` with
r=C, k=dt*B, v=x_heads (include_current=True).

Zamba2: 54 Mamba2 layers with one *shared* attention(+MLP) block applied every
``attn_every`` layers (identical weights each invocation) — implemented as a
two-level scan (groups x layers-per-group) so HLO stays compact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import scan_ops

CONV_K = 4           # depthwise conv kernel size
N_GROUPS = 1         # B/C groups


def _dims(cfg: ModelConfig):
    H = cfg.ssm_heads
    hd = cfg.ssm_head_dim or (cfg.d_model // H)
    d_inner = H * hd
    N = cfg.ssm_state
    return H, hd, d_inner, N


def init_layer(key, cfg: ModelConfig):
    d = cfg.d_model
    H, hd, d_inner, N = _dims(cfg)
    conv_dim = d_inner + 2 * N_GROUPS * N
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones((d,)),
        "in_proj": L.dense_init(ks[0], (d, 2 * d_inner + 2 * N_GROUPS * N + H)),
        "conv_w": L.dense_init(ks[1], (CONV_K, conv_dim), in_axis_size=CONV_K),
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.zeros((H,)),                 # A = -exp(A_log) ~ -1
        "D": jnp.ones((H,)),
        "dt_bias": jnp.full((H,), -2.0),          # softplus^-1-ish small dt
        "out_norm": jnp.ones((d_inner,)),
        "out_proj": L.dense_init(ks[2], (d_inner, d), in_axis_size=d_inner),
    }


def _split_proj(cfg, zxbcdt):
    H, hd, d_inner, N = _dims(cfg)
    z, xc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N_GROUPS * N], axis=-1)
    return z, xc, dt      # xc = conv input (x | B | C)


def _causal_conv(xc, w, b, conv_state=None):
    """Depthwise causal conv, kernel CONV_K. xc: (B,S,C).
    Returns (out, new_conv_state (B, CONV_K-1, C))."""
    Bsz, S, C = xc.shape
    pad = conv_state if conv_state is not None else jnp.zeros(
        (Bsz, CONV_K - 1, C), xc.dtype)
    xp = jnp.concatenate([pad.astype(xc.dtype), xc], axis=1)     # (B, S+K-1, C)
    out = sum(xp[:, i:i + S] * w[i].astype(xc.dtype) for i in range(CONV_K))
    out = jax.nn.silu(out + b.astype(xc.dtype))
    new_state = xp[:, -(CONV_K - 1):] if CONV_K > 1 else pad
    return out, new_state


def block(p, cfg: ModelConfig, x, state, *, impl="jnp"):
    """One Mamba2 layer. state = dict(conv (B,K-1,C), ssm (B,H,N,hd) f32).
    Returns (x_out, new_state)."""
    Bsz, S, d = x.shape
    H, hd, d_inner, N = _dims(cfg)
    dt_ = x.dtype
    h = L.rms_norm(x, p["ln"])
    z, xc, dt_raw = _split_proj(cfg, h @ p["in_proj"].astype(dt_))
    xc, conv_state = _causal_conv(xc, p["conv_w"], p["conv_b"], state["conv"])
    xs, B_, C_ = jnp.split(xc, [d_inner, d_inner + N_GROUPS * N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,S,H)
    log_decay = -dt * jnp.exp(p["A_log"].astype(jnp.float32))     # (B,S,H)

    v = xs.reshape(Bsz, S, H, hd)
    k = jnp.broadcast_to(B_.reshape(Bsz, S, N_GROUPS, N),
                         (Bsz, S, H, N)) * dt[..., None].astype(dt_)
    r = jnp.broadcast_to(C_.reshape(Bsz, S, N_GROUPS, N), (Bsz, S, H, N))

    if S > 1:
        y, ssm = scan_ops.chunked_scan(r, k, v, log_decay, state["ssm"],
                                       include_current=True,
                                       chunk=min(cfg.chunk_size, S), impl=impl)
    else:
        y1, ssm = scan_ops.recurrent_step(r[:, 0], k[:, 0], v[:, 0],
                                          log_decay[:, 0], state["ssm"],
                                          include_current=True)
        y = y1[:, None]

    y = y + v * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(Bsz, S, d_inner) * jax.nn.silu(z)
    y = L.rms_norm(y, p["out_norm"])
    out = y @ p["out_proj"].astype(dt_)
    return x + out, {"conv": conv_state, "ssm": ssm}


def init_state(cfg: ModelConfig, num_layers: int, batch: int, dtype):
    H, hd, d_inner, N = _dims(cfg)
    conv_dim = d_inner + 2 * N_GROUPS * N
    return {
        "conv": jnp.zeros((num_layers, batch, CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((num_layers, batch, H, N, hd), jnp.float32),
    }


# --------------------------------------------------------------------------
# shared attention block (zamba2)
# --------------------------------------------------------------------------

def init_shared_attn(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln_a": jnp.ones((cfg.d_model,)),
        "attn": L.init_attention(ks[0], cfg),
        "ln_m": jnp.ones((cfg.d_model,)),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def shared_attn_block(p, cfg: ModelConfig, x, positions, kv_cache=None, *,
                      window: int = 0):
    h = L.rms_norm(x, p["ln_a"])
    att, new_cache = L.attention(p["attn"], cfg, h, positions, kv_cache,
                                 window=window)
    x = x + att
    x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln_m"]))
    return x, new_cache
