"""Pluggable fault-injection / heterogeneity layer (DESIGN.md §10).

The simulator's robustness story used to ride on geometry alone: every
satellite trained at the same speed, no transfer was ever lost, and no
satellite ever powered down.  ``FaultModel`` makes the three missing
failure axes first-class, following FLGo's ``system_simulator`` shape
(pluggable availability / latency / dropout state on a shared clock):

* **compute-rate heterogeneity** — per-satellite multipliers that
  stretch local-training time (and therefore every ``TRAIN_DONE``
  instant): ``train_time_scale`` draws a seeded spread in
  ``[1, 1 + compute_rate_spread]`` (or takes explicit per-sat rates).
  Threaded through `FLSimulation._train_times`, the ONE shared timing
  helper of the epoch loop and the event runtime, so driver parity is
  preserved under heterogeneity.
* **eclipse / duty-cycle availability** — ``availability_mask`` returns
  a (T, S) boolean that is ANDed into ``VisibilityTimeline.grid`` at
  simulator construction: a satellite in its (seeded-phase, periodic)
  eclipse window is simply not visible to any PS, so every downstream
  rule — contact windows, downlink stars, ISL relay seeds, uplink
  direct/relay/wait — routes around it without special cases.
* **lossy transfers** — ``transfer_fails`` is a *deterministic* seeded
  Bernoulli draw per (satellite, round, attempt): the event runtime
  turns a failed sat->PS model transfer into a ``TRANSFER_FAILED``
  event at the would-be arrival instant and re-times the retransmission
  from ``t + retry_backoff_s * 2**attempt`` through the contact plan
  (which charges a fresh rx-channel grant — retries re-enter the
  `ChannelPool`), up to ``max_retries`` attempts; grants of retries
  that can never complete are rolled back via the existing
  snapshot/restore machinery.  Loss requires the event runtime — the
  epoch loop cannot express retries and refuses to run with
  ``loss_prob > 0``.

Every draw is a pure function of ``(seed, satellite, round, attempt)``
— no global RNG state — so a fault schedule is reproducible across
runs and independent of event-processing order.

**Off-switch contract**: ``SimConfig.fault_model=None`` attaches no
state at all, and a default ``FaultModel()`` (every axis off) takes the
identical code paths — both are bit-identical to the fault-free
simulator (tests/test_faults.py pins this).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

# domain-separation tags so the three fault axes never share a stream
_TAG_COMPUTE = 0xC0
_TAG_ECLIPSE = 0xEC
_TAG_LOSS = 0xF417


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Declarative fault / heterogeneity scenario (all axes off by
    default; validated at construction).

    ``compute_rate_spread=s`` draws per-sat training-time multipliers
    uniformly in ``[1, 1+s]`` (0 = homogeneous); ``compute_rates``
    overrides with explicit multipliers.  ``eclipse_fraction=f`` makes
    each satellite unavailable for a fraction ``f`` of every
    ``eclipse_period_s`` window (seeded per-sat phase).  ``loss_prob``
    is the per-attempt Bernoulli loss of a sat->PS model transfer;
    ``max_retries`` bounds retransmissions and ``retry_backoff_s`` is
    the base of the exponential backoff (attempt k waits
    ``retry_backoff_s * 2**k``)."""
    seed: int = 0
    # heterogeneity
    compute_rate_spread: float = 0.0
    compute_rates: Optional[Tuple[float, ...]] = None
    # eclipse / duty cycle
    eclipse_fraction: float = 0.0
    eclipse_period_s: float = 5400.0
    # lossy transfers
    loss_prob: float = 0.0
    max_retries: int = 3
    retry_backoff_s: float = 120.0

    def __post_init__(self):
        if int(self.seed) < 0:
            raise ValueError(f"FaultModel.seed must be >= 0, got {self.seed}")
        if self.compute_rate_spread < 0.0:
            raise ValueError("FaultModel.compute_rate_spread must be >= 0, "
                             f"got {self.compute_rate_spread}")
        if self.compute_rates is not None:
            rates = tuple(float(r) for r in self.compute_rates)
            if not rates or min(rates) <= 0.0:
                raise ValueError("FaultModel.compute_rates must be a "
                                 "non-empty tuple of positive multipliers, "
                                 f"got {self.compute_rates!r}")
            object.__setattr__(self, "compute_rates", rates)
        if not 0.0 <= self.eclipse_fraction < 1.0:
            raise ValueError("FaultModel.eclipse_fraction must be in "
                             f"[0, 1), got {self.eclipse_fraction}")
        if self.eclipse_period_s <= 0.0:
            raise ValueError("FaultModel.eclipse_period_s must be > 0, "
                             f"got {self.eclipse_period_s}")
        if not 0.0 <= self.loss_prob <= 1.0:
            raise ValueError("FaultModel.loss_prob must be in [0, 1], "
                             f"got {self.loss_prob}")
        if int(self.max_retries) < 0:
            raise ValueError("FaultModel.max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.retry_backoff_s <= 0.0:
            raise ValueError("FaultModel.retry_backoff_s must be > 0, "
                             f"got {self.retry_backoff_s}")

    # ---- derived state (pure functions of the frozen config) ---------------

    @property
    def is_null(self) -> bool:
        """True when every fault axis is off — a null model must be
        bit-identical to ``fault_model=None`` (the off-switch contract)."""
        return (self.compute_rate_spread == 0.0
                and self.compute_rates is None
                and self.eclipse_fraction == 0.0
                and self.loss_prob == 0.0)

    def train_time_scale(self, num_sats: int) -> Optional[np.ndarray]:
        """Per-satellite training-time multipliers (>= 1 under a spread),
        or None when homogeneous — callers then keep the scalar
        ``train_time_s`` math, bit-identical to the fault-free path."""
        if self.compute_rates is not None:
            if len(self.compute_rates) < num_sats:
                raise ValueError(
                    f"FaultModel.compute_rates has {len(self.compute_rates)} "
                    f"entries but the constellation has {num_sats} satellites")
            return np.asarray(self.compute_rates[:num_sats], np.float64)
        if self.compute_rate_spread <= 0.0:
            return None
        rng = np.random.default_rng((self.seed, _TAG_COMPUTE))
        return 1.0 + self.compute_rate_spread * rng.random(num_sats)

    def availability_mask(self, times: np.ndarray,
                          num_sats: int) -> Optional[np.ndarray]:
        """(T, S) bool — True where a satellite is powered/available.
        None when eclipse modelling is off (no grid mutation at all).
        Each satellite is dark for ``eclipse_fraction`` of every
        ``eclipse_period_s`` window, at a seeded per-sat phase."""
        if self.eclipse_fraction <= 0.0:
            return None
        rng = np.random.default_rng((self.seed, _TAG_ECLIPSE))
        phase = rng.random(num_sats) * self.eclipse_period_s      # (S,)
        dark = self.eclipse_fraction * self.eclipse_period_s
        rel = (np.asarray(times, np.float64)[:, None] + phase[None, :]) \
            % self.eclipse_period_s
        return rel >= dark

    def transfer_fails(self, sat: int, round_idx: int, attempt: int) -> bool:
        """Deterministic Bernoulli draw for one transfer attempt.  Keyed
        on (seed, sat, round, attempt) so the schedule is independent of
        event-processing order and reproducible across runs."""
        if self.loss_prob <= 0.0:
            return False
        if self.loss_prob >= 1.0:
            return True
        rng = np.random.default_rng(
            (self.seed, _TAG_LOSS, int(sat), int(round_idx), int(attempt)))
        return bool(rng.random() < self.loss_prob)

    def retry_delay_s(self, attempt: int) -> float:
        """Exponential backoff before retransmission ``attempt + 1``."""
        return float(self.retry_backoff_s * (2.0 ** int(attempt)))
