"""Fused epoch-step program (core/epoch_step.py, DESIGN.md §6).

Covers: three-way simulator parity (legacy pytrees / stacked ModelBank /
fused one-dispatch program), the one-donated-dispatch-per-epoch contract,
the stale+new-orbit two-dispatch fallback, the no-participant guard, and
lazy (non-blocking) losses/evaluation.  The multi-device NamedSharding /
shard_map path runs in a subprocess (device count is locked at first jax
init).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core.epoch_step import (EpochStepProgram, carry_capacity,
                                   make_epoch_program, next_pow2)
from repro.core.modelbank import FlatSpec, ModelBank, flatten_tree
from repro.fl import get_strategy

W0 = {"w": np.zeros((6,), np.float32), "b": np.ones((3,), np.float32)}


class TinyFusedTrainer:
    """Deterministic trainer exposing all three protocols with identical
    math: model * 0.9 + per-(sat, seed) offset."""

    def __init__(self, w0):
        self.spec = FlatSpec.of(w0)

    def data_size(self, sat):
        return 100 + (sat % 5) * 10

    # fused protocol ------------------------------------------------------
    def epoch_inputs(self, ids_np):
        return None

    def epoch_train_fn(self):
        def _fn(params, inputs, ids, seed):
            flat = flatten_tree(params)
            offs = ((ids * 37 + seed.astype(jnp.int32)) % 11
                    - 5).astype(jnp.float32) * 0.01
            stack = flat[None, :] * 0.9 + offs[:, None]
            return stack, jnp.zeros(ids.shape[0])
        return _fn

    # stacked protocol ----------------------------------------------------
    def train_many_stacked(self, sats, params, seed):
        flat = self.spec.flatten(params)
        offs = jnp.asarray([(s * 37 + seed) % 11 - 5 for s in sats],
                           jnp.float32) * 0.01
        stack = flat[None, :] * 0.9 + offs[:, None]
        return ModelBank(self.spec, stack), np.zeros(len(sats))

    # legacy protocol -----------------------------------------------------
    def train_many(self, sats, params, seed):
        bank, losses = self.train_many_stacked(sats, params, seed)
        return bank.to_pytrees(), losses


def _run(mode, name, trainer_cls=TinyFusedTrainer, evaluator=None,
         max_epochs=4, **simkw):
    sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                    use_model_bank=mode != "legacy",
                    use_fused_step=mode == "fused", **simkw)
    fls = FLSimulation(get_strategy(name), trainer_cls(W0), evaluator, sim)
    hist = fls.run(W0, max_epochs=max_epochs)
    rows = [(r.epoch, round(r.time_s, 6), r.num_models,
             round(r.gamma, 6), r.stale_groups) for r in hist]
    return fls, rows


# ---- three-way simulator parity -------------------------------------------

@pytest.mark.parametrize("name", ["asyncfleo-twohap", "fedhap", "fedsat",
                                  "fedspace"])
def test_fused_history_matches_stacked_and_legacy(name):
    rows = {m: _run(m, name)[1] for m in ("legacy", "stacked", "fused")}
    assert rows["legacy"] == rows["stacked"] == rows["fused"]


@pytest.mark.parametrize("name", ["asyncfleo-twohap", "fedsat"])
def test_fused_history_parity_with_stragglers(name):
    """A tight window forces late arrivals -> carried stale models."""
    rows = {m: _run(m, name, agg_timeout_s=120.0)[1]
            for m in ("legacy", "stacked", "fused")}
    assert rows["legacy"] == rows["stacked"] == rows["fused"]


def test_fused_final_models_match():
    evals = {}
    for mode in ("legacy", "stacked", "fused"):
        seen = []

        def ev(params, seen=seen):
            seen.append(np.concatenate(
                [np.ravel(np.asarray(params["w"])),
                 np.ravel(np.asarray(params["b"]))]))
            return 0.0
        _run(mode, "asyncfleo-twohap", evaluator=ev, agg_timeout_s=120.0)
        evals[mode] = seen
    assert len(evals["legacy"]) == len(evals["fused"]) > 0
    for a, b in zip(evals["legacy"], evals["fused"]):
        np.testing.assert_allclose(a, b, atol=1e-5)
    for a, b in zip(evals["stacked"], evals["fused"]):
        np.testing.assert_allclose(a, b, atol=1e-5)


# ---- the one-donated-dispatch-per-epoch contract --------------------------

def test_one_dispatch_per_epoch():
    fls, rows = _run("fused", "asyncfleo-twohap")
    prog = fls._fused_prog
    assert prog is not None
    assert prog.dispatches == len(rows)      # exactly one program per epoch
    assert prog.fallback_dispatches == 0


def test_program_donates_and_matches_manual():
    spec = FlatSpec.of(W0)
    trainer = TinyFusedTrainer(W0)
    prog = EpochStepProgram(spec, trainer.epoch_train_fn())
    N = spec.num_params
    C, cap = 4, 4
    # reference host copy from a SEPARATE flatten: fetching the donated
    # buffer to host first would cache an _npy_value and keep it alive
    w_host = np.asarray(spec.flatten(W0))
    w = spec.flatten(W0)
    carry = jnp.asarray(np.linspace(0, 1, cap * N,
                                    dtype=np.float32).reshape(cap, N))
    ids = np.arange(C, dtype=np.int32)
    wv = np.array([0.1, 0.2, 0.0, 0.05], np.float32)
    wc = np.array([0.03, 0.0, 0.0, 0.0], np.float32)
    # two new orbits: rows {0,1} -> orbit 0 (half weight each), row 2 ->
    # orbit 1; row 3 owned by no orbit (dump segment kpad=2)
    kpad = 2
    dw_row = np.array([0.5, 0.5, 1.0, 0.0], np.float32)
    dw_seg = np.array([0, 0, 1, kpad], np.int32)
    dwc = np.zeros((kpad, cap), np.float32)
    ref = jnp.zeros(N)

    new_w, stack, dists, losses = prog.step(
        w, carry, None, ids, 7, wv, wc, 0.6, dw_row, dw_seg, kpad,
        0, dwc, ref)
    assert prog.dispatches == 1
    # donation: the global-model input buffer was consumed
    assert w.is_deleted()
    # manual reference
    offs = ((ids * 37 + 7) % 11 - 5).astype(np.float32) * 0.01
    stack_ref = w_host[None, :] * 0.9 + offs[:, None]
    np.testing.assert_allclose(np.asarray(stack), stack_ref, atol=1e-6)
    w_ref = 0.6 * w_host + wv @ stack_ref + wc @ np.asarray(carry)
    np.testing.assert_allclose(np.asarray(new_w), w_ref, atol=1e-5)
    # dense equivalent of the (dw_row, dw_seg) distance inputs
    dw = np.array([[0.5, 0.5, 0.0, 0.0], [0.0, 0.0, 1.0, 0.0]], np.float32)
    d_ref = np.linalg.norm(dw @ stack_ref, axis=1)
    np.testing.assert_allclose(np.asarray(dists)[:2], d_ref, rtol=1e-5)
    # the blocked-einsum layout (orbit k owns rows [k*2, k*2+2)) must give
    # the same distances as the dense one-hot path
    w2 = spec.flatten(W0)
    _nw, _st, dists_b, _l = prog.step(
        w2, carry, None, ids, 7, wv, wc, 0.6, dw_row, dw_seg, kpad,
        2, dwc, ref)
    np.testing.assert_allclose(np.asarray(dists_b)[:2], d_ref, rtol=1e-5)


def test_fused_kernel_routing_parity():
    """``use_agg_kernel`` routes the fused program's aggregation
    contraction through the Pallas fed_agg kernel (interpret mode on CPU);
    history and final weights must match the XLA contraction, and the
    kernel-routed program must be cached separately."""
    import dataclasses as dc
    rows, finals = {}, {}
    for flag in (False, True):
        spec = dc.replace(get_strategy("asyncfleo-twohap"),
                          use_agg_kernel=flag)
        sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                        use_model_bank=True, use_fused_step=True)
        fls = FLSimulation(spec, TinyFusedTrainer(W0), None, sim)
        hist = fls.run(W0, max_epochs=3)
        rows[flag] = [(r.epoch, round(r.time_s, 6), r.num_models)
                      for r in hist]
        finals[flag] = np.asarray(fls._w_flat)
        assert fls._fused_prog.use_kernel is flag
        assert fls._fused_prog.dispatches == len(hist)
    assert rows[False] == rows[True]
    np.testing.assert_allclose(finals[False], finals[True], atol=1e-5)


def test_program_cached_on_trainer():
    trainer = TinyFusedTrainer(W0)
    p1 = make_epoch_program(trainer, W0)
    p2 = make_epoch_program(trainer, W0)
    assert p1 is p2                       # compiled program reused across runs


def test_carry_capacity_buckets():
    assert carry_capacity(0) == carry_capacity(1) == carry_capacity(4) == 4
    assert carry_capacity(5) == 8
    assert next_pow2(1) == 1 and next_pow2(3) == 4


# ---- stale + new-orbit fallback -------------------------------------------

def _staged_downlink(fls, visible_epochs):
    """Patch _downlink so epoch e only reaches the sats in
    visible_epochs[min(e, len-1)] (the rest wait)."""
    state = {"calls": 0}
    S = fls.constellation.num_sats

    def fake(t0, bits, source):
        idx = min(state["calls"], len(visible_epochs) - 1)
        state["calls"] += 1
        recv = np.full(S, np.inf)
        vis = list(visible_epochs[idx])
        # spread receive times so arrivals straddle the collection window
        recv[vis] = t0 + 60.0 + 90.0 * np.arange(len(vis))
        return recv
    fls._downlink = fake


def test_fallback_parity_new_orbit_with_stale():
    """A model from a never-seen orbit is pending as a STALE straggler
    when fresh models arrive: group membership (and hence the weight
    vector) depends on this epoch's distances, so the fused path must
    split into two dispatches — and still match the stacked path."""
    spec = FlatSpec.of(W0)
    straggler = (np.asarray(spec.flatten(W0)) + 0.7)[None, :]
    rows, evals, progs = {}, {}, {}
    for mode in ("stacked", "fused"):
        seen = []

        def ev(params, seen=seen):
            seen.append(np.asarray(params["w"]).copy())
            return 0.0
        sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                        use_model_bank=True,
                        use_fused_step=mode == "fused")
        fls = FLSimulation(get_strategy("asyncfleo-twohap"),
                           TinyFusedTrainer(W0), ev, sim)
        # sat 8 belongs to orbit 1, which the grouping has never seen; its
        # model arrives immediately but was trained "before epoch 0"
        fls._pend_meta = [(1.0, 8, -1)]
        fls._pend_dev = jnp.asarray(straggler.astype(np.float32))
        _staged_downlink(fls, [range(0, 8)])   # only orbit 0 trains
        hist = fls.run(W0, max_epochs=2)
        rows[mode] = [(r.epoch, round(r.time_s, 6), r.num_models,
                       round(r.gamma, 6), r.stale_groups) for r in hist]
        evals[mode] = seen
        progs[mode] = fls._fused_prog
    assert rows["stacked"] == rows["fused"]
    assert any(r[4] > 0 for r in rows["fused"])     # a stale-only group
    for a, b in zip(evals["stacked"], evals["fused"]):
        np.testing.assert_allclose(a, b, atol=1e-5)
    assert progs["fused"].fallback_dispatches >= 1


# ---- no-participant / never-trained guards --------------------------------

@pytest.mark.parametrize("mode", ["stacked", "fused"])
def test_pending_without_training_regression(mode):
    """_pend_meta populated while no participant ever trained: the stacked
    path used to reach _combine with base=None (spec never set) and crash;
    now the base falls back to the pytree's own FlatSpec."""
    sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                    use_model_bank=True, use_fused_step=mode == "fused")
    fls = FLSimulation(get_strategy("asyncfleo-twohap"),
                       TinyFusedTrainer(W0), None, sim)
    spec = FlatSpec.of(W0)
    row = np.asarray(spec.flatten(W0))[None, :] + 1.0
    fls._pend_meta = [(10.0, 3, 0)]
    fls._pend_dev = jnp.asarray(row.astype(np.float32))
    _staged_downlink(fls, [()])              # nobody is ever visible
    hist = fls.run(W0, max_epochs=2)
    assert len(hist) == 1                    # straggler-only aggregation
    assert hist[0].num_models == 1


# ---- lazy losses / lazy evaluation ----------------------------------------

def test_stacked_losses_are_lazy_device_values():
    from repro.fl.client import ImageClassifierPool
    from repro.configs.paper_models import SmallNetConfig
    from repro.models import cnn
    rng = np.random.default_rng(0)
    images = rng.standard_normal((64, 8, 8, 1)).astype(np.float32)
    labels = np.asarray(rng.integers(0, 3, 64))
    shards = [np.arange(i * 16, (i + 1) * 16) for i in range(4)]
    cfg = SmallNetConfig("t", "mlp", image_size=8, channels=1,
                         num_classes=3, hidden=8)
    pool = ImageClassifierPool(cfg, images, labels, shards, local_iters=2)
    # dataset stays host-side (satellite shards are gathered per call)
    assert isinstance(pool._sel, np.ndarray)
    assert not hasattr(pool, "_imgs")
    w0 = cnn.init_params(jax.random.PRNGKey(0), cfg)
    bank, losses = pool.train_many_stacked([0, 2], w0, seed=1)
    assert isinstance(losses, jax.Array)     # no np.asarray block
    assert np.isfinite(np.asarray(losses)).all()
    # fused protocol present and consistent with the stacked call
    fn = pool.epoch_train_fn()
    ids = np.array([0, 2], np.int32)
    stacked, l2 = fn(w0, jax.tree.map(jnp.asarray, pool.epoch_inputs(ids)),
                     jnp.asarray(ids), jnp.uint32(1))
    np.testing.assert_allclose(np.asarray(l2), np.asarray(losses),
                               atol=1e-6)


def test_history_accuracy_finalized_to_float():
    class Ev:
        def eval_async(self, params):
            return jnp.mean(params["w"])     # device scalar

        def __call__(self, params):
            return float(self.eval_async(params))

    sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                    use_model_bank=True, use_fused_step=True)
    fls = FLSimulation(get_strategy("asyncfleo-twohap"),
                       TinyFusedTrainer(W0), Ev(), sim)
    hist = fls.run(W0, max_epochs=2)
    assert len(hist) >= 1
    assert all(isinstance(r.accuracy, float) for r in hist)


# ---- multi-device sharding (subprocess: device count locks at jax init) ---

MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.epoch_step import EpochStepProgram, bank_sharding
    from repro.core.modelbank import FlatSpec, flatten_tree
    from repro.launch.mesh import make_host_mesh

    assert len(jax.devices()) == 4
    w0 = {"w": np.arange(24, dtype=np.float32).reshape(4, 6),
          "b": np.ones(8, np.float32)}
    spec = FlatSpec.of(w0)

    def train_fn(params, inputs, ids, seed):
        flat = flatten_tree(params)
        offs = ((ids * 37 + seed.astype(jnp.int32)) % 11
                - 5).astype(jnp.float32) * 0.01
        stack = flat[None, :] * 0.9 + offs[:, None] + inputs[:, None]
        return stack, offs

    mesh = make_host_mesh(data=4, model=1)
    C, cap, K = 8, 4, 2
    ids = np.arange(C, dtype=np.int32)
    inputs = np.linspace(0.0, 1.0, C).astype(np.float32)
    wv = np.linspace(0.1, 0.2, C).astype(np.float32)
    wc = np.zeros(cap, np.float32)
    carry = jnp.zeros((cap, spec.num_params), jnp.float32)
    dw_row = np.full(C, 0.25, np.float32)
    dw_seg = np.array([0] * 4 + [1] * 4, np.int32)
    dwc = np.zeros((K, cap), np.float32)
    ref = jnp.zeros(spec.num_params)

    outs = {}
    for name, m in (("single", None), ("mesh", mesh)):
        prog = EpochStepProgram(spec, train_fn, mesh=m)
        w = spec.flatten(w0)
        new_w, stack, dists, losses = prog.step(
            w, carry, jnp.asarray(inputs), ids, 7, wv, wc, 0.5,
            dw_row, dw_seg, K, 0, dwc, ref)
        outs[name] = (np.asarray(new_w), np.asarray(stack),
                      np.asarray(dists))
        if name == "mesh":
            # the bank's NamedSharding spec is actually applied
            assert stack.sharding.is_equivalent_to(bank_sharding(mesh),
                                                   stack.ndim), \
                stack.sharding
            assert w.is_deleted()             # donation holds under the mesh
    for a, b in zip(outs["single"], outs["mesh"]):
        np.testing.assert_allclose(a, b, atol=1e-5)

    # end-to-end: a full simulation on the data mesh matches the
    # single-device run epoch for epoch
    from test_epoch_step import TinyFusedTrainer, W0
    from repro.core import FLSimulation, SimConfig
    from repro.fl import get_strategy
    from repro.launch.mesh import make_data_mesh

    rows = {}
    for label, mesh_arg in (("single", None), ("mesh", make_data_mesh())):
        sim = SimConfig(duration_s=86400.0, train_time_s=300.0,
                        use_model_bank=True, use_fused_step=True,
                        mesh=mesh_arg)
        fls = FLSimulation(get_strategy("asyncfleo-twohap"),
                           TinyFusedTrainer(W0), None, sim)
        hist = fls.run(W0, max_epochs=3)
        rows[label] = [(r.epoch, round(r.time_s, 6), r.num_models,
                        round(r.gamma, 6)) for r in hist]
        assert fls._fused_prog.dispatches == len(hist)
    assert rows["single"] == rows["mesh"]
    print("SHARDED-OK")
""")


def test_epoch_program_multi_device_sharding():
    here = os.path.dirname(__file__)
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(here, "..", "src"), here]))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED-OK" in proc.stdout
