# Event-driven async FL scheduling (DESIGN.md §7-§9): contact plans
# compiled from orbital geometry, a priority-queue runtime that pipelines
# up to StrategySpec.max_in_flight overlapping rounds over the fused
# epoch program, pluggable trigger policies (AsyncFLEO / sync barrier /
# FedAsync, with optional per-divergence-group deadlines), sink
# handoff policies (ring role swap / contact-plan next-contact), and
# finite per-PS link capacity (ContentionModel: StrategySpec.ps_channels
# parallel tx/rx channels per PS, FIFO grants, cross-round serialization),
# plus a pluggable fault/heterogeneity layer (FaultModel: per-sat compute
# rates, eclipse availability, lossy transfers with bounded retry/backoff)
# and its §11 degradation-and-recovery axes (Gilbert–Elliott burst loss,
# PS outage schedules with ring failover, per-sat energy budgets).
from repro.sched.contacts import (ChannelPool, ContactPlan, ContactWindow,
                                  ContentionModel)
from repro.sched.events import Event, EventKind, EventQueue
from repro.sched.faults import EnergyState, FaultModel, OutageSchedule
from repro.sched.policies import (AsyncFLEOPolicy, FedAsyncPolicy,
                                  HANDOFF_POLICIES, NextContactHandoff,
                                  POLICIES, RingHandoff, SyncBarrierPolicy,
                                  make_handoff_policy, make_policy)
from repro.sched.runtime import EventDrivenRuntime, RoundState

__all__ = ["ChannelPool", "ContactPlan", "ContactWindow", "ContentionModel",
           "Event", "EventKind", "FaultModel", "OutageSchedule",
           "EnergyState",
           "EventQueue", "AsyncFLEOPolicy", "SyncBarrierPolicy",
           "FedAsyncPolicy", "POLICIES", "make_policy",
           "RingHandoff", "NextContactHandoff", "HANDOFF_POLICIES",
           "make_handoff_policy", "EventDrivenRuntime", "RoundState"]
