"""Hypothesis property tests on the system's invariants.

Skips cleanly when ``hypothesis`` is not installed (it is not part of the
runtime container; CI installs it)."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import FLSimulation, SimConfig
from repro.core.aggregation import SatelliteMeta, asyncfleo_aggregate, fedavg
from repro.core.constellation import WalkerDelta
from repro.core.grouping import group_by_gaps
from repro.fl import get_strategy
from repro.kernels.fed_agg.ops import fed_agg
from repro.kernels.fed_agg.ref import fed_agg_flat_ref
from repro.models.scan_ops import chunked_scan, recurrent_scan
from repro.sched import EventDrivenRuntime, FaultModel, OutageSchedule
from repro.sched.policies import (AsyncFLEOPolicy, FedAsyncPolicy,
                                  SyncBarrierPolicy)

from test_epoch_step import TinyFusedTrainer, W0

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(vals=st.lists(st.floats(-10, 10), min_size=2, max_size=6),
       sizes=st.lists(st.integers(1, 500), min_size=2, max_size=6))
def test_fedavg_convex_hull(vals, sizes):
    n = min(len(vals), len(sizes))
    models = [{"w": np.full((3,), v, np.float32)} for v in vals[:n]]
    out = fedavg(models, sizes[:n])
    assert out["w"].min() >= min(vals[:n]) - 1e-4
    assert out["w"].max() <= max(vals[:n]) + 1e-4


@settings(**SETTINGS)
@given(vals=st.lists(st.floats(-5, 5), min_size=1, max_size=5),
       epochs=st.lists(st.integers(0, 4), min_size=1, max_size=5),
       beta=st.integers(1, 4), prev=st.floats(-5, 5))
def test_asyncfleo_always_convex(vals, epochs, beta, prev):
    n = min(len(vals), len(epochs))
    models = [{"w": np.full((2,), v, np.float32)} for v in vals[:n]]
    metas = [SatelliteMeta(i, 100.0, (0, 0), 0.0, e)
             for i, e in enumerate(epochs[:n])]
    w_prev = {"w": np.full((2,), prev, np.float32)}
    groups = {0: list(range(n))}
    w, info = asyncfleo_aggregate(w_prev, groups, models, metas, beta)
    lo = min(vals[:n] + [prev]) - 1e-4
    hi = max(vals[:n] + [prev]) + 1e-4
    assert (w["w"] >= lo).all() and (w["w"] <= hi).all()
    assert 0.0 <= info["gamma"] <= 1.0


@settings(**SETTINGS)
@given(ds=st.lists(st.floats(0.01, 100), min_size=1, max_size=12),
       k=st.integers(1, 4))
def test_group_by_gaps_partition(ds, k):
    d = {i: v for i, v in enumerate(ds)}
    groups = group_by_gaps(d, num_groups=k)
    flat = [o for g in groups for o in g]
    assert sorted(flat) == sorted(d)                    # exact partition
    # contiguity in distance order: max of one group <= min of next
    for a, b in zip(groups, groups[1:]):
        assert max(d[o] for o in a) <= min(d[o] for o in b) + 1e-12


@settings(**SETTINGS)
@given(o=st.integers(1, 6), n=st.integers(1, 10),
       alt=st.floats(500e3, 2000e3),
       t=st.floats(0, 20000))
def test_walker_positions_on_shell(o, n, alt, t):
    c = WalkerDelta(o, n, alt, 80.0)
    pos = c.positions(float(t))
    np.testing.assert_allclose(np.linalg.norm(pos, axis=-1), c.radius_m,
                               rtol=1e-9)


@settings(**SETTINGS)
@given(c=st.integers(1, 8), n=st.integers(1, 600),
       bw=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_fed_agg_kernel_property(c, n, bw, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    stack = jax.random.normal(ks[0], (c, n))
    gamma = jax.random.uniform(ks[1], (c,)) / c
    base = jax.random.normal(ks[2], (n,))
    out = fed_agg(stack, gamma, base, bw)
    ref = fed_agg_flat_ref(stack, gamma, base, bw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def _trigger_stub(sync: bool, min_models: int, timeout_s: float,
                  stall_s: float, duration_s: float):
    """`FLSimulation._trigger` reads only .sim and .spec — skip __init__
    (no constellation/timeline needed to exercise the split branches)."""
    fls = FLSimulation.__new__(FLSimulation)
    fls.sim = SimConfig(duration_s=duration_s, agg_timeout_s=timeout_s,
                        sync_stall_s=stall_s, min_models=min_models)
    fls.spec = get_strategy("fedisl" if sync else "asyncfleo-gs")
    return fls


@settings(**SETTINGS)
@given(steps=st.lists(st.integers(0, 40), min_size=0, max_size=10),
       dt=st.sampled_from([10.0, 30.0]),
       min_models=st.integers(1, 6),
       window=st.integers(0, 30),
       sync=st.booleans(),
       fired=st.integers(0, 50),
       horizon=st.integers(5, 60))
def test_trigger_splits_conserve_arrivals(steps, dt, min_models, window,
                                          sync, fired, horizon):
    """Every trigger policy's split must partition a round's arrivals
    EXACTLY — ``used + late == arrivals``, no drops, no duplicates — on
    every branch: the sync barrier, the async window, the min_models
    backstop, per-group deadlines, and FedAsync per-arrival.  Arrival
    times are dt-grid-quantized so exact ties (the ISSUE-5 regression
    class: tied arrivals at the backstop instant used to vanish) are
    common."""
    times = sorted(s * dt for s in steps)       # quantized -> exact ties
    arrivals = [(t, i, i) for i, t in enumerate(times)]
    fls = _trigger_stub(sync, min_models, window * dt, 20 * dt,
                        horizon * dt)
    t_agg, used, late = fls._trigger(arrivals, 0.0)
    assert used + late == arrivals              # exact partition
    assert used == arrivals[:len(used)]         # used is always a prefix
    assert all(a[0] <= t_agg for a in used) or len(used) == min(
        min_models, len(arrivals))              # backstop branch
    rt = SimpleNamespace(sim=fls.sim, fls=fls)
    rnd = SimpleNamespace(expected=arrivals, t_start=0.0, committed=False)
    for pol in (AsyncFLEOPolicy(),              # delegates to _trigger
                AsyncFLEOPolicy(group_timeouts={0: window * dt}),
                SyncBarrierPolicy(),
                FedAsyncPolicy()):
        t2, u2, l2 = pol.split(rt, rnd, fired * dt)
        assert u2 + l2 == arrivals, pol.name
        assert u2 == arrivals[:len(u2)], pol.name


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([8, 16, 32]),
       include_current=st.booleans())
def test_chunked_scan_equals_sequential(seed, chunk, include_current):
    key = jax.random.PRNGKey(seed)
    B, T, H, K, V = 1, 64, 2, 4, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, V)) * 0.3
    ld = -jax.random.uniform(ks[3], (B, T, H, K)) * 0.9
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    kw = dict(include_current=include_current)
    if not include_current:
        kw["bonus"] = u
    y1, s1 = recurrent_scan(r, k, v, ld, **kw)
    y2, s2 = chunked_scan(r, k, v, ld, chunk=chunk, **kw)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-5, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-5, rtol=1e-3)


@settings(**SETTINGS)
@given(ops=st.lists(st.tuples(st.integers(0, 2),          # PS id
                              st.floats(0.0, 1000.0),     # request time
                              st.floats(0.1, 60.0)),      # duration
                    min_size=1, max_size=30),
       snap_at=st.integers(0, 29), restore_at=st.integers(0, 29),
       channels=st.integers(1, 3))
def test_retries_never_double_reserve(ops, snap_at, restore_at, channels):
    """The §10 retry invariant: however grants, snapshots and restores
    interleave (a lossy retry rolls back its speculative grant and
    re-books after the backoff), every channel's busy intervals stay
    sorted and pairwise disjoint — a retransmission can never
    double-reserve a channel interval — and every grant honors its
    request time."""
    from repro.sched.contacts import ContentionModel
    c = ContentionModel(3, channels)
    snap = None
    for i, (ps, t, d) in enumerate(ops):
        if i == snap_at:
            snap = c.snapshot()
        assert c.grant_rx(ps, t, d) >= t
        if i == restore_at and snap is not None:
            c.restore(snap)                  # retry rollback...
            assert c.grant_rx(ps, t + d, d) >= t + d   # ...re-book later
    for ps in range(3):
        per_ch = {}
        for ch, s, e in c.rx.intervals(ps):
            per_ch.setdefault(ch, []).append((s, e))
        for ivs in per_ch.values():
            assert ivs == sorted(ivs)
            assert all(s < e for s, e in ivs)
            assert all(e0 <= s1 for (_, e0), (s1, _) in zip(ivs, ivs[1:]))


@settings(**SETTINGS)
@given(seed=st.integers(0, 100),
       keys=st.lists(st.tuples(st.integers(0, 5),      # sat
                               st.integers(0, 2),      # ps
                               st.integers(0, 3),      # round
                               st.integers(0, 3),      # attempt
                               st.floats(0.0, 50000.0)),  # t
                     min_size=1, max_size=40))
def test_fault_schedules_independent_of_query_order(seed, keys):
    """The §11 determinism contract: the Gilbert–Elliott channel and the
    PS outage schedule are pure functions of (seed, ids, time) — query
    them in any order (the event runtime pops events in time order, but
    retries/reroutes interleave arbitrarily) and the answers must not
    change, nor may compiling the schedule twice disagree."""
    fm = FaultModel(seed=seed, loss_prob=0.4, burst_len_s=900.0,
                    ps_outage_fraction=0.25, ps_outage_period_s=7200.0)
    def draw(k):
        s, p, r, a, t = k
        return (fm.transfer_fails(s, r, a, ps=p, t=t),
                fm.in_bad_window(s, p, t))
    fwd = [draw(k) for k in keys]
    rev = [draw(k) for k in reversed(keys)]
    assert fwd == rev[::-1]
    assert fm.outage_intervals(3, 50000.0) == fm.outage_intervals(3, 50000.0)
    sched = OutageSchedule(fm.outage_intervals(3, 50000.0), 3)
    downs = [sched.down_at(p, t) for (_, p, _, _, t) in keys]
    downs_rev = [sched.down_at(p, t) for (_, p, _, _, t) in reversed(keys)]
    assert downs == downs_rev[::-1]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 4),
       loss=st.sampled_from([0.0, 0.35]),
       burst=st.sampled_from([0.0, 1800.0]),
       outage=st.booleans(),
       energy=st.booleans(),
       strategy=st.sampled_from(["asyncfleo-twohap", "asyncfleo-pipelined"]))
def test_arrival_conservation_ledger(seed, loss, burst, outage, energy,
                                     strategy):
    """The §11 conservation ledger, across every recovery path at once
    (loss retries, burst fading, outage reroutes/failover, energy
    deferrals): every arrival a round ever expected is either committed
    (used directly or adopted from the carried-straggler set), dropped
    into exactly one ``dropped_*`` bucket, or still pending when the run
    ends — nothing leaks, nothing is double-counted.  Holds for the
    AsyncFLEO trigger policy, whose commits drain carried stragglers
    exhaustively."""
    kw = dict(seed=seed, loss_prob=loss, burst_len_s=burst,
              max_retries=2, retry_backoff_s=120.0)
    if outage:
        kw["ps_outages"] = ((0, 2000.0, 20000.0),)
    if energy:
        kw.update(battery_j=80.0, train_energy_j=50.0, tx_energy_j=10.0,
                  recharge_w=0.1)
    cfg = SimConfig(event_driven=True, duration_s=86400.0,
                    train_time_s=300.0, use_model_bank=True,
                    use_fused_step=True, fault_model=FaultModel(**kw))
    fls = FLSimulation(get_strategy(strategy), TinyFusedTrainer(W0),
                       None, cfg)
    rt = EventDrivenRuntime(fls)
    rt.run(W0, max_epochs=3)
    s = rt.stats
    dropped = (s["dropped_after_max_retries"] + s["dropped_unreachable"]
               + s["dropped_outage"] + s["dropped_energy"])
    leftover = len(fls._pend_meta) + sum(
        len(r.expected) for r in rt.rounds.values() if not r.committed)
    assert s["arrivals_expected"] == (
        s["arrivals_committed"] + dropped + leftover)


# ---- sparse contact compilation parity (DESIGN.md §14) ---------------------

@settings(max_examples=20, deadline=None)
@given(o=st.integers(1, 4), n=st.integers(1, 8),
       alt=st.floats(500e3, 2000e3),
       inc=st.floats(40.0, 90.0),
       scenario=st.sampled_from(["gs", "hap", "twohap", "hapring:4"]),
       dt=st.sampled_from([30.0, 60.0]),
       hours=st.integers(2, 5),
       t_query=st.floats(0.0, 7200.0))
def test_sparse_dense_contact_parity_property(o, n, alt, inc, scenario,
                                              dt, hours, t_query):
    """For ANY Walker geometry and PS scenario the sparse segment
    compiler must reproduce the dense grid's contact plan exactly: the
    identical window set (sats, nodes, bounds, delays) and identical
    next-contact answers — the coarse-to-fine elevation bound may only
    ever *defer* to dense evaluation, never disagree with it."""
    from repro.core.constellation import make_ps_nodes
    from repro.sched import ContactPlan

    cst = WalkerDelta(o, n, float(alt), float(inc))
    nodes = make_ps_nodes(scenario)
    dur = hours * 3600.0
    dense = ContactPlan.compile(cst, nodes, dur, dt)
    sparse = ContactPlan.compile(cst, nodes, dur, dt, visibility="sparse")
    wd, ws = dense.windows(), sparse.windows()
    assert [(w.sat, w.node, w.t_start, w.t_end, w.delay_s) for w in wd] == \
        [(w.sat, w.node, w.t_start, w.t_end, w.delay_s) for w in ws]
    assert dense.summary() == sparse.summary()
    sats = np.arange(cst.num_sats)
    t = min(float(t_query), dur - dt)
    td, pd = dense.next_contact(sats, t)
    ts, ps = sparse.next_contact(sats, t)
    np.testing.assert_array_equal(td, ts)
    np.testing.assert_array_equal(pd, ps)
    np.testing.assert_array_equal(dense.next_contact_by_node(t),
                                  sparse.next_contact_by_node(t))


# ---- batched scenario engine parity (DESIGN.md §13) ------------------------

@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_batched_sweep_parity_property(data):
    """The sweep engine's differential contract, under randomly drawn
    scenario batches over five axes — seed, constellation geometry,
    link rate, trigger policy (strategy) and staleness function: the
    batched engine's per-scenario histories, final weights and logical
    dispatch counts are BIT-identical to running each scenario
    sequentially through the event-driven runtime (the shared checker
    in test_sweep.py; `mode="exact"` unrolls the same per-scenario HLO
    into one program, so this is equality, not allclose)."""
    import dataclasses as _dc

    from test_sweep import BASE as SWEEP_BASE, assert_batched_parity

    n = data.draw(st.integers(min_value=2, max_value=4))
    specs = tuple(_dc.replace(
        SWEEP_BASE,
        seed=data.draw(st.integers(min_value=0, max_value=5)),
        num_orbits=data.draw(st.sampled_from([2, 3])),
        rate_bps=data.draw(st.sampled_from([16e6, 1e5])),
        strategy=data.draw(st.sampled_from(
            ["asyncfleo-gs", "fedisl", "asyncfleo-pipelined",
             "fedasync"])),
        staleness_fn=data.draw(st.sampled_from(["eq13", "poly", "hinge"])),
    ) for _ in range(n))
    assert_batched_parity(list(specs), max_epochs=2)
