"""Model aggregation (paper §IV-C2, Algorithm 2, eqs. 13-14) + FedAvg (eq. 4).

Selection: per group, keep *fresh* models (metadata.epoch == current beta) and
discard stale ones — unless a group has only stale models, in which case its
models participate with the staleness discount gamma (eq. 13):

    gamma = sum_n (D_n / D) * (k_n / beta)

Update (eq. 14):  w^{beta+1} = (1 - gamma) w^beta + sum_n p_n w_n, with
per-model weights p_n ∝ D_n * (k_n/beta) normalized to sum to gamma.  The
literal eq. 14 multiplies every selected model by the scalar gamma, which is
not convex for >1 model; ``strict_paper_eq14=True`` reproduces it anyway
(DESIGN.md §3 records this interpretation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modelbank import ModelBank


@dataclasses.dataclass
class SatelliteMeta:
    """Metadata tuple <ID, size, loc, ts, epoch> (paper §IV-C1)."""
    sat_id: int
    size: float                   # training-data size D_n
    loc: tuple                    # angular coordinates (for next-visit calc)
    ts: float                     # timestamp of transmission
    epoch: int                    # last global epoch this sat's model joined

    def is_fresh(self, beta: int) -> bool:
        return self.epoch >= beta


def dedup_indices(metas: List[SatelliteMeta]) -> List[int]:
    """Indices surviving duplicate-filtering (§IV-C1): keep the most recent
    timestamp per satellite id.  Host-only — callers with device-resident
    models use this to adjust row bookkeeping without touching tensors."""
    best: Dict[int, int] = {}
    for i, m in enumerate(metas):
        j = best.get(m.sat_id)
        if j is None or metas[j].ts < m.ts:
            best[m.sat_id] = i
    return sorted(best.values())


def dedup(models, metas: List[SatelliteMeta]):
    """Filter duplicates; ``models`` may be a list of pytrees or a
    device-resident ``ModelBank`` (row gather only when needed)."""
    keep = dedup_indices(metas)
    if len(keep) == len(metas):         # no duplicates: skip the row gather
        return models, metas
    if isinstance(models, ModelBank):
        return models.select(keep), [metas[i] for i in keep]
    return [models[i] for i in keep], [metas[i] for i in keep]


@jax.jit
def _wsum_flat(stack, w, base, bw):
    return bw * base + w @ stack


@jax.jit
def _wsum_flat_nobase(stack, w):
    return w @ stack


def _flat_base(bank: ModelBank, base):
    """Base model as a flat (N,) device vector (None -> None)."""
    from repro.core.modelbank import flat_base
    return flat_base(bank.spec, base)


def scatter_weights(rows, weights, n_rows: int) -> np.ndarray:
    """Host-side weight scatter shared by the segmented stacked paths:
    ``w_seg[rows[j]] = weights[j]`` for every ``rows[j] >= 0`` (model j
    lives in another segment otherwise)."""
    w = np.zeros(n_rows, dtype=np.float32)
    for j, r in enumerate(rows):
        if r >= 0:
            w[r] = weights[j]
    return w


def combine_stacked(terms, base_flat=None, base_weight: float = 0.0, *,
                    use_kernel: bool = False):
    """w = base_weight * base + sum over (stack, weight_vector) terms.

    Each term is one fused (C_s,) @ (C_s, N) contraction — models split
    across several device matrices (epoch bank, carried stragglers) are
    combined without gathering or concatenating rows.  Zero-weight terms
    are skipped on host.  ``use_kernel`` chains the terms through the
    Pallas fed_agg kernel (the first pass folds in the base, later passes
    accumulate).  Returns the flat (N,) result.
    """
    live = []
    for stack, w in terms:
        if stack is None or stack.shape[0] == 0:
            continue
        w = np.asarray(w, np.float32)
        if not w.any():
            continue
        live.append((stack, w))
    if not live:
        return (jnp.float32(base_weight) * jnp.asarray(base_flat)
                if base_flat is not None and base_weight != 0.0
                else (jnp.zeros_like(base_flat) if base_flat is not None
                      else None))
    if use_kernel:
        from repro.kernels.fed_agg import ops as agg_ops
        out = None
        for stack, w in live:
            if out is None:
                out = agg_ops.fed_agg(stack, jnp.asarray(w),
                                      None if base_weight == 0.0
                                      or base_flat is None
                                      else jnp.asarray(base_flat),
                                      base_weight)
            else:
                out = agg_ops.fed_agg(stack, jnp.asarray(w), out, 1.0)
        return out
    out = None
    if base_flat is not None and base_weight != 0.0:
        out = jnp.float32(base_weight) * jnp.asarray(base_flat)
    for stack, w in live:
        term = _wsum_flat_nobase(stack, jnp.asarray(w))
        out = term if out is None else out + term
    return out


def weighted_sum_stacked(bank: ModelBank, weights, base=None,
                         base_weight: float = 0.0, *,
                         use_kernel: bool = False) -> jnp.ndarray:
    """Stacked fast path of :func:`weighted_sum`.

    The per-model weights are a host-side vector (they come from metadata,
    eq. 13/14); all tensor work is one fused device call — a (1,C)x(C,N)
    contraction — either through XLA or the Pallas ``fed_agg`` kernel.
    Returns the flat (N,) result; unflatten via ``bank.spec`` when a pytree
    is needed.
    """
    w = jnp.asarray(np.asarray(weights, np.float32))
    if use_kernel:
        from repro.kernels.fed_agg import ops as agg_ops
        return agg_ops.fed_agg_bank(bank, w, base, base_weight)
    bflat = _flat_base(bank, base)
    if bflat is not None and base_weight != 0.0:
        return _wsum_flat(bank.stack, w, bflat,
                          jnp.float32(base_weight))
    return _wsum_flat_nobase(bank.stack, w)


def weighted_sum(models, weights: Sequence[float], base=None,
                 base_weight: float = 0.0, *, use_kernel: bool = False):
    """w = base_weight * base + sum_i weights_i * models_i.

    ``models`` may be a list of pytrees (host math, legacy path) or a
    ``ModelBank`` — then the whole reduction is a single fused device call
    and the *flat* (N,) result is returned (see DESIGN.md §2).
    ``use_kernel`` routes the reduction through the Pallas fed_agg kernel.
    """
    if isinstance(models, ModelBank):
        return weighted_sum_stacked(models, weights, base, base_weight,
                                    use_kernel=use_kernel)
    if use_kernel:
        from repro.kernels.fed_agg import ops as agg_ops
        return agg_ops.fed_agg_pytree(models, np.asarray(weights, np.float32),
                                      base, base_weight)
    ws = [float(w) for w in weights]

    def comb(*leaves):
        acc = sum(w * np.asarray(l, dtype=np.float32) for w, l in zip(ws, leaves))
        return acc
    out = jax.tree.map(comb, *models)
    if base is not None and base_weight != 0.0:
        out = jax.tree.map(lambda b, o: base_weight * np.asarray(b, np.float32) + o,
                           base, out)
    elif base is not None:
        pass
    return out


def fedavg(models, sizes: Sequence[float], *, use_kernel=False):
    """Synchronous FedAvg (eq. 4).  Accepts pytree lists or a ModelBank."""
    total = float(sum(sizes))
    return weighted_sum(models, [s / total for s in sizes], use_kernel=use_kernel)


def staleness_gamma(metas: Sequence[SatelliteMeta], total_data: float,
                    beta: int) -> float:
    """eq. (13) over the selected (stale) models."""
    if beta <= 0:
        return 1.0
    g = sum((m.size / total_data) * (max(m.epoch, 0) / beta) for m in metas)
    return float(np.clip(g, 0.0, 1.0))


# ---- staleness-function zoo (DESIGN.md §10) ---------------------------------
# The paper pins eq. 13's discount k_n/beta; FedGSM motivates sweeping
# alternatives, so the FedAsync family (SNIPPETS.md §1, FLGo defaults) is
# selectable per strategy via StrategySpec.staleness_fn.  All but "eq13"
# discount by the staleness *gap* delta = beta - k_n.
STALENESS_FNS = ("eq13", "constant", "hinge", "poly")
HINGE_A = 10.0      # FLGo fedasync defaults
HINGE_B = 6.0
POLY_A = 0.5


def staleness_factor(fn: str, beta: int, epoch: int) -> float:
    """Multiplicative staleness discount in (0, 1] for a model last
    aggregated at global epoch ``epoch``, joining at epoch ``beta``.

    * ``eq13``     — k_n / beta (the paper's discount; 0 for never-joined)
    * ``constant`` — 1 (FedAsync a-lin: no mitigation)
    * ``hinge``    — 1 while delta <= b, then 1 / (a * (delta - b))
    * ``poly``     — (1 + delta) ** -a
    """
    if fn == "eq13":
        return max(epoch, 0) / max(beta, 1)
    delta = max(beta - epoch, 0)
    if fn == "constant":
        return 1.0
    if fn == "hinge":
        return 1.0 if delta <= HINGE_B else 1.0 / (HINGE_A * (delta - HINGE_B))
    if fn == "poly":
        return float((1.0 + delta) ** (-POLY_A))
    raise ValueError(f"unknown staleness_fn {fn!r}; available: "
                     f"{STALENESS_FNS}")


def asyncfleo_weights(groups: Dict[int, List[int]],
                      metas: List[SatelliteMeta], beta: int, *,
                      strict_paper_eq14: bool = False,
                      min_gamma: float = 0.1,
                      staleness_fn: str = "eq13"):
    """Algorithm 2 selection + eq. 13/14 weight vector — pure host metadata
    math, no tensors.  Returns (selected indices, per-selected weights,
    gamma, info); selected is empty when nothing qualifies.

    ``staleness_fn`` swaps eq. 13's k_n/beta discount for one of the
    FedAsync family (:func:`staleness_factor`); "eq13" (the default)
    keeps the paper's exact arithmetic, byte for byte."""
    selected: List[int] = []
    stale_only_groups = 0
    for gi, idxs in groups.items():
        fresh = [i for i in idxs if metas[i].is_fresh(beta)]
        if fresh:
            selected.extend(fresh)          # discard the group's stale models
        else:
            selected.extend(idxs)           # stale-only group joins, discounted
            stale_only_groups += 1
    if not selected:
        return [], np.zeros(0), 0.0, {"gamma": 0.0, "selected": 0,
                                      "stale_groups": 0}

    total_data = sum(metas[i].size for i in selected)
    sel_metas = [metas[i] for i in selected]
    all_fresh = all(m.is_fresh(beta) for m in sel_metas)
    if all_fresh:
        gamma = 1.0                          # pure data-weighted FedAvg step
        raw = np.array([m.size for m in sel_metas], np.float64)
    elif staleness_fn == "eq13":
        gamma = max(staleness_gamma(sel_metas, total_data, beta), min_gamma)
        raw = np.array([m.size * (max(m.epoch, 0) / max(beta, 1) if not m.is_fresh(beta) else 1.0)
                        for m in sel_metas], np.float64)
        if raw.sum() <= 0.0:                 # all k_n == 0: size-weight instead
            raw = np.array([m.size for m in sel_metas], np.float64)
    else:
        # zoo discount: gamma is the size-weighted mean of the per-model
        # factors (the eq. 13 shape with s(delta) in place of k_n/beta),
        # clipped to [min_gamma, 1]; stale models weight by size * s(delta)
        phi = [staleness_factor(staleness_fn, beta, m.epoch)
               for m in sel_metas]
        g = sum((m.size / total_data) * p for m, p in zip(sel_metas, phi))
        gamma = float(np.clip(g, min_gamma, 1.0))
        raw = np.array([m.size * (p if not m.is_fresh(beta) else 1.0)
                        for m, p in zip(sel_metas, phi)], np.float64)
        if raw.sum() <= 0.0:
            raw = np.array([m.size for m in sel_metas], np.float64)

    if strict_paper_eq14:
        weights = np.full(len(selected), gamma)
    else:
        weights = gamma * raw / raw.sum()
    info = {"gamma": gamma, "selected": len(selected),
            "stale_groups": stale_only_groups}
    return selected, weights, gamma, info


def epoch_weight_vector(agg_mode: str, metas: List[SatelliteMeta],
                        beta: int, groups: Optional[Dict[int, List[int]]],
                        *, strict_paper_eq14: bool = False,
                        staleness_fn: str = "eq13"):
    """Per-model weight vector + base weight for one epoch's update —
    pure host metadata math shared by the stacked and fused simulator
    paths (the fused epoch program takes the result as an input,
    DESIGN.md §6).  Returns (ws (n_meta,), base_weight, info).

    ``agg_mode``: "fedavg" (eq. 4), "per_arrival" (FedSat-style EMA,
    closed form), "interval" (FedSpace emulation, DESIGN.md §3), anything
    else -> AsyncFLEO Alg. 2 selection + eqs. 13/14 over ``groups``.
    """
    n_meta = len(metas)
    info = {"gamma": 1.0, "stale_groups": 0}
    if n_meta == 0:
        return np.zeros(0), 1.0, info
    if agg_mode == "fedavg":
        total = float(sum(m.size for m in metas))
        return np.array([m.size / total for m in metas]), 0.0, info
    if agg_mode == "per_arrival":
        # closed form of the sequential EMA: model i keeps
        # alpha_i * prod_{j>i} (1 - alpha_j)
        alphas = [0.5 / (1.0 + max(beta - m.epoch, 0)) for m in metas]
        ws = np.zeros(n_meta)
        bw = 1.0
        for i in reversed(range(n_meta)):
            ws[i] = alphas[i] * (1.0 if i == n_meta - 1 else
                                 ws[i + 1] / alphas[i + 1]
                                 * (1.0 - alphas[i + 1]))
        for i in range(n_meta):
            bw *= 1.0 - alphas[i]
        return ws, bw, info
    if agg_mode == "interval":
        total = sum(m.size for m in metas)
        raw = np.array([m.size * (1.0 / (1.0 + max(beta - m.epoch, 0)))
                        for m in metas])
        gam = float(np.clip(raw.sum() / max(total, 1e-9), 0.2, 1.0))
        info["gamma"] = gam
        return gam * raw / raw.sum(), 1.0 - gam, info
    selected, wsel, gamma, info = asyncfleo_weights(
        groups, metas, beta, strict_paper_eq14=strict_paper_eq14,
        staleness_fn=staleness_fn)
    ws = np.zeros(n_meta)
    if selected:
        ws[selected] = wsel
        return ws, 1.0 - gamma, info
    return ws, 1.0, info


def asyncfleo_aggregate(w_prev, groups: Dict[int, List[int]], models,
                        metas: List[SatelliteMeta], beta: int, *,
                        strict_paper_eq14: bool = False,
                        min_gamma: float = 0.1,
                        staleness_fn: str = "eq13",
                        use_kernel: bool = False):
    """Algorithm 2 lines 12-17.

    ``groups``: group id -> indices into models/metas.  ``models`` may be a
    list of pytrees or a device-resident ``ModelBank``; selection and the
    per-model weight vector are host metadata work either way
    (:func:`asyncfleo_weights`), the tensor update is one fused call on the
    stacked path.  Returns (w_new, info dict) — ``w_new`` is flat (N,) on
    the stacked path, a pytree otherwise.
    """
    stacked = isinstance(models, ModelBank)
    selected, weights, gamma, info = asyncfleo_weights(
        groups, metas, beta, strict_paper_eq14=strict_paper_eq14,
        min_gamma=min_gamma, staleness_fn=staleness_fn)
    if not selected:
        return w_prev, info

    if stacked:
        # no row gather: selection becomes zeros in the weight vector over
        # the full bank, so the update stays one fused call
        full = np.zeros(len(models), dtype=np.float64)
        full[selected] = weights
        sel_models, weights = models, full
    else:
        sel_models = [models[i] for i in selected]

    w_new = weighted_sum(sel_models, weights, base=w_prev,
                         base_weight=1.0 - gamma, use_kernel=use_kernel)
    return w_new, info
