"""Satellite grouping by model-weight divergence (paper §IV-C1, Fig. 5).

The PS cannot see data (FL), so data-distribution similarity is inferred from
model weights: per orbit, a *partial global model* S'_o = data-size-weighted
average of that orbit's received local models; its Euclidean distance to the
*initial* global model w0 (largest divergence happens in epoch 1, giving the
sharpest differentiation) places the orbit on a 1-D axis; orbits with similar
distances form a group.  Later epochs assign new orbits to the group whose
members' mean distance is closest.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modelbank import ModelBank


@functools.partial(jax.jit, static_argnames=("k",))
def _blocked_distances(stack, w, ref, k):
    """Distances of k equal contiguous-block partial models to ref — one
    fused O(C*N) batched contraction (weights normalized per block)."""
    c, n = stack.shape
    pm = jnp.einsum("kc,kcn->kn", w.reshape(k, c // k),
                    stack.reshape(k, c // k, n))
    return jnp.linalg.norm(pm - ref[None, :], axis=1)


@jax.jit
def _dense_distances(weight_matrix, stack, ref):
    """General case: per-orbit weight rows -> partial models -> distances,
    one fused (K,C)x(C,N) contraction."""
    return jnp.linalg.norm(weight_matrix @ stack - ref[None, :], axis=1)


def flatten_model(model) -> np.ndarray:
    if getattr(model, "ndim", None) == 1:         # already a flat vector
        return np.asarray(model, dtype=np.float32)
    return np.concatenate([np.asarray(l, dtype=np.float32).ravel()
                           for l in jax.tree_util.tree_leaves(model)])


def model_distance(model, ref_flat: np.ndarray) -> float:
    """|| flat(model) - flat(w0) ||_2."""
    return float(np.linalg.norm(flatten_model(model) - ref_flat))


def partial_global_model(models, sizes: Sequence[float]):
    """Data-size-weighted average of one orbit's local models (Fig. 5a).
    With a ``ModelBank`` this is one fused (1,C)x(C,N) device contraction
    returning the flat (N,) partial model; pytree lists keep host math."""
    total = float(sum(sizes))
    if isinstance(models, ModelBank):
        ws = jnp.asarray(np.asarray(sizes, np.float32) / total)
        return ws @ models.stack
    ws = [s / total for s in sizes]
    return jax.tree.map(
        lambda *leaves: sum(w * np.asarray(l, dtype=np.float32)
                            for w, l in zip(ws, leaves)),
        *models)


def group_by_gaps(distances: Dict[int, float], num_groups: int = 3) -> List[List[int]]:
    """1-D clustering: sort orbit distances, split at the (num_groups-1)
    largest gaps.  Deterministic; matches the paper's 'similar Euclidean
    distances are grouped together'."""
    orbits = sorted(distances, key=lambda o: distances[o])
    if len(orbits) <= num_groups:
        return [[o] for o in orbits]
    vals = np.array([distances[o] for o in orbits])
    gaps = np.diff(vals)
    cuts = np.sort(np.argsort(gaps)[::-1][: num_groups - 1])
    groups, start = [], 0
    for c in cuts:
        groups.append(orbits[start:c + 1])
        start = c + 1
    groups.append(orbits[start:])
    return groups


def segment_partial_inputs(new_orbits: Sequence[int],
                           orbit_indices: Dict[int, List[int]],
                           rows: Sequence[int], sizes: Sequence[float],
                           totals: Dict[int, float], n_rows: int,
                           dump: int):
    """Per-row (weight, segment id) arrays for the fused epoch program's
    O(C*N) partial-model segment-sum: row ``r`` gets orbit k's
    size-normalized weight when model j with ``rows[j] == r`` belongs to
    ``new_orbits[k]``; unowned rows get weight 0 and segment ``dump``.
    Each bank row feeds at most one orbit, which is what makes the
    segment-sum equivalent to the dense (K, n_rows) matrix product."""
    w = np.zeros(n_rows, np.float32)
    seg = np.full(n_rows, dump, np.int32)
    for k, orbit in enumerate(new_orbits):
        for j in orbit_indices[orbit]:
            r = rows[j]
            if r >= 0:
                w[r] = sizes[j] / totals[orbit]
                seg[r] = k
    return w, seg


def segment_weight_matrix(new_orbits: Sequence[int],
                          orbit_indices: Dict[int, List[int]],
                          rows: Sequence[int], sizes: Sequence[float],
                          totals: Dict[int, float],
                          n_rows: int) -> np.ndarray:
    """(K, n_rows) per-orbit partial-model weight rows for ONE segment:
    row k holds the size-normalized weights of orbit k's models that live
    in this segment (``rows[j]`` is model j's row there, -1 elsewhere).
    Host metadata math — shared by ``observe_orbits_multi`` and the fused
    epoch program, which takes the matrices as inputs and returns the
    distances (DESIGN.md §6)."""
    from repro.core.aggregation import scatter_weights
    return np.stack([scatter_weights(
        [rows[j] for j in orbit_indices[orbit]],
        [sizes[j] / totals[orbit] for j in orbit_indices[orbit]],
        n_rows) for orbit in new_orbits]) if new_orbits else \
        np.zeros((0, n_rows), np.float32)


@dataclasses.dataclass
class GroupingState:
    """Incremental grouping maintained by the sink HAP."""
    ref_flat: Optional[np.ndarray] = None          # flat(w0), host copy
    distances: Dict[int, float] = dataclasses.field(default_factory=dict)
    groups: List[List[int]] = dataclasses.field(default_factory=list)
    num_groups: int = 3
    use_dist_kernel: bool = False      # route distances through pairwise_dist
    _ref_dev: Optional[object] = dataclasses.field(default=None, repr=False)

    def set_reference(self, w0) -> None:
        self.ref_flat = flatten_model(w0)
        self._ref_dev = jnp.asarray(self.ref_flat)

    def _ref_device(self):
        """Device copy of ref_flat — derived lazily so a GroupingState
        built with the public ``ref_flat`` field (legacy style) still works
        on the stacked paths."""
        if self._ref_dev is None:
            assert self.ref_flat is not None, "set_reference(w0) first"
            self._ref_dev = jnp.asarray(self.ref_flat)
        return self._ref_dev

    def group_of(self, orbit: int) -> Optional[int]:
        for gi, g in enumerate(self.groups):
            if orbit in g:
                return gi
        return None

    def observe_orbit(self, orbit: int, models, sizes: Sequence[float]) -> int:
        """Ingest an orbit's freshly received models; returns its group id.
        First sighting computes the partial-model distance; known orbits keep
        their stored group (paper: 'directly assigned to the associated
        group').  ``models`` may be a pytree list or a ``ModelBank`` — the
        stacked path fuses the partial model and its distance-to-w0 into
        device calls (only the scalar distance reaches host)."""
        gi = self.group_of(orbit)
        if gi is not None:
            return gi
        assert self.ref_flat is not None, "set_reference(w0) first"
        pm = partial_global_model(models, sizes)
        if isinstance(models, ModelBank):
            if self.use_dist_kernel:
                from repro.kernels.pairwise_dist.ops import dist_to_ref
                d = float(dist_to_ref(pm[None], self._ref_device())[0])
            else:
                d = float(jnp.linalg.norm(pm - self._ref_device()))
        else:
            d = model_distance(pm, self.ref_flat)
        self.distances[orbit] = d
        if len(self.groups) < self.num_groups:
            # still building the grouping (paper: first epoch(s)) — recluster
            # over every orbit distance seen so far so early arrivals don't
            # freeze a degenerate single group.
            self.groups = group_by_gaps(self.distances, self.num_groups)
            return self.group_of(orbit)                     # type: ignore
        # grouping established: assign to nearest group by mean distance
        means = [np.mean([self.distances[o] for o in g if o in self.distances])
                 if any(o in self.distances for o in g) else np.inf
                 for g in self.groups]
        gi = int(np.argmin([abs(d - m) for m in means]))
        self.groups[gi].append(orbit)
        return gi

    def observe_orbits(self, orbit_indices: Dict[int, List[int]],
                       bank: ModelBank,
                       sizes: Sequence[float]) -> Dict[int, int]:
        """Batched ``observe_orbit`` over a whole epoch's arrivals.

        ``orbit_indices``: orbit id -> row indices into ``bank``;
        ``sizes``: per-row data sizes.  All partial global models of *new*
        orbits are computed in ONE fused segment-sum over the stacked
        (C, N) bank and all distances-to-w0 in one norm call — only the
        per-orbit scalar distances reach host.  Returns orbit -> group id.
        """
        out: Dict[int, int] = {}
        new_orbits = []
        for orbit in orbit_indices:
            gi = self.group_of(orbit)
            if gi is not None:
                out[orbit] = gi
            else:
                new_orbits.append(orbit)
        if not new_orbits:
            return out
        assert self.ref_flat is not None, "set_reference(w0) first"
        # per-model weight vectors are host metadata math; the tensor work
        # is one fused device call either way
        counts = [len(orbit_indices[o]) for o in new_orbits]
        idx_all = np.concatenate([orbit_indices[o] for o in new_orbits])
        if (len(set(counts)) == 1 and len(idx_all) == len(bank)
                and np.array_equal(idx_all, np.arange(len(bank)))):
            # common layout (constellation order, equal orbits): O(C*N)
            # blocked reduction instead of the O(K*C*N) dense contraction
            w = np.zeros(len(bank), dtype=np.float32)
            for orbit in new_orbits:
                idxs = orbit_indices[orbit]
                total = float(sum(sizes[j] for j in idxs))
                for j in idxs:
                    w[j] = sizes[j] / total
            ds = np.asarray(_blocked_distances(bank.stack, jnp.asarray(w),
                                               self._ref_device(),
                                               len(new_orbits)))
        else:
            W = np.zeros((len(new_orbits), len(bank)), dtype=np.float32)
            for k, orbit in enumerate(new_orbits):
                idxs = orbit_indices[orbit]
                total = float(sum(sizes[j] for j in idxs))
                for j in idxs:
                    W[k, j] = sizes[j] / total
            ds = np.asarray(_dense_distances(jnp.asarray(W), bank.stack,
                                             self._ref_device()))
        self._assign_new(new_orbits, ds, out)
        return out

    def observe_orbits_multi(self, orbit_indices: Dict[int, List[int]],
                             segments, sizes: Sequence[float]) -> Dict[int, int]:
        """``observe_orbits`` over models split across device matrices.

        ``segments``: list of (stack (C_s, N) or None, rows) where
        ``rows[j]`` is model j's row in that stack (-1 elsewhere) — e.g. the
        epoch's training bank plus a small carried-stragglers matrix.  Each
        segment contributes one fused (K,C_s)x(C_s,N) term to the partial
        models; no rows are gathered or concatenated.
        """
        out: Dict[int, int] = {}
        new_orbits = [o for o in orbit_indices if self.group_of(o) is None]
        for o in orbit_indices:
            if o not in new_orbits:
                out[o] = self.group_of(o)                       # type: ignore
        if not new_orbits:
            return out
        assert self.ref_flat is not None, "set_reference(w0) first"
        totals = {o: float(sum(sizes[j] for j in orbit_indices[o]))
                  for o in new_orbits}
        pm = None
        for stack, rows in segments:
            if stack is None or stack.shape[0] == 0:
                continue
            W = segment_weight_matrix(new_orbits, orbit_indices, rows,
                                      sizes, totals, stack.shape[0])
            if not W.any():
                continue
            term = jnp.asarray(W) @ stack
            pm = term if pm is None else pm + term
        if pm is None:
            return out
        ds = np.asarray(jnp.linalg.norm(pm - self._ref_device()[None, :],
                                        axis=1))
        self._assign_new(new_orbits, ds, out)
        return out

    def assign_distances(self, new_orbits: Sequence[int],
                         ds: Sequence[float]) -> Dict[int, int]:
        """Record externally computed distances-to-w0 (e.g. the fused epoch
        program's output) for new orbits and assign their groups — the same
        sequential replay ``observe_orbits*`` uses."""
        out: Dict[int, int] = {}
        self._assign_new(list(new_orbits), np.asarray(ds), out)
        return out

    def _assign_new(self, new_orbits, ds, out: Dict[int, int]) -> None:
        """Replay the exact sequential observe_orbit assignment logic
        (distances enter one at a time so intermediate reclusters match)."""
        for orbit, d in zip(new_orbits, ds):
            self.distances[orbit] = float(d)
            if len(self.groups) < self.num_groups:
                self.groups = group_by_gaps(self.distances, self.num_groups)
                out[orbit] = self.group_of(orbit)               # type: ignore
                continue
            means = [np.mean([self.distances[o] for o in g
                              if o in self.distances])
                     if any(o in self.distances for o in g) else np.inf
                     for g in self.groups]
            gi = int(np.argmin([abs(float(d) - m) for m in means]))
            self.groups[gi].append(orbit)
            out[orbit] = gi

    def regroup(self) -> None:
        """Re-run the gap clustering over all seen orbits (end of an epoch
        where new orbits appeared)."""
        if self.distances:
            self.groups = group_by_gaps(self.distances, self.num_groups)

    def all_grouped(self, num_orbits: int) -> bool:
        return sum(len(g) for g in self.groups) >= num_orbits
