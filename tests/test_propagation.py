import numpy as np
import pytest

from repro.core.constellation import make_ps_nodes, paper_constellation
from repro.core.links import LinkModel
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline

BITS = 1e6 * 32


@pytest.fixture(scope="module", params=["hap", "twohap"])
def prop(request):
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes(request.param), 86400.0, 10.0)
    topo = RingOfStars(c, tl.nodes, tl)
    return PropagationModel(topo, LinkModel())


def test_downlink_times_causal(prop):
    recv = prop.downlink_times(0.0, BITS, source=0)
    finite = recv[np.isfinite(recv)]
    assert len(finite) > 0
    assert (finite >= 0.0).all()
    # visible satellites receive before the invisible ones they relay to
    vis0 = prop.topo.timeline.visible(0.0)[:, 0]
    if vis0.any() and (~vis0).any():
        assert recv[vis0].min() <= recv[~vis0].min() + 1e9


def test_downlink_relay_bounds(prop):
    """A satellite reached via k ISL hops receives exactly k hop-delays after
    its seed when its orbit has a visible seed at t0."""
    topo = prop.topo
    recv = prop.downlink_times(0.0, BITS, source=0)
    hop = prop.isl_hop_delay(BITS)
    for orbit in range(topo.constellation.num_orbits):
        sats = topo.orbit_sats(orbit)
        rs = recv[sats]
        if not np.isfinite(rs).all():
            continue
        # max spread within an orbit <= (N/2 hops) * hop delay + direct spread
        assert rs.max() - rs.min() <= 4 * hop + 60.0


def test_uplink_after_done(prop):
    t_done = 1000.0
    for sat in range(0, 40, 7):
        t_arr, hap = prop.uplink(sat, t_done, BITS, sink=0)
        if np.isfinite(t_arr):
            assert t_arr > t_done
            assert 0 <= hap < prop.topo.num_ps


def test_uplink_visible_faster_than_invisible(prop):
    """Satellites visible at t_done upload sooner (no waiting)."""
    tl = prop.topo.timeline
    t = 0.0
    vis = tl.visible(t).any(axis=1)
    if vis.any() and (~vis).any():
        t_vis, _ = prop.uplink(int(np.flatnonzero(vis)[0]), t, BITS, 0)
        # the visible satellite's arrival is prompt (< 10 min)
        assert t_vis - t < 600.0


def test_hap_receive_times_ring(prop):
    ht = prop.hap_receive_times(0.0, BITS, source=0)
    assert ht[0] == 0.0
    if len(ht) > 1:
        assert (ht[1:] > 0).all()
