"""Discrete-event FL simulation over LEO trajectories (paper §V).

The simulator advances *simulated* time (seconds over a 3-day horizon) while
running *real* JAX training for every satellite's local model.  Per global
epoch beta:

  1. downlink  — Alg. 1 timing gives each satellite its receive time of
     w^beta (ring-of-stars + ISL relay for strategies that have ISL; plain
     next-visibility otherwise);
  2. train     — each satellite trains for J local iterations (real SGD),
     finishing ``train_time_s`` later in simulated time;
  3. uplink    — arrival time of each local model at the sink PS;
  4. aggregate — strategy-dependent trigger and rule (AsyncFLEO grouping +
     staleness discounting; FedAvg barrier; per-arrival; fixed interval);
  5. evaluate  — test accuracy of the new global model at the trigger time.

When the trainer exposes ``train_many_stacked`` (and
``SimConfig.use_model_bank`` is left on), steps 2-4 run on the
device-resident ``ModelBank`` path: local models stay one stacked (C, N)
array from training output through grouping and aggregation — no
per-satellite pytree unstacking, no ``device_get``; only the new global
model is unflattened (on device) once per epoch for the evaluator and the
next downlink.  Trainers without the stacked API (e.g. test stubs) use the
legacy pytree path.

The output is a history of (sim_time_s, epoch, accuracy, ...) rows, from
which convergence time (time to reach a target accuracy) is read — the
paper's Table II / Fig. 6 quantities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import SatelliteMeta
from repro.core.constellation import (WalkerDelta, make_ps_nodes,
                                      paper_constellation)
from repro.core.grouping import GroupingState
from repro.core.links import LinkModel, model_bits
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline
from repro.fl.strategies import StrategySpec


@dataclasses.dataclass
class SimConfig:
    duration_s: float = 3 * 86400.0
    dt_s: float = 10.0
    train_time_s: float = 600.0        # on-board local-training wall time
    agg_timeout_s: float = 1500.0      # async collection window per epoch
    min_models: int = 2                # never aggregate on fewer arrivals
    eval_fn: Optional[object] = None   # params -> accuracy
    seed: int = 0
    sync_stall_s: float = 86400.0      # cap a sync round at this (stragglers)
    link: Optional[LinkModel] = None   # None -> paper Table I RF (16 Mb/s)
    use_model_bank: bool = True        # stacked path when trainer supports it


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    time_s: float
    accuracy: float
    num_models: int
    gamma: float
    stale_groups: int


class FLSimulation:
    def __init__(self, spec: StrategySpec, trainer, evaluator,
                 sim: SimConfig, constellation: Optional[WalkerDelta] = None):
        self.spec = spec
        self.trainer = trainer
        self.evaluator = evaluator
        self.sim = sim
        self.constellation = constellation or paper_constellation()
        self.nodes = make_ps_nodes(spec.ps_scenario)
        self.timeline = VisibilityTimeline(self.constellation, self.nodes,
                                           sim.duration_s, sim.dt_s)
        self.topo = RingOfStars(self.constellation, self.nodes, self.timeline)
        self.prop = PropagationModel(self.topo, sim.link or LinkModel())
        self.grouping = GroupingState(num_groups=spec.num_groups)
        self.orbit_ids = self.constellation.orbit_ids()
        # persistent per-satellite bookkeeping
        self.last_epoch_included: Dict[int, int] = {}
        # legacy path: (arrival_t, sat, host pytree, trained_from_epoch)
        self.pending: List[tuple] = []
        # stacked path: stragglers live in a small host matrix (O(late)
        # rows, not O(S)) and re-enter aggregation as their own fused term
        self._pend_np: Optional[np.ndarray] = None       # (L, N) float32
        self._pend_meta: List[tuple] = []      # (arrival_t, sat, epoch)
        self._spec = None              # FlatSpec of the stacked path

    # ------------------------------------------------------------------

    def _downlink(self, t0: float, bits: float, source: int) -> np.ndarray:
        if self.spec.use_isl:
            return self.prop.downlink_times(t0, bits, source)
        # no ISL: each satellite waits for direct visibility (vectorized)
        S = self.constellation.num_sats
        sats = np.arange(S)
        tv, ps = self.timeline.next_visible_after(sats, t0)
        recv = np.full(S, np.inf)
        ok = np.isfinite(tv)
        for h in np.unique(ps[ok]):
            m = ok & (ps == h)
            d = self.topo.sat_ps_distances(sats[m], int(h), tv[m])
            recv[m] = tv[m] + self.prop.link.total_delay(bits, d)
        return recv

    def _uplink_many(self, sats, t_done, bits: float, sink: int):
        if self.spec.use_isl:
            return self.prop.uplink_many(sats, t_done, bits, sink)
        sats = np.asarray(sats, dtype=np.int64)
        tv, ps = self.timeline.next_visible_after(sats, t_done)
        out = np.full(len(sats), np.inf)
        hap = np.asarray(ps, dtype=np.int64)
        ok = np.isfinite(tv)
        for h in np.unique(hap[ok]):
            m = ok & (hap == h)
            d = self.topo.sat_ps_distances(sats[m], int(h), tv[m])
            out[m] = tv[m] + self.prop.link.total_delay(bits, d)
        return out, hap

    def _combine(self, segments, weights, base_flat, base_weight: float):
        """Map metas-indexed ``weights`` onto per-segment weight vectors and
        run the fused stacked combination (host bookkeeping + one
        contraction per segment)."""
        terms = []
        for stack, rows in segments:
            if stack is None or stack.shape[0] == 0:
                continue
            terms.append((stack,
                          agg.scatter_weights(rows, weights, stack.shape[0])))
        out = agg.combine_stacked(terms, base_flat, base_weight,
                                  use_kernel=self.spec.use_agg_kernel)
        return base_flat if out is None else out

    # ------------------------------------------------------------------

    def run(self, w0, max_epochs: int = 30,
            target_accuracy: Optional[float] = None) -> List[EpochRecord]:
        sim, spec = self.sim, self.spec
        bits = model_bits(w0)
        self.grouping.set_reference(w0)
        stacked = sim.use_model_bank and hasattr(self.trainer,
                                                 "train_many_stacked")
        w_tree = w0                       # pytree view (trainer/evaluator)
        w_flat = None                     # flat device view (stacked path)
        t = 0.0
        source = 0
        history: List[EpochRecord] = []
        S = self.constellation.num_sats

        for beta in range(max_epochs):
            if t >= sim.duration_s:
                break
            sink = self.topo.sink_of(source)
            recv = self._downlink(t, bits, source)

            # local training (real JAX, one batched call) + uplink timing
            participants = [s for s in range(S) if np.isfinite(recv[s])]
            bank = None
            if participants:
                if stacked:
                    bank, _losses = self.trainer.train_many_stacked(
                        participants, w_tree, seed=sim.seed * 1000 + beta)
                    self._spec = bank.spec
                    trained = range(len(participants))   # row indices
                else:
                    trained, _losses = self.trainer.train_many(
                        participants, w_tree, seed=sim.seed * 1000 + beta)
                t_done = recv[participants] + sim.train_time_s
                t_arr_vec, _haps = self._uplink_many(participants, t_done,
                                                     bits, sink)
                arrivals = [(float(t_arr_vec[k]), s, payload)
                            for k, (s, payload)
                            in enumerate(zip(participants, trained))
                            if np.isfinite(t_arr_vec[k])]
                arrivals.sort(key=lambda a: a[0])
            else:
                arrivals = []
            if not arrivals and not self.pending and not self._pend_meta:
                break

            # ---- aggregation trigger --------------------------------------
            if spec.sync:
                t_agg = min(arrivals[-1][0] if arrivals else t,
                            t + sim.sync_stall_s)
                used = [a for a in arrivals if a[0] <= t_agg]
                late = [a for a in arrivals if a[0] > t_agg]
            else:
                t_first = arrivals[0][0] if arrivals else t
                t_agg = min(t_first + sim.agg_timeout_s, sim.duration_s)
                used = [a for a in arrivals if a[0] <= t_agg]
                if len(used) < sim.min_models:
                    used = arrivals[: sim.min_models]
                    t_agg = used[-1][0] if used else t_agg
                late = [a for a in arrivals if a[0] > t_agg]

            # models stuck from previous epochs arrive as stale candidates
            metas = [SatelliteMeta(s, self.trainer.data_size(s),
                                   loc=(0.0, 0.0), ts=ta, epoch=beta)
                     for (ta, s, _p) in used]
            segments = None
            if stacked:
                c_idx = [i for i, (ta, _s, _ep) in enumerate(self._pend_meta)
                         if ta <= t_agg]
                k_idx = [i for i in range(len(self._pend_meta))
                         if i not in c_idx]
                metas += [SatelliteMeta(s, self.trainer.data_size(s),
                                        loc=(0.0, 0.0), ts=ta, epoch=ep)
                          for (ta, s, ep) in (self._pend_meta[i]
                                              for i in c_idx)]
                # row bookkeeping instead of row gathers: metas index j maps
                # to a row of the intact epoch bank or the carried matrix
                bank_rows = ([k for (_, _, k) in used]
                             + [-1] * len(c_idx))
                carry_rows = [-1] * len(used) + list(range(len(c_idx)))
                carry_np = (self._pend_np[np.asarray(c_idx)]
                            if c_idx else None)
                # retire carried stragglers, enqueue this epoch's late rows
                # (bucketed gather + one small device_get — O(late), not O(S))
                keep_np = (self._pend_np[np.asarray(k_idx)]
                           if k_idx else None)
                keep_meta = [self._pend_meta[i] for i in k_idx]
                if late:
                    from repro.core.modelbank import (gather_rows,
                                                      pad_bucket_ids)
                    lk, n_late = pad_bucket_ids([k for (_, _, k) in late])
                    late_np = np.asarray(jax.device_get(
                        gather_rows(bank.stack, lk)))[:n_late]
                    keep_np = (late_np if keep_np is None else
                               np.concatenate([keep_np, late_np]))
                    keep_meta += [(ta, s, beta) for (ta, s, _k) in late]
                self._pend_np, self._pend_meta = keep_np, keep_meta

                keep = agg.dedup_indices(metas)
                if len(keep) < len(metas):
                    metas = [metas[i] for i in keep]
                    bank_rows = [bank_rows[i] for i in keep]
                    carry_rows = [carry_rows[i] for i in keep]
                carry_dev = (jnp.asarray(carry_np)
                             if carry_np is not None
                             and any(r >= 0 for r in carry_rows) else None)
                segments = [(bank.stack if bank is not None else None,
                             bank_rows), (carry_dev, carry_rows)]
                models = None
            else:
                carried = [(ta, s, p, ep) for (ta, s, p, ep) in self.pending
                           if ta <= t_agg]
                self.pending = [x for x in self.pending if x[0] > t_agg]
                self.pending.extend((ta, s, p, beta) for (ta, s, p) in late)
                metas += [SatelliteMeta(s, self.trainer.data_size(s),
                                        loc=(0.0, 0.0), ts=ta, epoch=ep)
                          for (ta, s, _p, ep) in carried]
                models = ([p for (_, _, p) in used]
                          + [p for (_, _, p, _) in carried])
                models, metas = agg.dedup(models, metas)
            if stacked and w_flat is None:
                w_flat = self._spec.flatten(w_tree) if self._spec else None
            base = w_flat if stacked else w_tree

            # ---- aggregate -------------------------------------------------
            # per-model weights are host metadata math in every mode; on the
            # stacked path the tensor update is a couple of fused per-segment
            # contractions (epoch bank + carried stragglers), no row copies
            info = {"gamma": 1.0, "stale_groups": 0}
            n_meta = len(metas)
            if spec.agg_mode == "fedavg":
                if stacked:
                    total = float(sum(m.size for m in metas))
                    ws = np.array([m.size / total for m in metas])
                    w_new = self._combine(segments, ws, None, 0.0)
                else:
                    w_new = agg.fedavg(models, [m.size for m in metas],
                                       use_kernel=spec.use_agg_kernel)
            elif spec.agg_mode == "per_arrival":
                if stacked:
                    # closed form of the sequential EMA: model i keeps
                    # alpha_i * prod_{j>i} (1 - alpha_j)
                    alphas = [0.5 / (1.0 + max(beta - m.epoch, 0))
                              for m in metas]
                    ws = np.zeros(n_meta)
                    bw = 1.0
                    for i in reversed(range(n_meta)):
                        ws[i] = alphas[i] * (1.0 if i == n_meta - 1 else
                                             ws[i + 1] / alphas[i + 1]
                                             * (1.0 - alphas[i + 1]))
                    for i in range(n_meta):
                        bw *= 1.0 - alphas[i]
                    w_new = self._combine(segments, ws, base, bw)
                else:
                    w_new = base
                    for m_i, meta in zip(models, metas):
                        alpha = 0.5 / (1.0 + max(beta - meta.epoch, 0))
                        w_new = agg.weighted_sum([m_i], [alpha], base=w_new,
                                                 base_weight=1.0 - alpha)
            elif spec.agg_mode == "interval":
                total = sum(m.size for m in metas)
                raw = np.array([m.size * (1.0 / (1.0 + max(beta - m.epoch, 0)))
                                for m in metas])
                gam = float(np.clip(raw.sum() / max(total, 1e-9), 0.2, 1.0))
                if stacked:
                    w_new = self._combine(segments, gam * raw / raw.sum(),
                                          base, 1.0 - gam)
                else:
                    w_new = agg.weighted_sum(models, gam * raw / raw.sum(),
                                             base=base, base_weight=1.0 - gam)
                t_agg = max(t_agg, t + spec.interval_s)
                info["gamma"] = gam
            else:                                        # asyncfleo (Alg. 2)
                groups: Dict[int, List[int]] = {}
                if not spec.grouping:                    # ablation: one group
                    groups[0] = list(range(len(metas)))
                elif stacked:
                    # batched: all new-orbit partial models + distances in
                    # fused per-segment contractions over the bank
                    orbit_indices: Dict[int, List[int]] = {}
                    for i, meta in enumerate(metas):
                        orbit_indices.setdefault(
                            int(self.orbit_ids[meta.sat_id]), []).append(i)
                    orbit_group = self.grouping.observe_orbits_multi(
                        orbit_indices, segments, [m.size for m in metas])
                    for i, meta in enumerate(metas):
                        gi = orbit_group[int(self.orbit_ids[meta.sat_id])]
                        groups.setdefault(gi, []).append(i)
                else:
                    for i, meta in enumerate(metas):
                        orbit = int(self.orbit_ids[meta.sat_id])
                        gi = self.grouping.group_of(orbit)
                        if gi is None:     # first sighting: distance to w0
                            same_orbit = [j for j, mm in enumerate(metas)
                                          if int(self.orbit_ids[mm.sat_id])
                                          == orbit]
                            gi = self.grouping.observe_orbit(
                                orbit, [models[j] for j in same_orbit],
                                [metas[j].size for j in same_orbit])
                        groups.setdefault(gi, [])
                        if i not in groups[gi]:
                            groups[gi].append(i)
                if stacked:
                    selected, wsel, gamma, info = agg.asyncfleo_weights(
                        groups, metas, beta,
                        strict_paper_eq14=spec.strict_paper_eq14)
                    if selected:
                        ws = np.zeros(n_meta)
                        ws[selected] = wsel
                        w_new = self._combine(segments, ws, base, 1.0 - gamma)
                    else:
                        w_new = base
                else:
                    w_new, info = agg.asyncfleo_aggregate(
                        base, groups, models, metas, beta,
                        strict_paper_eq14=spec.strict_paper_eq14,
                        use_kernel=spec.use_agg_kernel)

            if stacked:
                w_flat = (w_new if getattr(w_new, "ndim", None) == 1
                          else self._spec.flatten(w_new))
                w_tree = self._spec.unflatten(w_flat)    # device, 1x/epoch
            else:
                w_tree = w_new

            for meta in metas:
                self.last_epoch_included[meta.sat_id] = beta

            acc = float(self.evaluator(w_tree)) if self.evaluator else float("nan")
            history.append(EpochRecord(beta, t_agg, acc, len(metas),
                                       float(info.get("gamma", 1.0)),
                                       int(info.get("stale_groups", 0))))
            t = t_agg
            source, sink = sink, source            # §IV-B3 role swap
            if target_accuracy is not None and acc >= target_accuracy:
                break
        return history


def convergence_time(history: List[EpochRecord], target: float) -> Optional[float]:
    for rec in history:
        if rec.accuracy >= target:
            return rec.time_s
    return None
