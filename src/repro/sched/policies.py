"""Pluggable trigger + handoff policies for the event runtime
(DESIGN.md §7; the per-group deadlines and the handoff contract are §8).

A *trigger* policy decides WHEN the sink PS aggregates; WHAT the update
computes (eqs. 4/13/14, the per-arrival EMA, the interval emulation) stays
with the strategy's ``agg_mode`` (`core/aggregation.epoch_weight_vector`),
so a policy is pure scheduling logic over a round's expected/observed
arrivals:

* ``round_deadline``  — absolute TRIGGER_TIMEOUT to schedule when a round
  opens (the sync barrier's straggler stall; the idle timeout of a round
  that only drains carried stragglers), or None;
* ``on_arrival``      — absolute trigger time a MODEL_ARRIVAL should
  schedule (AsyncFLEO schedules first-arrival + idle timeout — or, with
  ``group_timeouts`` set, one deadline per divergence group of the
  arriving satellite, DESIGN.md §8; the sync barrier fires when the last
  expected model lands; FedAsync fires on every arrival), or None;
* ``split``           — at trigger time, the (t_agg, used, late) partition
  of the round's arrivals.  AsyncFLEO and the sync barrier delegate to
  ``FLSimulation._trigger`` so the event runtime reproduces the epoch
  loop's aggregation instants *exactly* (the parity contract in
  tests/test_sched.py);
* ``round_complete``  — whether a commit closes the round (PS roles swap).

A *handoff* policy decides WHERE the next round runs when a SINK_HANDOFF
fires (DESIGN.md §8 handoff contract):

* ``next_round(rt, rnd, t) -> (source, sink)`` — the PS that broadcasts
  the next global model and the PS that collects its arrivals.
  ``RingHandoff`` reproduces the paper's §IV-B3 role swap (the previous
  sink becomes the source, the farthest ring HAP the sink) and is the
  ``max_in_flight=1`` parity default; ``NextContactHandoff`` consults the
  compiled ``ContactPlan`` (``next_contact_by_node``) and picks the PS
  with the earliest upcoming satellite contact as source (and, with >1
  PS, the next-earliest as sink) — the contact-plan-driven downlink
  scheduling of arXiv:2302.13447.
* ``next_open_time(rt, rnd) -> float | None`` — when a *pipelined*
  successor round may open while ``rnd`` is still in flight (None =
  never).  The default is the round's first expected arrival: by then
  the fastest satellites are done training and the constellation can
  absorb the next downlink while the current collection window runs.
* ``failover_sink(rt, rnd, t) -> int | None`` — the replacement sink for
  an open round whose sink PS just went dark (a PS_DOWN event,
  DESIGN.md §11).  ``RingHandoff`` picks the nearest live ring PS;
  ``NextContactHandoff`` prefers the live PS with the earliest upcoming
  satellite contact (least-rx-busy tiebreak).  None = every PS is dark;
  the round keeps its sink and its arrivals hold at the ring edge until
  a recovery.

Policies are selected from the strategy table (`fl/strategies.py`):
``StrategySpec.sched_policy`` names the trigger policy (sync strategies
default to the barrier, ``per_arrival`` aggregation to FedAsync,
everything else to the AsyncFLEO window), ``StrategySpec.handoff_policy``
names the handoff policy ("" -> ring swap), and
``StrategySpec.group_timeouts`` feeds the AsyncFLEO policy's per-group
deadlines.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.simulator import split_min_models

Arrival = Tuple[float, int, int]                 # (t_arrival, sat, bank row)


@dataclasses.dataclass
class AsyncFLEOPolicy:
    """AsyncFLEO (Alg. 2 trigger): the first arrival of a round opens a
    collection window of ``agg_timeout_s``; everything that lands inside
    aggregates in ONE fused dispatch, later arrivals carry over as
    stragglers.  ``min_models`` backstop handled by ``_trigger``.

    ``group_timeouts`` (group id -> window seconds; -1 = not-yet-grouped
    orbits) turns the single window into per-divergence-group deadlines
    (DESIGN.md §8): the first arrival FROM EACH GROUP opens that group's
    window and the round commits at the earliest group deadline.  Empty
    (the default) keeps the single global window — bit-identical to the
    epoch loop, which the parity tests pin.

    ``rx_backlog_threshold_s`` (from ``StrategySpec``, DESIGN.md §10)
    makes the windows contention-aware: when the sink PS's pending
    rx-channel backlog exceeds the threshold at window-open time, the
    window is multiplied by ``rx_backlog_window_scale`` — a congested
    sink commits sooner instead of idling for arrivals that are stuck in
    the rx queue anyway.  None (the default) never scales and keeps the
    ``split`` delegation to ``_trigger`` — bit-identical windows."""
    name: str = "asyncfleo"
    group_timeouts: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    rx_backlog_threshold_s: Optional[float] = None
    rx_backlog_window_scale: float = 0.5

    def window_s(self, rt, group: int) -> float:
        return float(self.group_timeouts.get(group, rt.sim.agg_timeout_s))

    def _scaled(self, rt, rnd, t: float, window: float) -> float:
        """Contention-aware shrink of an idle window (no-op when the
        threshold is off or the sink's rx pool is under it)."""
        thr = self.rx_backlog_threshold_s
        if thr is None:
            return window
        ctn = getattr(rt.plan, "contention", None)
        if ctn is None or ctn.backlog("rx", rnd.sink, t) <= thr:
            return window
        stats = getattr(rt, "stats", None)
        if stats is not None:
            stats["shrunk_windows"] = stats.get("shrunk_windows", 0) + 1
        tracer = getattr(rt, "tracer", None)
        if tracer is not None and tracer.enabled:
            from repro.obs.trace import EV_WINDOW_SHRUNK
            tracer.instant(EV_WINDOW_SHRUNK, t, track=f"round {rnd.idx}",
                           window_s=float(window),
                           scale=float(self.rx_backlog_window_scale))
        return window * self.rx_backlog_window_scale

    def round_deadline(self, rt, rnd) -> Optional[float]:
        if rnd.expected:                 # first arrival opens the window
            return None
        return min(rnd.t_start + rt.sim.agg_timeout_s, rt.sim.duration_s)

    def on_arrival(self, rt, rnd, t: float, sat: int = -1
                   ) -> Optional[float]:
        if not self.group_timeouts:
            if rnd.trigger_scheduled is None:
                return min(t + self._scaled(rt, rnd, t, rt.sim.agg_timeout_s),
                           rt.sim.duration_s)
            return None
        g = rt.group_of_sat(sat)
        if g in rnd.group_first:         # group window already open
            return None
        rnd.group_first[g] = t
        return min(t + self._scaled(rt, rnd, t, self.window_s(rt, g)),
                   rt.sim.duration_s)

    def on_arrival_batch(self, rt, rnd, t: float, sats) -> List[
            Optional[float]]:
        """Batched ``on_arrival`` for a same-instant arrival run
        (DESIGN.md §14).  Contract shared by every policy: the policy
        performs the per-arrival ``rnd.arrived_count`` increments itself
        and returns one trigger (or None) per arrival, exactly what the
        sequential increment-then-call loop would have produced — in
        particular it must account for the runtime's between-arrival
        ``trigger_scheduled`` updates.  Here: without group deadlines
        only the FIRST arrival of the run can open the window (the
        sequential loop sets ``trigger_scheduled`` before the second
        call); with groups, per-arrival calls are already independent of
        ``trigger_scheduled`` and delegate unchanged."""
        if not self.group_timeouts:
            rnd.arrived_count += len(sats)
            out: List[Optional[float]] = [None] * len(sats)
            if rnd.trigger_scheduled is None:
                out[0] = min(
                    t + self._scaled(rt, rnd, t, rt.sim.agg_timeout_s),
                    rt.sim.duration_s)
            return out
        out = []
        for s in sats:
            rnd.arrived_count += 1
            out.append(self.on_arrival(rt, rnd, t, sat=s))
        return out

    def split(self, rt, rnd, t_fired: float):
        if not self.group_timeouts and self.rx_backlog_threshold_s is None:
            # delegate to the epoch loop's trigger: identical aggregation
            # instants (the parity contract)
            return rt.fls._trigger(rnd.expected, rnd.t_start)
        # per-group / contention-aware mode: the fired deadline IS the
        # aggregation instant (with shrink active, `_trigger` would
        # recompute the unshrunk window); the min_models backstop is the
        # SAME helper `_trigger`'s async branch uses, so the two can't
        # drift (and tied arrivals at the backstop instant are carried,
        # not dropped)
        t_agg = min(t_fired, rt.sim.duration_s)
        return split_min_models(rnd.expected, t_agg, rt.sim.min_models)

    def round_complete(self, rnd) -> bool:
        return True

    def on_expected_drop(self, rt, rnd, t: float) -> Optional[float]:
        """A lossy transfer was dropped from ``rnd.expected`` after max
        retries (DESIGN.md §10).  When nothing is left in flight and no
        window is pending the round can never resolve on its own —
        trigger now (a 0-model commit / carried-straggler drain) instead
        of hanging until the event queue drains."""
        if not rnd.expected and rnd.trigger_scheduled is None:
            return t
        return None


@dataclasses.dataclass
class SyncBarrierPolicy:
    """Synchronous FedAvg barrier: aggregate when every expected model has
    arrived, or at the straggler stall ``sync_stall_s`` — whichever comes
    first (the GS-FedAvg baselines: fedisl / fedhap / Razmi-style
    ground-station FL)."""
    name: str = "sync"

    def round_deadline(self, rt, rnd) -> Optional[float]:
        if not rnd.expected:
            return rnd.t_start               # nothing to wait for
        # horizon-clamped like the AsyncFLEO / FedAsync deadlines: a
        # barrier stall must not fire (and commit an epoch) past the end
        # of the simulation
        return min(rnd.t_start + rt.sim.sync_stall_s, rt.sim.duration_s)

    def on_arrival(self, rt, rnd, t: float, sat: int = -1
                   ) -> Optional[float]:
        if rnd.arrived_count == len(rnd.expected):
            return t                         # barrier complete: fire now
        return None

    def on_arrival_batch(self, rt, rnd, t: float, sats) -> List[
            Optional[float]]:
        """Sequential semantics: the count walks base+1 .. base+n and the
        barrier fires at the single index where it equals the expected
        size — a naive increment-all-then-test would fire every arrival
        of the completing run (duplicate TRIGGER pushes, sequence-number
        drift, broken bit-parity)."""
        base = rnd.arrived_count
        n_exp = len(rnd.expected)
        rnd.arrived_count = base + len(sats)
        return [t if base + i + 1 == n_exp else None
                for i in range(len(sats))]

    def split(self, rt, rnd, t_fired: float):
        return rt.fls._trigger(rnd.expected, rnd.t_start)

    def round_complete(self, rnd) -> bool:
        return True

    def on_expected_drop(self, rt, rnd, t: float) -> Optional[float]:
        """A dropped transfer shrinks the barrier: when every *surviving*
        expected model has already arrived the barrier is complete now —
        fire instead of stalling until ``sync_stall_s``."""
        if rnd.arrived_count >= len(rnd.expected):
            return t
        return None


@dataclasses.dataclass
class FedAsyncPolicy:
    """FedAsync-style immediate aggregation: every MODEL_ARRIVAL triggers
    its own (small) aggregation — the first one of a round consumes the
    fused training dispatch (remaining rows carry over as pending
    stragglers), later ones drain the carried matrix as they land.  The
    round closes after its last expected arrival."""
    name: str = "per_arrival"

    def round_deadline(self, rt, rnd) -> Optional[float]:
        if rnd.expected:
            return None
        return min(rnd.t_start + rt.sim.agg_timeout_s, rt.sim.duration_s)

    def on_arrival(self, rt, rnd, t: float, sat: int = -1
                   ) -> Optional[float]:
        return t

    def on_arrival_batch(self, rt, rnd, t: float, sats) -> List[
            Optional[float]]:
        # every arrival fires: n triggers at t, pushed in arrival order
        # by the runtime's batch tail — same sequence numbers as the
        # sequential loop's per-arrival pushes
        rnd.arrived_count += len(sats)
        return [t] * len(sats)

    def split(self, rt, rnd, t_fired: float):
        if not rnd.committed:
            used = [a for a in rnd.expected if a[0] <= t_fired]
            late = [a for a in rnd.expected if a[0] > t_fired]
            return t_fired, used, late
        return t_fired, [], []               # drain carried arrivals only

    def round_complete(self, rnd) -> bool:
        return rnd.arrived_count >= len(rnd.expected)

    def on_expected_drop(self, rt, rnd, t: float) -> Optional[float]:
        """Same rescue as the AsyncFLEO window: an uncommitted round whose
        every transfer was dropped must still resolve (``round_complete``
        is re-checked by the runtime after the drop either way)."""
        if not rnd.expected and rnd.trigger_scheduled is None:
            return t
        return None


POLICIES = {
    "asyncfleo": AsyncFLEOPolicy,
    "sync": SyncBarrierPolicy,
    "per_arrival": FedAsyncPolicy,
}


def make_policy(spec, name: str = ""):
    """Policy for a strategy spec: the explicit ``spec.sched_policy`` when
    set, else derived — sync strategies get the barrier, ``per_arrival``
    aggregation gets FedAsync, everything else the AsyncFLEO window.
    ``spec.group_timeouts`` pairs feed the AsyncFLEO policy's per-group
    deadlines (DESIGN.md §8)."""
    key = name or getattr(spec, "sched_policy", "")
    if not key:
        if spec.sync:
            key = "sync"
        elif spec.agg_mode == "per_arrival":
            key = "per_arrival"
        else:
            key = "asyncfleo"
    if key not in POLICIES:
        raise KeyError(f"unknown scheduler policy {key!r}; "
                       f"available: {sorted(POLICIES)}")
    policy = POLICIES[key]()
    gt = dict(getattr(spec, "group_timeouts", ()) or ())
    if gt and isinstance(policy, AsyncFLEOPolicy):
        policy.group_timeouts = gt
    if isinstance(policy, AsyncFLEOPolicy):
        policy.rx_backlog_threshold_s = getattr(
            spec, "rx_backlog_threshold_s", None)
        policy.rx_backlog_window_scale = float(getattr(
            spec, "rx_backlog_window_scale", 0.5))
    return policy


# ---- sink handoff (where the next round runs, DESIGN.md §8) ----------------


@dataclasses.dataclass
class RingHandoff:
    """The paper's §IV-B3 role swap: the previous round's sink becomes
    the next source, and the sink is the ring HAP farthest from it
    (`topology.sink_of`).  This is the ``max_in_flight=1`` parity
    default — the epoch loop hard-codes exactly this rotation."""
    name: str = "ring"

    def next_round(self, rt, rnd, t: float) -> Tuple[int, int]:
        source = rnd.sink
        return source, rt.fls.topo.sink_of(source)

    def next_open_time(self, rt, rnd) -> Optional[float]:
        # pipeline a successor at the round's first expected arrival:
        # the fastest satellites are free again and the sink's collection
        # window runs concurrently with the next downlink
        return rnd.expected[0][0] if rnd.expected else None

    def failover_sink(self, rt, rnd, t: float) -> Optional[int]:
        # PS outage failover (DESIGN.md §11): the nearest live ring PS
        # takes over collection; None when every PS is dark
        return rt._next_live_ps(rnd.sink, t)


@dataclasses.dataclass
class NextContactHandoff(RingHandoff):
    """Contact-plan-driven handoff: the next round's source is the PS
    with the *earliest upcoming satellite contact* at handoff time
    (``ContactPlan.next_contact_by_node``), so the new global model
    starts moving as soon as any link exists; with more than one PS the
    sink is the next-earliest-contact PS (it can start collecting
    soonest).  Ties on contact time break toward the PS with the lowest
    channel occupancy (pending tx backlog for the source, rx backlog for
    the sink — `ContentionModel.backlog`, DESIGN.md §9), so under finite
    ``ps_channels`` overlapping rounds spread across the least-loaded
    HAPs, the FedHAP-style collaborative-transfer effect.  Without a
    contention model every backlog is 0 and the lowest PS id wins —
    identical to the historical ``argmin``.  Falls back to the ring swap
    when the plan is exhausted."""
    name: str = "next_contact"

    @staticmethod
    def _least_busy(rt, candidates: List[int], t: float, kind: str) -> int:
        ctn = getattr(rt.plan, "contention", None)
        if ctn is None or len(candidates) == 1:
            return candidates[0]
        return min(candidates, key=lambda p: (ctn.backlog(kind, p, t), p))

    def next_round(self, rt, rnd, t: float) -> Tuple[int, int]:
        tv = rt.plan.next_contact_by_node(t)
        if not np.isfinite(tv).any():
            return RingHandoff.next_round(self, rt, rnd, t)
        cands = [int(p) for p in np.flatnonzero(tv == tv.min())]
        source = self._least_busy(rt, cands, t, "tx")
        if len(tv) > 1:
            rest = tv.copy()
            rest[source] = np.inf
            if np.isfinite(rest).any():
                sc = [int(p) for p in np.flatnonzero(rest == rest.min())]
                sink = self._least_busy(rt, sc, t, "rx")
            else:
                sink = rt.fls.topo.sink_of(source)
        else:
            sink = source
        return source, sink

    def failover_sink(self, rt, rnd, t: float) -> Optional[int]:
        # among LIVE PSs (excluding the dead sink), prefer the one whose
        # next satellite contact comes earliest — it can resume
        # collecting soonest — with the §9 least-rx-busy tiebreak; falls
        # back to the ring nearest-live rule when no live PS has a
        # finite upcoming contact
        o = rt._outages
        tv = rt.plan.next_contact_by_node(t)
        live = [p for p in range(len(tv))
                if p != rnd.sink and not o.down_at(p, t)
                and np.isfinite(tv[p])]
        if not live:
            return RingHandoff.failover_sink(self, rt, rnd, t)
        best = min(tv[p] for p in live)
        cands = [p for p in live if tv[p] == best]
        return self._least_busy(rt, cands, t, "rx")


HANDOFF_POLICIES = {
    "ring": RingHandoff,
    "next_contact": NextContactHandoff,
}


def make_handoff_policy(spec, name: str = ""):
    """Handoff policy for a strategy spec ("" -> the ring role swap)."""
    key = name or getattr(spec, "handoff_policy", "") or "ring"
    if key not in HANDOFF_POLICIES:
        raise KeyError(f"unknown handoff policy {key!r}; "
                       f"available: {sorted(HANDOFF_POLICIES)}")
    return HANDOFF_POLICIES[key]()
