"""Host-side dispatch profiling for the fused epoch program.

`core/epoch_step.EpochStepProgram` counts dispatches but says nothing
about where the host wall-clock went — cold trace+compile calls are
orders of magnitude slower than steady-state executes, and without
separating them a bench row's ``wall_s`` conflates both.  A
:class:`DispatchProfiler` attached as ``program.profiler`` (or via
``SimConfig.profiler``, which `core/simulator._init_run` forwards)
receives a callback around every ``step()`` dispatch:

* **cold vs steady**: a dispatch whose static signature — (carry rows,
  participant count, ``kpad``, ``blocked_m``, fallback) — has not been
  seen by this profiler is a trace+compile call and its wall time lands
  in ``compile_s``; repeats land in ``dispatch_s``.  Dispatch is async
  (the program returns lazy arrays), so these are *host dispatch*
  times; pass ``block=True`` to block on the outputs inside the timed
  region for device-inclusive numbers (changes what is measured, never
  the results).
* **dispatches per trigger**: the event runtime calls ``trigger()``
  once per commit, so ``summary()`` can report how many device programs
  each aggregation trigger consumed (> 1 only via the two-dispatch
  fallback).

``profiler=None`` (the default everywhere) skips the hook entirely —
the program's ``step`` takes the exact pre-existing path.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Set, Tuple


class DispatchProfiler:
    """Wall-clock accounting of fused-epoch dispatches.

    One profiler per run (it keys cold-ness on signatures *it* has
    seen; the program's jit cache may be warmer when the trainer is
    reused across runs — then every call lands in ``dispatch_s``, which
    is the truth: nothing compiled).
    """

    def __init__(self, block: bool = False):
        self.block = bool(block)
        self.dispatches = 0                # total step() calls
        self.cold_dispatches = 0           # first-seen static signatures
        self.fallback_dispatches = 0       # two-dispatch fallback calls
        self.compile_s = 0.0               # host seconds in cold calls
        self.dispatch_s = 0.0              # host seconds in warm calls
        self.triggers = 0                  # runtime commits observed
        self._seen: Set[Tuple] = set()
        # scenario-batched sweeps share one profiler across worker
        # threads (sweep/batch.py): commits race on trigger(); record()
        # stays driver-thread-only so the timing path is uncontended
        self._trigger_lock = threading.Lock()

    # ---- hooks (called by EpochStepProgram.step / the runtime) -------------

    def record(self, signature: Tuple, fallback: bool,
               wall_s: float) -> None:
        """One dispatch completed: ``signature`` is the static shape key,
        ``wall_s`` the host seconds spent in the dispatch call."""
        self.dispatches += 1
        if fallback:
            self.fallback_dispatches += 1
        if signature in self._seen:
            self.dispatch_s += wall_s
        else:
            self._seen.add(signature)
            self.cold_dispatches += 1
            self.compile_s += wall_s

    def trigger(self) -> None:
        """One aggregation trigger committed (runtime hook)."""
        with self._trigger_lock:
            self.triggers += 1

    # ---- reading -----------------------------------------------------------

    def timer(self) -> float:
        return time.perf_counter()

    def summary(self) -> Dict:
        """JSON-serializable wall-clock attribution for bench rows."""
        warm = self.dispatches - self.cold_dispatches
        return {
            "dispatches": self.dispatches,
            "cold_dispatches": self.cold_dispatches,
            "fallback_dispatches": self.fallback_dispatches,
            "compile_s": self.compile_s,
            "dispatch_s": self.dispatch_s,
            "dispatch_mean_s": (self.dispatch_s / warm) if warm else None,
            "triggers": self.triggers,
            "dispatches_per_trigger": ((self.dispatches / self.triggers)
                                       if self.triggers else None),
            "blocking": self.block,
        }

    def reset(self) -> None:
        self.__init__(block=self.block)
