"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for every
model input / parameter / optimizer leaf — weak-type-correct, shardable, no
device allocation."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.steps import cache_len_for, make_optimizer
from repro.models import registry as R


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract batch for (arch, shape) — the paper-assigned global shapes."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32)}
    if cfg.frontend == "audio_stub":
        out = {"frame_embeds": _sds((B, S, cfg.d_model), cfg.dtype),
               "labels": _sds((B, S), jnp.int32),
               "mask": _sds((B, S), jnp.bool_)}
        if shape.kind == "prefill":
            out.pop("labels")
            out.pop("mask")
        return out
    if cfg.frontend == "vision_stub":
        P = cfg.num_prefix_embeds
        return {"tokens": _sds((B, max(S - P, 1)), jnp.int32),
                "prefix_embeds": _sds((B, P, cfg.d_model), cfg.dtype)}
    return {"tokens": _sds((B, S), jnp.int32)}


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda k: R.init_params(k, cfg),
                          _sds((2,), jnp.uint32))


def opt_state_specs(cfg: ModelConfig, params_spec=None):
    opt = make_optimizer()
    params_spec = params_spec or param_specs(cfg)
    return jax.eval_shape(opt.init, params_spec)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    assert shape.kind == "decode"
    cl = cache_len_for(cfg, shape)
    return jax.eval_shape(
        lambda: R.init_cache(cfg, shape.global_batch, cl, jnp.dtype(cfg.dtype)))
