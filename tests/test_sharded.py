"""Constellation-parallel shard_map runtime + sharding-rule resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.fl.sharded import make_fl_round
from repro.launch import make_host_mesh
from repro.launch.sharding import (BASE_RULES, FSDP_RULES, classify_leaf,
                                   partition_spec, tree_shardings)


def test_fl_round_runs_and_aggregates():
    mesh = make_host_mesh(data=1)
    num_sats, J = 4, 3

    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"]
        return jnp.mean((pred - y) ** 2)

    fl_round = make_fl_round(loss_fn, mesh, local_iters=J, lr=0.1)
    params = {"w": jnp.zeros((5, 1))}
    rng = np.random.default_rng(0)
    w_true = rng.standard_normal((5, 1)).astype(np.float32)
    xs = rng.standard_normal((num_sats, J, 16, 5)).astype(np.float32)
    ys = xs @ w_true
    weights = jnp.full((num_sats,), 1.0 / num_sats)

    w1, loss1 = fl_round(params, (jnp.asarray(xs), jnp.asarray(ys)), weights)
    w2, loss2 = fl_round(w1, (jnp.asarray(xs), jnp.asarray(ys)), weights)
    assert float(loss2) < float(loss1)          # global model improves
    # gamma=1 -> result is average of locally trained models (no prev term)
    assert np.isfinite(np.asarray(w2["w"])).all()


def test_fl_round_partial_gamma_keeps_prev():
    mesh = make_host_mesh(data=1)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] - batch) ** 2)

    fl_round = make_fl_round(loss_fn, mesh, local_iters=2, lr=0.0)  # lr=0: no move
    params = {"w": jnp.full((3,), 7.0)}
    batch = jnp.zeros((2, 2, 3))
    weights = jnp.full((2,), 0.25)               # gamma = 0.5
    w1, _ = fl_round(params, batch, weights)
    np.testing.assert_allclose(np.asarray(w1["w"]), 7.0, rtol=1e-6)


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

def test_classify_known_leaves():
    assert classify_leaf("wq", 3) == ("embed", "heads", "head")
    assert classify_leaf("wq", 4) == (None, "embed", "heads", "head")   # stacked
    # routed-expert weights are we* — MUST not collide with stacked dense w1
    assert classify_leaf("we1", 3) == ("expert", "embed", "moe_mlp")
    assert classify_leaf("we1", 4) == (None, "expert", "embed", "moe_mlp")
    assert classify_leaf("w1", 3) == (None, "embed", "mlp")   # stacked dense
    assert classify_leaf("embedding", 2) == ("vocab", "embed")
    assert classify_leaf("unknown_leaf", 2) == (None, None)


def test_partition_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # model axis size 1: everything divides; use a fake 16-wide mesh check via
    # direct sizes by constructing the spec logic with a wider mesh if devices
    # allow — here we assert the no-crash property and correct axis names.
    spec = partition_spec((32, 14, 64), ("embed", "heads", "head"),
                          mesh, BASE_RULES)
    assert isinstance(spec, P)


def test_tree_shardings_cover_params():
    from repro.configs import ARCHS
    from repro.launch.specs import param_specs
    mesh = make_host_mesh()
    cfg = ARCHS["qwen3-4b"].reduced()
    specs = param_specs(cfg)
    sh = tree_shardings(specs, mesh, BASE_RULES)
    n_leaves = len(jax.tree_util.tree_leaves(specs))
    n_sh = len(jax.tree_util.tree_leaves(
        sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_leaves == n_sh


def test_fsdp_rules_shard_embed_dim():
    """On a mesh with a >1 'data' axis, FSDP rules shard the embed dim."""
    if len(jax.devices()) < 2:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = partition_spec((256, 512), ("embed", "mlp"), mesh, FSDP_RULES)
        assert isinstance(spec, P)      # single device: still resolves
    else:
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        spec = partition_spec((256, 512), ("embed", "mlp"), mesh, FSDP_RULES)
        assert spec[0] == "data"
