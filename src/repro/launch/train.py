"""Real (small-scale, host-mesh) training driver for the assigned archs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 20 \
        [--reduced] [--batch 4] [--seq 128]

Runs actual optimizer steps on this host's devices (reduced configs on CPU);
the full-size configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data.synthetic import token_stream
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import registry as R


def make_batch(cfg, batch: int, seq: int, seed: int = 0):
    toks = token_stream(seed, batch * seq, cfg.vocab_size).reshape(batch, seq)
    b = {"tokens": jnp.asarray(toks)}
    if cfg.frontend == "vision_stub":
        P = cfg.num_prefix_embeds
        b["prefix_embeds"] = jnp.asarray(
            np.random.default_rng(seed).standard_normal(
                (batch, P, cfg.d_model)) * 0.02, jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_stub":
        rng = np.random.default_rng(seed)
        b = {"frame_embeds": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)) * 0.02,
                jnp.dtype(cfg.dtype)),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                                   jnp.int32),
             "mask": jnp.asarray(rng.random((batch, seq)) < 0.3)}
    return b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(remat=False, dtype="float32")
    opt = make_optimizer(args.lr)
    step = jax.jit(make_train_step(cfg, opt))

    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    print(f"{args.arch}: {sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params)):,} params (reduced={args.reduced})")

    for i in range(args.steps):
        batch = make_batch(cfg, args.batch, args.seq, seed=i)
        t0 = time.time()
        params, opt_state, loss = step(params, opt_state, batch)
        loss = float(loss)
        print(f"step {i:3d} loss {loss:.4f} ({time.time()-t0:.2f}s)")
        assert np.isfinite(loss), "loss diverged"
    print("OK")


if __name__ == "__main__":
    main()
