"""Public API: pairwise distances between model pytrees (grouping step)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.pairwise_dist.kernel import pairwise_dist_sq


def pairwise_dist(x, *, squared: bool = False,
                  interpret: Optional[bool] = None):
    """x: (M, N) stacked flat models -> (M, M) L2 (or squared) distances."""
    if interpret is None:
        interpret = default_interpret()
    d2 = pairwise_dist_sq(x, interpret=interpret)
    return d2 if squared else jnp.sqrt(d2)


def model_pairwise_dist(models: Sequence, *, interpret: Optional[bool] = None):
    flat = jnp.stack([
        jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                         for l in jax.tree_util.tree_leaves(m)])
        for m in models])
    return pairwise_dist(flat, interpret=interpret)
