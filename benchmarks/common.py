"""Shared benchmark scaffolding: the paper's §V-A experimental setup."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import CIFAR_CNN, CIFAR_MLP, MNIST_CNN, MNIST_MLP
from repro.core import (FLSimulation, SimConfig, convergence_time,
                        paper_constellation)
from repro.data import (class_conditional_images, iid_partition,
                        paper_noniid_partition)
from repro.fl import Evaluator, ImageClassifierPool, get_strategy
from repro.models import cnn

SEPARATION = 0.8       # calibrated so the task saturates ~95-100% centrally
TRAIN_N = 4000
TEST_N = 1000
LOCAL_ITERS = 30


def small_cfg(dataset: str, kind: str):
    base = {("mnist", "cnn"): MNIST_CNN, ("mnist", "mlp"): MNIST_MLP,
            ("cifar", "cnn"): CIFAR_CNN, ("cifar", "mlp"): CIFAR_MLP}[(dataset, kind)]
    if kind == "cnn":
        return dataclasses.replace(base, conv_channels=(8, 16))
    return base


def make_setup(dataset: str = "mnist", model: str = "cnn",
               iid: bool = False, seed: int = 0):
    cfg = small_cfg(dataset, model)
    size = cfg.image_size
    ch = cfg.channels
    const = paper_constellation()
    imgs, labs = class_conditional_images(seed, TRAIN_N, size=size,
                                          channels=ch, separation=SEPARATION)
    ti, tl = class_conditional_images(seed + 99, TEST_N, size=size,
                                      channels=ch, separation=SEPARATION)
    if iid:
        shards = iid_partition(labs, const.num_sats, seed)
    else:
        shards = paper_noniid_partition(labs, const.orbit_ids(), seed)
    pool = ImageClassifierPool(cfg, imgs, labs, shards, local_iters=LOCAL_ITERS)
    ev = Evaluator(cfg, ti, tl)
    w0 = jax.device_get(cnn.init_params(jax.random.PRNGKey(seed), cfg))
    return pool, ev, w0


def run_strategy(name: str, pool, ev, w0, *, max_epochs: int = 16,
                 duration_s: float = 3 * 86400.0,
                 target_accuracy: Optional[float] = None,
                 use_agg_kernel: bool = False):
    spec = get_strategy(name)
    if use_agg_kernel:
        spec = dataclasses.replace(spec, use_agg_kernel=True)
    sim = FLSimulation(spec, pool, ev, SimConfig(duration_s=duration_s))
    t0 = time.time()
    hist = sim.run(w0, max_epochs=max_epochs, target_accuracy=target_accuracy)
    wall = time.time() - t0
    best = max(r.accuracy for r in hist) if hist else 0.0
    return {"strategy": name, "history": hist, "best_acc": best,
            "final_time_h": hist[-1].time_s / 3600 if hist else float("inf"),
            "wall_s": wall}


def fmt_hist(res: Dict) -> List[str]:
    return [f"{res['strategy']},{r.epoch},{r.time_s/3600:.3f},{r.accuracy:.4f},"
            f"{r.num_models},{r.gamma:.3f}" for r in res["history"]]
