"""Architecture registry: ``get_config(arch_id)`` and the assigned shapes."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, LONG_CONTEXT_WINDOW

from repro.configs import (
    qwen3_4b, llama3_8b, internvl2_1b, deepseek_v2_236b, rwkv6_7b,
    zamba2_2_7b, kimi_k2_1t, hubert_xlarge, granite_8b, starcoder2_3b,
)
from repro.configs.paper_models import (
    SmallNetConfig, MNIST_CNN, MNIST_MLP, CIFAR_CNN, CIFAR_MLP,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen3_4b, llama3_8b, internvl2_1b, deepseek_v2_236b, rwkv6_7b,
              zamba2_2_7b, kimi_k2_1t, hubert_xlarge, granite_8b, starcoder2_3b)
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(shape_id: str) -> ShapeConfig:
    if shape_id not in SHAPES:
        raise KeyError(f"unknown shape {shape_id!r}; available: {sorted(SHAPES)}")
    return SHAPES[shape_id]


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch, shape) is runnable (DESIGN.md applicability matrix)."""
    if cfg.is_encoder_only and shape.kind == "decode":
        return False              # encoder-only: no decode step
    return True


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "LONG_CONTEXT_WINDOW",
    "get_config", "get_shape", "applicable",
    "SmallNetConfig", "MNIST_CNN", "MNIST_MLP", "CIFAR_CNN", "CIFAR_MLP",
]
