"""Pallas TPU kernels for the compute hot spots.

Targets TPU (pl.pallas_call + BlockSpec VMEM tiling); this container is
CPU-only so every public op takes ``interpret=`` (default auto: True on CPU)
and the test-suite validates each kernel against its pure-jnp oracle in
interpret mode across shape/dtype sweeps.

  flash_attention — block-tiled causal/windowed GQA attention (prefill path)
  chunk_scan      — chunked linear recurrence (RWKV6 vector decay /
                    Mamba2-SSD scalar decay)
  fed_agg         — staleness-discounted model aggregation (paper eq. 14)
  pairwise_dist   — pairwise squared-L2 between flattened models (grouping)
"""
import jax


def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"
