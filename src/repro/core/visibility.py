"""Visibility: satellite<->ground elevation gating and inter-satellite LoS.

The paper's link condition (§III-B): a satellite n and PS g can communicate
iff the elevation of n above g's local horizon is >= the minimum elevation
angle.  ``VisibilityTimeline`` precomputes the boolean visibility grid over
the whole simulation horizon (vectorized — 3 days at dt=10 s for 40 sats x
2 PSs is ~52k x 40 x 2 bools) and answers next-visible queries in O(1)-ish.

``SparseVisibilityTimeline`` (DESIGN.md §14) answers the SAME queries from
a segment representation — per-(sat, PS) visibility windows as
``[lo, hi)`` grid-step intervals — without ever materializing the dense
(T, S, P) grid or the (T, S, 3) position tensor.  At S = 10^4 over a
1-day horizon the dense grid + positions are gigabytes; the windows are
a few megabytes.  Compilation is chunked coarse-to-fine: elevation is
sampled every ``coarse`` steps, a provable bound on the elevation rate
(relative angular speed over the minimum slant range, plus Earth
rotation) classifies whole coarse intervals as certainly-visible /
certainly-invisible, and only satellites with an uncertain interval in a
chunk are evaluated densely — so the boolean per step is EXACTLY what
the dense grid holds, and every query below is pinned bit-identical to
the dense timeline (tests/test_sparse_contacts.py, test_property.py).

Both classes share the query API that downstream code consumes (the
contact plan, topology and propagation layers never index ``.grid``
directly anymore): ``visible`` / ``visible_sats`` / ``visible_rows`` /
``next_visible_time`` / ``next_visible_after`` / ``next_orbit_visible``
/ ``visibility_fraction`` plus the segment exports ``node_windows``,
``node_cover`` and ``covered_steps``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.constellation import (GroundNode, OMEGA_EARTH, R_EARTH,
                                      WalkerDelta)

ATMOSPHERE_MARGIN_M = 80e3   # ISL grazing margin above the surface


def elevation_deg(sat_pos: np.ndarray, gnd_pos: np.ndarray) -> np.ndarray:
    """Elevation of satellite(s) above ground node's horizon, degrees.
    Broadcasts over leading dims; last dim is xyz."""
    d = sat_pos - gnd_pos
    dn = np.linalg.norm(d, axis=-1)
    gn = np.linalg.norm(gnd_pos, axis=-1)
    sin_el = np.sum(d * gnd_pos, axis=-1) / np.maximum(dn * gn, 1e-9)
    return np.rad2deg(np.arcsin(np.clip(sin_el, -1.0, 1.0)))


def horizon_dip_deg(altitude_m: float) -> float:
    """Geometric horizon dip for an elevated observer: arccos(R/(R+h)).
    ~4.5 deg at 20 km — the physical reason a HAP sees more satellites than
    a GS at the same nominal minimum elevation (paper §I/§III)."""
    if altitude_m <= 0:
        return 0.0
    return float(np.rad2deg(np.arccos(R_EARTH / (R_EARTH + altitude_m))))


def is_visible(sat_pos, node: GroundNode, node_pos) -> np.ndarray:
    eff_min = node.min_elevation_deg - horizon_dip_deg(node.altitude_m)
    return elevation_deg(sat_pos, node_pos) >= eff_min


def sat_los(p1: np.ndarray, p2: np.ndarray,
            margin_m: float = ATMOSPHERE_MARGIN_M) -> np.ndarray:
    """Inter-satellite line-of-sight: True if the segment p1-p2 clears the
    Earth (+margin).  Broadcasts over leading dims."""
    d = p2 - p1
    dd = np.sum(d * d, axis=-1)
    t = -np.sum(p1 * d, axis=-1) / np.maximum(dd, 1e-9)
    t = np.clip(t, 0.0, 1.0)
    closest = p1 + t[..., None] * d
    return np.linalg.norm(closest, axis=-1) >= (R_EARTH + margin_m)


@dataclasses.dataclass
class VisibilityTimeline:
    """Precomputed sat x PS visibility over [0, duration] at step dt."""
    constellation: WalkerDelta
    nodes: List[GroundNode]
    duration_s: float
    dt_s: float = 10.0

    def __post_init__(self):
        self.times = np.arange(0.0, self.duration_s + self.dt_s, self.dt_s)
        sat_pos = self.constellation.positions(self.times)      # (T,S,3)
        self.grid = np.zeros((len(self.times), self.constellation.num_sats,
                              len(self.nodes)), dtype=bool)
        self._sat_pos = sat_pos
        for j, node in enumerate(self.nodes):
            npos = node.position(self.times)[:, None, :]        # (T,1,3)
            self.grid[:, :, j] = is_visible(sat_pos, node, npos)

    # ---- queries ----------------------------------------------------------

    def _ti(self, t: float) -> int:
        return int(np.clip(round(t / self.dt_s), 0, len(self.times) - 1))

    def visible(self, t: float) -> np.ndarray:
        """(S, P) bool at time t."""
        return self.grid[self._ti(t)]

    def visible_sats(self, t: float, node_idx: int) -> np.ndarray:
        return np.flatnonzero(self.grid[self._ti(t), :, node_idx])

    def next_visible_time(self, sat: int, t: float,
                          node_idx: Optional[int] = None) -> Optional[float]:
        """Earliest time >= t when ``sat`` sees any PS (or a specific one).
        None if never within the horizon."""
        ti = self._ti(t)
        col = (self.grid[ti:, sat, :].any(axis=-1) if node_idx is None
               else self.grid[ti:, sat, node_idx])
        hits = np.flatnonzero(col)
        if len(hits) == 0:
            return None
        return float(self.times[ti + hits[0]])

    def _next_visible_grid(self) -> np.ndarray:
        """(T, S) int32: for each (time step, sat), the earliest row >= t
        where the satellite sees any PS (== T when never again).  Built once
        by a reverse running-minimum over the visibility grid and cached —
        it turns every next-visible query into one fancy-index lookup."""
        if not hasattr(self, "_nxt"):
            T = self.grid.shape[0]
            any_ps = self.grid.any(axis=2)                      # (T, S)
            idx = np.where(any_ps, np.arange(T, dtype=np.int32)[:, None],
                           np.int32(T))
            self._nxt = np.minimum.accumulate(idx[::-1], axis=0)[::-1]
        return self._nxt

    def next_visible_after(self, sats, t):
        """Vectorized ``next_visible_time`` over (sat, per-sat time) pairs.
        Returns (times (P,), first-visible PS (P,)) with inf / -1 where a
        satellite is never visible again within the horizon."""
        sats = np.atleast_1d(np.asarray(sats, dtype=np.int64))
        t = np.broadcast_to(np.asarray(t, dtype=np.float64), sats.shape)
        ti = np.clip(np.round(t / self.dt_s).astype(np.int64), 0,
                     len(self.times) - 1)
        row = self._next_visible_grid()[ti, sats]
        ok = row < self.grid.shape[0]
        rowc = np.minimum(row, self.grid.shape[0] - 1)
        times = np.where(ok, self.times[rowc], np.inf)
        ps = np.where(ok, np.argmax(self.grid[rowc, sats, :], axis=1), -1)
        return times, ps

    def next_orbit_visible(self, orbit_sats: Sequence[int], t: float):
        """Earliest (time, sat) at/after t when any satellite of an orbit sees
        any PS.  Returns (None, None) if never."""
        ti = self._ti(t)
        sub = self.grid[ti:][:, list(orbit_sats), :].any(axis=-1)   # (T', n)
        rows = np.flatnonzero(sub.any(axis=1))
        if len(rows) == 0:
            return None, None
        row = rows[0]
        sat_local = int(np.flatnonzero(sub[row])[0])
        return float(self.times[ti + row]), int(list(orbit_sats)[sat_local])

    def visibility_fraction(self, sat: int) -> float:
        return float(self.grid[:, sat, :].any(axis=-1).mean())

    # ---- segment exports (shared with SparseVisibilityTimeline) -----------

    def visible_rows(self, rows, sats) -> np.ndarray:
        """Visibility at explicit grid rows: ``grid[rows, sats, :]`` with
        numpy broadcasting between ``rows`` and ``sats`` — bool (..., P).
        This is the query the propagation layer uses instead of indexing
        the grid directly, so it works against both timeline classes."""
        return self.grid[rows, sats, :]

    def node_windows(self, node_idx: int):
        """RLE visibility windows of one PS as ``(sats, lo, hi)`` int64
        arrays sorted by (sat, lo); ``hi`` is the EXCLUSIVE end row and
        may equal T when a window runs off the horizon."""
        col = self.grid[:, :, node_idx]                  # (T, S)
        pad = np.zeros((1, col.shape[1]), dtype=np.int8)
        d = np.diff(np.concatenate([pad, col.astype(np.int8), pad]),
                    axis=0)                              # (T+1, S)
        starts = np.argwhere(d == 1)                     # (n, 2): (row, sat)
        ends = np.argwhere(d == -1)
        if len(starts) == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), z.copy()
        # argwhere is row-major sorted; regroup per sat so the k-th start
        # pairs with the k-th end of the same column
        order_s = np.lexsort((starts[:, 0], starts[:, 1]))
        order_e = np.lexsort((ends[:, 0], ends[:, 1]))
        return (starts[order_s, 1].astype(np.int64),
                starts[order_s, 0].astype(np.int64),
                ends[order_e, 0].astype(np.int64))

    def node_cover(self, node_idx: int):
        """Merged any-sat coverage runs of one PS: ``(lo, hi)`` int64
        arrays of maximal covered row intervals, ``hi`` exclusive."""
        any_sat = self.grid[:, :, node_idx].any(axis=1).astype(np.int8)
        d = np.diff(np.concatenate([[0], any_sat, [0]]))
        return (np.flatnonzero(d == 1).astype(np.int64),
                np.flatnonzero(d == -1).astype(np.int64))

    def covered_steps(self) -> int:
        """Total (step, sat) samples with any PS in view — the scalar the
        plan's coverage/degeneracy checks reduce to."""
        return int(self.grid.any(axis=2).sum())


def _positions_subset(cst: WalkerDelta, t: np.ndarray,
                      sats: np.ndarray) -> np.ndarray:
    """``cst.positions(t)[:, sats]`` without materializing the full
    (T, S, 3) tensor.  Replicates ``WalkerDelta.positions`` op-for-op on a
    column subset; every operation there is elementwise over (T, S), so
    the subset values are BITWISE identical to slicing the full tensor —
    the property the sparse-vs-dense parity pins rest on."""
    t = np.asarray(t, dtype=np.float64)
    sats = np.asarray(sats, dtype=np.int64)
    O, N = cst.num_orbits, cst.sats_per_orbit
    o, s = sats // N, sats % N
    raan = 2 * np.pi * o / O
    phase0 = 2 * np.pi * s / N + cst.phasing * 2 * np.pi * o / (O * N)
    u = phase0[None, :] + cst.mean_motion * t[:, None]          # (T,B)
    inc = np.deg2rad(cst.inclination_deg)
    r = cst.radius_m
    xp, yp = r * np.cos(u), r * np.sin(u)
    x1, y1, z1 = xp, yp * np.cos(inc), yp * np.sin(inc)
    cosO, sinO = np.cos(raan)[None, :], np.sin(raan)[None, :]
    return np.stack([x1 * cosO - y1 * sinO, x1 * sinO + y1 * cosO, z1],
                    axis=-1)


def elevation_rate_bound_deg_s(cst: WalkerDelta, node: GroundNode) -> float:
    """Provable upper bound on |d(elevation)/dt| in deg/s for any
    satellite of ``cst`` as seen from ``node``.

    The line-of-sight direction rotates at most v_rel / d_min, with
    v_rel <= v_sat + Omega_E * (R + h_node) (the node's inertial speed)
    and d_min = alt_sat - h_node (the two bodies live on concentric
    spheres, so their distance is at least the radius difference).  The
    node's local horizon frame itself rotates at Omega_E, which adds at
    most Omega_E to the elevation rate.  A 5% safety factor absorbs the
    small-angle approximations; inf (= no interval pruning, full dense
    evaluation) when the geometry degenerates (sat shell at/below the
    node altitude)."""
    d_min = cst.altitude_m - node.altitude_m
    if d_min <= 0:
        return float("inf")
    v_node = OMEGA_EARTH * (R_EARTH + node.altitude_m)
    rate_rad = (cst.velocity + v_node) / d_min + OMEGA_EARTH
    return float(np.rad2deg(rate_rad) * 1.05)


@dataclasses.dataclass
class SparseVisibilityTimeline:
    """Segment-based drop-in for :class:`VisibilityTimeline` (DESIGN.md
    §14): per-(sat, PS) visibility windows as ``[lo, hi)`` grid-step
    intervals, compiled chunked coarse-to-fine and queried by bisect on
    composite ``sat*(T+1)+row`` keys.  Never materializes the (T, S, P)
    grid or the full (T, S, 3) position tensor — memory and query cost
    are O(windows), which is what makes S = 10^4 compile in CI.

    Exactness: coarse elevation samples every ``coarse`` steps classify
    whole sample intervals via :func:`elevation_rate_bound_deg_s`
    (certainly-visible / certainly-invisible / uncertain); uncertain
    interval interiors are densely evaluated with the same elementwise
    math the dense grid uses (:func:`_positions_subset` + is_visible), so
    every per-step boolean — hence every window, query answer, and
    downstream runtime history — is bit-identical to the dense timeline.
    """
    constellation: WalkerDelta
    nodes: List[GroundNode]
    duration_s: float
    dt_s: float = 10.0
    chunk_steps: int = 2048     # rows densely addressable per compile chunk
    coarse: int = 8             # coarse sampling stride (rows)

    def __post_init__(self):
        self.times = np.arange(0.0, self.duration_s + self.dt_s, self.dt_s)
        self._T = len(self.times)
        self._compile()

    # ---- compilation ------------------------------------------------------

    def _compile(self) -> None:
        cst, T = self.constellation, self._T
        S = cst.num_sats
        P = len(self.nodes)
        eff_min = [n.min_elevation_deg - horizon_dip_deg(n.altitude_m)
                   for n in self.nodes]
        band_rate = [elevation_rate_bound_deg_s(cst, n) * self.dt_s
                     for n in self.nodes]                # deg per gap-step
        prev = [np.zeros(S, dtype=bool) for _ in range(P)]
        acc_s = [[] for _ in range(P)]   # per node: (rows, sats) start pairs
        acc_e = [[] for _ in range(P)]
        for c0 in range(0, T, self.chunk_steps):
            c1 = min(c0 + self.chunk_steps, T)
            L = c1 - c0
            samp = np.arange(0, L, self.coarse, dtype=np.int64)
            if samp[-1] != L - 1:
                samp = np.append(samp, L - 1)
            t_samp = self.times[c0 + samp]
            pos = cst.positions(t_samp)                  # (Q, S, 3)
            qidx = np.searchsorted(samp, np.arange(L), side="right") - 1
            for j, node in enumerate(self.nodes):
                npos = node.position(t_samp)[:, None, :]
                margin = elevation_deg(pos, npos) - eff_min[j]   # (Q, S)
                # sample rows are exact; interval interiors inherit the
                # left endpoint's sign unless the interval is uncertain
                vis = (margin >= 0.0)[qidx]              # (L, S) bool
                if len(samp) > 1:
                    m0, m1 = margin[:-1], margin[1:]
                    gap = np.diff(samp).astype(np.float64)[:, None]
                    band = band_rate[j] * gap + 1e-9
                    certain = (((m0 > 0) & (m1 > 0) & (m0 + m1 > band))
                               | ((m0 < 0) & (m1 < 0) & (-(m0 + m1) > band)))
                    unc = ~certain                       # (Q-1, S)
                    active = np.flatnonzero(unc.any(axis=0))
                    mark = np.zeros(L, dtype=bool)
                    for q in np.flatnonzero(unc.any(axis=1)):
                        mark[samp[q] + 1:samp[q + 1]] = True
                    rows_u = np.flatnonzero(mark)
                    if len(rows_u) and len(active):
                        t_u = self.times[c0 + rows_u]
                        npos_u = node.position(t_u)[:, None, :]
                        for b0 in range(0, len(active), 4096):
                            batch = active[b0:b0 + 4096]
                            pos_u = _positions_subset(cst, t_u, batch)
                            vis[np.ix_(rows_u, batch)] = \
                                is_visible(pos_u, node, npos_u)
                ext = np.concatenate([prev[j][None, :].astype(np.int8),
                                      vis.astype(np.int8)], axis=0)
                d = np.diff(ext, axis=0)                 # (L, S)
                st = np.argwhere(d == 1)                 # (n, 2): (row, sat)
                en = np.argwhere(d == -1)
                if len(st):
                    acc_s[j].append((st[:, 0] + c0, st[:, 1]))
                if len(en):
                    acc_e[j].append((en[:, 0] + c0, en[:, 1]))
                prev[j] = vis[-1].copy()
        # flush windows still open at the horizon: exclusive end = T
        for j in range(P):
            tail = np.flatnonzero(prev[j])
            if len(tail):
                acc_e[j].append((np.full(len(tail), T, dtype=np.int64), tail))
        self._wsat: List[np.ndarray] = []
        self._wlo: List[np.ndarray] = []
        self._whi: List[np.ndarray] = []
        self._klo: List[np.ndarray] = []
        self._khi: List[np.ndarray] = []
        for j in range(P):
            if acc_s[j]:
                s_rows = np.concatenate([r for r, _ in acc_s[j]])
                s_sats = np.concatenate([s for _, s in acc_s[j]])
                e_rows = np.concatenate([r for r, _ in acc_e[j]])
                e_sats = np.concatenate([s for _, s in acc_e[j]])
                os_ = np.lexsort((s_rows, s_sats))
                oe = np.lexsort((e_rows, e_sats))
                sat = s_sats[os_].astype(np.int64)
                lo = s_rows[os_].astype(np.int64)
                hi = e_rows[oe].astype(np.int64)
                assert len(lo) == len(hi) and np.array_equal(
                    sat, e_sats[oe].astype(np.int64))
            else:
                sat = lo = hi = np.zeros(0, dtype=np.int64)
            self._wsat.append(sat)
            self._wlo.append(lo)
            self._whi.append(hi)
            self._klo.append(sat * (T + 1) + lo)
            self._khi.append(sat * (T + 1) + hi)
        # cross-node union per sat (any-PS queries): merge overlapping or
        # touching windows in the composite key space, where distinct
        # sats can never merge (hi <= T < T+1 separates their ranges)
        if any(len(w) for w in self._wsat):
            glo = np.concatenate([k for k in self._klo])
            ghi = np.concatenate([k for k in self._khi])
            order = np.argsort(glo, kind="stable")
            glo, ghi = glo[order], ghi[order]
            run_hi = np.maximum.accumulate(ghi)
            new = np.ones(len(glo), dtype=bool)
            new[1:] = glo[1:] > run_hi[:-1]
            heads = np.flatnonzero(new)
            ulo_g = glo[heads]
            uhi_g = np.maximum.reduceat(ghi, heads)
            self._usat = ulo_g // (T + 1)
            self._ulo = ulo_g - self._usat * (T + 1)
            self._uhi = uhi_g - self._usat * (T + 1)
        else:
            self._usat = self._ulo = self._uhi = np.zeros(0, dtype=np.int64)
        self._uklo = self._usat * (T + 1) + self._ulo
        self._ukhi = self._usat * (T + 1) + self._uhi
        self._cover: List = [None] * P

    # ---- queries (same contracts as VisibilityTimeline) -------------------

    def _ti(self, t: float) -> int:
        return int(np.clip(round(t / self.dt_s), 0, self._T - 1))

    def _point(self, j: int, key: np.ndarray, sats: np.ndarray,
               rows: np.ndarray) -> np.ndarray:
        """Window-containment test for node j at composite keys."""
        i = np.searchsorted(self._klo[j], key, side="right") - 1
        ic = np.maximum(i, 0)
        return ((i >= 0) & (self._wsat[j][ic] == sats)
                & (self._whi[j][ic] > rows))

    def visible(self, t: float) -> np.ndarray:
        """(S, P) bool at time t."""
        ti = self._ti(t)
        out = np.zeros((self.constellation.num_sats, len(self.nodes)),
                       dtype=bool)
        for j in range(len(self.nodes)):
            m = (self._wlo[j] <= ti) & (self._whi[j] > ti)
            out[self._wsat[j][m], j] = True
        return out

    def visible_sats(self, t: float, node_idx: int) -> np.ndarray:
        ti = self._ti(t)
        j = node_idx
        m = (self._wlo[j] <= ti) & (self._whi[j] > ti)
        return self._wsat[j][m]

    def visible_rows(self, rows, sats) -> np.ndarray:
        rows_b, sats_b = np.broadcast_arrays(
            np.asarray(rows, dtype=np.int64), np.asarray(sats, np.int64))
        key = sats_b * (self._T + 1) + rows_b
        out = np.zeros(rows_b.shape + (len(self.nodes),), dtype=bool)
        for j in range(len(self.nodes)):
            out[..., j] = self._point(j, key, sats_b, rows_b)
        return out

    def _next_from(self, khi: np.ndarray, wsat: np.ndarray,
                   wlo: np.ndarray, sats: np.ndarray,
                   rows: np.ndarray):
        """First window of each (sat, row>=rows) pair in a key-sorted
        window list: (ok, row-of-first-visibility)."""
        i = np.searchsorted(khi, sats * (self._T + 1) + rows, side="right")
        ic = np.minimum(i, len(khi) - 1) if len(khi) else i * 0
        ok = (i < len(khi)) & (len(khi) > 0)
        if len(khi):
            ok &= wsat[ic] == sats
            row = np.maximum(wlo[ic], rows)
        else:
            row = rows
        return ok, row

    def next_visible_time(self, sat: int, t: float,
                          node_idx: Optional[int] = None) -> Optional[float]:
        ti = self._ti(t)
        sats = np.asarray([sat], dtype=np.int64)
        rows = np.asarray([ti], dtype=np.int64)
        if node_idx is None:
            ok, row = self._next_from(self._ukhi, self._usat, self._ulo,
                                      sats, rows)
        else:
            j = node_idx
            ok, row = self._next_from(self._khi[j], self._wsat[j],
                                      self._wlo[j], sats, rows)
        if not ok[0]:
            return None
        return float(self.times[row[0]])

    def next_visible_after(self, sats, t):
        sats = np.atleast_1d(np.asarray(sats, dtype=np.int64))
        t = np.broadcast_to(np.asarray(t, dtype=np.float64), sats.shape)
        ti = np.clip(np.round(t / self.dt_s).astype(np.int64), 0,
                     self._T - 1)
        ok, row = self._next_from(self._ukhi, self._usat, self._ulo,
                                  sats, ti)
        rowc = np.where(ok, row, 0)
        times = np.where(ok, self.times[rowc], np.inf)
        # first-visible PS = lowest node index in view at the row (the
        # dense path's argmax-of-bool), found by per-node containment
        ps = np.full(sats.shape, -1, dtype=np.int64)
        remaining = ok.copy()
        key = sats * (self._T + 1) + rowc
        for j in range(len(self.nodes)):
            if not remaining.any():
                break
            hit = remaining & self._point(j, key, sats, rowc)
            ps[hit] = j
            remaining &= ~hit
        return times, ps

    def next_orbit_visible(self, orbit_sats: Sequence[int], t: float):
        sats = np.asarray(list(orbit_sats), dtype=np.int64)
        ti = np.full(sats.shape, self._ti(t), dtype=np.int64)
        ok, row = self._next_from(self._ukhi, self._usat, self._ulo,
                                  sats, ti)
        if not ok.any():
            return None, None
        rowv = np.where(ok, row, self._T)
        best = int(rowv.min())
        first = int(np.flatnonzero(ok & (rowv == best))[0])
        return float(self.times[best]), int(sats[first])

    def visibility_fraction(self, sat: int) -> float:
        m = self._usat == sat
        covered = int((self._uhi[m] - self._ulo[m]).sum())
        return float(covered / self._T)

    # ---- segment exports --------------------------------------------------

    def node_windows(self, node_idx: int):
        j = node_idx
        return self._wsat[j], self._wlo[j], self._whi[j]

    def node_cover(self, node_idx: int):
        if self._cover[node_idx] is None:
            lo, hi = self._wlo[node_idx], self._whi[node_idx]
            if len(lo) == 0:
                z = np.zeros(0, dtype=np.int64)
                self._cover[node_idx] = (z, z.copy())
            else:
                order = np.argsort(lo, kind="stable")
                lo, hi = lo[order], hi[order]
                run_hi = np.maximum.accumulate(hi)
                new = np.ones(len(lo), dtype=bool)
                new[1:] = lo[1:] > run_hi[:-1]
                heads = np.flatnonzero(new)
                self._cover[node_idx] = (lo[heads],
                                         np.maximum.reduceat(hi, heads))
        return self._cover[node_idx]

    def covered_steps(self) -> int:
        return int((self._uhi - self._ulo).sum())

    @property
    def num_windows(self) -> int:
        return int(sum(len(w) for w in self._wsat))
