"""Configuration system for repro.

Two levels:
  * ``ModelConfig`` — a single dataclass describing every supported
    architecture family (dense / moe / ssm / hybrid / vlm / audio).  One
    module per assigned architecture instantiates it with the exact
    published numbers (citation in the module docstring).
  * ``ShapeConfig`` — the assigned input shapes (train_4k, prefill_32k,
    decode_32k, long_500k).

Configs are plain frozen dataclasses — hashable, printable, and safe to close
over in jit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attn-free)
    num_kv_heads: int                 # GQA kv heads
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- attention options ------------------------------------------------
    qk_norm: bool = False             # RMSNorm on q/k per head (qwen3)
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True               # False for encoder-only (hubert)
    sliding_window: int = 0           # 0 = full attention; >0 = window size

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0              # 0 = dense FFN
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden (d_ff used for dense/shared)
    first_dense_layers: int = 0       # leading dense layers before MoE (dsv2 style)
    moe_capacity_factor: float = 1.25  # per-expert capacity (tokens over cap drop)

    # --- MLA (deepseek) ----------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64           # decoupled rope dims for MLA
    nope_head_dim: int = 128

    # --- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0                # mamba2 d_state
    ssm_heads: int = 0                # mamba2 / rwkv6 heads
    ssm_head_dim: int = 0             # mamba2 head dim (d_inner = heads*this)
    attn_every: int = 0               # hybrid: shared attn block period (zamba2)
    chunk_size: int = 128             # chunked-scan chunk length

    # --- modality frontend stubs -------------------------------------------
    frontend: str = "none"            # none | vision_stub | audio_stub
    num_prefix_embeds: int = 0        # patch/frame embeddings prepended (stub)

    # --- training ----------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True                # checkpoint each scanned layer
    tie_embeddings: bool = False

    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.registry import analytic_param_count
        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import analytic_param_count
        return analytic_param_count(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Reduced variant for CPU smoke tests: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, heads) if heads else 0
        kw = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=max(kv, 1) if heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else 0,
        )
        if self.is_moe:
            kw.update(num_experts=4, top_k=min(self.top_k, 2),
                      moe_d_ff=min(self.moe_d_ff or self.d_ff, 256),
                      num_shared_experts=min(self.num_shared_experts, 1),
                      first_dense_layers=min(self.first_dense_layers, 1))
        if self.use_mla:
            kw.update(kv_lora_rank=64, q_lora_rank=0, rope_head_dim=32,
                      nope_head_dim=32, head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16),
                      ssm_heads=min(self.ssm_heads or 4, 4), chunk_size=32,
                      ssm_head_dim=min(self.ssm_head_dim, 64)
                      if self.ssm_head_dim else 0)
        if self.family == "ssm":
            kw.update(ssm_heads=min(self.ssm_heads or 4, 4), chunk_size=32)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.num_prefix_embeds:
            kw.update(num_prefix_embeds=8)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 128), global_batch=min(self.global_batch, 4))


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Sliding-window size applied to full-attention archs for long_500k decode
# (sub-quadratic carve-out documented in DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8192
