"""End-to-end behaviour: real FL over the constellation learns, async beats
sync on simulated convergence time, and the AsyncFLEO components cooperate
(grouping + staleness discounting engage under non-IID straggler orbits)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import MNIST_CNN
from repro.core import FLSimulation, SimConfig, paper_constellation
from repro.data import class_conditional_images, paper_noniid_partition
from repro.fl import Evaluator, ImageClassifierPool, get_strategy
from repro.models import cnn


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(MNIST_CNN, conv_channels=(4, 8))
    const = paper_constellation()
    imgs, labs = class_conditional_images(0, 1500, separation=1.4)
    ti, tl = class_conditional_images(99, 400, separation=1.4)
    shards = paper_noniid_partition(labs, const.orbit_ids(), 0)
    pool = ImageClassifierPool(cfg, imgs, labs, shards, local_iters=20)
    ev = Evaluator(cfg, ti, tl)
    w0 = jax.device_get(cnn.init_params(jax.random.PRNGKey(0), cfg))
    return pool, ev, w0


def test_asyncfleo_end_to_end_learns(setup):
    pool, ev, w0 = setup
    sim = FLSimulation(get_strategy("asyncfleo-hap"), pool, ev,
                       SimConfig(duration_s=86400.0))
    hist = sim.run(w0, max_epochs=6)
    assert len(hist) >= 3
    accs = [r.accuracy for r in hist]
    assert max(accs) > 0.25          # non-IID early epochs still beat chance
    assert all(np.isfinite(a) for a in accs)
    assert all(r.num_models >= 2 for r in hist)


def test_async_epoch_cadence_beats_sync(setup):
    pool, ev, w0 = setup
    h_async = FLSimulation(get_strategy("asyncfleo-hap"), pool, ev,
                           SimConfig(duration_s=86400.0)).run(w0, max_epochs=3)
    h_sync = FLSimulation(get_strategy("fedhap"), pool, ev,
                          SimConfig(duration_s=86400.0)).run(w0, max_epochs=3)
    # first aggregated model is available far earlier (idle-waiting removed)
    assert h_async[0].time_s < h_sync[0].time_s
    # and the async scheme completes more epochs per simulated hour
    assert h_async[-1].time_s < h_sync[-1].time_s


def test_grouping_engages(setup):
    pool, ev, w0 = setup
    sim = FLSimulation(get_strategy("asyncfleo-hap"), pool, ev,
                       SimConfig(duration_s=86400.0))
    sim.run(w0, max_epochs=4)
    # at least one orbit was observed and grouped via weight-divergence
    assert len(sim.grouping.distances) >= 1
    assert len(sim.grouping.groups) >= 1
