"""repro — AsyncFLEO (asynchronous federated learning for LEO constellations
with HAPs) as a production-grade JAX framework.

Subpackages: core (the paper's contribution), fl (runtime), models, data,
optim, checkpoint, kernels (Pallas), configs, launch.
"""
__version__ = "1.0.0"
