"""Chunked linear-recurrence scan — the shared math under RWKV6 (vector,
per-channel decay) and Mamba2/SSD (scalar, per-head decay).

Recurrence (per batch b, head h):
    S_t = diag(exp(ld_t)) . S_{t-1} + k_t v_t^T          S in R^{K x V}
    y_t = r_t . (S_t)                        if include_current (Mamba2/SSD)
    y_t = r_t . (S_{t-1}) + (r_t*bonus . k_t) v_t         else (RWKV6 w/ u)

The chunked form computes, per chunk of length Lc with L = cumsum(ld):
    carry   : y_cross = (r * exp(M)) @ S_in
    intra   : A[t,s]  = (r_t * exp(M_t)) . (k_s * exp(-L_s)),  masked s<t|s<=t
    update  : S_out   = exp(L_end) * S_in + sum_s exp(L_end - L_s) k_s v_s^T

where M_t = L_t (include_current) or L_{t-1} (not).  exp(M) <= 1 always; the
exp(-L_s) factor is bounded by exp(|ld|·Lc), so per-step log-decay is clamped
to ``>= -LOG_DECAY_CLAMP`` (documented deviation; data-dependent decays in
trained RWKV6 models live near 0 so the clamp is rarely active).

All exponentials run in f32; inputs/outputs keep their dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

LOG_DECAY_CLAMP = 1.0   # per-step |log decay| cap for the factorized form


def _prep_decay(log_decay, K):
    """Broadcast scalar-per-head decay (B,T,H) to (B,T,H,K); clamp."""
    ld = log_decay.astype(jnp.float32)
    if ld.ndim == 3:
        ld = ld[..., None]
    ld = jnp.broadcast_to(ld, ld.shape[:-1] + (K,))
    return jnp.clip(ld, -LOG_DECAY_CLAMP, 0.0)


def recurrent_scan(r, k, v, log_decay, state0=None, *, include_current=True,
                   bonus=None):
    """Oracle: plain sequential lax.scan over time.  Shapes:
    r, k: (B,T,H,K); v: (B,T,H,V); log_decay: (B,T,H,K) or (B,T,H).
    Returns (y (B,T,H,V), final_state (B,H,K,V))."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    ld = _prep_decay(log_decay, K)
    f32 = jnp.float32
    S0 = jnp.zeros((B, H, K, V), f32) if state0 is None else state0.astype(f32)

    def step(S, inp):
        rt, kt, vt, ldt = inp                       # (B,H,K/V)
        rt, kt, vt = rt.astype(f32), kt.astype(f32), vt.astype(f32)
        decayed = jnp.exp(ldt)[..., None] * S       # (B,H,K,V)
        kv = kt[..., None] * vt[..., None, :]
        S_new = decayed + kv
        if include_current:
            y = jnp.einsum("bhk,bhkv->bhv", rt, S_new)
        else:
            y = jnp.einsum("bhk,bhkv->bhv", rt, S)
            y = y + jnp.einsum("bhk,bhk->bh", rt * bonus.astype(f32), kt)[..., None] * vt
        return S_new, y

    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(ld, 1, 0))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), S_fin


def chunked_scan(r, k, v, log_decay, state0=None, *, include_current=True,
                 bonus=None, chunk=64, impl: str = "jnp"):
    """Chunk-parallel scan. Same contract as :func:`recurrent_scan`.

    ``impl='pallas'`` routes the per-chunk compute through the Pallas kernel
    (`repro.kernels.chunk_scan`) — interpret mode on CPU.
    """
    if impl == "pallas":
        from repro.kernels.chunk_scan import ops as cs_ops
        return cs_ops.chunk_scan(r, k, v, log_decay, state0,
                                 include_current=include_current,
                                 bonus=bonus, chunk=chunk)
    B, T, H, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc, Lc = T // chunk, chunk
    f32 = jnp.float32
    ld = _prep_decay(log_decay, K)

    def to_chunks(x):                # (B,T,...) -> (nc, B, Lc, ...)
        x = x.reshape((B, nc, Lc) + x.shape[2:])
        return jnp.moveaxis(x, 1, 0)

    rc, kc, vc, ldc = map(to_chunks, (r, k, v, ld))
    S0 = jnp.zeros((B, H, K, V), f32) if state0 is None else state0.astype(f32)

    tri = jnp.tril(jnp.ones((Lc, Lc), bool), 0 if include_current else -1)

    def chunk_step(S, inp):
        rq, kq, vq, ldq = inp                       # (B,Lc,H,·)
        rq, kq, vq = rq.astype(f32), kq.astype(f32), vq.astype(f32)
        L = jnp.cumsum(ldq, axis=1)                 # (B,Lc,H,K) inclusive
        M = L if include_current else jnp.pad(L, ((0, 0), (1, 0), (0, 0), (0, 0)))[:, :-1]
        L_end = L[:, -1]                            # (B,H,K)

        q_t = rq * jnp.exp(M)                       # bounded by |r|
        k_t = kq * jnp.exp(-L)                      # bounded by exp(clamp*Lc)
        y_cross = jnp.einsum("blhk,bhkv->blhv", q_t, S)
        A = jnp.einsum("blhk,bshk->bhls", q_t, k_t)
        A = jnp.where(tri[None, None], A, 0.0)
        y_intra = jnp.einsum("bhls,bshv->blhv", A, vq)
        y = y_cross + y_intra
        if not include_current:
            diag = jnp.einsum("blhk,blhk->blh", rq * bonus.astype(f32), kq)
            y = y + diag[..., None] * vq
        k_carry = kq * jnp.exp(L_end[:, None] - L)
        S_new = (jnp.exp(L_end)[..., None] * S
                 + jnp.einsum("blhk,blhv->bhkv", k_carry, vq))
        return S_new, y

    S_fin, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, ldc))
    ys = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, V)
    return ys.astype(v.dtype), S_fin


def recurrent_step(r, k, v, log_decay, state, *, include_current=True, bonus=None):
    """Single decode step. r,k:(B,H,K) v:(B,H,V) state:(B,H,K,V) f32."""
    f32 = jnp.float32
    K = r.shape[-1]
    ld = _prep_decay(log_decay[:, None], K)[:, 0]    # add/strip a time axis
    r32, k32, v32 = r.astype(f32), k.astype(f32), v.astype(f32)
    kv = k32[..., None] * v32[..., None, :]
    S_new = jnp.exp(ld)[..., None] * state + kv
    if include_current:
        y = jnp.einsum("bhk,bhkv->bhv", r32, S_new)
    else:
        y = jnp.einsum("bhk,bhkv->bhv", r32, state)
        y = y + jnp.einsum("bhk,bhk->bh", r32 * bonus.astype(f32), k32)[..., None] * v32
    return y.astype(v.dtype), S_new
