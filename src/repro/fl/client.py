"""FL client pool: on-board local training (paper eq. 3).

Each satellite trains the received global model for J local SGD iterations on
its own shard.  ``ImageClassifierPool`` is the paper's workload (CNN/MLP on
image classification); ``LMPool`` trains transformer LMs (our LLM-scale
federated examples).  Training is jitted once and reused across satellites.

Both pools expose three result forms:

* ``epoch_train_fn`` / ``epoch_inputs`` — the fused-epoch protocol
  (DESIGN.md §6): a *traceable* training function the simulator inlines
  into its single donated epoch program, plus the host-side gather of the
  participants' data shards for one call.
* ``train_many_stacked`` — one jitted vmap over the whole participant set,
  returning a device-resident ``ModelBank`` (stacked ``(C, N)`` float32,
  see DESIGN.md §2) and *lazy* device losses (``np.asarray`` only at
  history-record time, so timing math overlaps training dispatch).
  Participant counts are padded up to power-of-two buckets so a changing
  number of participants hits at most O(log S) traces instead of one per
  distinct count.
* ``train_many`` — legacy form materializing per-satellite host pytrees
  (one ``device_get``); kept for callers that need pytrees.

Datasets stay host-side in both pools: only the participants' shards are
put on device per call (the whole (S, m, ...) tensor must not live in HBM
for mega-constellation S).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import SmallNetConfig
from repro.core.modelbank import FlatSpec, ModelBank, pad_bucket_ids
from repro.models import cnn
from repro.optim import sgd, apply_updates

# participant-count bucketing (padded rows trained and discarded) so a
# changing participant set retraces the jitted vmap O(log S) times
_pad_ids = pad_bucket_ids


def _empty_bank(params) -> Tuple[ModelBank, np.ndarray]:
    """Zero-participant result (legacy pools returned ([], []))."""
    spec = FlatSpec.of(params)
    return (ModelBank(spec, jnp.zeros((0, spec.num_params), jnp.float32)),
            np.zeros(0))


@dataclasses.dataclass
class ImageClassifierPool:
    cfg: SmallNetConfig
    images: np.ndarray                 # (N, H, W, C)
    labels: np.ndarray                 # (N,)
    shards: List[np.ndarray]           # per-satellite index arrays
    local_iters: int = 30              # J
    batch_size: int = 32               # b
    lr: float = 0.01                   # eta (Table I)

    def __post_init__(self):
        opt = sgd(self.lr)
        self._true_sizes = [len(s) for s in self.shards]
        m = min(self._true_sizes)                     # equalize for vmap
        # host-side (S, m) index grid: participants' shards are gathered and
        # put on device per call (the full dataset never lives in HBM)
        self._sel = np.stack([s[:m] for s in self.shards])

        def _train_one(params, imgs, labs, key):
            state = opt.init(params)
            n = imgs.shape[0]

            def step(carry, k):
                params, state = carry
                idx = jax.random.randint(k, (self.batch_size,), 0, n)
                loss, grads = jax.value_and_grad(cnn.loss_fn)(
                    params, self.cfg, imgs[idx], labs[idx])
                upd, state = opt.update(grads, state, params)
                return (apply_updates(params, upd), state), loss

            keys = jax.random.split(key, self.local_iters)
            (params, _), losses = jax.lax.scan(step, (params, state), keys)
            return params, losses.mean()

        self._train_one = _train_one
        # one jitted vmap over the whole constellation — params broadcast
        self._train_many = jax.jit(jax.vmap(_train_one, in_axes=(None, 0, 0, 0)))

    @property
    def num_clients(self) -> int:
        return len(self.shards)

    def data_size(self, sat: int) -> int:
        return int(self._true_sizes[sat])

    def epoch_inputs(self, ids_np: np.ndarray):
        """Host gather of the padded participants' shards for one call."""
        sel = self._sel[ids_np]
        return (self.images[sel], self.labels[sel])

    def epoch_train_fn(self):
        """Traceable (params, inputs, ids, seed) -> (stacked_params, losses)
        for the fused epoch program (keys derived exactly as the stacked
        path does, so the two paths stay bit-comparable)."""
        train_one = self._train_one

        def _fn(params, inputs, ids, seed):
            imgs, labs = inputs
            keys = jax.vmap(lambda s: jax.random.PRNGKey(
                seed * jnp.uint32(9973) + s.astype(jnp.uint32)))(ids)
            return jax.vmap(train_one,
                            in_axes=(None, 0, 0, 0))(params, imgs, labs, keys)
        return _fn

    def train_many_stacked(self, sat_ids: Sequence[int], params, seed: int):
        """Train the given satellites from the same global model in one
        batched call.  Returns (ModelBank of per-sat models — stacked (C, N)
        on device, no host copy — and *lazy* device losses (C,))."""
        ids_np, n = _pad_ids(sat_ids)
        if n == 0:
            return _empty_bank(params)
        ids = jnp.asarray(ids_np)
        keys = jax.vmap(lambda s: jax.random.PRNGKey(
            (np.uint32(seed) * np.uint32(9973)) + s.astype(jnp.uint32)))(ids)
        imgs, labs = self.epoch_inputs(ids_np)
        stacked, losses = self._train_many(params, jnp.asarray(imgs),
                                           jnp.asarray(labs), keys)
        bank = ModelBank.from_stacked_tree(stacked)
        return ModelBank(bank.spec, bank.stack[:n]), losses[:n]

    def train_many(self, sat_ids: Sequence[int], params, seed: int):
        """Legacy form: (list of per-sat host param pytrees, losses)."""
        bank, losses = self.train_many_stacked(sat_ids, params, seed)
        return bank.to_pytrees(), losses

    def train(self, sat: int, params, seed: int):
        outs, losses = self.train_many([sat], params, seed)
        return outs[0], float(losses[0])


@dataclasses.dataclass
class Evaluator:
    cfg: SmallNetConfig
    images: np.ndarray
    labels: np.ndarray

    def __post_init__(self):
        self._acc = jax.jit(functools.partial(cnn.accuracy, cfg=self.cfg))
        # device the evaluation set once, not per epoch
        self._imgs = jnp.asarray(self.images)
        self._labs = jnp.asarray(self.labels)

    def eval_async(self, params):
        """Lazy device scalar — the simulator blocks on it only when the
        history row is finalized, so evaluation overlaps the next epoch's
        host work."""
        return self._acc(params, images=self._imgs, labels=self._labs)

    def __call__(self, params) -> float:
        return float(self.eval_async(params))


@dataclasses.dataclass
class LMPool:
    """Federated LM pretraining pool (tokens partitioned across satellites).

    Shards are truncated to a common sequence count so the whole participant
    set trains in one jitted vmap (like ``ImageClassifierPool``) — the
    per-satellite loop of the seed retraced ``_train`` whenever a shard's
    token count differed.

    ``size_mode`` picks what ``data_size`` (the D_n of eqs. 13/14) reports:
    ``"on_board"`` (default) keeps the paper's reading — the full shard a
    satellite holds — while ``"trained"`` reports the truncated per-call
    sequence count the vmap actually trained on, making aggregation weights
    proportional to gradient contributions instead of data held
    (DESIGN.md §3 records the trade-off).
    """
    model_cfg: object                  # ModelConfig
    tokens: np.ndarray                 # (N_seqs, seq_len)
    shards: List[np.ndarray]
    local_iters: int = 4
    batch_size: int = 4
    lr: float = 1e-3
    size_mode: str = "on_board"        # "on_board" (paper D_n) | "trained"

    def __post_init__(self):
        if self.size_mode not in ("on_board", "trained"):
            raise ValueError(
                f"size_mode must be 'on_board' or 'trained', "
                f"got {self.size_mode!r}")
        from repro.models import registry as R
        from repro.optim import adamw
        opt = adamw(self.lr)
        cfg = self.model_cfg
        self._true_sizes = [len(s) for s in self.shards]
        m = min(self._true_sizes)                     # equalize for vmap
        self._sel = np.stack([s[:m] for s in self.shards])  # (S, m)
        # tokens stay host-side: only the participants' shards are put on
        # device per call (an LLM-scale corpus must not live in HBM)

        def _train_one(params, toks, key):
            state = opt.init(params)
            n = toks.shape[0]

            def step(carry, k):
                params, state = carry
                idx = jax.random.randint(k, (self.batch_size,), 0, n)
                (loss, _), grads = jax.value_and_grad(
                    R.train_loss, has_aux=True)(params, cfg, {"tokens": toks[idx]})
                upd, state = opt.update(grads, state, params)
                return (apply_updates(params, upd), state), loss

            keys = jax.random.split(key, self.local_iters)
            (params, _), losses = jax.lax.scan(step, (params, state), keys)
            return params, losses.mean()

        self._train_one = _train_one
        self._train_many = jax.jit(jax.vmap(_train_one, in_axes=(None, 0, 0)))

    @property
    def num_clients(self) -> int:
        return len(self.shards)

    def data_size(self, sat: int) -> int:
        if self.size_mode == "trained":
            return int(self._sel.shape[1])     # truncated common length
        return int(self._true_sizes[sat])      # full on-board shard (D_n)

    def epoch_inputs(self, ids_np: np.ndarray):
        return self.tokens[self._sel[ids_np]]

    def epoch_train_fn(self):
        train_one = self._train_one

        def _fn(params, toks, ids, seed):
            keys = jax.vmap(lambda s: jax.random.PRNGKey(
                seed * jnp.uint32(7919) + s.astype(jnp.uint32)))(ids)
            return jax.vmap(train_one,
                            in_axes=(None, 0, 0))(params, toks, keys)
        return _fn

    def train_many_stacked(self, sat_ids: Sequence[int], params, seed: int):
        """One batched call over the participant set -> (ModelBank, lazy
        device losses)."""
        ids_np, n = _pad_ids(sat_ids)
        if n == 0:
            return _empty_bank(params)
        ids = jnp.asarray(ids_np)
        keys = jax.vmap(lambda s: jax.random.PRNGKey(
            np.uint32(seed) * np.uint32(7919) + s.astype(jnp.uint32)))(ids)
        toks = jnp.asarray(self.epoch_inputs(ids_np))
        stacked, losses = self._train_many(params, toks, keys)
        bank = ModelBank.from_stacked_tree(stacked)
        return ModelBank(bank.spec, bank.stack[:n]), losses[:n]

    def train_many(self, sat_ids: Sequence[int], params, seed: int):
        bank, losses = self.train_many_stacked(sat_ids, params, seed)
        return bank.to_pytrees(), losses

    def train(self, sat: int, params, seed: int):
        outs, losses = self.train_many([sat], params, seed)
        return outs[0], float(losses[0])
