"""Head-to-head convergence-delay benchmark under the event runtime.

The paper's headline (Table II / Fig. 6) is not an accuracy number but a
*delay* number: time-to-target-accuracy under asynchronous aggregation vs
the synchronous barrier.  This benchmark finally makes that comparison
runnable: the SAME constellation, contact plan and (deterministic,
fused-protocol) trainer run under each strategy's trigger policy in the
event-driven runtime (`sched/runtime.py`), and the simulated convergence
delay to a target accuracy is read off the shared history format with
``convergence_time``.

Per policy it records: simulated convergence delay (seconds), epochs to
target, fused dispatch counts, event counts, and host wall time; plus the
compiled contact-plan summary for the scenario.  Results go to
``BENCH_sched.json`` (CI uploads it next to ``BENCH_epoch.json``).

``--fail-if-not-lower`` exits nonzero unless the AsyncFLEO policy's
convergence delay is strictly lower than the sync GS-FedAvg baseline's —
the acceptance gate for the paper's ordering.

Usage:  PYTHONPATH=src python benchmarks/sched_bench.py [--target 0.9]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import FLSimulation, SimConfig, convergence_time
from repro.core.modelbank import FlatSpec, flatten_tree
from repro.fl.strategies import get_strategy
from repro.sched import EventDrivenRuntime

# async vs sync on the same constellation with the SAME PS placement
# (a single ground station, the Razmi-style GS-FL setup), plus the
# FedAsync per-arrival baseline for reference
POLICY_ROWS = (
    ("async_asyncfleo", "asyncfleo-gs"),
    ("sync_gs_fedavg", "fedisl"),
    ("fedasync_per_arrival", "fedasync"),
)


def make_model(key_seed: int = 0, width: int = 64):
    rng = np.random.default_rng(key_seed)
    return {
        "w1": rng.standard_normal((width, width)).astype(np.float32) * 0.0,
        "w2": rng.standard_normal((width, width)).astype(np.float32) * 0.0,
        "b": np.zeros((width,), np.float32),
    }


class ConvergingTrainer:
    """Deterministic fused-protocol trainer: every local step moves the
    model halfway toward the all-ones optimum (plus a zero-mean per-sat
    perturbation), so accuracy-vs-epoch is identical across policies and
    the measured difference is PURE scheduling delay."""

    def __init__(self, w0, rate: float = 0.5, jitter: float = 1e-3):
        self.spec = FlatSpec.of(w0)
        self._rate = rate
        self._jitter = jitter

    def data_size(self, sat: int) -> int:
        return 100 + (sat % 7) * 10

    def epoch_inputs(self, ids_np):
        return None

    def epoch_train_fn(self):
        rate, jitter = self._rate, self._jitter

        def _fn(params, inputs, ids, seed):
            flat = flatten_tree(params)
            # zero-mean per-(sat, seed) jitter: cancels in aggregation up
            # to weighting differences, so policies stay comparable
            phase = ((ids * 37 + seed.astype(jnp.int32)) % 13
                     - 6).astype(jnp.float32) * jitter
            stack = (flat[None, :] * (1.0 - rate) + rate
                     + phase[:, None])
            return stack, jnp.zeros(ids.shape[0])
        return _fn

    def train_many_stacked(self, sats, params, seed):   # stacked protocol
        from repro.core.modelbank import ModelBank, pad_bucket_ids
        ids, n = pad_bucket_ids(list(sats))
        fn = self.epoch_train_fn()
        stack, _ = fn(params, None, jnp.asarray(ids),
                      jnp.uint32(np.uint32(seed)))
        return ModelBank(self.spec, stack[:n]), np.zeros(n)


class MeanDistanceEvaluator:
    """acc = 1 - mean|w - 1| (clipped): 0 at w0 = zeros, 1 at the optimum."""

    def __call__(self, params) -> float:
        flat = np.asarray(flatten_tree(params))
        return 1.0 - min(1.0, float(np.mean(np.abs(flat - 1.0))))


def bench_policy(name: str, strategy: str, w0, target: float,
                 max_epochs: int, duration_s: float) -> Dict:
    sim = SimConfig(duration_s=duration_s, dt_s=30.0, train_time_s=300.0,
                    use_model_bank=True, use_fused_step=True,
                    event_driven=True)
    fls = FLSimulation(get_strategy(strategy), ConvergingTrainer(w0),
                       MeanDistanceEvaluator(), sim)
    rt = EventDrivenRuntime(fls)
    t0 = time.perf_counter()
    hist = rt.run(w0, max_epochs=max_epochs, target_accuracy=target)
    wall = time.perf_counter() - t0
    conv = convergence_time(hist, target)
    return {
        "policy": name,
        "strategy": strategy,
        "trigger_policy": rt.policy.name,
        "target_accuracy": target,
        "convergence_delay_s": conv,
        "epochs_to_target": (len(hist) if conv is not None else None),
        "final_accuracy": float(hist[-1].accuracy) if hist else None,
        "aggregations": len(hist),
        "fused_dispatches": fls._fused_prog.dispatches,
        "fallback_dispatches": fls._fused_prog.fallback_dispatches,
        "event_counts": dict(rt.events.counts),
        "wall_s": wall,
        "plan": fls.plan.summary(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", type=float, default=0.9)
    ap.add_argument("--max-epochs", type=int, default=30)
    ap.add_argument("--days", type=float, default=3.0)
    ap.add_argument("--out", default="BENCH_sched.json")
    ap.add_argument("--fail-if-not-lower", action="store_true",
                    help="exit 1 unless AsyncFLEO's convergence delay is "
                         "strictly lower than the sync GS-FedAvg baseline")
    args = ap.parse_args()

    w0 = make_model()
    report = {"target": args.target, "policies": []}
    for name, strategy in POLICY_ROWS:
        # per-arrival aggregations are single-model EMA steps, so FedAsync
        # needs ~participants-per-round more of them per unit of progress
        budget = (args.max_epochs * 20 if strategy == "fedasync"
                  else args.max_epochs)
        r = bench_policy(name, strategy, w0, args.target, budget,
                         args.days * 86400.0)
        conv = r["convergence_delay_s"]
        print(f"{name:22s} ({strategy:13s}): conv_delay "
              f"{conv / 3600.0 if conv else float('nan'):8.2f} h  "
              f"epochs {r['epochs_to_target']}  "
              f"dispatches {r['fused_dispatches']}  wall {r['wall_s']:.2f} s")
        report["policies"].append(r)

    by_name = {r["policy"]: r for r in report["policies"]}
    a = by_name["async_asyncfleo"]["convergence_delay_s"]
    s = by_name["sync_gs_fedavg"]["convergence_delay_s"]
    report["async_vs_sync_speedup"] = (s / a if a and s else None)
    if report["async_vs_sync_speedup"]:
        print(f"async/sync convergence-delay speedup: "
              f"{report['async_vs_sync_speedup']:.1f}x")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if args.fail_if_not_lower:
        if a is None or s is None or not a < s:
            raise SystemExit(
                f"async convergence delay ({a}) not strictly lower than "
                f"sync ({s})")


if __name__ == "__main__":
    main()
