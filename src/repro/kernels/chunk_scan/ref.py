"""Oracle for chunk_scan: the sequential recurrence from models/scan_ops."""
from repro.models.scan_ops import recurrent_scan


def chunk_scan_ref(r, k, v, log_decay, state0=None, *, include_current=True,
                   bonus=None):
    return recurrent_scan(r, k, v, log_decay, state0,
                          include_current=include_current, bonus=bonus)
