"""Kernel microbenchmarks: us_per_call for each Pallas kernel (interpret mode
on CPU — relative numbers + oracle comparisons; real perf comes from the
roofline analysis, not CPU wall time) and the XLA reference path."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.chunk_scan.ops import chunk_scan
from repro.kernels.fed_agg.ops import fed_agg
from repro.kernels.fed_agg.ref import fed_agg_flat_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pairwise_dist.ops import pairwise_dist
from repro.kernels.pairwise_dist.ref import pairwise_dist_sq_ref
from repro.models.scan_ops import chunked_scan


def _time(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    # fed_agg: 40-satellite CNN-scale aggregation
    C, N = 40, 200_000
    stack = jax.random.normal(key, (C, N))
    gamma = jnp.full((C,), 1.0 / C)
    base = jax.random.normal(key, (N,))
    rows.append(("fed_agg_pallas_interp", _time(
        lambda: fed_agg(stack, gamma, base, 0.2)), f"C={C},N={N}"))
    ref = jax.jit(fed_agg_flat_ref)
    rows.append(("fed_agg_xla_ref", _time(
        lambda: ref(stack, gamma, base, 0.2)), f"C={C},N={N}"))

    # pairwise_dist: 5 orbit models
    x = jax.random.normal(key, (5, 200_000))
    rows.append(("pairwise_dist_pallas_interp", _time(
        lambda: pairwise_dist(x, squared=True)), "M=5,N=200k"))
    refp = jax.jit(pairwise_dist_sq_ref)
    rows.append(("pairwise_dist_xla_ref", _time(lambda: refp(x)), "M=5,N=200k"))

    # chunk_scan vs jnp chunked path (mamba-style)
    B, T, H, K, V = 1, 512, 4, 16, 32
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, V)) * 0.3
    ld = -jax.random.uniform(ks[3], (B, T, H)) * 0.5
    rows.append(("chunk_scan_pallas_interp", _time(
        lambda: chunk_scan(r, k, v, ld, chunk=64)), f"T={T},H={H}"))
    jn = jax.jit(lambda *a: chunked_scan(*a, include_current=True, chunk=64))
    rows.append(("chunk_scan_xla_chunked", _time(
        lambda: jn(r, k, v, ld)), f"T={T},H={H}"))

    # flash attention
    q = jax.random.normal(ks[0], (1, 512, 4, 64)) * 0.5
    kk = jax.random.normal(ks[1], (1, 512, 2, 64)) * 0.5
    vv = jax.random.normal(ks[2], (1, 512, 2, 64)) * 0.5
    rows.append(("flash_attn_pallas_interp", _time(
        lambda: flash_attention(q, kk, vv)), "S=512,H=4,GQA2"))

    def xla_ref():
        k2, v2 = jnp.repeat(kk, 2, 2), jnp.repeat(vv, 2, 2)
        fl = lambda t: t.transpose(0, 2, 1, 3).reshape(4, 512, 64)
        return attention_ref(fl(q), fl(k2), fl(v2))
    xr = jax.jit(xla_ref)
    rows.append(("flash_attn_xla_ref", _time(xr), "S=512,H=4"))
    return rows


def main():
    print("name,us_per_call,config")
    for name, us, cfgs in run():
        print(f"{name},{us:.0f},{cfgs}")


if __name__ == "__main__":
    main()
