"""Pallas kernel: chunked linear recurrence (RWKV6 / Mamba2-SSD).

Grid = (B*H, T/Lc); the chunk axis is innermost and sequential, carrying the
(K, V) recurrent state in VMEM scratch across chunks of the same batch-head
(re-seeded from the state0 input at chunk 0).  Per chunk: two (Lc,K)x(K,V)
matmuls + one (Lc,K)x(K,Lc) masked matmul — MXU work — with the decay
exponentials computed in f32 on the VPU.  See models/scan_ops.py for the
math and the stabilization/clamp discussion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_kernel(r_ref, k_ref, v_ref, ld_ref, s0_ref, u_ref,
                  y_ref, sfin_ref, state, *, include_current: bool, Lc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _seed():
        state[...] = s0_ref[0]

    S = state[...]                                        # (K, V) f32
    r = r_ref[0].astype(jnp.float32)                      # (Lc, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                      # (Lc, V)
    ld = ld_ref[0].astype(jnp.float32)                    # (Lc, K)

    L = jnp.cumsum(ld, axis=0)
    if include_current:
        M = L
    else:
        M = jnp.concatenate([jnp.zeros((1, L.shape[1]), jnp.float32), L[:-1]], 0)
    L_end = L[-1]                                         # (K,)

    q_t = r * jnp.exp(M)
    k_t = k * jnp.exp(-L)
    y_cross = jnp.dot(q_t, S, preferred_element_type=jnp.float32)
    A = jnp.dot(q_t, k_t.T, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    keep = (rows >= cols) if include_current else (rows > cols)
    A = jnp.where(keep, A, 0.0)
    y = y_cross + jnp.dot(A, v, preferred_element_type=jnp.float32)
    if not include_current:
        u = u_ref[0].astype(jnp.float32)                  # (K,)
        diag = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)
        y = y + diag * v

    k_carry = k * jnp.exp(L_end[None, :] - L)
    S_new = (jnp.exp(L_end)[:, None] * S
             + jnp.dot(k_carry.T, v, preferred_element_type=jnp.float32))
    state[...] = S_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit_state():
        sfin_ref[0] = S_new


@functools.partial(jax.jit, static_argnames=("include_current", "chunk",
                                             "interpret"))
def chunk_scan_flat(r, k, v, ld, s0, u, *, include_current: bool,
                    chunk: int, interpret: bool = True):
    """Flattened-batch-head form.
    r, k, ld: (BH, T, K); v: (BH, T, V); s0: (BH, K, V); u: (BH, K).
    Returns (y (BH, T, V), s_fin (BH, K, V))."""
    BH, T, K = r.shape
    V = v.shape[-1]
    Lc = chunk
    assert T % Lc == 0, (T, Lc)
    grid = (BH, T // Lc)
    kernel = functools.partial(_chunk_kernel, include_current=include_current,
                               Lc=Lc)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lc, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, V), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, K, V), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, K), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lc, V), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, K, V), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), v.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, ld, s0, u)
    return y, s_fin
