"""Quickstart: AsyncFLEO end-to-end in ~2 minutes on CPU.

Builds the paper's constellation (40 LEO satellites, 5 orbits, 2000 km),
partitions a synthetic MNIST-like dataset non-IID across orbits (paper
§V-A), and runs the AsyncFLEO asynchronous FL loop with a single HAP as
parameter server, printing simulated-time accuracy as it converges.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro.configs import MNIST_CNN
from repro.core import FLSimulation, SimConfig, paper_constellation
from repro.data import class_conditional_images, paper_noniid_partition
from repro.fl import Evaluator, ImageClassifierPool, get_strategy
from repro.models import cnn


def main():
    cfg = dataclasses.replace(MNIST_CNN, conv_channels=(8, 16))
    const = paper_constellation()
    print(f"constellation: {const.num_orbits} orbits x {const.sats_per_orbit} "
          f"satellites @ {const.altitude_m/1e3:.0f} km, period "
          f"{const.period_s/60:.1f} min")

    imgs, labs = class_conditional_images(0, 3000, separation=0.8)
    test_i, test_l = class_conditional_images(99, 800, separation=0.8)
    shards = paper_noniid_partition(labs, const.orbit_ids(), seed=0)
    pool = ImageClassifierPool(cfg, imgs, labs, shards, local_iters=20)
    ev = Evaluator(cfg, test_i, test_l)
    w0 = jax.device_get(cnn.init_params(jax.random.PRNGKey(0), cfg))

    sim = FLSimulation(get_strategy("asyncfleo-hap"), pool, ev,
                       SimConfig(duration_s=86400.0))
    print("running AsyncFLEO-HAP (async, ring-of-stars, grouping, "
          "staleness discounting)...")
    hist = sim.run(w0, max_epochs=8, target_accuracy=0.9)
    for r in hist:
        print(f"  epoch {r.epoch:2d}  sim-time {r.time_s/3600:5.2f} h  "
              f"accuracy {r.accuracy:.3f}  models {r.num_models:2d}  "
              f"gamma {r.gamma:.2f}")
    print(f"final accuracy {hist[-1].accuracy:.3f} after "
          f"{hist[-1].time_s/3600:.2f} simulated hours")


if __name__ == "__main__":
    main()
