"""Shared neural-net building blocks (pure JAX, functional, pytree params).

Conventions
-----------
* Params are nested dicts of jnp arrays.  Layer-stacked params carry a
  leading ``L`` axis and are consumed by ``jax.lax.scan``.
* Compute dtype is ``cfg.dtype`` (bf16 by default); params are kept in
  ``cfg.param_dtype`` (f32 master copies) and cast at use.
* Attention weights are stored 3-D ``(embed, heads, head_dim)`` so the
  ``heads`` axis can be tensor-sharded by name (see launch/sharding.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common transformer practice)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def group_norm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head group norm used by RWKV time-mix output. x: (..., H, hd)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if x.ndim == angles.ndim + 1:                              # has heads axis
        angles = angles[..., None, :]                          # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, optional qk_norm / sliding window / bidirectional)
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), in_axis_size=d),
        "wk": dense_init(ks[1], (d, KV, hd), in_axis_size=d),
        "wv": dense_init(ks[2], (d, KV, hd), in_axis_size=d),
        "wo": dense_init(ks[3], (H, hd, d), in_axis_size=H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def _mask_bias(q_pos, k_pos, causal: bool, window: int, dtype):
    """Additive mask bias (..., Sq, Sk) from query/key positions."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, dtype=bool)
    if causal:
        ok &= diff >= 0
    if window:
        ok &= diff < window
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def attention_scores(q, k, v, q_pos, k_pos, *, causal, window, kv_groups):
    """Reference (XLA) attention. q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    q = q.reshape(B, Sq, KV, kv_groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    bias = _mask_bias(q_pos, k_pos, causal, window, jnp.float32)  # (B?,Sq,Sk)
    bias = bias.reshape(bias.shape[:-2] + (1,) * (scores.ndim - bias.ndim)
                        + bias.shape[-2:])
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def attention(p, cfg: ModelConfig, x, positions, kv_cache=None, *,
              window: int = 0, impl: str = "xla", q_chunks: int = 1):
    """Full GQA attention block.

    ``kv_cache``: None for train/prefill over the whole sequence; else a dict
    ``{"k","v","index"}`` holding a (possibly ring-buffered) cache for decode.
    Returns (out, new_cache_or_None).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    if kv_cache is not None and positions is None:
        positions = jnp.broadcast_to(kv_cache["index"][None, None],
                                     (x.shape[0], x.shape[1]))
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is None:
        k_pos = positions
        q_pos = positions
        kk, vv = k, v
    else:
        # decode: write this step's k/v into the ring buffer
        cache_len = kv_cache["k"].shape[1]
        idx = kv_cache["index"]                      # scalar int32 steps so far
        slot = jnp.mod(idx, cache_len)
        kk = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(kv_cache["k"].dtype),
                                          (0, slot, 0, 0))
        vv = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(kv_cache["v"].dtype),
                                          (0, slot, 0, 0))
        new_cache = {"k": kk, "v": vv, "index": idx + 1}
        # Reconstruct each ring slot's absolute position from its "age"
        # relative to the current write slot; slots never written get a huge
        # positive position so the causal mask removes them.
        slots = jnp.arange(cache_len)
        written = jnp.minimum(idx + 1, cache_len)
        age = jnp.mod(slot - slots, cache_len)       # 0 = newest (this step)
        k_pos = jnp.where(age < written, idx - age, 10**9)
        k_pos = jnp.broadcast_to(k_pos, (x.shape[0], cache_len))
        q_pos = jnp.broadcast_to(jnp.asarray(idx)[None], (x.shape[0], 1))
        kk = kk.astype(dt)
        vv = vv.astype(dt)

    if impl == "pallas" and kv_cache is None:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, kk, vv, causal=cfg.causal, window=window)
    elif (q_chunks > 1 and kv_cache is None and cfg.causal
          and x.shape[1] % q_chunks == 0):
        # chunked causal prefill: chunk i attends to keys [0, (i+1)*S/n)
        S = x.shape[1]
        cs = S // q_chunks
        outs = []
        for i in range(q_chunks):
            hi = (i + 1) * cs
            outs.append(attention_scores(
                q[:, i * cs:hi], kk[:, :hi], vv[:, :hi],
                q_pos[..., i * cs:hi], k_pos[..., :hi],
                causal=True, window=window, kv_groups=H // KV))
        out = jnp.concatenate(outs, axis=1)
    else:
        out = attention_scores(q, kk, vv, q_pos, k_pos,
                               causal=cfg.causal or kv_cache is not None,
                               window=window, kv_groups=H // KV)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, new_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d_model, d_ff)),
        "w3": dense_init(ks[1], (d_model, d_ff)),
        "w2": dense_init(ks[2], (d_ff, d_model), in_axis_size=d_ff),
    }


def mlp(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["w1"].astype(dt)) * (x @ p["w3"].astype(dt))
    return h @ p["w2"].astype(dt)


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p = {"embedding": embed_init(ks[0], (cfg.vocab_size, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))
    return p


def embed(p, cfg: ModelConfig, tokens, dtype):
    return p["embedding"].astype(dtype)[tokens]


def unembed(p, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return x @ p["embedding"].astype(x.dtype).T
    return x @ p["unembed"].astype(x.dtype)
