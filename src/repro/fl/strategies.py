"""FL-Satcom strategies: AsyncFLEO and the paper's baselines (§II, §V-A).

Each strategy is a declarative spec consumed by ``repro.core.simulator``:

=================  ====== ======= ========== ============ =====================
strategy           sync   ISL     grouping   aggregation  PS placement
=================  ====== ======= ========== ============ =====================
asyncfleo-gs       no     yes     yes        asyncfleo    GS, arbitrary (Rolla)
asyncfleo-hap      no     yes     yes        asyncfleo    1 HAP, arbitrary
asyncfleo-twohap   no     yes     yes        asyncfleo    2 HAPs (ring)
fedavg / fedisl    yes    yes     no         fedavg       GS, arbitrary
fedisl-ideal       yes    yes     no         fedavg       GS at the North Pole
fedsat             no     no      no         per-arrival  GS at the North Pole
fedspace           no     no      no         interval     GS, arbitrary
fedhap             yes    yes     no         fedavg       1 HAP
fedasync           no     yes     no         per-arrival  GS, arbitrary
asyncfleo-pipelined no    yes     yes        asyncfleo    GS, 3 rounds in flight
=================  ====== ======= ========== ============ =====================

FedSpace's real scheduler optimizes the schedule from uploaded raw-data
fractions (which AsyncFLEO criticizes); we emulate its idle-vs-staleness
trade-off with a fixed-interval staleness-weighted aggregation (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# mirror of repro.core.aggregation.STALENESS_FNS (kept literal here so this
# module stays import-light; test_faults pins the two in sync)
_STALENESS_FNS = ("eq13", "constant", "hinge", "poly")
_AGG_MODES = ("asyncfleo", "fedavg", "per_arrival", "interval")


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    name: str
    sync: bool
    use_isl: bool
    grouping: bool
    agg_mode: str                    # asyncfleo | fedavg | per_arrival | interval
    ps_scenario: str                 # gs | hap | twohap | gs-np | hapring:N
    interval_s: float = 1800.0       # for agg_mode == interval
    num_groups: int = 3
    strict_paper_eq14: bool = False
    use_agg_kernel: bool = False     # route eq. 14 through the Pallas kernel
    # event-runtime trigger policy (sched/policies.py): "" derives it from
    # sync/agg_mode — sync -> barrier, per_arrival -> FedAsync, else the
    # AsyncFLEO idle-timeout window
    sched_policy: str = ""
    # pipelined event runtime (sched/runtime.py, DESIGN.md §8): how many
    # rounds may be in flight at once (1 = the single-round loop,
    # bit-identical to the epoch loop) and which sink-handoff policy
    # picks the next source/sink PS ("" -> the §IV-B3 ring role swap;
    # "next_contact" -> earliest-next-contact from the contact plan)
    max_in_flight: int = 1
    handoff_policy: str = ""
    # per-divergence-group trigger deadlines for the AsyncFLEO policy:
    # ((group_id, window_s), ...) pairs (group -1 = not-yet-grouped
    # orbits); empty keeps the single global agg_timeout_s window
    group_timeouts: tuple = ()
    # finite per-PS link capacity (sched/contacts.ContentionModel,
    # DESIGN.md §9): how many model transfers a PS can send (and,
    # separately, receive) in parallel — concurrent transfers at the same
    # PS beyond this serialize FIFO, including transfers from different
    # in-flight rounds.  None = infinite parallelism with no contention
    # state at all, bit-identical to the pre-contention semantics (the
    # parity default)
    ps_channels: Optional[int] = None
    # staleness-mitigation function for the asyncfleo aggregation mode
    # (core/aggregation.staleness_factor): "eq13" is the paper's k_n/beta
    # discount; "constant" / "hinge" / "poly" are the FedAsync family
    # (SNIPPETS.md §1) over the staleness gap beta - k_n
    staleness_fn: str = "eq13"
    # contention-aware trigger windows (DESIGN.md §10): when set, the
    # AsyncFLEO policy multiplies an idle window by
    # rx_backlog_window_scale whenever the sink PS's pending rx-channel
    # backlog exceeds this many channel-seconds at window-open time — a
    # congested sink commits sooner instead of waiting for arrivals that
    # are stuck in the queue anyway.  None (default) = off, windows
    # bit-identical to the uncontended trigger logic
    rx_backlog_threshold_s: Optional[float] = None
    rx_backlog_window_scale: float = 0.5
    # fault-aware participant selection (DESIGN.md §11): when True, the
    # event runtime skips recruiting satellites whose FaultModel eclipse
    # window covers the expected uplink instant (recv + training time),
    # or whose expected uplink lands in a total PS outage — the model
    # would only wait out the dark window anyway.  False (default) keeps
    # recruitment bit-identical to the fault-unaware runtime
    fault_aware_selection: bool = False

    def __post_init__(self):
        """Fail fast on malformed specs — a bad channel count or timeout
        table used to surface as an opaque IndexError deep in the
        runtime."""
        if self.agg_mode not in _AGG_MODES:
            raise ValueError(f"StrategySpec.agg_mode must be one of "
                             f"{_AGG_MODES}, got {self.agg_mode!r}")
        if self.staleness_fn not in _STALENESS_FNS:
            raise ValueError(f"StrategySpec.staleness_fn must be one of "
                             f"{_STALENESS_FNS}, got {self.staleness_fn!r}")
        if self.interval_s <= 0.0:
            raise ValueError(f"StrategySpec.interval_s must be > 0, "
                             f"got {self.interval_s}")
        if int(self.num_groups) < 1:
            raise ValueError(f"StrategySpec.num_groups must be >= 1, "
                             f"got {self.num_groups}")
        if int(self.max_in_flight) < 1:
            raise ValueError(f"StrategySpec.max_in_flight must be >= 1, "
                             f"got {self.max_in_flight}")
        if self.ps_channels is not None and int(self.ps_channels) < 1:
            raise ValueError(f"StrategySpec.ps_channels must be >= 1 or "
                             f"None (infinite), got {self.ps_channels}")
        for pair in self.group_timeouts:
            try:
                ok = (len(pair) == 2 and float(pair[0]) == int(pair[0])
                      and float(pair[1]) > 0.0)
            except (TypeError, ValueError):
                ok = False
            if not ok:
                raise ValueError(
                    "StrategySpec.group_timeouts must be (group_id, "
                    f"window_s > 0) pairs, got {self.group_timeouts!r}")
        if (self.rx_backlog_threshold_s is not None
                and self.rx_backlog_threshold_s < 0.0):
            raise ValueError(f"StrategySpec.rx_backlog_threshold_s must be "
                             f">= 0 or None, got {self.rx_backlog_threshold_s}")
        if not 0.0 < self.rx_backlog_window_scale <= 1.0:
            raise ValueError(f"StrategySpec.rx_backlog_window_scale must be "
                             f"in (0, 1], got {self.rx_backlog_window_scale}")


STRATEGIES = {
    "asyncfleo-gs": StrategySpec("asyncfleo-gs", False, True, True,
                                 "asyncfleo", "gs"),
    "asyncfleo-hap": StrategySpec("asyncfleo-hap", False, True, True,
                                  "asyncfleo", "hap"),
    "asyncfleo-twohap": StrategySpec("asyncfleo-twohap", False, True, True,
                                     "asyncfleo", "twohap"),
    "fedisl": StrategySpec("fedisl", True, True, False, "fedavg", "gs"),
    "fedisl-ideal": StrategySpec("fedisl-ideal", True, True, False,
                                 "fedavg", "gs-np"),
    "fedsat": StrategySpec("fedsat", False, False, False,
                           "per_arrival", "gs-np"),
    "fedspace": StrategySpec("fedspace", False, False, False,
                             "interval", "gs"),
    "fedhap": StrategySpec("fedhap", True, True, False, "fedavg", "hap"),
    # FedAsync-style baseline: immediate per-arrival aggregation at a GS
    # PS, full ISL relay — only meaningfully different from fedsat under
    # the event-driven runtime, where every MODEL_ARRIVAL triggers its own
    # aggregation instead of a batched window
    "fedasync": StrategySpec("fedasync", False, True, False,
                             "per_arrival", "gs", sched_policy="per_arrival"),
    # pipelined AsyncFLEO (DESIGN.md §8): same physics and PS placement
    # as asyncfleo-gs, but the event runtime keeps up to 3 rounds in
    # flight and opens each from the contact-plan-chosen PS — the
    # head-to-head row that isolates what overlap buys
    "asyncfleo-pipelined": StrategySpec("asyncfleo-pipelined", False, True,
                                        True, "asyncfleo", "gs",
                                        max_in_flight=3,
                                        handoff_policy="next_contact"),
}


def get_strategy(name: str) -> StrategySpec:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; available: {sorted(STRATEGIES)}")
    return STRATEGIES[name]
