"""Expert-parallel all-to-all MoE: single-rank equivalence + an 8-fake-device
multi-rank equivalence run in a subprocess (device count is locked at first
jax init, so the multi-rank case needs its own process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import moe as MOE
from repro.models.moe_ep import ep_capacity, make_ep_moe_layer


def test_ep_capacity_rounding():
    assert ep_capacity(128, 2, 4, 1.0) % 8 == 0
    assert ep_capacity(1, 1, 64, 1.0) == 8          # floor


def test_ep_single_rank_matches_reference():
    cfg = ARCHS["deepseek-v2-236b"].reduced().replace(
        dtype="float32", moe_capacity_factor=64.0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe_ffn(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    out, aux = make_ep_moe_layer(cfg, mesh, capacity_factor=64.0)(p, x)
    ref = MOE.moe_ffn_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    assert np.isfinite(float(aux))


MULTI_RANK_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS
    from repro.models import moe as MOE
    from repro.models.moe_ep import make_ep_moe_layer

    cfg = ARCHS["deepseek-v2-236b"].reduced().replace(
        dtype="float32", moe_capacity_factor=64.0)     # 4 experts / 4 ranks
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe_ffn(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    out, aux = make_ep_moe_layer(cfg, mesh, capacity_factor=64.0)(p, x)
    ref = MOE.moe_ffn_reference(p, cfg, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    print("ERR", err)
    assert err < 1e-4, err
""")


def test_ep_multi_rank_matches_reference():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", MULTI_RANK_SCRIPT],
                          env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ERR" in proc.stdout
