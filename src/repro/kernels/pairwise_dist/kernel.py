"""Pallas kernel: pairwise squared-L2 distances between M flattened models.

    D[i,j] = ||X[i] - X[j]||^2 = n_i + n_j - 2 * X X^T

The parameter dimension N is huge (models have 1e5..1e9 entries) while M is
tiny (orbits / satellites), so the kernel streams N in VMEM-sized tiles and
accumulates the (M, M) Gram matrix and the per-row squared norms in VMEM
scratch, finalizing D on the last grid step — one HBM pass, no (M, N)
temporaries materialized twice like the broadcast-subtract oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_N = 4096


def _pdist_kernel(x_ref, out_ref, gram_acc, norm_acc):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_acc[...] = jnp.zeros_like(gram_acc)
        norm_acc[...] = jnp.zeros_like(norm_acc)

    xb = x_ref[...].astype(jnp.float32)                     # (M, BLOCK_N)
    gram_acc[...] += jnp.dot(xb, xb.T, preferred_element_type=jnp.float32)
    norm_acc[...] += jnp.sum(xb * xb, axis=1, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _finalize():
        n = norm_acc[...]
        d = n + n.T - 2.0 * gram_acc[...]
        out_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def pairwise_dist_sq(x, *, interpret: bool = True, block_n: int = BLOCK_N):
    """x: (M, N) -> (M, M) squared distances."""
    M, N = x.shape
    pad = (-N) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))   # zero pad leaves distances intact
    grid = ((N + pad) // block_n,)
    return pl.pallas_call(
        _pdist_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((M, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((M, M), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, M), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((M, M), jnp.float32),
            pltpu.VMEM((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
