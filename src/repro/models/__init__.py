from repro.models import registry
from repro.models.registry import (
    init_params, apply, init_cache, decode_step, train_loss,
    analytic_param_count,
)

__all__ = ["registry", "init_params", "apply", "init_cache", "decode_step",
           "train_loss", "analytic_param_count"]
