"""Contact-plan compilation: orbital geometry -> schedulable link windows
(DESIGN.md §7; the multi-sink handoff query it serves is §8).

A *contact plan* is the standard artifact of DTN / satellite-network
scheduling (LRSIM's dynamic-state generation follows the same shape): the
constellation geometry, visibility grid and link model are compiled ONCE
into sorted availability windows, and everything downstream — the
event-driven runtime (`sched/runtime.py`), benchmarks, exports — consumes
the plan instead of re-deriving geometry.

``ContactPlan`` bundles three things:

* **windows** — run-length-encoded sat<->PS visibility intervals
  ``[t_start, t_end)`` (from the timeline's ``node_windows`` segment
  export — dense-grid RLE or the sparse timeline's precompiled
  segments, DESIGN.md §14), each annotated with the one-hop link delay
  at window start for a nominal payload.  Compiled lazily and cached.
* **ISL / IHL availability** — intra-orbit ISL rings are permanently
  available (adjacent neighbors, §IV-A), so they are a constant hop delay,
  not windows; the HAP ring likewise.
* **timing evaluators** — ``downlink_times`` / ``uplink_times`` answer
  "when does satellite n hold the global model" / "when does n's local
  model reach the sink" for a *specific* payload and instant, delegating
  the fine-grained delay math to the compiled-in ``PropagationModel``
  (the plan's windows and the evaluators read the same grid, so they never
  disagree).  The ``use_isl`` switch (strategies without inter-satellite
  links wait for direct visibility) lives here, moved out of the
  simulator.

`core/simulator.py` routes its propagation timing through a plan, and the
event-driven runtime schedules its wake-ups from the same object — one
compiled view of "who can talk to whom, when, at what delay".

**Link capacity** (DESIGN.md §9): a plan may own a ``ContentionModel`` —
per-PS transmit and receive pools of ``k`` parallel channels with FIFO
grant-by-request-time queuing — in which case the timing evaluators
charge every sat<->PS model transfer one channel grant, so concurrent
transfers at the same PS serialize (including transfers from *different*
in-flight rounds, since the pools persist across round opens).
``contention=None`` (the default) keeps the historical
infinite-parallelism semantics bit-for-bit.
"""
from __future__ import annotations

import bisect
import copy
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.constellation import GroundNode, WalkerDelta
from repro.core.links import LinkModel
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import (SparseVisibilityTimeline,
                                   VisibilityTimeline)
from repro.obs.metrics import Histogram


class ChannelPool:
    """Per-PS pool of ``channels`` parallel link channels (one direction).

    ``grant(ps, t_req, duration)`` reserves one channel of ``ps`` for a
    transfer that *wants* to start at ``t_req`` and occupies the channel
    for ``duration`` seconds (the transmission time — propagation and
    processing do not hold the channel).  Each channel keeps its sorted
    busy intervals, and a grant takes the earliest feasible slot at or
    after ``t_req`` across channels — *gaps between existing reservations
    are usable* (a round's far-future straggler reservation must not lock
    the idle hours before it), so an uncontended request always starts
    exactly at ``t_req``.  FIFO: callers must request in ascending
    ``t_req`` order within a batch (`ContentionModel.grant_*_many` sorts
    for them).  Returns the granted start time.  ``channels=None`` models
    infinite parallelism: every grant starts at its request time and only
    telemetry is kept.
    """

    def __init__(self, num_ps: int, channels: Optional[int]):
        assert channels is None or channels >= 1
        self.channels = channels
        # per-PS, per-channel sorted disjoint busy intervals [start, end)
        self.res: List[List[List[Tuple[float, float]]]] = [
            ([[] for _ in range(channels)] if channels is not None else [])
            for _ in range(num_ps)]
        self.grants = 0
        self.queue_wait_s = 0.0
        self.busy_s = [0.0] * num_ps
        # per-grant FIFO queue-wait distribution (obs/metrics.py,
        # DESIGN.md §12) — lives INSIDE the pool so ContentionModel's
        # snapshot/restore deepcopy rolls rejected grants' observations
        # back along with the reservations themselves
        self.wait_hist = Histogram("queue_wait_s")

    @staticmethod
    def _earliest(iv: List[Tuple[float, float]], t_req: float,
                  duration: float) -> float:
        """Earliest start >= t_req with a free gap of ``duration`` on one
        channel's sorted busy intervals."""
        cand = t_req
        for s, e in iv:
            if e <= cand:
                continue
            if s >= cand + duration:
                break                    # the gap before this slot fits
            cand = e
        return cand

    @staticmethod
    def _insert(iv: List[Tuple[float, float]], s: float, e: float) -> None:
        i = bisect.bisect_left(iv, (s, e))
        # reservations never overlap; merge with abutting neighbors so
        # back-to-back serialized transfers keep the list compact
        if i > 0 and iv[i - 1][1] >= s:
            s = iv[i - 1][0]
            e = max(e, iv[i - 1][1])
            i -= 1
            iv.pop(i)
        if i < len(iv) and iv[i][0] <= e:
            e = max(e, iv[i][1])
            iv.pop(i)
        iv.insert(i, (s, e))

    def grant(self, ps: int, t_req: float, duration: float) -> float:
        self.grants += 1
        self.busy_s[ps] += duration
        if self.channels is None or duration <= 0.0:
            return t_req
        best, best_c = None, 0
        for c, iv in enumerate(self.res[ps]):
            start = self._earliest(iv, t_req, duration)
            if best is None or start < best:
                best, best_c = start, c
            if best == t_req:
                break                    # can't start any earlier
        self._insert(self.res[ps][best_c], best, best + duration)
        self.queue_wait_s += best - t_req
        self.wait_hist.observe(best - t_req)
        return best

    def backlog(self, ps: int, t: float) -> float:
        """Total reserved channel-seconds still pending at ``ps`` after
        ``t`` — the occupancy signal handoff policies tie-break on (and
        the contention-aware trigger windows threshold on, §10)."""
        return float(sum(max(0.0, e - max(s, t))
                         for iv in self.res[ps] for (s, e) in iv))

    def intervals(self, ps: int) -> List[Tuple[int, float, float]]:
        """All (channel, start, end) reservations at ``ps`` — invariant
        checks (the no-double-reserve property in tests/test_property.py)
        and debugging; not on the hot path."""
        return [(c, s, e) for c, iv in enumerate(self.res[ps])
                for (s, e) in iv]

    def stats(self, horizon_s: float) -> Dict:
        cap = self.channels if self.channels is not None else 1
        denom = max(float(horizon_s) * cap, 1e-12)
        return {"grants": self.grants,
                "queue_wait_s": self.queue_wait_s,
                "queue_wait_hist": self.wait_hist.summary(),
                "busy_s": list(self.busy_s),
                "utilization": [b / denom for b in self.busy_s]}


class ContentionModel:
    """Finite per-PS link capacity (DESIGN.md §9): one transmit and one
    receive `ChannelPool` of ``channels`` parallel channels each.

    The plan's timing evaluators charge one **tx** grant per global-model
    copy a PS unicasts to a visible satellite (downlink) and one **rx**
    grant per local model arriving at its first-receiving PS (uplink);
    the PS<->PS ring is treated as dedicated point-to-point trunks and is
    not charged.  Pools persist across rounds, so transfers from
    different in-flight rounds serialize against each other — the
    cross-round invariant `sched/runtime.py` relies on.  Grants within
    one batch are FIFO by request time; batches are granted in event
    (round-open) order, i.e. a round *reserves* its transfer slots when
    it opens.  Later-opened rounds may still backfill idle gaps between
    existing reservations (`ChannelPool` gap-fitting) but never displace
    a reservation.

    ``snapshot`` / ``restore`` let the runtime roll back the grants of a
    round that was timed but never opened (aborted speculative opens).
    """

    def __init__(self, num_ps: int, channels: Optional[int]):
        self.num_ps = num_ps
        self.channels = channels
        self.tx = ChannelPool(num_ps, channels)
        self.rx = ChannelPool(num_ps, channels)

    # ---- grants ------------------------------------------------------------

    def grant_tx(self, ps: int, t_req: float, duration: float) -> float:
        return self.tx.grant(int(ps), float(t_req), float(duration))

    def grant_rx(self, ps: int, t_req: float, duration: float) -> float:
        return self.rx.grant(int(ps), float(t_req), float(duration))

    def _grant_many(self, pool: ChannelPool, ps_ids: Sequence[int],
                    t_req: Sequence[float], duration: float) -> np.ndarray:
        """FIFO batch grant: requests are granted in ascending request
        time (ties: PS id, then input order); returns start times aligned
        with the input order."""
        ps_ids = np.asarray(ps_ids, dtype=np.int64)
        t_req = np.asarray(t_req, dtype=np.float64)
        starts = np.empty(len(ps_ids), np.float64)
        order = sorted(range(len(ps_ids)),
                       key=lambda j: (t_req[j], ps_ids[j], j))
        for j in order:
            starts[j] = pool.grant(int(ps_ids[j]), float(t_req[j]),
                                   float(duration))
        return starts

    def grant_tx_many(self, ps_ids, t_req, duration: float) -> np.ndarray:
        return self._grant_many(self.tx, ps_ids, t_req, duration)

    def grant_rx_many(self, ps_ids, t_req, duration: float) -> np.ndarray:
        return self._grant_many(self.rx, ps_ids, t_req, duration)

    # ---- queries / lifecycle ------------------------------------------------

    def backlog(self, kind: str, ps: int, t: float) -> float:
        return (self.tx if kind == "tx" else self.rx).backlog(int(ps), t)

    def reset(self) -> None:
        self.tx = ChannelPool(self.num_ps, self.channels)
        self.rx = ChannelPool(self.num_ps, self.channels)

    def snapshot(self):
        """Deep copy of both pools.  Rollback points for actions whose
        grants may turn out infeasible: aborted speculative round opens
        (DESIGN.md §8) and lossy-transfer retries whose retransmission
        can never complete (§10) restore through this, so a transfer that
        never happens leaves no channel occupancy.  A snapshot is
        reusable — ``restore`` copies it again, so the same rollback
        point can unwind several divergent continuations."""
        return copy.deepcopy((self.tx, self.rx))

    def restore(self, snap) -> None:
        self.tx, self.rx = copy.deepcopy(snap)

    def stats(self, horizon_s: float) -> Dict:
        """Telemetry for benchmarks: grants, FIFO queue-wait totals and
        per-PS utilization (busy channel-seconds / channels*horizon)."""
        return {"ps_channels": self.channels,
                "tx": self.tx.stats(horizon_s),
                "rx": self.rx.stats(horizon_s)}


@dataclasses.dataclass(frozen=True)
class ContactWindow:
    """One sat<->PS visibility interval ``[t_start, t_end)`` with the
    link delay (transmission + propagation for the plan's nominal payload)
    evaluated at window start."""
    sat: int
    node: int
    t_start: float
    t_end: float
    delay_s: float


@dataclasses.dataclass
class ContactPlan:
    """Compiled contact plan over one simulation horizon.

    Construct via :meth:`compile` (builds timeline/topology/propagation
    from a constellation + PS nodes) or directly from an existing
    simulator's objects — ``FLSimulation`` does the latter so the epoch
    loop and the event runtime share one plan.
    """
    constellation: WalkerDelta
    nodes: List[GroundNode]
    timeline: VisibilityTimeline
    topo: RingOfStars
    prop: PropagationModel
    use_isl: bool = True
    nominal_bits: float = 0.0          # payload for window delay annotation
    # finite per-PS link capacity (DESIGN.md §9); None = infinite
    # parallelism, bit-identical to the pre-contention semantics
    contention: Optional[ContentionModel] = None

    _windows: Optional[List[ContactWindow]] = dataclasses.field(
        default=None, repr=False)
    _node_vis: Optional[List[Tuple[np.ndarray, np.ndarray]]] = \
        dataclasses.field(default=None, repr=False)
    # ^ per-PS merged any-sat coverage runs (lo, hi), rows, hi exclusive

    # ---- construction ------------------------------------------------------

    @classmethod
    def compile(cls, constellation: WalkerDelta, nodes: List[GroundNode],
                duration_s: float, dt_s: float = 10.0,
                link: Optional[LinkModel] = None, *, use_isl: bool = True,
                nominal_bits: float = 0.0,
                visibility: str = "dense") -> "ContactPlan":
        """``visibility="sparse"`` compiles through the segment-based
        :class:`SparseVisibilityTimeline` — O(windows) memory instead of
        the dense (T, S, P) grid; windows and all plan queries are pinned
        bit-identical (DESIGN.md §14)."""
        tl_cls = {"dense": VisibilityTimeline,
                  "sparse": SparseVisibilityTimeline}[visibility]
        timeline = tl_cls(constellation, nodes, duration_s, dt_s)
        topo = RingOfStars(constellation, nodes, timeline)
        prop = PropagationModel(topo, link or LinkModel())
        return cls(constellation, nodes, timeline, topo, prop,
                   use_isl=use_isl, nominal_bits=nominal_bits)

    # ---- windows (lazy RLE over the visibility grid) -----------------------

    def windows(self) -> List[ContactWindow]:
        """Sorted (by t_start, then sat) sat<->PS contact windows."""
        if self._windows is None:
            self._windows = self._compile_windows()
        return self._windows

    def _compile_windows(self) -> List[ContactWindow]:
        tl = self.timeline
        T = len(tl.times)
        dt = tl.dt_s
        out: List[ContactWindow] = []
        # per-node windows from the timeline's segment export — dense RLE
        # or the sparse timeline's precompiled segments, identically shaped
        for p in range(len(self.nodes)):
            s_sats, s_rows, e_rows = tl.node_windows(p)
            if len(s_sats) == 0:
                continue
            t0 = tl.times[s_rows]
            # exclusive end: one step past the last visible sample, clamped
            t1 = tl.times[np.minimum(e_rows, T - 1)]
            t1 = np.where(e_rows >= T, tl.times[T - 1] + dt, t1)
            dist = self.topo.sat_ps_distances(s_sats, p, t0)
            delay = self.prop.link.total_delay(self.nominal_bits, dist)
            delay = np.broadcast_to(np.asarray(delay, np.float64),
                                    s_sats.shape)
            out.extend(ContactWindow(int(s), p, float(a), float(b), float(dl))
                       for s, a, b, dl in zip(s_sats, t0, t1, delay))
        out.sort(key=lambda w: (w.t_start, w.sat, w.node))
        return out

    # ---- plan-level queries -------------------------------------------------

    @property
    def num_sats(self) -> int:
        return self.constellation.num_sats

    @property
    def is_degenerate(self) -> bool:
        """True when every satellite sees a PS at every grid step — the
        all-visible plan used by the runtime-vs-epoch-loop parity tests."""
        tl = self.timeline
        return tl.covered_steps() == len(tl.times) * self.num_sats

    def isl_hop_delay(self, bits: float) -> float:
        """Intra-orbit ISL ring hop delay (permanently available)."""
        return self.prop.isl_hop_delay(bits)

    def next_contact(self, sats, t):
        """Vectorized earliest contact at/after ``t``: (times, ps ids),
        inf / -1 for satellites never visible again within the horizon."""
        return self.timeline.next_visible_after(sats, t)

    def next_contact_by_node(self, t: float) -> np.ndarray:
        """Per-PS earliest instant >= ``t`` at which ANY satellite is in
        view — ``(P,)`` with inf where a node sees nothing for the rest
        of the horizon.  This is the multi-sink handoff signal
        (DESIGN.md §8): `sched/policies.NextContactHandoff` opens the
        next round at the HAP that can start talking soonest.  The
        per-node coverage runs are built once and cached; each query is
        then two bisects per node instead of an O(T) scan."""
        if self._node_vis is None:
            self._node_vis = [self.timeline.node_cover(p)
                              for p in range(len(self.nodes))]
        times = self.timeline.times
        T = len(times)
        row_min = int(np.searchsorted(times, t, side="left"))
        out = np.full(len(self._node_vis), np.inf)
        for p, (lo, hi) in enumerate(self._node_vis):
            i = int(np.searchsorted(hi, row_min, side="right"))
            if i < len(lo):
                row = max(int(lo[i]), row_min)
                if row < T:
                    out[p] = times[row]
        return out

    def next_any_contact(self, t: float) -> Optional[float]:
        """Earliest time >= t when ANY satellite sees a PS (None if the
        plan is exhausted) — the runtime's idle-skip wake-up."""
        tv, _ps = self.timeline.next_visible_after(
            np.arange(self.constellation.num_sats), t)
        tmin = float(np.min(tv))
        return None if not np.isfinite(tmin) else tmin

    def coverage_fraction(self) -> float:
        """Mean fraction of grid steps with any PS in view, over sats."""
        tl = self.timeline
        return float(tl.covered_steps() / (len(tl.times) * self.num_sats))

    def summary(self) -> Dict:
        """Plan statistics for benchmarks / exports (windows compiled on
        first call)."""
        ws = self.windows()
        return {
            "num_sats": self.constellation.num_sats,
            "num_ps": len(self.nodes),
            "duration_s": float(self.timeline.duration_s),
            "dt_s": float(self.timeline.dt_s),
            "use_isl": bool(self.use_isl),
            "num_windows": len(ws),
            "coverage_fraction": self.coverage_fraction(),
            "mean_window_s": (float(np.mean([w.t_end - w.t_start
                                             for w in ws])) if ws else 0.0),
            "is_degenerate": self.is_degenerate,
        }

    def to_dicts(self) -> List[Dict]:
        """Windows as plain dicts (JSON-exportable contact-plan format,
        DESIGN.md §7)."""
        return [dataclasses.asdict(w) for w in self.windows()]

    # ---- model-propagation timing (moved from FLSimulation) ----------------

    def downlink_times(self, t0: float, bits: float,
                       source: int) -> np.ndarray:
        """Per-satellite receive time of the global model sent from
        ``source`` at ``t0`` (Alg. 1 with ISL relay; plain next-visibility
        per satellite for ISL-less strategies).  With a `ContentionModel`
        attached, each PS->sat copy is one tx-channel grant and concurrent
        transfers at the same PS serialize (DESIGN.md §9)."""
        if self.use_isl:
            return self.prop.downlink_times(t0, bits, source,
                                            contention=self.contention)
        S = self.constellation.num_sats
        sats = np.arange(S)
        tv, ps = self.timeline.next_visible_after(sats, t0)
        recv = np.full(S, np.inf)
        ok = np.isfinite(tv)
        for h in np.unique(ps[ok]):
            m = ok & (ps == h)
            d = self.topo.sat_ps_distances(sats[m], int(h), tv[m])
            recv[m] = tv[m] + self.prop.link.total_delay(bits, d)
        if self.contention is not None and ok.any():
            # the transfer would start transmitting at visibility (tv);
            # a queued grant shifts it by (start - tv), zero when free
            idx = np.flatnonzero(ok)
            t_t = self.prop.link.transmission_delay(bits)
            starts = self.contention.grant_tx_many(ps[idx], tv[idx], t_t)
            recv[idx] += starts - tv[idx]
        return recv

    def uplink_times(self, sats, t_done, bits: float,
                     sink: int) -> Tuple[np.ndarray, np.ndarray]:
        """Arrival times of the given satellites' local models at the sink
        (and the first-receiving PS ids); inf / -1 where unreachable.
        With a `ContentionModel` attached, each arriving model is one
        rx-channel grant at its first-receiving PS (DESIGN.md §9)."""
        if self.use_isl:
            return self.prop.uplink_many(sats, t_done, bits, sink,
                                         contention=self.contention)
        sats = np.asarray(sats, dtype=np.int64)
        tv, ps = self.timeline.next_visible_after(sats, t_done)
        out = np.full(len(sats), np.inf)
        hap = np.asarray(ps, dtype=np.int64)
        ok = np.isfinite(tv)
        for h in np.unique(hap[ok]):
            m = ok & (hap == h)
            d = self.topo.sat_ps_distances(sats[m], int(h), tv[m])
            out[m] = tv[m] + self.prop.link.total_delay(bits, d)
        if self.contention is not None and ok.any():
            # same convention as the ISL path: the PS receives over the
            # [arrival - transmission, arrival) interval — propagation
            # and processing delay the payload, not the receiver
            idx = np.flatnonzero(ok)
            t_t = self.prop.link.transmission_delay(bits)
            req = out[idx] - t_t
            starts = self.contention.grant_rx_many(hap[idx], req, t_t)
            out[idx] += starts - req
        return out, hap

    def reroute_times(self, ps_from: int, ps_to: int, t: float,
                      bits: float, avoid=()) -> float:
        """Ring-failover re-timing (DESIGN.md §11): a model that reached
        the ring at ``ps_from`` at instant ``t`` but found its sink dark
        relays along the ring to the live PS ``ps_to`` (routing around
        the ``avoid`` set, +inf when both arcs are blocked) and is
        charged one fresh rx-channel grant there, under the same §9
        convention as ``uplink_times``: the PS receives over the
        [arrival - transmission, arrival) interval, and a queued grant
        shifts the arrival by (start - request) — exactly 0.0 when
        uncontended, so ``ps_channels=None`` stays bit-identical."""
        delay = self.prop.ring_relay_delay(bits, ps_from, ps_to, t,
                                           avoid=avoid)
        ta = float(t) + float(delay)
        if self.contention is not None and np.isfinite(ta):
            t_t = self.prop.link.transmission_delay(bits)
            req = ta - t_t
            start = self.contention.grant_rx(ps_to, req, t_t)
            ta += start - req
        return ta
