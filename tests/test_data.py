import numpy as np
import pytest

from repro.data import (class_conditional_images, dirichlet_partition,
                        iid_partition, paper_noniid_partition, token_stream)


def test_images_shape_and_range():
    x, y = class_conditional_images(0, 200)
    assert x.shape == (200, 28, 28, 1) and y.shape == (200,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_prototypes_shared_across_seeds():
    """Same proto_seed => same task; different sample seeds give new samples."""
    x0, y0 = class_conditional_images(0, 500)
    x1, y1 = class_conditional_images(1, 500)
    # class-0 means should correlate strongly across splits
    m0 = x0[y0 == 0].mean(0).ravel()
    m1 = x1[y1 == 0].mean(0).ravel()
    corr = np.corrcoef(m0, m1)[0, 1]
    assert corr > 0.5


def test_iid_partition_disjoint_cover():
    _, y = class_conditional_images(0, 400)
    parts = iid_partition(y, 8, 0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 400
    assert len(np.unique(allidx)) == 400


def test_paper_noniid_partition_class_split():
    _, y = class_conditional_images(0, 2000)
    orbits = np.arange(40) // 8
    parts = paper_noniid_partition(y, orbits, 0)
    # satellites in orbits 0-1 hold only classes 0-3; orbits 2-4 only 4-9
    for s in range(16):
        assert set(np.unique(y[parts[s]])) <= {0, 1, 2, 3}
    for s in range(16, 40):
        assert set(np.unique(y[parts[s]])) <= {4, 5, 6, 7, 8, 9}
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx)


def test_dirichlet_partition_cover():
    _, y = class_conditional_images(0, 500)
    parts = dirichlet_partition(y, 10, alpha=0.5, seed=0)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(np.unique(allidx)) == len(allidx) == 500


def test_token_stream():
    t = token_stream(0, 10_000, 512)
    assert t.shape == (10_000,) and t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 512
    # zipf: low ids much more common
    assert (t < 64).mean() > (t >= 448).mean()
