"""Contact-plan compilation: orbital geometry -> schedulable link windows
(DESIGN.md §7; the multi-sink handoff query it serves is §8).

A *contact plan* is the standard artifact of DTN / satellite-network
scheduling (LRSIM's dynamic-state generation follows the same shape): the
constellation geometry, visibility grid and link model are compiled ONCE
into sorted availability windows, and everything downstream — the
event-driven runtime (`sched/runtime.py`), benchmarks, exports — consumes
the plan instead of re-deriving geometry.

``ContactPlan`` bundles three things:

* **windows** — run-length-encoded sat<->PS visibility intervals
  ``[t_start, t_end)`` (from ``VisibilityTimeline.grid``), each annotated
  with the one-hop link delay at window start for a nominal payload.
  Compiled lazily (one pass over the grid) and cached.
* **ISL / IHL availability** — intra-orbit ISL rings are permanently
  available (adjacent neighbors, §IV-A), so they are a constant hop delay,
  not windows; the HAP ring likewise.
* **timing evaluators** — ``downlink_times`` / ``uplink_times`` answer
  "when does satellite n hold the global model" / "when does n's local
  model reach the sink" for a *specific* payload and instant, delegating
  the fine-grained delay math to the compiled-in ``PropagationModel``
  (the plan's windows and the evaluators read the same grid, so they never
  disagree).  The ``use_isl`` switch (strategies without inter-satellite
  links wait for direct visibility) lives here, moved out of the
  simulator.

`core/simulator.py` routes its propagation timing through a plan, and the
event-driven runtime schedules its wake-ups from the same object — one
compiled view of "who can talk to whom, when, at what delay".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.constellation import GroundNode, WalkerDelta
from repro.core.links import LinkModel
from repro.core.propagation import PropagationModel
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline


@dataclasses.dataclass(frozen=True)
class ContactWindow:
    """One sat<->PS visibility interval ``[t_start, t_end)`` with the
    link delay (transmission + propagation for the plan's nominal payload)
    evaluated at window start."""
    sat: int
    node: int
    t_start: float
    t_end: float
    delay_s: float


@dataclasses.dataclass
class ContactPlan:
    """Compiled contact plan over one simulation horizon.

    Construct via :meth:`compile` (builds timeline/topology/propagation
    from a constellation + PS nodes) or directly from an existing
    simulator's objects — ``FLSimulation`` does the latter so the epoch
    loop and the event runtime share one plan.
    """
    constellation: WalkerDelta
    nodes: List[GroundNode]
    timeline: VisibilityTimeline
    topo: RingOfStars
    prop: PropagationModel
    use_isl: bool = True
    nominal_bits: float = 0.0          # payload for window delay annotation

    _windows: Optional[List[ContactWindow]] = dataclasses.field(
        default=None, repr=False)
    _node_vis: Optional[List[np.ndarray]] = dataclasses.field(
        default=None, repr=False)      # per-PS sorted any-sat-visible times

    # ---- construction ------------------------------------------------------

    @classmethod
    def compile(cls, constellation: WalkerDelta, nodes: List[GroundNode],
                duration_s: float, dt_s: float = 10.0,
                link: Optional[LinkModel] = None, *, use_isl: bool = True,
                nominal_bits: float = 0.0) -> "ContactPlan":
        timeline = VisibilityTimeline(constellation, nodes, duration_s, dt_s)
        topo = RingOfStars(constellation, nodes, timeline)
        prop = PropagationModel(topo, link or LinkModel())
        return cls(constellation, nodes, timeline, topo, prop,
                   use_isl=use_isl, nominal_bits=nominal_bits)

    # ---- windows (lazy RLE over the visibility grid) -----------------------

    def windows(self) -> List[ContactWindow]:
        """Sorted (by t_start, then sat) sat<->PS contact windows."""
        if self._windows is None:
            self._windows = self._compile_windows()
        return self._windows

    def _compile_windows(self) -> List[ContactWindow]:
        tl = self.timeline
        grid = tl.grid                                   # (T, S, P) bool
        T = grid.shape[0]
        dt = tl.dt_s
        out: List[ContactWindow] = []
        # per (node) batched RLE: transitions of the padded column
        for p in range(grid.shape[2]):
            col = grid[:, :, p]                          # (T, S)
            pad = np.zeros((1, col.shape[1]), dtype=np.int8)
            d = np.diff(np.concatenate([pad, col.astype(np.int8), pad]),
                        axis=0)                          # (T+1, S)
            starts = np.argwhere(d == 1)                 # (n, 2): (row, sat)
            ends = np.argwhere(d == -1)
            if len(starts) == 0:
                continue
            # argwhere is row-major sorted; regroup per sat so the k-th
            # start pairs with the k-th end of the same column
            order_s = np.lexsort((starts[:, 0], starts[:, 1]))
            order_e = np.lexsort((ends[:, 0], ends[:, 1]))
            s_rows, s_sats = starts[order_s, 0], starts[order_s, 1]
            e_rows = ends[order_e, 0]
            t0 = tl.times[s_rows]
            # exclusive end: one step past the last visible sample, clamped
            t1 = tl.times[np.minimum(e_rows, T - 1)]
            t1 = np.where(e_rows >= T, tl.times[T - 1] + dt, t1)
            dist = self.topo.sat_ps_distances(s_sats, p, t0)
            delay = self.prop.link.total_delay(self.nominal_bits, dist)
            delay = np.broadcast_to(np.asarray(delay, np.float64),
                                    s_sats.shape)
            out.extend(ContactWindow(int(s), p, float(a), float(b), float(dl))
                       for s, a, b, dl in zip(s_sats, t0, t1, delay))
        out.sort(key=lambda w: (w.t_start, w.sat, w.node))
        return out

    # ---- plan-level queries -------------------------------------------------

    @property
    def num_sats(self) -> int:
        return self.constellation.num_sats

    @property
    def is_degenerate(self) -> bool:
        """True when every satellite sees a PS at every grid step — the
        all-visible plan used by the runtime-vs-epoch-loop parity tests."""
        return bool(self.timeline.grid.any(axis=2).all())

    def isl_hop_delay(self, bits: float) -> float:
        """Intra-orbit ISL ring hop delay (permanently available)."""
        return self.prop.isl_hop_delay(bits)

    def next_contact(self, sats, t):
        """Vectorized earliest contact at/after ``t``: (times, ps ids),
        inf / -1 for satellites never visible again within the horizon."""
        return self.timeline.next_visible_after(sats, t)

    def next_contact_by_node(self, t: float) -> np.ndarray:
        """Per-PS earliest instant >= ``t`` at which ANY satellite is in
        view — ``(P,)`` with inf where a node sees nothing for the rest
        of the horizon.  This is the multi-sink handoff signal
        (DESIGN.md §8): `sched/policies.NextContactHandoff` opens the
        next round at the HAP that can start talking soonest.  The
        per-node visible-step index is built once and cached."""
        if self._node_vis is None:
            any_sat = self.timeline.grid.any(axis=1)         # (T, P)
            self._node_vis = [self.timeline.times[any_sat[:, p]]
                              for p in range(any_sat.shape[1])]
        out = np.full(len(self._node_vis), np.inf)
        for p, times in enumerate(self._node_vis):
            i = int(np.searchsorted(times, t, side="left"))
            if i < len(times):
                out[p] = times[i]
        return out

    def next_any_contact(self, t: float) -> Optional[float]:
        """Earliest time >= t when ANY satellite sees a PS (None if the
        plan is exhausted) — the runtime's idle-skip wake-up."""
        tv, _ps = self.timeline.next_visible_after(
            np.arange(self.constellation.num_sats), t)
        tmin = float(np.min(tv))
        return None if not np.isfinite(tmin) else tmin

    def coverage_fraction(self) -> float:
        """Mean fraction of grid steps with any PS in view, over sats."""
        return float(self.timeline.grid.any(axis=2).mean())

    def summary(self) -> Dict:
        """Plan statistics for benchmarks / exports (windows compiled on
        first call)."""
        ws = self.windows()
        return {
            "num_sats": self.constellation.num_sats,
            "num_ps": len(self.nodes),
            "duration_s": float(self.timeline.duration_s),
            "dt_s": float(self.timeline.dt_s),
            "use_isl": bool(self.use_isl),
            "num_windows": len(ws),
            "coverage_fraction": self.coverage_fraction(),
            "mean_window_s": (float(np.mean([w.t_end - w.t_start
                                             for w in ws])) if ws else 0.0),
            "is_degenerate": self.is_degenerate,
        }

    def to_dicts(self) -> List[Dict]:
        """Windows as plain dicts (JSON-exportable contact-plan format,
        DESIGN.md §7)."""
        return [dataclasses.asdict(w) for w in self.windows()]

    # ---- model-propagation timing (moved from FLSimulation) ----------------

    def downlink_times(self, t0: float, bits: float,
                       source: int) -> np.ndarray:
        """Per-satellite receive time of the global model sent from
        ``source`` at ``t0`` (Alg. 1 with ISL relay; plain next-visibility
        per satellite for ISL-less strategies)."""
        if self.use_isl:
            return self.prop.downlink_times(t0, bits, source)
        S = self.constellation.num_sats
        sats = np.arange(S)
        tv, ps = self.timeline.next_visible_after(sats, t0)
        recv = np.full(S, np.inf)
        ok = np.isfinite(tv)
        for h in np.unique(ps[ok]):
            m = ok & (ps == h)
            d = self.topo.sat_ps_distances(sats[m], int(h), tv[m])
            recv[m] = tv[m] + self.prop.link.total_delay(bits, d)
        return recv

    def uplink_times(self, sats, t_done, bits: float,
                     sink: int) -> Tuple[np.ndarray, np.ndarray]:
        """Arrival times of the given satellites' local models at the sink
        (and the first-receiving PS ids); inf / -1 where unreachable."""
        if self.use_isl:
            return self.prop.uplink_many(sats, t_done, bits, sink)
        sats = np.asarray(sats, dtype=np.int64)
        tv, ps = self.timeline.next_visible_after(sats, t_done)
        out = np.full(len(sats), np.inf)
        hap = np.asarray(ps, dtype=np.int64)
        ok = np.isfinite(tv)
        for h in np.unique(hap[ok]):
            m = ok & (hap == h)
            d = self.topo.sat_ps_distances(sats[m], int(h), tv[m])
            out[m] = tv[m] + self.prop.link.total_delay(bits, d)
        return out, hap
