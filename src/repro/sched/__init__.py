# Event-driven async FL scheduling: contact plans compiled from orbital
# geometry, a priority-queue runtime reusing the fused epoch program, and
# pluggable trigger policies (AsyncFLEO / sync barrier / FedAsync).
from repro.sched.contacts import ContactPlan, ContactWindow
from repro.sched.events import Event, EventKind, EventQueue
from repro.sched.policies import (AsyncFLEOPolicy, FedAsyncPolicy, POLICIES,
                                  SyncBarrierPolicy, make_policy)
from repro.sched.runtime import EventDrivenRuntime, RoundState

__all__ = ["ContactPlan", "ContactWindow", "Event", "EventKind",
           "EventQueue", "AsyncFLEOPolicy", "SyncBarrierPolicy",
           "FedAsyncPolicy", "POLICIES", "make_policy",
           "EventDrivenRuntime", "RoundState"]
