"""Fig. 8: the Fig. 7 settings sweep on CIFAR-10-like data (32x32x3)."""
from benchmarks import fig7_mnist


def run(quick: bool = True, max_epochs: int = 12):
    return fig7_mnist.run("cifar", quick=quick, max_epochs=max_epochs)


def main(quick=True):
    return fig7_mnist.main(dataset="cifar", quick=quick)


if __name__ == "__main__":
    main(quick=False)
