"""Differential tests for the batched scenario engine (DESIGN.md §13).

The headline contract: for any scenario batch, the batched engine's
per-scenario histories, final weights and logical dispatch counts are
BIT-IDENTICAL to running each scenario sequentially through the
event-driven runtime.  ``assert_batched_parity`` is the one shared
checker — the hypothesis property in ``test_property.py`` drives it
with randomly drawn axes; the cases here pin named regressions and the
engine's own machinery (grid/draw compiler, percentile reduction,
dispatch economy, error propagation, determinism).
"""
import numpy as np
import pytest

from repro.sweep import (ConvergingTrainer, DispatchBatcher,
                         MeanDistanceEvaluator, ScenarioSpec, draw,
                         draw_spec, grid, make_model, percentile_bands,
                         reduce_results, run_scenarios)

# small-but-real default: 8 sats over 2 orbits, a 4 h horizon
BASE = ScenarioSpec(num_orbits=2, sats_per_orbit=4, duration_s=4 * 3600.0,
                    dt_s=60.0, train_time_s=300.0)
W0 = make_model()


def _hist_key(hist):
    return [(r.epoch, r.time_s, r.accuracy, r.num_models, r.gamma,
             r.stale_groups) for r in hist]


def assert_batched_parity(specs, max_epochs=3, target=0.9, mode="exact",
                          batcher=None):
    """Run ``specs`` sequentially and batched; assert bit-identical
    per-scenario histories, weights and dispatch counts.  Returns
    (sequential, batched, batcher) for callers that inspect more."""
    seq = run_scenarios(specs, W0, batched=False, max_epochs=max_epochs,
                        target_accuracy=target)
    batcher = batcher or DispatchBatcher(mode=mode)
    bat = run_scenarios(specs, W0, batched=True, max_epochs=max_epochs,
                        target_accuracy=target, batcher=batcher)
    for s, b in zip(seq, bat):
        assert _hist_key(s.history) == _hist_key(b.history), s.spec
        assert np.array_equal(s.final_weights, b.final_weights), s.spec
        assert (s.dispatches, s.fallback_dispatches) == \
            (b.dispatches, b.fallback_dispatches), s.spec
        assert s.convergence_delay_s == b.convergence_delay_s, s.spec
        assert s.stats == b.stats, s.spec
    return seq, bat, batcher


# ---- scenario compiler -----------------------------------------------------

def test_grid_is_sorted_cartesian_product():
    specs = grid(BASE, seed=[0, 1], strategy=["asyncfleo-gs", "fedisl"])
    assert len(specs) == 4
    # axes sorted by name: seed outer, strategy inner
    assert [(s.seed, s.strategy) for s in specs] == [
        (0, "asyncfleo-gs"), (0, "fedisl"),
        (1, "asyncfleo-gs"), (1, "fedisl")]
    assert all(s.num_orbits == 2 for s in specs)   # base preserved


def test_grid_rejects_unknown_axis():
    with pytest.raises(ValueError, match="unknown scenario axes"):
        grid(BASE, not_a_field=[1])
    with pytest.raises(ValueError, match="no values"):
        grid(BASE, seed=[])


def test_draw_is_seed_deterministic():
    axes = {"seed": [0, 1, 2, 3], "rate_bps": [16e6, 1e5],
            "strategy": ["asyncfleo-gs", "fedasync"]}
    a = draw(6, axes, seed=7, base=BASE)
    b = draw(6, axes, seed=7, base=BASE)
    assert a == b
    assert draw(6, axes, seed=8, base=BASE) != a
    assert all(s.rate_bps in axes["rate_bps"] for s in a)
    spec = draw_spec(axes, seed=7, n=6)
    assert spec["kind"] == "draw" and spec["n"] == 6
    assert list(spec["axes"]) == sorted(axes)      # JSON-stable order


def test_draw_rejects_bad_n():
    with pytest.raises(ValueError, match="n >= 1"):
        draw(0, {"seed": [1]})


# ---- percentile reduction --------------------------------------------------

def test_percentile_bands_values_and_failures():
    bands = percentile_bands([10.0, 20.0, 30.0, None])
    assert bands["n"] == 4 and bands["n_failed"] == 1
    assert bands["p50"] == 20.0
    assert bands["p10"] == pytest.approx(12.0)
    assert bands["p90"] == pytest.approx(28.0)


def test_percentile_bands_all_failed():
    bands = percentile_bands([None, None])
    assert bands["n"] == 2 and bands["n_failed"] == 2
    assert bands["p10"] is bands["p50"] is bands["p90"] is None


# ---- differential parity ---------------------------------------------------

def test_parity_seed_batch():
    specs = grid(BASE, seed=[0, 1, 2, 3])
    _, _, batcher = assert_batched_parity(specs)
    # homogeneous scenarios share every dispatch: one program per epoch
    assert batcher.physical_dispatches < 4 * batcher.max_group
    assert batcher.max_group == 4


def test_parity_heterogeneous_axes():
    """Mixed strategies (incl. sync barrier + pipelined), geometries,
    link rates and staleness functions in ONE batch."""
    axes = {
        "seed": [0, 3],
        "num_orbits": [2, 3],
        "rate_bps": [16e6, 1e5],
        "strategy": ["asyncfleo-gs", "fedisl", "asyncfleo-pipelined"],
        "staleness_fn": ["eq13", "poly"],
    }
    specs = draw(6, axes, seed=11, base=BASE)
    assert_batched_parity(specs)


def test_parity_fedasync_per_arrival():
    # per-arrival EMA commits: many more (solo-sized) dispatches
    specs = grid(BASE, seed=[0, 1], strategy=["fedasync"])
    _, bat, _ = assert_batched_parity(specs, max_epochs=6)
    assert all(r.epochs > 0 for r in bat)


def test_parity_trainer_without_batch_key_runs_solo():
    """A trainer with no scenario_batch_key must still be correct —
    every dispatch routes solo through its own program."""
    class KeylessTrainer(ConvergingTrainer):
        def __init__(self, w0):
            super().__init__(w0)
            del self.scenario_batch_key

    specs = grid(BASE, seed=[0, 1])
    seq = run_scenarios(specs, W0, batched=False, max_epochs=3,
                        target_accuracy=0.9,
                        trainer_factory=lambda w0: KeylessTrainer(w0))
    batcher = DispatchBatcher()
    bat = run_scenarios(specs, W0, batched=True, max_epochs=3,
                        target_accuracy=0.9,
                        trainer_factory=lambda w0: KeylessTrainer(w0),
                        batcher=batcher)
    for s, b in zip(seq, bat):
        assert _hist_key(s.history) == _hist_key(b.history)
        assert np.array_equal(s.final_weights, b.final_weights)
    assert batcher.batched_dispatches == 0          # nothing grouped
    assert batcher.solo_dispatches == batcher.physical_dispatches > 0


def test_batched_run_is_deterministic():
    specs = draw(5, {"seed": [0, 1, 2], "strategy":
                     ["asyncfleo-gs", "fedisl"]}, seed=3, base=BASE)
    a = run_scenarios(specs, W0, batched=True, max_epochs=3,
                      target_accuracy=0.9)
    b = run_scenarios(specs, W0, batched=True, max_epochs=3,
                      target_accuracy=0.9)
    for ra, rb in zip(a, b):
        assert _hist_key(ra.history) == _hist_key(rb.history)
        assert np.array_equal(ra.final_weights, rb.final_weights)
        assert ra.dispatches == rb.dispatches


def test_vmap_mode_is_close_not_required_exact():
    """The opt-in vmap mode trades bit-exactness for one batched GEMM:
    results must stay allclose to sequential (documented non-exact)."""
    specs = grid(BASE, seed=[0, 1, 2])
    seq = run_scenarios(specs, W0, batched=False, max_epochs=3,
                        target_accuracy=0.9)
    bat = run_scenarios(specs, W0, batched=True, mode="vmap",
                        max_epochs=3, target_accuracy=0.9)
    for s, b in zip(seq, bat):
        assert len(s.history) == len(b.history)
        np.testing.assert_allclose(s.final_weights, b.final_weights,
                                   atol=1e-4)


# ---- dispatch economy ------------------------------------------------------

def test_dispatch_economy_small():
    specs = grid(BASE, seed=list(range(6)))
    _, bat, batcher = assert_batched_parity(specs)
    logical = sum(r.dispatches + r.fallback_dispatches for r in bat)
    assert batcher.physical_dispatches < logical
    summary = batcher.summary()
    assert summary["physical_dispatches"] == batcher.physical_dispatches
    assert summary["mode"] == "exact"


@pytest.mark.slow
def test_dispatch_economy_64_scenarios():
    """The acceptance-criteria sweep: 64 scenarios complete in fewer
    physical fused dispatches than 64 sequential runs, counted via the
    PR 8 DispatchProfiler, with per-scenario parity intact."""
    from repro.obs import DispatchProfiler
    specs = grid(BASE, seed=list(range(32)),
                 strategy=["asyncfleo-gs", "fedisl"])
    assert len(specs) == 64
    prof = DispatchProfiler()
    batcher = DispatchBatcher(profiler=prof)
    _, bat, _ = assert_batched_parity(specs, max_epochs=3,
                                      batcher=batcher)
    logical = sum(r.dispatches + r.fallback_dispatches for r in bat)
    # the profiler saw every physical program launch, and batching won
    assert prof.dispatches == batcher.physical_dispatches
    assert batcher.physical_dispatches < logical
    assert batcher.max_group >= 32


# ---- failure handling ------------------------------------------------------

def test_worker_error_propagates():
    class ExplodingEvaluator(MeanDistanceEvaluator):
        def __call__(self, params):
            raise RuntimeError("boom")

    specs = grid(BASE, seed=[0, 1])
    with pytest.raises(RuntimeError, match="scenario"):
        run_scenarios(specs, W0, batched=True, max_epochs=2,
                      target_accuracy=0.9,
                      evaluator_factory=ExplodingEvaluator)


# ---- seed-determinism regression (sched_bench-equivalent runs) -------------

def _bench_equivalent_run(seed: int):
    """One sched_bench-style traced run (paper constellation, the PR 3
    head-to-head config at a shorter horizon), as `_run_policy` builds
    it; returns (history keys, stats, trace span count, weights)."""
    from repro.core import FLSimulation, SimConfig
    from repro.fl.strategies import get_strategy
    from repro.obs import Tracer
    from repro.sched import EventDrivenRuntime

    tracer = Tracer()
    sim = SimConfig(duration_s=86400.0, dt_s=30.0, train_time_s=300.0,
                    event_driven=True, seed=seed, tracer=tracer)
    fls = FLSimulation(get_strategy("asyncfleo-gs"), ConvergingTrainer(W0),
                       MeanDistanceEvaluator(), sim)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=4, target_accuracy=0.9)
    return (_hist_key(hist), dict(rt.stats), len(tracer.spans),
            np.asarray(fls._w_flat))


def test_seed_determinism_regression():
    """Two sched_bench-equivalent runs with the same seed produce
    identical histories, stats and trace span counts — the determinism
    the sweep engine (and every band row) rides on."""
    h1, s1, n1, w1 = _bench_equivalent_run(seed=0)
    h2, s2, n2, w2 = _bench_equivalent_run(seed=0)
    assert h1 == h2
    assert s1 == s2
    assert n1 == n2
    assert np.array_equal(w1, w2)


def test_parity_trainer_with_epoch_inputs():
    """Trainers whose ``epoch_inputs`` carries per-participant arrays
    batch too: the batcher stacks every batch leaf along the scenario
    axis and parity must still be exact."""
    import jax.numpy as jnp

    class InputsTrainer(ConvergingTrainer):
        def __init__(self, w0):
            super().__init__(w0)
            self.scenario_batch_key = ("inputs-converging",)

        def epoch_inputs(self, ids_np):
            return jnp.asarray(np.asarray(ids_np, np.float32) % 3.0)

        def epoch_train_fn(self):
            rate, jitter = self._rate, self._jitter

            def _fn(params, inputs, ids, seed):
                from repro.core.modelbank import flatten_tree
                flat = flatten_tree(params)
                phase = ((ids * 37 + seed.astype(jnp.int32)) % 13
                         - 6).astype(jnp.float32) * jitter
                stack = (flat[None, :] * (1.0 - rate) + rate
                         + phase[:, None] + inputs[:, None] * 1e-4)
                return stack, jnp.zeros(ids.shape[0])
            return _fn

        def train_many_stacked(self, sats, params, seed):
            from repro.core.modelbank import ModelBank, pad_bucket_ids
            ids, n = pad_bucket_ids(list(sats))
            fn = self.epoch_train_fn()
            stack, _ = fn(params, self.epoch_inputs(ids),
                          jnp.asarray(ids), jnp.uint32(np.uint32(seed)))
            return ModelBank(self.spec, stack[:n]), np.zeros(n)

    specs = grid(BASE, seed=[0, 1, 2])
    seq = run_scenarios(specs, W0, batched=False, max_epochs=3,
                        target_accuracy=0.9,
                        trainer_factory=lambda w0: InputsTrainer(w0))
    batcher = DispatchBatcher()
    bat = run_scenarios(specs, W0, batched=True, max_epochs=3,
                        target_accuracy=0.9,
                        trainer_factory=lambda w0: InputsTrainer(w0),
                        batcher=batcher)
    for s, b in zip(seq, bat):
        assert _hist_key(s.history) == _hist_key(b.history)
        assert np.array_equal(s.final_weights, b.final_weights)
    assert batcher.batched_dispatches > 0    # inputs batched, not solo'd


def test_parity_strategy_knob_overrides():
    """ScenarioSpec's ps_channels / max_in_flight / staleness_fn
    overrides reach the StrategySpec and stay parity-exact."""
    specs = [
        ScenarioSpec(num_orbits=2, sats_per_orbit=4, duration_s=4 * 3600.0,
                     dt_s=60.0, train_time_s=300.0, seed=1,
                     strategy="asyncfleo-pipelined", ps_channels=1,
                     max_in_flight=2, staleness_fn="hinge",
                     rate_bps=1e5),
        ScenarioSpec(num_orbits=2, sats_per_orbit=4, duration_s=4 * 3600.0,
                     dt_s=60.0, train_time_s=300.0, seed=2,
                     strategy="asyncfleo-gs", ps_channels=2),
    ]
    assert_batched_parity(specs)
