"""Pallas kernel: fused staleness-discounted aggregation (paper eq. 14).

    out = base_weight * w_prev + sum_c gamma_c * W[c]

W is the stack of C client models flattened to (C, N).  The grid tiles N;
each step loads a (C, BLOCK_N) VMEM tile of W, the matching (BLOCK_N,) tile
of w_prev, and reduces over clients with a (1,C)x(C,BLOCK_N) dot — MXU work,
one HBM pass over the client stack, no intermediate (C, N) temporaries like
the naive tree_map sum would make.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _agg_kernel(w_ref, gamma_ref, base_ref, bw_ref, out_ref):
    # w_ref: (C, BLOCK_N) VMEM; gamma_ref: (1, C); base_ref/out_ref: (1, BLOCK_N)
    mixed = jnp.dot(gamma_ref[...], w_ref[...],
                    preferred_element_type=jnp.float32)        # (1, BLOCK_N)
    out_ref[...] = bw_ref[0, 0] * base_ref[...] + mixed


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def fed_agg_flat(stack, gamma, base, base_weight, *, interpret: bool = True,
                 block_n: int = BLOCK_N):
    """stack: (C, N) f32, gamma: (C,), base: (N,), base_weight: scalar."""
    C, N = stack.shape
    pad = (-N) % block_n
    if pad:
        stack = jnp.pad(stack, ((0, 0), (0, pad)))
        base = jnp.pad(base, (0, pad))
    Np = N + pad
    grid = (Np // block_n,)
    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.float32),
        interpret=interpret,
    )(stack.astype(jnp.float32), gamma[None].astype(jnp.float32),
      base[None].astype(jnp.float32),
      jnp.asarray(base_weight, jnp.float32)[None, None])
    return out[0, :N]
