"""Step functions lowered by the dry-run and used by the real drivers.

  train_step(params, opt_state, batch)   -> (params, opt_state, loss)
  prefill_step(params, batch)            -> logits
  decode_step(params, cache, tokens)     -> (logits, cache)   [serve_step]
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, LONG_CONTEXT_WINDOW
from repro.models import registry as R
from repro.optim import adamw, apply_updates, Optimizer


def window_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Sliding-window size: full-attention archs get a window only for
    long_500k (the sub-quadratic carve-out); SSM/hybrid run native — the
    hybrid's shared-attention cache is itself windowed at long context."""
    if shape.name == "long_500k" and (cfg.num_heads > 0 or cfg.use_mla):
        return LONG_CONTEXT_WINDOW
    return 0


def cache_len_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    w = window_for(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


def make_optimizer(lr: float = 3e-4) -> Optimizer:
    return adamw(lr, weight_decay=0.1)


def make_train_step(cfg: ModelConfig, opt: Optional[Optimizer] = None,
                    window: int = 0, impl: str = "xla", q_chunks: int = 1):
    opt = opt or make_optimizer()

    def train_step(params, opt_state, batch):
        (loss, _metrics), grads = jax.value_and_grad(
            R.train_loss, has_aux=True)(params, cfg, batch,
                                        window=window, impl=impl,
                                        q_chunks=q_chunks)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        params2 = apply_updates(params, updates)
        return params2, opt_state2, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, window: int = 0, impl: str = "xla",
                      q_chunks: int = 1):
    def prefill_step(params, batch):
        logits, _aux = R.apply(params, cfg, batch, window=window, impl=impl,
                               q_chunks=q_chunks)
        return logits
    return prefill_step


def make_decode_step(cfg: ModelConfig, window: int = 0):
    def decode_step(params, cache, batch):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        return R.decode_step(params, cfg, cache, tokens, window=window)
    return decode_step
