"""Event-driven scheduler subsystem (sched/, DESIGN.md §7).

Covers: contact-plan compilation (RLE windows reconstruct the visibility
grid, delays, summary/export), the runtime-vs-epoch-loop parity contract
(degenerate all-visible plan AND the real paper constellation: aggregated
weights within atol 1e-5 and the same fused-dispatch count), the sync
barrier and FedAsync per-arrival policies, policy selection via
fl/strategies, and the convergence-delay ordering the paper claims
(async < sync on the same constellation).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core.modelbank import flatten_tree
from repro.fl import get_strategy
from repro.sched import (ContactPlan, EventDrivenRuntime, EventKind,
                         make_policy)
from repro.sched.policies import (AsyncFLEOPolicy, FedAsyncPolicy,
                                  SyncBarrierPolicy)

from test_epoch_step import TinyFusedTrainer, W0, _staged_downlink

SIMKW = dict(duration_s=86400.0, train_time_s=300.0,
             use_model_bank=True, use_fused_step=True)


def _sim(name, event_driven, **kw):
    cfg = SimConfig(event_driven=event_driven, **{**SIMKW, **kw})
    return FLSimulation(get_strategy(name), TinyFusedTrainer(W0), None, cfg)


def _rows(hist):
    return [(r.epoch, round(r.time_s, 6), r.num_models,
             round(r.gamma, 6), r.stale_groups) for r in hist]


# ---- contact-plan compilation ---------------------------------------------

def test_contact_windows_reconstruct_grid():
    fls = _sim("asyncfleo-twohap", False)
    plan = fls.plan
    tl = fls.timeline
    rebuilt = np.zeros_like(tl.grid)
    for w in plan.windows():
        i0 = int(round(w.t_start / tl.dt_s))
        i1 = int(round(w.t_end / tl.dt_s))
        assert w.t_end > w.t_start
        assert w.delay_s >= 0.0
        rebuilt[i0:i1, w.sat, w.node] = True
    np.testing.assert_array_equal(rebuilt, tl.grid)


def test_contact_plan_summary_and_export():
    fls = _sim("asyncfleo-hap", False)
    plan = ContactPlan.compile(fls.constellation, fls.nodes,
                               duration_s=6 * 3600.0, dt_s=30.0)
    s = plan.summary()
    assert s["num_windows"] == len(plan.to_dicts()) > 0
    assert 0.0 < s["coverage_fraction"] < 1.0
    assert not s["is_degenerate"]
    assert plan.isl_hop_delay(0.0) > 0.0
    d = plan.to_dicts()[0]
    assert set(d) == {"sat", "node", "t_start", "t_end", "delay_s"}


def test_next_contact_matches_timeline():
    fls = _sim("asyncfleo-twohap", False)
    tv, ps = fls.plan.next_contact([0, 7, 23], 1234.0)
    tv2, ps2 = fls.timeline.next_visible_after([0, 7, 23], 1234.0)
    np.testing.assert_array_equal(tv, tv2)
    np.testing.assert_array_equal(ps, ps2)
    t_any = fls.plan.next_any_contact(0.0)
    assert t_any is not None and t_any >= 0.0


# ---- runtime vs epoch-loop parity -----------------------------------------

def _degenerate(fls):
    """All sats always visible — the acceptance-criteria contact plan."""
    fls.timeline.grid[:] = True
    assert fls.plan.is_degenerate
    return fls


def test_parity_degenerate_plan_asyncfleo():
    """The acceptance contract: under an all-visible plan and the AsyncFLEO
    policy the event runtime reproduces the fused epoch loop's aggregated
    weights (atol 1e-5) with the SAME fused-dispatch count."""
    a = _degenerate(_sim("asyncfleo-twohap", False))
    b = _degenerate(_sim("asyncfleo-twohap", True))
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches == len(ha)
    assert a._fused_prog.fallback_dispatches == \
        b._fused_prog.fallback_dispatches


@pytest.mark.parametrize("name", ["asyncfleo-twohap", "asyncfleo-hap",
                                  "fedhap", "fedisl"])
def test_parity_real_constellation(name):
    """Same contract on the real paper constellation (async idle-timeout
    and sync barrier policies both delegate their split to _trigger)."""
    a, b = _sim(name, False), _sim(name, True)
    ha = a.run(W0, max_epochs=4)
    hb = b.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches


def test_parity_with_stragglers():
    """A tight collection window forces late arrivals: the runtime's
    straggler carry-over must match the epoch loop's."""
    a = _sim("asyncfleo-twohap", False, agg_timeout_s=120.0)
    b = _sim("asyncfleo-twohap", True, agg_timeout_s=120.0)
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)


def test_parity_sync_stall_all_late():
    """A sync stall shorter than every uplink: the barrier round must
    still consume its training dispatch (0-model epoch, all rows carried)
    instead of silently dropping the round — and match the epoch loop."""
    for stall in (350.0, 900.0):
        a = _sim("fedhap", False, sync_stall_s=stall)
        b = _sim("fedhap", True, sync_stall_s=stall)
        ha = a.run(W0, max_epochs=4)
        hb = b.run(W0, max_epochs=4)
        assert _rows(ha) == _rows(hb), f"stall={stall}"
        np.testing.assert_allclose(np.asarray(a._w_flat),
                                   np.asarray(b._w_flat), atol=1e-5)


def test_idle_round_sleeps_until_straggler_lands():
    """A round with no participants and a straggler hours out must wake
    at the straggler's landing (not re-arm the same trigger forever) and
    aggregate it."""
    fls = _sim("asyncfleo-twohap", True)
    row = (np.asarray(flatten_tree(W0)) + 1.0)[None, :]
    ta = 50000.0                        # far beyond t_start + agg_timeout
    fls._pend_meta = [(ta, 3, 0)]
    fls._pend_dev = jnp.asarray(row.astype(np.float32))
    _staged_downlink(fls, [()])         # nobody is ever visible
    hist = fls.run(W0, max_epochs=3)
    assert len(hist) == 1
    assert hist[0].num_models == 1
    assert hist[0].time_s >= ta


def test_idle_round_drops_past_horizon_straggler():
    """A pending straggler landing after the horizon is dropped (the
    epoch loop's `t >= duration` break) — the run terminates cleanly."""
    fls = _sim("asyncfleo-twohap", True)
    row = (np.asarray(flatten_tree(W0)) + 1.0)[None, :]
    fls._pend_meta = [(SIMKW["duration_s"] + 100.0, 3, 0)]
    fls._pend_dev = jnp.asarray(row.astype(np.float32))
    _staged_downlink(fls, [()])
    hist = fls.run(W0, max_epochs=3)
    assert hist == []


def test_runtime_event_counts_and_rounds():
    fls = _sim("asyncfleo-twohap", True)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=3)
    assert len(hist) == 3
    c = rt.events.counts
    # every participant trains once per round; every finite arrival fires
    assert c[EventKind.TRAIN_DONE.name] >= c[EventKind.MODEL_ARRIVAL.name]
    assert c[EventKind.MODEL_ARRIVAL.name] > 0
    assert c[EventKind.TRIGGER_TIMEOUT.name] >= len(hist)
    assert c[EventKind.SINK_HANDOFF.name] >= len(hist) - 1


def test_runtime_requires_fused_trainer():
    class LegacyOnly:
        def data_size(self, sat):
            return 1

        def train_many(self, sats, params, seed):
            return [params for _ in sats], np.zeros(len(sats))

    cfg = SimConfig(event_driven=True, **SIMKW)
    fls = FLSimulation(get_strategy("asyncfleo-twohap"), LegacyOnly(),
                       None, cfg)
    with pytest.raises(ValueError, match="fused"):
        fls.run(W0, max_epochs=2)


def test_runtime_target_accuracy_stops_early():
    def ev(params):
        flat = np.concatenate([np.ravel(np.asarray(params["w"])),
                               np.ravel(np.asarray(params["b"]))])
        return 1.0 - min(1.0, float(np.mean(np.abs(flat - 1.0))))

    class Converging(TinyFusedTrainer):
        def epoch_train_fn(self):
            def _fn(params, inputs, ids, seed):
                flat = flatten_tree(params)
                stack = (flat[None, :] * 0.5 + 0.5
                         + 0.0 * ids[:, None].astype(np.float32))
                return stack, np.zeros(ids.shape[0])
            return _fn

    cfg = SimConfig(event_driven=True, **SIMKW)
    fls = FLSimulation(get_strategy("asyncfleo-twohap"), Converging(W0),
                       ev, cfg)
    hist = fls.run(W0, max_epochs=20, target_accuracy=0.9)
    assert hist[-1].accuracy >= 0.9
    assert len(hist) < 20


# ---- policies --------------------------------------------------------------

def test_policy_selection_via_strategies():
    assert isinstance(make_policy(get_strategy("asyncfleo-hap")),
                      AsyncFLEOPolicy)
    assert isinstance(make_policy(get_strategy("fedhap")),
                      SyncBarrierPolicy)
    assert isinstance(make_policy(get_strategy("fedisl")),
                      SyncBarrierPolicy)
    assert isinstance(make_policy(get_strategy("fedasync")),
                      FedAsyncPolicy)
    assert isinstance(make_policy(get_strategy("fedsat")),
                      FedAsyncPolicy)
    with pytest.raises(KeyError):
        make_policy(get_strategy("fedhap"), name="nope")


def test_fedasync_per_arrival_aggregation():
    """FedAsync: every arrival triggers its own aggregation — many small
    commits per round, but still only ONE fused training dispatch."""
    fls = _sim("fedasync", True)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=6)
    assert len(hist) == 6
    # per-arrival commits are small (one or a few simultaneous arrivals)
    assert max(r.num_models for r in hist) <= 4
    times = [r.time_s for r in hist]
    assert all(b >= a for a, b in zip(times, times[1:]))
    # the first commit consumed the round's single training dispatch; the
    # later per-arrival commits drained the carried matrix eagerly
    assert fls._fused_prog.dispatches < len(hist)


def test_sync_barrier_fires_on_last_arrival():
    """The barrier commits exactly when the last expected model lands (not
    at the stall deadline) when every satellite reports in time."""
    fls = _sim("fedhap", True)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=2)
    assert len(hist) == 2
    assert all(r.num_models == fls.constellation.num_sats for r in hist)
    assert hist[0].time_s < SIMKW["duration_s"]


# ---- the paper's headline ordering ----------------------------------------

def test_async_convergence_delay_beats_sync():
    """Same constellation, same trainer: the AsyncFLEO policy reaches the
    same epoch count in strictly less simulated time than the sync
    barrier — the paper's Table II quantity, now runnable head-to-head."""
    h_async = _sim("asyncfleo-gs", True).run(W0, max_epochs=3)
    h_sync = _sim("fedisl", True).run(W0, max_epochs=3)
    assert h_async[-1].time_s < h_sync[-1].time_s
