import numpy as np
import pytest

from repro.core.aggregation import (SatelliteMeta, asyncfleo_aggregate, dedup,
                                    fedavg, staleness_gamma, weighted_sum)


def _model(val):
    return {"w": np.full((3, 2), val, np.float32)}


def _meta(sid, size=100.0, epoch=0, ts=0.0):
    return SatelliteMeta(sid, size, (0.0, 0.0), ts, epoch)


def test_fedavg_equal_sizes_is_mean():
    out = fedavg([_model(0.0), _model(2.0)], [50, 50])
    np.testing.assert_allclose(out["w"], 1.0)


def test_fedavg_weighted():
    out = fedavg([_model(0.0), _model(4.0)], [300, 100])
    np.testing.assert_allclose(out["w"], 1.0)


def test_weighted_sum_with_base():
    out = weighted_sum([_model(2.0)], [0.5], base=_model(4.0), base_weight=0.5)
    np.testing.assert_allclose(out["w"], 3.0)


def test_dedup_keeps_latest():
    models = [_model(1.0), _model(2.0), _model(3.0)]
    metas = [_meta(7, ts=1.0), _meta(7, ts=5.0), _meta(8, ts=2.0)]
    m2, t2 = dedup(models, metas)
    assert len(m2) == 2
    vals = sorted(float(m["w"][0, 0]) for m in m2)
    assert vals == [2.0, 3.0]


def test_staleness_gamma_bounds():
    metas = [_meta(0, size=100, epoch=2), _meta(1, size=100, epoch=4)]
    g = staleness_gamma(metas, 200.0, beta=4)
    assert 0.0 <= g <= 1.0
    assert g == pytest.approx((0.5 * 0.5) + (0.5 * 1.0))


def test_asyncfleo_all_fresh_is_fedavg_step():
    w_prev = _model(0.0)
    models = [_model(1.0), _model(3.0)]
    metas = [_meta(0, size=100, epoch=5), _meta(1, size=100, epoch=5)]
    w, info = asyncfleo_aggregate(w_prev, {0: [0, 1]}, models, metas, beta=5)
    assert info["gamma"] == 1.0
    np.testing.assert_allclose(w["w"], 2.0)     # pure data-weighted average


def test_asyncfleo_stale_group_discounted():
    w_prev = _model(10.0)
    models = [_model(0.0)]
    metas = [_meta(0, size=100, epoch=1)]       # stale at beta=4
    w, info = asyncfleo_aggregate(w_prev, {0: [0]}, models, metas, beta=4)
    g = info["gamma"]
    assert 0.0 < g < 1.0
    np.testing.assert_allclose(w["w"], (1 - g) * 10.0, rtol=1e-6)
    assert info["stale_groups"] == 1


def test_asyncfleo_fresh_shadows_stale_within_group():
    """Stale models in a group WITH fresh ones are discarded this epoch."""
    w_prev = _model(0.0)
    models = [_model(4.0), _model(-100.0)]
    metas = [_meta(0, epoch=3), _meta(1, epoch=0)]
    w, info = asyncfleo_aggregate(w_prev, {0: [0, 1]}, models, metas, beta=3)
    assert info["selected"] == 1
    np.testing.assert_allclose(w["w"], 4.0)


def test_asyncfleo_convexity():
    """Output leaves lie within [min, max] of inputs+base (convex combo)."""
    rng = np.random.default_rng(0)
    w_prev = {"w": rng.standard_normal((4,)).astype(np.float32)}
    models = [{"w": rng.standard_normal((4,)).astype(np.float32)} for _ in range(3)]
    metas = [_meta(i, size=rng.integers(50, 200), epoch=rng.integers(0, 3))
             for i in range(3)]
    w, _ = asyncfleo_aggregate(w_prev, {0: [0, 1], 1: [2]}, models, metas, beta=2)
    allv = np.stack([w_prev["w"]] + [m["w"] for m in models])
    assert (w["w"] <= allv.max(0) + 1e-5).all()
    assert (w["w"] >= allv.min(0) - 1e-5).all()


def test_strict_paper_eq14():
    w_prev = _model(0.0)
    models = [_model(1.0), _model(1.0)]
    metas = [_meta(0, epoch=2), _meta(1, epoch=2)]
    w, info = asyncfleo_aggregate(w_prev, {0: [0, 1]}, models, metas, beta=2,
                                  strict_paper_eq14=True)
    # literal eq. 14: each selected model weighted by gamma (=1 here) -> sum=2
    np.testing.assert_allclose(w["w"], 2.0)


def test_lmpool_size_mode_on_board_vs_trained():
    """ISSUE: eq. 13/14 weights may use the full on-board shard (the
    paper's D_n, default) or the truncated per-call count the batched vmap
    actually trained on (DESIGN.md §3)."""
    from repro.fl import LMPool
    toks = np.zeros((10, 8), np.int32)
    shards = [np.arange(0, 6), np.arange(6, 10)]     # sizes 6 and 4 -> m=4
    pool = LMPool(model_cfg=None, tokens=toks, shards=shards)
    assert pool.size_mode == "on_board"
    assert pool.data_size(0) == 6 and pool.data_size(1) == 4
    trained = LMPool(model_cfg=None, tokens=toks, shards=shards,
                     size_mode="trained")
    assert trained.data_size(0) == trained.data_size(1) == 4
    with pytest.raises(ValueError, match="size_mode"):
        LMPool(model_cfg=None, tokens=toks, shards=shards, size_mode="full")
