"""deepseek-v2-236b — MoE 160e top-6 with 2 shared experts, MLA kv_lora=512.

[arXiv:2405.04434] — 60 layers, d_model 5120, 128 heads, per-expert ffn 1536,
first layer dense (d_ff 12288), MLA with kv_lora_rank 512, q_lora_rank 1536,
decoupled rope head dim 64, nope head dim 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288,                 # dense/first-layer FFN hidden
    moe_d_ff=1536,              # per-routed-expert hidden
    vocab_size=102400,
    num_experts=160, num_shared_experts=2, top_k=6, first_dense_layers=1,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    rope_head_dim=64, nope_head_dim=128, head_dim=192,
    rope_theta=10000.0,
    citation="arXiv:2405.04434",
)
