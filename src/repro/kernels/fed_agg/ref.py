"""Pure-jnp oracle for the fed_agg kernel."""
import jax.numpy as jnp


def fed_agg_flat_ref(stack, gamma, base, base_weight):
    stack = stack.astype(jnp.float32)
    return (jnp.asarray(base_weight, jnp.float32) * base.astype(jnp.float32)
            + jnp.einsum("c,cn->n", gamma.astype(jnp.float32), stack))
