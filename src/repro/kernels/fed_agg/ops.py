"""Public API for the fed_agg kernel: flat and pytree forms."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels.fed_agg.kernel import fed_agg_flat


def fed_agg(stack, gamma, base=None, base_weight: float = 0.0, *,
            interpret: Optional[bool] = None):
    """out = base_weight * base + sum_c gamma[c] * stack[c]   (flat (C,N))."""
    if interpret is None:
        interpret = default_interpret()
    if base is None:
        base = jnp.zeros((stack.shape[1],), jnp.float32)
        base_weight = 0.0
    return fed_agg_flat(stack, gamma, base, base_weight, interpret=interpret)


def fed_agg_bank(bank, gamma, base=None, base_weight: float = 0.0, *,
                 interpret: Optional[bool] = None):
    """Aggregate a device-resident ``ModelBank`` in one kernel pass.

    ``bank.stack`` is already the kernel's native (C, N) layout, so unlike
    :func:`fed_agg_pytree` there is no per-model flatten: the stack goes
    straight to the fused reduction.  ``base`` may be a flat (N,) vector or a
    pytree (flattened once via the bank's spec).  Returns the flat (N,)
    aggregated model; use ``bank.spec.unflatten`` to materialize a pytree.
    """
    from repro.core.modelbank import flat_base
    return fed_agg(bank.stack, jnp.asarray(gamma, jnp.float32),
                   flat_base(bank.spec, base), base_weight,
                   interpret=interpret)


def fed_agg_pytree(models: Sequence, gamma: np.ndarray, base=None,
                   base_weight: float = 0.0, *,
                   interpret: Optional[bool] = None):
    """Aggregate a list of model pytrees into one (paper eq. 14).

    Flattens every model once, runs a single fused kernel pass over the
    concatenated parameter vector, and unflattens back to the tree
    structure.
    """
    leaves_list = [jax.tree_util.tree_leaves(m) for m in models]
    treedef = jax.tree_util.tree_structure(models[0])
    flat_models = jnp.stack([
        jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
        for leaves in leaves_list])
    if base is not None:
        base_leaves = jax.tree_util.tree_leaves(base)
        flat_base = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                                     for l in base_leaves])
    else:
        flat_base = None
    out = fed_agg(flat_models, jnp.asarray(gamma), flat_base, base_weight,
                  interpret=interpret)
    # unflatten
    sizes = [int(np.prod(l.shape)) for l in leaves_list[0]]
    shapes = [l.shape for l in leaves_list[0]]
    parts = []
    off = 0
    for size, shape in zip(sizes, shapes):
        parts.append(out[off:off + size].reshape(shape))
        off += size
    return jax.tree_util.tree_unflatten(treedef, parts)
