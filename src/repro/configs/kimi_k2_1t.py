"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8. [arXiv:2501.kimi2]

61 layers, d_model 7168, 64 heads (GQA kv=8... per assignment table), MoE
per-expert hidden 2048, 1 shared expert, first layer dense, MLA-style not
assigned — plain GQA per the table.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=18432,                # dense/first-layer FFN hidden
    moe_d_ff=2048,             # per-expert hidden
    vocab_size=163840,
    num_experts=384, num_shared_experts=1, top_k=8, first_dense_layers=1,
    head_dim=128, rope_theta=50000.0,
    citation="arXiv:2501.kimi2 (paper-table)",
)
