import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, apply_updates, clip_by_global_norm, global_norm, sgd


def _quadratic(params):
    return sum(jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(params))


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adamw(0.05), adamw(0.05, weight_decay=0.01)])
def test_optimizers_decrease_quadratic(opt):
    params = {"a": jnp.ones((4, 4)), "b": jnp.full((3,), 2.0)}
    state = opt.init(params)
    loss0 = float(_quadratic(params))
    for _ in range(50):
        grads = jax.grad(_quadratic)(params)
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(_quadratic(params)) < 0.2 * loss0


def test_global_norm_and_clip():
    tree = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    gn = float(global_norm(tree))
    assert gn == pytest.approx(np.sqrt(3 * 16 + 4 * 9))
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # no-op when under the cap
    clipped2, _ = clip_by_global_norm(tree, 1e9)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 4.0)


def test_adamw_state_shapes_and_dtype():
    opt = adamw(1e-3)
    params = {"w": jnp.ones((5, 2), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32       # fp32 master moments
    grads = {"w": jnp.ones((5, 2), jnp.bfloat16)}
    upd, state = opt.update(grads, state, params)
    assert upd["w"].dtype == jnp.bfloat16             # cast back to param dtype
    assert int(state["step"]) == 1


@pytest.mark.parametrize("opt", [sgd(0.1, momentum=0.9), adamw(0.05)])
def test_optimizer_state_under_donated_buffers(opt):
    """The fused-epoch discipline applied to optimizer steps: donating
    the params AND state buffers to a jitted update must be bitwise
    identical to the undonated step, step after step, while the donated
    inputs are actually consumed."""
    def make():
        params = {"a": jnp.ones((4, 4)), "b": jnp.full((3,), 2.0)}
        return params, opt.init(params)

    def step(params, state):
        grads = jax.grad(_quadratic)(params)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state

    plain = jax.jit(step)
    donated = jax.jit(step, donate_argnums=(0, 1))

    p_ref, s_ref = make()
    p_don, s_don = make()
    for _ in range(5):
        p_ref, s_ref = plain(p_ref, s_ref)
        prev_p, prev_s = p_don, s_don
        p_don, s_don = donated(p_don, s_don)
        # bitwise-identical trajectory, params and every state leaf
        for l_ref, l_don in zip(jax.tree_util.tree_leaves((p_ref, s_ref)),
                                jax.tree_util.tree_leaves((p_don, s_don))):
            np.testing.assert_array_equal(np.asarray(l_ref),
                                          np.asarray(l_don))
        # the donated buffers were consumed: XLA reused them in place
        assert all(l.is_deleted() for l in
                   jax.tree_util.tree_leaves(prev_p))
    # momentum/moment state really advanced (not a fixed point)
    leaves = jax.tree_util.tree_leaves(s_don)
    assert any(float(np.abs(np.asarray(l)).max()) > 0 for l in leaves)
