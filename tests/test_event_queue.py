"""EventQueue ordering contracts (DESIGN.md §14).

Batched event processing rests on two properties that used to be
implicit: (1) equal-timestamp events pop in push (FIFO) order across
EVERY event kind — the (time, sequence) heap key; (2) ``pop_batch``
drains exactly the maximal same-(time, kind, round) run the sequential
loop would have popped consecutively, in the same order.  These tests
pin both so a heap-key or batching regression cannot silently reorder
histories.
"""
import random

import pytest

from repro.sched.events import Event, EventKind, EventQueue

ALL_KINDS = list(EventKind)


def _drain_pop(q: EventQueue):
    out = []
    while q:
        out.append(q.pop())
    return out


def _drain_batch(q: EventQueue):
    out = []
    while q:
        batch = q.pop_batch()
        assert len(batch) >= 1
        # batch invariant: one (time, kind, round_idx) per batch
        assert len({(e.time, e.kind, e.round_idx) for e in batch}) == 1
        out.extend(batch)
    return out


def test_equal_timestamp_fifo_all_kinds():
    """Events of every kind pushed at ONE instant pop in exact push
    order — the FIFO tie-break the runtime's bit-parity depends on."""
    q = EventQueue()
    pushed = []
    rng = random.Random(7)
    for i in range(200):
        kind = rng.choice(ALL_KINDS)
        ev = Event(100.0, kind, round_idx=rng.randrange(3), sat=i)
        q.push(ev)
        pushed.append(ev)
    assert _drain_pop(q) == pushed


def test_equal_timestamp_fifo_within_time_groups():
    """FIFO holds within each timestamp group under interleaved pushes
    of mixed times."""
    q = EventQueue()
    rng = random.Random(11)
    pushed = []
    for i in range(300):
        t = float(rng.choice([10.0, 20.0, 30.0]))
        ev = Event(t, rng.choice(ALL_KINDS), round_idx=0, sat=i)
        q.push(ev)
        pushed.append(ev)
    popped = _drain_pop(q)
    for t in (10.0, 20.0, 30.0):
        assert [e for e in popped if e.time == t] == \
            [e for e in pushed if e.time == t]


def test_pop_batch_equals_sequential_pops():
    """Draining via pop_batch yields the byte-identical event sequence
    the one-at-a-time pop loop yields."""
    rng = random.Random(3)
    evs = [Event(float(rng.randrange(5)), rng.choice(ALL_KINDS),
                 round_idx=rng.randrange(3), sat=i, row=i)
           for i in range(500)]
    qa, qb = EventQueue(), EventQueue()
    for ev in evs:
        qa.push(ev)
        qb.push(ev)
    assert _drain_pop(qa) == _drain_batch(qb)


def test_pop_batch_boundaries():
    """A batch stops at a kind change, a round change, or a time change —
    and never crosses one even when later events would re-match."""
    q = EventQueue()
    seq = [Event(1.0, EventKind.MODEL_ARRIVAL, 0, sat=0),
           Event(1.0, EventKind.MODEL_ARRIVAL, 0, sat=1),
           Event(1.0, EventKind.TRAIN_DONE, 0, sat=2),       # kind change
           Event(1.0, EventKind.MODEL_ARRIVAL, 1, sat=3),    # round change
           Event(1.0, EventKind.MODEL_ARRIVAL, 0, sat=4),
           Event(2.0, EventKind.MODEL_ARRIVAL, 0, sat=5)]    # time change
    for ev in seq:
        q.push(ev)
    sizes = []
    while q:
        sizes.append([e.sat for e in q.pop_batch()])
    assert sizes == [[0, 1], [2], [3], [4], [5]]


def test_pop_batch_flood():
    """The mega-constellation shape: one dt-slice flood of arrivals pops
    as ONE batch in push order."""
    q = EventQueue()
    for i in range(10_000):
        q.push(Event(60.0, EventKind.MODEL_ARRIVAL, 2, sat=i, row=i))
    batch = q.pop_batch()
    assert len(batch) == 10_000
    assert [e.sat for e in batch] == list(range(10_000))
    assert not q


def test_push_many_preserves_sequence_order():
    """push_many(evs) assigns the same sequence numbers as per-event
    pushes: its events pop after earlier same-time pushes and in input
    order among themselves."""
    q = EventQueue()
    first = Event(5.0, EventKind.TRIGGER_TIMEOUT, 0, sat=-1)
    q.push(first)
    bulk = [Event(5.0, EventKind.TRIGGER_TIMEOUT, 0, sat=i)
            for i in range(20)]
    q.push_many(bulk)
    assert _drain_pop(q) == [first] + bulk


def test_nan_time_rejected():
    with pytest.raises(AssertionError):
        Event(float("nan"), EventKind.TRAIN_DONE, 0)
