import numpy as np
import pytest

from repro.core.constellation import make_ps_nodes, paper_constellation
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline


@pytest.fixture(scope="module")
def topo():
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes("twohap"), 3600.0, 10.0)
    return RingOfStars(c, tl.nodes, tl)


def test_ring_hops(topo):
    assert topo.ring_hops(0, 0) == 0
    assert topo.ring_hops(0, 1) == 1
    assert topo.sink_of(0) == 1 and topo.sink_of(1) == 0


def test_isl_neighbors_ring(topo):
    prev, nxt = topo.isl_neighbors(0)
    assert prev == 7 and nxt == 1            # orbit 0 is sats 0..7
    prev, nxt = topo.isl_neighbors(8)
    assert prev == 15 and nxt == 9


def test_isl_ring_distance_metric(topo):
    # symmetric, zero on self, shorter-arc
    assert topo.isl_ring_distance(0, 0) == 0
    assert topo.isl_ring_distance(0, 1) == topo.isl_ring_distance(1, 0) == 1
    assert topo.isl_ring_distance(0, 7) == 1   # wraparound
    assert topo.isl_ring_distance(0, 4) == 4   # antipodal in 8-ring
    assert topo.isl_ring_distance(0, 9) >= 10**9   # cross-orbit: unreachable


def test_isl_chord(topo):
    c = topo.constellation
    expected = 2 * c.radius_m * np.sin(np.pi / 8)
    assert topo.isl_chord_m() == pytest.approx(expected)


def test_star_members_are_visible(topo):
    mem = topo.star_members(0, 0.0)
    vis = topo.timeline.visible(0.0)
    for s in mem:
        assert vis[s, 0]


def test_ihl_distance_positive(topo):
    d = topo.ihl_distance(0, 1, 0.0)
    assert 1e5 < d < 1e7     # Rolla<->Portland ~2400 km
