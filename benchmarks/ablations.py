"""Beyond-paper ablation study: which AsyncFLEO component buys what.

Variants of asyncfleo-hap with one component removed each:
  full            — grouping + staleness discounting + ISL relay (the paper)
  no-grouping     — all orbits in a single group (staleness discount still on)
  no-isl          — star topology only: satellites wait for direct visibility
  strict-eq14     — the literal (non-convex) eq. 14 instead of the normalized
                    interpretation (DESIGN.md §3)
  kernel-agg      — full, with eq. 14 routed through the Pallas fed_agg kernel
                    (numerical-equivalence + integration check)

The paper reports no ablation; this table shows the relay dominates
convergence *time* while grouping dominates non-IID *accuracy*.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import make_setup, run_strategy
from repro.benchmarks_io import emit
from repro.core import FLSimulation, SimConfig
from repro.fl import get_strategy


VARIANTS = {
    "full": {},
    "no-grouping": {"grouping": False},
    "no-isl": {"use_isl": False},
    "strict-eq14": {"strict_paper_eq14": True},
    "kernel-agg": {"use_agg_kernel": True},
}


def run(max_epochs: int = 10):
    pool, ev, w0 = make_setup("mnist", "cnn", iid=False)
    rows, curves = [], []
    for name, overrides in VARIANTS.items():
        spec = dataclasses.replace(get_strategy("asyncfleo-hap"), **overrides)
        sim = FLSimulation(spec, pool, ev, SimConfig(duration_s=2 * 86400.0))
        hist = sim.run(w0, max_epochs=max_epochs)
        best = max(r.accuracy for r in hist) if hist else 0.0
        rows.append({"variant": name, "best_acc": round(best, 4),
                     "final_time_h": round(hist[-1].time_s / 3600, 2) if hist else None,
                     "epochs": len(hist),
                     "mean_gamma": round(sum(r.gamma for r in hist) / max(len(hist), 1), 3)})
        for r in hist:
            curves.append((name, r.epoch, round(r.time_s / 3600, 3),
                           round(r.accuracy, 4)))
    return {"rows": rows, "curves": curves}


def main(max_epochs: int = 10):
    out = run(max_epochs)
    print("variant,best_acc,final_time_h,epochs,mean_gamma")
    for r in out["rows"]:
        print(f"{r['variant']},{r['best_acc']},{r['final_time_h']},"
              f"{r['epochs']},{r['mean_gamma']}")
    emit("ablations", out)
    return out


if __name__ == "__main__":
    main()
