"""Multiplexing per-scenario epoch dispatches into shared device programs.

The event-driven runtime is host logic — contact plans, priority queues,
channel reservations differ per scenario and stay per-scenario.  What IS
shared is the device work: every committed epoch funnels through
``EpochStepProgram.step``.  The sweep engine therefore runs each
scenario's full runtime on its own worker thread and intercepts that one
choke point with a ``BatchedProgram`` proxy: instead of dispatching, the
worker enqueues a *dispatch request* and blocks.  When every live
scenario is either blocked on a request or finished, the driver thread
flushes: requests with identical static signatures (same program spec,
participant count, carry rows, kpad/blocked_m, fallback split, batch
structure and the trainer's ``scenario_batch_key``) become ONE physical
``batched_step`` dispatch; singletons and unbatchable programs (mesh /
Pallas kernel / no batch key) run solo through their own ``step`` —
trivially bit-exact.  Each scenario gets back lazy ``out[j]`` slices, so
nothing blocks at flush time; workers force values only where the
sequential runtime already would (evaluator, recorded stats).

Deadlock-freedom: workers block only inside ``submit``; the driver
flushes exactly when no worker can make progress without it; every lazy
value a worker forces after waking was enqueued by that flush.

Parity contract (DESIGN.md §13, pinned by tests/test_sweep.py and the
hypothesis property): per-scenario histories, weights and *logical*
dispatch counts from a batched run are bit-identical to running each
scenario sequentially — ``mode="exact"`` dispatches the same per-scenario
HLO, just unrolled into one program.  ``mode="vmap"`` trades that for one
batched GEMM (not bit-exact; opt-in).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _normalize_step_args(w_flat, carry, inputs, ids_np, seed, wv_bank,
                         wv_carry, base_w, dw_row, dw_seg, kpad, blocked_m,
                         dw_carry, ref):
    """Exactly ``EpochStepProgram.step``'s host->device conversions, done
    once at enqueue time so grouping and stacking see committed arrays.
    The result re-passes through ``step`` unchanged (every conversion is
    idempotent), so solo execution stays bit-identical."""
    return (w_flat, carry, inputs,
            jnp.asarray(ids_np, jnp.int32), np.uint32(seed),
            jnp.asarray(np.asarray(wv_bank, np.float32)),
            jnp.asarray(np.asarray(wv_carry, np.float32)),
            np.float32(base_w),
            jnp.asarray(np.asarray(dw_row, np.float32)),
            jnp.asarray(np.asarray(dw_seg, np.int32)),
            int(kpad), int(blocked_m),
            jnp.asarray(np.asarray(dw_carry, np.float32)),
            ref)


def _inputs_sig(inputs) -> Optional[Tuple]:
    if inputs is None:
        return None
    leaves, treedef = jax.tree.flatten(inputs)
    return (treedef,
            tuple((tuple(l.shape), str(getattr(l, "dtype", type(l))))
                  for l in leaves))


@dataclasses.dataclass
class _Request:
    """One scenario's pending epoch dispatch."""
    prog: Any                          # the scenario's own EpochStepProgram
    args: Tuple                        # normalized step-order args (14)
    fallback: bool
    sig: Tuple                         # grouping signature
    event: threading.Event = dataclasses.field(default_factory=threading.Event)
    out: Optional[Tuple] = None
    error: Optional[BaseException] = None


class BatchedProgram:
    """Drop-in ``EpochStepProgram`` facade handed to one scenario's
    simulator/runtime: same ``spec``/``profiler``/``step`` surface, same
    *logical* dispatch counters (``dispatches``/``fallback_dispatches``
    advance exactly as a sequential run's would — a parity invariant),
    but ``step`` routes through the shared :class:`DispatchBatcher`."""

    def __init__(self, batcher: "DispatchBatcher", inner, key=None):
        self._batcher = batcher
        self._inner = inner
        self._key = key
        self.dispatches = 0
        self.fallback_dispatches = 0

    @property
    def spec(self):
        return self._inner.spec

    @property
    def profiler(self):
        return self._inner.profiler

    @profiler.setter
    def profiler(self, value):
        self._inner.profiler = value

    def _batchable(self) -> bool:
        return (self._key is not None and self._inner.mesh is None
                and not self._inner.use_kernel)

    def step(self, w_flat, carry, inputs, ids_np, seed, wv_bank, wv_carry,
             base_w, dw_row, dw_seg, kpad, blocked_m, dw_carry, ref,
             *, fallback: bool = False):
        if fallback:
            self.fallback_dispatches += 1
        else:
            self.dispatches += 1
        args = _normalize_step_args(w_flat, carry, inputs, ids_np, seed,
                                    wv_bank, wv_carry, base_w, dw_row,
                                    dw_seg, kpad, blocked_m, dw_carry, ref)
        sig = (self._key if self._batchable() else None,
               self._inner.spec, int(args[1].shape[0]),
               int(args[3].shape[0]), int(kpad), int(blocked_m),
               bool(fallback), _inputs_sig(inputs))
        return self._batcher.submit(
            _Request(self._inner, args, bool(fallback), sig))


class DispatchBatcher:
    """The barrier + flush engine shared by one sweep's scenarios.

    Lifecycle: the driver ``register()``s each scenario before starting
    its worker thread, then loops in ``drain()`` on the main thread;
    workers go through ``wrap()``ed programs whose ``step`` calls
    ``submit()`` and blocks; ``finish()`` retires a worker.  All jit
    execution happens on the driver thread inside ``drain`` — workers
    only build arrays and force already-enqueued values.
    """

    def __init__(self, mode: str = "exact", profiler=None):
        if mode not in ("exact", "vmap"):
            raise ValueError(f"unknown scenario batch mode {mode!r}")
        self.mode = mode
        self.profiler = profiler       # obs.DispatchProfiler for *physical*
        self._cv = threading.Condition()
        self._pending: List[_Request] = []
        self._live = 0                 # registered, not yet finished
        self._running = 0              # live and not blocked in submit()
        # telemetry — physical accounting (logical lives on the proxies)
        self.flushes = 0
        self.physical_dispatches = 0   # programs actually launched
        self.batched_dispatches = 0    # ... of which multi-scenario
        self.solo_dispatches = 0       # ... of which single-scenario
        self.max_group = 0

    # ---- worker side -------------------------------------------------------

    def register(self) -> None:
        with self._cv:
            self._live += 1
            self._running += 1

    def wrap(self, prog, key=None):
        """Proxy ``prog`` for one scenario; ``key`` is the trainer's
        ``scenario_batch_key`` (None -> every dispatch runs solo)."""
        if prog is None:
            return None
        return BatchedProgram(self, prog, key=key)

    def submit(self, req: _Request):
        with self._cv:
            self._pending.append(req)
            self._running -= 1
            self._cv.notify_all()
        req.event.wait()
        with self._cv:
            self._running += 1
        if req.error is not None:
            raise req.error
        return req.out

    def finish(self) -> None:
        with self._cv:
            self._live -= 1
            self._running -= 1
            self._cv.notify_all()

    # ---- driver side -------------------------------------------------------

    def drain(self) -> None:
        """Run on the driver thread until every registered scenario has
        finished: wait for the barrier (no runnable worker), flush."""
        while True:
            with self._cv:
                self._cv.wait_for(
                    lambda: self._running == 0 and (self._pending
                                                    or self._live == 0))
                if not self._pending and self._live == 0:
                    return
                batch, self._pending = self._pending, []
            self._flush(batch)

    def _flush(self, batch: List[_Request]) -> None:
        groups: dict = {}
        for req in batch:
            groups.setdefault(req.sig, []).append(req)
        self.flushes += 1
        for reqs in groups.values():
            try:
                self._execute(reqs)
            except BaseException as e:   # propagate into every blocked worker
                for r in reqs:
                    r.error = e
            finally:
                for r in reqs:
                    r.event.set()

    def _execute(self, reqs: List[_Request]) -> None:
        prof = self.profiler
        t0 = prof.timer() if prof is not None else 0.0
        if len(reqs) == 1 or reqs[0].sig[0] is None:
            # singleton or unbatchable: the scenario's own program, its
            # own step() — bit-exact by construction
            for r in reqs:
                r.out = r.prog.step(*r.args, fallback=r.fallback)
                self.physical_dispatches += 1
                self.solo_dispatches += 1
            self.max_group = max(self.max_group, 1)
            if prof is not None:
                prof.record(("solo-group",) + reqs[0].sig[2:7],
                            reqs[0].fallback, prof.timer() - t0)
            return
        prog = reqs[0].prog            # batch_key certifies equivalence
        cols = list(zip(*(r.args for r in reqs)))
        if cols[2][0] is None:
            inputs = None
        else:
            inputs = jax.tree.map(lambda *ls: jnp.stack(ls), *cols[2])
        kpad, blocked_m = reqs[0].args[10], reqs[0].args[11]
        out = prog.batched_step(
            jnp.stack(cols[0]), jnp.stack(cols[1]), inputs,
            jnp.stack(cols[3]),
            jnp.asarray(np.asarray(cols[4], np.uint32)),
            jnp.stack(cols[5]), jnp.stack(cols[6]),
            jnp.asarray(np.asarray(cols[7], np.float32)),
            jnp.stack(cols[8]), jnp.stack(cols[9]), kpad, blocked_m,
            jnp.stack(cols[12]), jnp.stack(cols[13]),
            mode=self.mode, fallback=reqs[0].fallback)
        for j, r in enumerate(reqs):
            r.out = tuple(o[j] for o in out)
        self.physical_dispatches += 1
        self.batched_dispatches += 1
        self.max_group = max(self.max_group, len(reqs))
        if prof is not None:
            prof.record(("batched-group", self.mode, len(reqs))
                        + reqs[0].sig[2:7],
                        reqs[0].fallback, prof.timer() - t0)

    def summary(self) -> dict:
        return {"flushes": self.flushes,
                "physical_dispatches": self.physical_dispatches,
                "batched_dispatches": self.batched_dispatches,
                "solo_dispatches": self.solo_dispatches,
                "max_group": self.max_group,
                "mode": self.mode}
