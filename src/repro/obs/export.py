"""Trace export: Chrome trace-event JSON (Perfetto) and JSONL.

``export_chrome`` renders a `obs/trace.Tracer` buffer to the Chrome
trace-event format — the ``{"traceEvents": [...]}`` object that
https://ui.perfetto.dev and chrome://tracing load directly.  Mapping:

* one **process** (pid 0, the simulation run), one **thread per track**
  (``"round <idx>"``, ``"ps <p>"``, ...), named via ``"M"``
  (metadata) ``thread_name`` events so the timeline shows real labels;
* spans become ``"X"`` (complete) events with ``ts``/``dur`` in
  microseconds — simulated seconds × 1e6, so one timeline second is one
  simulated microsecond-tick and Perfetto's zoom works naturally;
* instants become ``"i"`` events with thread scope (``"s": "t"``);
* span/instant ``args`` pass through verbatim.

``export_jsonl`` writes one JSON object per line (``kind`` span /
instant, times in simulated seconds) for programmatic analysis —
`benchmarks/trace_report.py` consumes either format.

``add_runtime_tracks`` synthesizes the per-PS tracks the runtime never
records explicitly: channel-occupancy spans from the §9 pools' interval
reservations and outage windows from the §11 schedule.  Call it once at
run end, before exporting.

``validate_chrome_trace`` is the CI gate's schema check: structural
errors (missing keys, bad phases, negative durations) come back as a
list of strings, empty = valid.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.trace import SPAN_CHANNEL, SPAN_OUTAGE, Tracer

_US = 1e6          # simulated seconds -> trace microseconds


def _track_order(tracer: Tracer) -> Dict[str, int]:
    """track name -> tid; 'ps *' tracks first (sorted), then rounds in
    numeric order, then anything else in appearance order."""
    names = tracer.tracks()

    def key(n: str):
        parts = n.split()
        if parts[0] in ("ps", "round") and len(parts) == 2 \
                and parts[1].lstrip("-").isdigit():
            return (0 if parts[0] == "ps" else 1, int(parts[1]), n)
        return (2, 0, n)

    return {n: tid for tid, n in enumerate(sorted(names, key=key))}


def export_chrome(tracer: Tracer, path: Optional[str] = None) -> Dict:
    """Render the tracer buffer as a Chrome trace-event object; write it
    to ``path`` as JSON when given.  Returns the object either way."""
    tids = _track_order(tracer)
    events: List[Dict] = []
    for name, tid in tids.items():
        events.append({"ph": "M", "name": "thread_name", "pid": 0,
                       "tid": tid, "args": {"name": name}})
    for s in tracer.spans:
        events.append({"ph": "X", "name": s.name, "pid": 0,
                       "tid": tids[s.track],
                       "ts": s.t_start * _US,
                       "dur": (s.t_end - s.t_start) * _US,
                       "args": s.args})
    for i in tracer.instants:
        events.append({"ph": "i", "name": i.name, "pid": 0,
                       "tid": tids[i.track], "ts": i.t * _US, "s": "t",
                       "args": i.args})
    obj = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(obj, f)
    return obj


def export_jsonl(tracer: Tracer, path: str) -> int:
    """One JSON object per span/instant (times in simulated seconds);
    returns the number of lines written."""
    n = 0
    with open(path, "w") as f:
        for s in tracer.spans:
            f.write(json.dumps({"kind": "span", "name": s.name,
                                "track": s.track, "t_start": s.t_start,
                                "t_end": s.t_end, "args": s.args}) + "\n")
            n += 1
        for i in tracer.instants:
            f.write(json.dumps({"kind": "instant", "name": i.name,
                                "track": i.track, "t": i.t,
                                "args": i.args}) + "\n")
            n += 1
    return n


def validate_chrome_trace(obj) -> List[str]:
    """Structural schema check for an exported Chrome trace object (the
    parsed JSON, not a path).  Returns a list of human-readable errors —
    empty means the trace is loadable."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents must be a list"]
    for k, ev in enumerate(evs):
        where = f"traceEvents[{k}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "I", "M", "B", "E", "C"):
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing/non-string name")
        if "pid" not in ev:
            errors.append(f"{where}: missing pid")
        if ph == "M":
            continue                       # metadata needs no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: missing/non-numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)):
                errors.append(f"{where}: complete event missing dur")
            elif dur < 0:
                errors.append(f"{where}: negative dur {dur}")
    return errors


def add_runtime_tracks(tracer: Tracer, rt) -> None:
    """Synthesize the per-PS tracks from an `EventDrivenRuntime` after
    ``run()``: channel-occupancy spans from the contention pools'
    reservations (DESIGN.md §9) and outage windows from the compiled
    schedule (§11).  No-op for whatever the run did not configure."""
    if not tracer.enabled:
        return
    ctn = rt.plan.contention
    if ctn is not None and ctn.channels is not None:
        for direction, pool in (("tx", ctn.tx), ("rx", ctn.rx)):
            for ps in range(ctn.num_ps):
                for c, s, e in pool.intervals(ps):
                    tracer.span(SPAN_CHANNEL, s, e, track=f"ps {ps}",
                                direction=direction, channel=c)
    outages = getattr(rt, "_outages", None)
    if outages is not None:
        for ps, s, e in outages.events():
            tracer.span(SPAN_OUTAGE, s, e, track=f"ps {ps}", ps=ps)
