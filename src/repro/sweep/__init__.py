"""Batched scenario engine (DESIGN.md §13): Monte-Carlo sweeps over
seeds / geometry / link rates / trigger policies / staleness functions
run as a handful of shared device dispatches instead of sequential
benchmark rows, with percentile-band reduction — and a differential
parity contract pinning batched == sequential bit-identically."""
from repro.sweep.batch import BatchedProgram, DispatchBatcher
from repro.sweep.driver import ScenarioResult, run_scenarios
from repro.sweep.scenario import ScenarioSpec, draw, draw_spec, grid
from repro.sweep.stats import percentile_bands, reduce_results
from repro.sweep.testbed import (ConvergingTrainer, MeanDistanceEvaluator,
                                 make_model)

__all__ = [
    "BatchedProgram", "DispatchBatcher", "ScenarioResult", "ScenarioSpec",
    "ConvergingTrainer", "MeanDistanceEvaluator", "make_model",
    "draw", "draw_spec", "grid", "percentile_bands", "reduce_results",
    "run_scenarios",
]
