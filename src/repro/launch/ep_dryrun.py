import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Dry-run comparison: GSPMD sort-dispatch MoE vs explicit expert-parallel
all-to-all (models/moe_ep.py) at production scale — one MoE layer of the
given arch at train_4k token counts on the 16x16 mesh.

    PYTHONPATH=src python -m repro.launch.ep_dryrun --arch kimi-k2-1t-a32b \
        [--out out.json]
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import moe as MOE
from repro.models.moe_ep import make_ep_moe_layer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    mesh = make_production_mesh()
    B, S, d = shape.global_batch, shape.seq_len, cfg.d_model
    E, f = cfg.num_experts, cfg.moe_d_ff or cfg.d_ff

    p_spec = {
        "router": jax.ShapeDtypeStruct((d, E), jnp.float32),
        "we1": jax.ShapeDtypeStruct((E, d, f), jnp.float32),
        "we3": jax.ShapeDtypeStruct((E, d, f), jnp.float32),
        "we2": jax.ShapeDtypeStruct((E, f, d), jnp.float32),
    }
    x_spec = jax.ShapeDtypeStruct((B, S, d), jnp.dtype(cfg.dtype))

    results = {}
    with mesh:
        # --- GSPMD sort-dispatch ------------------------------------------
        p_shard = {
            "router": NamedSharding(mesh, P()),
            "we1": NamedSharding(mesh, P("model")),
            "we3": NamedSharding(mesh, P("model")),
            "we2": NamedSharding(mesh, P("model")),
        }
        x_shard = NamedSharding(mesh, P("data", None, None))

        def gspmd_layer(p, x):
            out, aux = MOE.moe_ffn(p, cfg, x)
            return out, aux

        for name, fn, shardings in [
            ("gspmd_dispatch", gspmd_layer, (p_shard, x_shard)),
            ("explicit_ep",
             lambda p, x: make_ep_moe_layer(cfg, mesh)(p, x), None),
        ]:
            t0 = time.time()
            if shardings is not None:
                jitted = jax.jit(fn, in_shardings=shardings)
            else:
                jitted = jax.jit(fn)
            lowered = jitted.lower(p_spec, x_spec)
            compiled = lowered.compile()
            coll = collective_bytes(compiled.as_text())
            mem = compiled.memory_analysis()
            results[name] = {
                "compile_s": round(time.time() - t0, 2),
                "collective_bytes": coll,
                "temp_gb_per_dev": round((getattr(mem, "temp_size_in_bytes", 0)
                                          or 0) / mesh.devices.size / 2**30, 3),
            }
            print(name, json.dumps(results[name]), flush=True)

    if args.out:
        with open(args.out, "w") as fh:
            json.dump({"arch": args.arch, "shape": args.shape, **results}, fh,
                      indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
