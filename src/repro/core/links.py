"""RF link budget and delay model (paper §III-B, eqs. 5-9, Table I).

SNR(x,y)   = Pt*Gx*Gy / (kB * T * B * FSPL)                       (eq. 5)
FSPL       = (4*pi*d*f/c)^2 for LoS, inf otherwise                (eq. 6)
t_c        = t_t + t_p + t_x + t_y                                (eq. 7)
t_t        = bits/R,  t_p = d/c                                   (eq. 8)
R          ~ B*log2(1+SNR)                                        (eq. 9)

The paper's evaluation fixes R = 16 Mb/s for fairness with baselines;
``LinkModel(rate_bps=...)`` reproduces that, while ``shannon_rate`` exposes
the full budget (and shows FSO-class rates are available if desired).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.constellation import C_LIGHT

K_BOLTZMANN = 1.380649e-23


def dbm_to_watt(dbm: float) -> float:
    return 10 ** ((dbm - 30) / 10)


def dbi_to_linear(dbi: float) -> float:
    return 10 ** (dbi / 10)


@dataclasses.dataclass(frozen=True)
class LinkModel:
    # Table I defaults
    tx_power_dbm: float = 40.0
    antenna_gain_dbi: float = 6.98
    carrier_freq_hz: float = 2.4e9
    noise_temp_k: float = 354.81
    bandwidth_hz: float = 20e6
    rate_bps: float = 16e6            # fixed evaluation rate (Table I)
    proc_delay_s: float = 0.5         # t_x + t_y combined

    def fspl(self, distance_m: float) -> float:
        return (4 * np.pi * distance_m * self.carrier_freq_hz / C_LIGHT) ** 2

    def snr(self, distance_m: float) -> float:
        pt = dbm_to_watt(self.tx_power_dbm)
        g = dbi_to_linear(self.antenna_gain_dbi)
        noise = K_BOLTZMANN * self.noise_temp_k * self.bandwidth_hz
        return pt * g * g / (noise * self.fspl(distance_m))

    def shannon_rate(self, distance_m: float) -> float:
        return self.bandwidth_hz * np.log2(1.0 + self.snr(distance_m))

    # ---- delays ------------------------------------------------------------

    def transmission_delay(self, bits: float, use_shannon: bool = False,
                           distance_m: float = 0.0) -> float:
        rate = self.shannon_rate(distance_m) if use_shannon else self.rate_bps
        return bits / rate

    def propagation_delay(self, distance_m: float) -> float:
        return distance_m / C_LIGHT

    def total_delay(self, bits: float, distance_m: float,
                    use_shannon: bool = False) -> float:
        return (self.transmission_delay(bits, use_shannon, distance_m)
                + self.propagation_delay(distance_m) + self.proc_delay_s)

    def busy_interval(self, t_start: float, bits: float):
        """Channel-occupancy interval ``[t_start, t_start + t_t)`` of one
        transfer that begins transmitting at ``t_start``: the channel is
        held for the transmission time only — propagation and processing
        delay the *payload*, not the transmitter.  This is the per-
        transfer quantity the contention model (`sched/contacts.py`,
        DESIGN.md §9) serializes; ``total_delay`` stays the payload's
        end-to-end latency."""
        return t_start, t_start + self.transmission_delay(bits)


def fso_link(rate_bps: float = 1e11, proc_delay_s: float = 0.1) -> LinkModel:
    """Free-space-optical link (paper §III-B: 'AsyncFLEO can actually benefit
    from FSO links... as high as Terabytes per second').  Default 100 Gb/s —
    conservative for laser ISL terminals."""
    return LinkModel(carrier_freq_hz=1.93e14,        # 1550 nm
                     bandwidth_hz=10e9, rate_bps=rate_bps,
                     proc_delay_s=proc_delay_s)


def model_bits(params) -> float:
    """Size in bits of a model pytree at fp32 (paper transmits fp32 weights)."""
    import jax
    return float(sum(np.prod(l.shape) if hasattr(l, "shape") else 1
                     for l in jax.tree_util.tree_leaves(params)) * 32)
