"""Model-level correctness: decode==full-forward per family, MoE vs oracle,
sliding-window ring buffer, chunked-vs-sequential scan paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import moe as MOE
from repro.models import registry as R
from repro.models.scan_ops import chunked_scan, recurrent_scan

KEY = jax.random.PRNGKey(1)


def _decode_vs_full(cfg, S=16, B=2, window=0, cache_len=None):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    params = R.init_params(KEY, cfg)
    full, _ = R.apply(params, cfg, {"tokens": toks}, window=window)
    cache = R.init_cache(cfg, B, cache_len or S, jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = R.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  window=window)
        outs.append(lg[:, 0])
    return float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))


@pytest.mark.parametrize("arch", ["qwen3-4b", "llama3-8b", "granite-8b",
                                  "starcoder2-3b"])
def test_dense_decode_matches_full(arch):
    cfg = ARCHS[arch].reduced().replace(remat=False, dtype="float32")
    assert _decode_vs_full(cfg) < 1e-4


def test_mla_moe_decode_matches_full():
    cfg = ARCHS["deepseek-v2-236b"].reduced().replace(
        remat=False, dtype="float32", moe_capacity_factor=64.0)
    assert _decode_vs_full(cfg) < 1e-4


def test_kimi_moe_decode_matches_full():
    cfg = ARCHS["kimi-k2-1t-a32b"].reduced().replace(
        remat=False, dtype="float32", moe_capacity_factor=64.0)
    assert _decode_vs_full(cfg) < 1e-4


def test_rwkv_decode_matches_full():
    cfg = ARCHS["rwkv6-7b"].reduced().replace(remat=False, dtype="float32")
    assert _decode_vs_full(cfg) < 1e-4


def test_hybrid_decode_matches_full():
    cfg = ARCHS["zamba2-2.7b"].reduced().replace(remat=False, dtype="float32")
    assert _decode_vs_full(cfg) < 1e-4


def test_sliding_window_ring_buffer():
    """Decode with a ring buffer capped at the window == full forward with the
    same window (the long_500k mechanism)."""
    cfg = ARCHS["qwen3-4b"].reduced().replace(remat=False, dtype="float32")
    W = 8
    assert _decode_vs_full(cfg, S=24, window=W, cache_len=W) < 1e-4


def test_moe_matches_reference():
    cfg = ARCHS["deepseek-v2-236b"].reduced().replace(dtype="float32")
    p = MOE.init_moe_ffn(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.5
    out, aux = MOE.moe_ffn(p, cfg, x, capacity_factor=64.0)
    ref = MOE.moe_ffn_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens():
    """With tight capacity some tokens drop (out differs from no-drop)."""
    cfg = ARCHS["deepseek-v2-236b"].reduced().replace(dtype="float32")
    p = MOE.init_moe_ffn(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model)) * 0.5
    tight, _ = MOE.moe_ffn(p, cfg, x, capacity_factor=0.25)
    loose, _ = MOE.moe_ffn(p, cfg, x, capacity_factor=64.0)
    assert float(jnp.max(jnp.abs(tight - loose))) > 1e-6


def test_chunked_scan_matches_sequential():
    B, T, H, K, V = 2, 64, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.3
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, V)) * 0.3
    ld = -jax.random.uniform(ks[3], (B, T, H)) * 0.7
    y1, s1 = recurrent_scan(r, k, v, ld, include_current=True)
    y2, s2 = chunked_scan(r, k, v, ld, include_current=True, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)


def test_vlm_prefix_loss_alignment():
    cfg = ARCHS["internvl2-1b"].reduced().replace(remat=False, dtype="float32")
    B, S = 2, 12
    P = cfg.num_prefix_embeds
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "prefix_embeds": jax.random.normal(KEY, (B, P, cfg.d_model)) * 0.02}
    params = R.init_params(KEY, cfg)
    logits, _ = R.apply(params, cfg, batch)
    assert logits.shape == (B, P + S, cfg.vocab_size)
    loss, m = R.train_loss(params, cfg, batch)
    assert np.isfinite(float(loss))


def test_audio_masked_prediction():
    cfg = ARCHS["hubert-xlarge"].reduced().replace(remat=False, dtype="float32")
    B, S = 2, 16
    batch = {"frame_embeds": jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1,
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "mask": jnp.asarray(np.random.default_rng(0).random((B, S)) < 0.4)}
    params = R.init_params(KEY, cfg)
    loss, _ = R.train_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    # bidirectional: permuting *future* frames changes past-frame logits
    logits, _ = R.apply(params, cfg, batch)
    batch2 = dict(batch)
    batch2["frame_embeds"] = batch["frame_embeds"].at[:, -1].set(0.7)
    logits2, _ = R.apply(params, cfg, batch2)
    assert float(jnp.max(jnp.abs(logits[:, 0] - logits2[:, 0]))) > 1e-7


def test_pallas_attention_path_in_model():
    """forward(impl='pallas') routes through the flash kernel and matches."""
    cfg = ARCHS["qwen3-4b"].reduced().replace(remat=False, dtype="float32")
    toks = jax.random.randint(KEY, (1, 32), 0, cfg.vocab_size)
    params = R.init_params(KEY, cfg)
    a, _ = R.apply(params, cfg, {"tokens": toks}, impl="xla")
    b, _ = R.apply(params, cfg, {"tokens": toks}, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
