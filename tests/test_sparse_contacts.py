"""Sparse contact compilation parity + multi-PS plans (DESIGN.md §14).

The sparse timeline replaces the dense (T, S, P) visibility grid with
segment-based contact windows and must be *bit-identical* to the dense
path everywhere it is observable: the compiled window set, every plan
query, and — the strongest pin — full event-driven runtime histories at
S ∈ {40, 200}.  Multi-PS plans (``hapring:N``, P > 3) are exercised
end-to-end through the same runtime.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core.constellation import (WalkerDelta, make_ps_nodes,
                                      paper_constellation)
from repro.fl import get_strategy
from repro.sched import ContactPlan, EventDrivenRuntime
from repro.sched.faults import FaultModel

from test_epoch_step import TinyFusedTrainer, W0
from test_sched import SIMKW, _rows

GEOMETRIES = {
    "paper-twohap": (paper_constellation(), "twohap"),
    "paper-hap": (paper_constellation(), "hap"),
    "walker200-ring4": (WalkerDelta(num_orbits=10, sats_per_orbit=20,
                                    altitude_m=600e3,
                                    inclination_deg=60.0), "hapring:4"),
}


def _plans(key, duration_s=6 * 3600.0, dt_s=30.0):
    cst, scenario = GEOMETRIES[key]
    nodes = make_ps_nodes(scenario)
    dense = ContactPlan.compile(cst, nodes, duration_s, dt_s)
    sparse = ContactPlan.compile(cst, nodes, duration_s, dt_s,
                                 visibility="sparse")
    return dense, sparse


def _sim2(name, visibility, *, constellation=None, spec_kw=None, **kw):
    cfg = SimConfig(event_driven=True, visibility=visibility,
                    **{**SIMKW, **kw})
    spec = get_strategy(name)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    return FLSimulation(spec, TinyFusedTrainer(W0), None, cfg,
                        constellation=constellation)


# ---- window-for-window parity ---------------------------------------------

@pytest.mark.parametrize("key", sorted(GEOMETRIES))
def test_sparse_windows_match_dense(key):
    dense, sparse = _plans(key)
    wd, ws = dense.windows(), sparse.windows()
    assert len(wd) == len(ws) > 0
    for a, b in zip(wd, ws):
        assert (a.sat, a.node) == (b.sat, b.node)
        assert a.t_start == b.t_start and a.t_end == b.t_end
        assert a.delay_s == b.delay_s


@pytest.mark.parametrize("key", sorted(GEOMETRIES))
def test_sparse_plan_queries_match_dense(key):
    dense, sparse = _plans(key)
    assert dense.summary() == sparse.summary()
    sats = np.arange(0, dense.num_sats, 3)
    rng = np.random.default_rng(5)
    for t in rng.uniform(0.0, 6 * 3600.0, size=40):
        td, pd = dense.next_contact(sats, float(t))
        ts, ps = sparse.next_contact(sats, float(t))
        np.testing.assert_array_equal(td, ts)
        np.testing.assert_array_equal(pd, ps)
        np.testing.assert_array_equal(dense.next_contact_by_node(float(t)),
                                      sparse.next_contact_by_node(float(t)))


def test_sparse_timeline_point_queries_match_dense():
    dense, sparse = _plans("paper-twohap")
    tld, tls = dense.timeline, sparse.timeline
    rng = np.random.default_rng(9)
    for t in rng.uniform(0.0, 6 * 3600.0, size=25):
        np.testing.assert_array_equal(tld.visible(float(t)),
                                      tls.visible(float(t)))
        for p in range(len(dense.nodes)):
            np.testing.assert_array_equal(tld.visible_sats(float(t), p),
                                          tls.visible_sats(float(t), p))
    for sat in range(0, dense.num_sats, 7):
        np.testing.assert_allclose(tld.visibility_fraction(sat),
                                   tls.visibility_fraction(sat))
    assert tld.covered_steps() == tls.covered_steps()
    for p in range(len(dense.nodes)):
        for a, b in zip(tld.node_windows(p), tls.node_windows(p)):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(tld.node_cover(p), tls.node_cover(p)):
            np.testing.assert_array_equal(a, b)


# ---- runtime-history bit-parity at S in {40, 200} --------------------------

@pytest.mark.parametrize("name,cst", [
    ("asyncfleo-twohap", None),                       # S=40 paper geometry
    ("asyncfleo-hap", None),
    ("asyncfleo-twohap", WalkerDelta(num_orbits=10, sats_per_orbit=20,
                                     altitude_m=600e3,
                                     inclination_deg=60.0)),  # S=200
])
def test_sparse_runtime_history_bit_identical(name, cst):
    """Dense and sparse visibility produce byte-identical event-driven
    histories AND exactly equal aggregated weights — the acceptance pin
    that sparse compilation changes nothing observable."""
    a = _sim2(name, "dense", constellation=cst)
    b = _sim2(name, "sparse", constellation=cst)
    ra, rb = EventDrivenRuntime(a), EventDrivenRuntime(b)
    ha = ra.run(W0, max_epochs=3)
    hb = rb.run(W0, max_epochs=3)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))
    assert ra.events.counts == rb.events.counts


# ---- multi-PS (P > 3) plans end-to-end -------------------------------------

@pytest.mark.parametrize("n_ps", [4, 6])
def test_hapring_multi_ps_end_to_end(n_ps):
    """A P>3 hapring compiles per-PS channel pools and completes an
    event-driven run: every ring PS appears in the contact plan and the
    sink handoff walks the full ring."""
    cst = WalkerDelta(num_orbits=10, sats_per_orbit=20,
                      altitude_m=600e3, inclination_deg=60.0)
    fls = _sim2("asyncfleo-gs", "sparse", constellation=cst,
                spec_kw={"ps_scenario": f"hapring:{n_ps}"})
    assert len(fls.nodes) == n_ps
    assert all(n.kind == "hap" for n in fls.nodes)
    nodes_seen = {w.node for w in fls.plan.windows()}
    assert nodes_seen == set(range(n_ps))
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=3)
    assert len(hist) == 3
    assert all(r.num_models > 0 for r in hist)
    # round sinks rotate across the ring rather than pinning one PS,
    # and every sink is a valid ring member
    sinks = {rnd.sink for rnd in rt.rounds.values()}
    assert len(sinks) >= 2
    assert sinks <= set(range(n_ps))


def test_hapring_rejects_empty_ring():
    with pytest.raises(ValueError):
        make_ps_nodes("hapring:0")


# ---- sparse-mode guard rails ----------------------------------------------

def test_sparse_rejects_grid_mask_faults():
    """Eclipse/outage fault models mutate the dense grid in place; the
    sparse timeline has no grid, so construction must fail loudly."""
    with pytest.raises(ValueError, match="sparse"):
        _sim2("asyncfleo-twohap", "sparse",
              fault_model=FaultModel(eclipse_fraction=0.25))
    with pytest.raises(ValueError, match="sparse"):
        _sim2("asyncfleo-twohap", "sparse",
              fault_model=FaultModel(ps_outage_fraction=0.1))


def test_unknown_visibility_mode_rejected():
    with pytest.raises(ValueError, match="visibility"):
        _sim2("asyncfleo-twohap", "banana")
