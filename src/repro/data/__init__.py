from repro.data.synthetic import class_conditional_images, token_stream, batches
from repro.data.partition import (
    iid_partition, paper_noniid_partition, dirichlet_partition,
)

__all__ = ["class_conditional_images", "token_stream", "batches",
           "iid_partition", "paper_noniid_partition", "dirichlet_partition"]
