import numpy as np
import pytest

from repro.core.constellation import make_ps_nodes, paper_constellation
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline


@pytest.fixture(scope="module")
def topo():
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes("twohap"), 3600.0, 10.0)
    return RingOfStars(c, tl.nodes, tl)


def test_ring_hops(topo):
    assert topo.ring_hops(0, 0) == 0
    assert topo.ring_hops(0, 1) == 1
    assert topo.sink_of(0) == 1 and topo.sink_of(1) == 0


def test_isl_neighbors_ring(topo):
    prev, nxt = topo.isl_neighbors(0)
    assert prev == 7 and nxt == 1            # orbit 0 is sats 0..7
    prev, nxt = topo.isl_neighbors(8)
    assert prev == 15 and nxt == 9


def test_isl_ring_distance_metric(topo):
    # symmetric, zero on self, shorter-arc
    assert topo.isl_ring_distance(0, 0) == 0
    assert topo.isl_ring_distance(0, 1) == topo.isl_ring_distance(1, 0) == 1
    assert topo.isl_ring_distance(0, 7) == 1   # wraparound
    assert topo.isl_ring_distance(0, 4) == 4   # antipodal in 8-ring
    assert topo.isl_ring_distance(0, 9) >= 10**9   # cross-orbit: unreachable


def test_isl_chord(topo):
    c = topo.constellation
    expected = 2 * c.radius_m * np.sin(np.pi / 8)
    assert topo.isl_chord_m() == pytest.approx(expected)


def test_star_members_are_visible(topo):
    mem = topo.star_members(0, 0.0)
    vis = topo.timeline.visible(0.0)
    for s in mem:
        assert vis[s, 0]


def test_ihl_distance_positive(topo):
    d = topo.ihl_distance(0, 1, 0.0)
    assert 1e5 < d < 1e7     # Rolla<->Portland ~2400 km


@pytest.fixture(scope="module")
def ring6():
    """A synthetic 6-HAP ring (ring arithmetic only needs num_ps)."""
    from repro.core.constellation import GroundNode
    nodes = [GroundNode(f"HAP-{i}", 10.0 + 5 * i, -120.0 + 20 * i, 20e3,
                        kind="hap") for i in range(6)]
    return RingOfStars(paper_constellation(), nodes, None)


def test_ring_hops_arc_symmetry(ring6):
    # min(d, H-d) metric: symmetric, zero on self, wraps the shorter way
    H = ring6.num_ps
    for a in range(H):
        for b in range(H):
            assert ring6.ring_hops(a, b) == ring6.ring_hops(b, a)
            assert ring6.ring_hops(a, b) <= H // 2
    assert ring6.ring_hops(0, 5) == 1        # wraparound beats 5 forward
    assert ring6.ring_hops(0, 3) == 3        # antipodal
    assert [ring6.ring_hops(0, d) for d in range(6)] == [0, 1, 2, 3, 2, 1]


def test_ring_path_matches_hops_and_ties(ring6):
    for a in range(6):
        for b in range(6):
            path = ring6.ring_path(a, b)
            assert path[0] == a and path[-1] == b
            assert len(path) == ring6.ring_hops(a, b) + 1
    # antipodal tie breaks toward increasing id
    assert ring6.ring_path(0, 3) == [0, 1, 2, 3]


def test_ring_path_via_takes_other_arc(ring6):
    # shorter arc 0->2 is via 1; with 1 dark, route the long way round
    assert ring6.ring_path_via(0, 2, avoid=()) == [0, 1, 2]
    assert ring6.ring_path_via(0, 2, avoid=(1,)) == [0, 5, 4, 3, 2]
    # endpoints are never checked against avoid
    assert ring6.ring_path_via(0, 2, avoid=(0, 2)) == [0, 1, 2]
    # both interiors blocked: unreachable
    assert ring6.ring_path_via(0, 3, avoid=(1, 2, 4, 5)) is None


def test_ring_relay_delay_arc_symmetry(ring6):
    """Relay delay follows the ACTUAL arc: symmetric src<->dst on the
    same arc, +inf when both arcs are blocked, and the detour arc costs
    at least the clear shorter arc."""
    from repro.core.links import LinkModel
    from repro.core.propagation import PropagationModel
    pm = PropagationModel(ring6, LinkModel())
    bits = 3.2e6
    d_fwd = pm.ring_relay_delay(bits, 0, 2, 0.0)
    d_rev = pm.ring_relay_delay(bits, 2, 0, 0.0)
    assert d_fwd > 0 and d_fwd == pytest.approx(d_rev, rel=1e-6)
    d_detour = pm.ring_relay_delay(bits, 0, 2, 0.0, avoid=(1,))
    assert d_detour > d_fwd                  # 4 hops vs 2
    assert np.isinf(pm.ring_relay_delay(bits, 0, 3, 0.0,
                                        avoid=(1, 2, 4, 5)))
    # vectorized send times keep shape and stay causal
    t0 = np.array([0.0, 600.0, 1200.0])
    dv = pm.ring_relay_delay(bits, 0, 2, t0)
    assert dv.shape == t0.shape and (dv > 0).all()
