"""Pallas kernel: block-tiled attention with online softmax (flash-style).

Grid = (B*H, Sq/BQ, Sk/BK); the key axis is innermost and sequential,
carrying the running max / denominator / accumulator in VMEM scratch.
Causal and sliding-window masks are applied per block from program ids;
fully-masked key blocks still iterate (Pallas grids are dense) but skip the
matmul via pl.when — on TPU the MXU sits idle for ~half the blocks of a
causal prefill, which is the expected 2x.

BQ/BK default to 128 — MXU-aligned (128x128 systolic array) and small enough
that q/k/v tiles + scratch fit VMEM comfortably:
(BQ+2*BK)*hd*4B + BQ*(hd+2)*4B ≈ 0.4 MB at hd=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, scale: float, bq: int, bk: int,
                  seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = kj * bk
    # block-level reachability (skip matmul when fully masked)
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window:
        reachable = jnp.logical_and(reachable,
                                    k_start + bk - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < seq_k
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                               # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (corr * acc_scr[...]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret",
                                             "bq", "bk"))
def flash_attention_flat(q, k, v, *, causal: bool = True, window: int = 0,
                         interpret: bool = True, bq: int = BQ, bk: int = BK):
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd). Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sqp, Skp = Sq + pad_q, Sk + pad_k
    grid = (BH, Sqp // bq, Skp // bk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window,
        scale=1.0 / math.sqrt(hd), bq=bq, bk=bk, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda i, j, l: (i, l, 0)),
            pl.BlockSpec((1, bk, hd), lambda i, j, l: (i, l, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j, l: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
