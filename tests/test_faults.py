"""Fault-injection / heterogeneity suite (sched/faults.FaultModel,
DESIGN.md §10) and the staleness-function zoo (core/aggregation).

Covers: FaultModel + StrategySpec construction validation, the
off-switch bit-parity contract (fault_model=None == FaultModel() ==
the PR-5 semantics — the CI-pinned gate), seeded determinism of the
fault schedule, compute-rate heterogeneity (stretched TRAIN_DONE times,
epoch-loop-vs-runtime parity preserved), eclipse availability masking,
lossy transfers with bounded retry/backoff (retry telemetry, drop after
max retries, termination under total loss, barrier rescue on drops, the
epoch loop refusing loss), the staleness zoo's eq13-default parity, and
the contention-aware trigger-window shrink.
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core import aggregation as agg
from repro.core.aggregation import (SatelliteMeta, STALENESS_FNS,
                                    asyncfleo_weights, staleness_factor)
from repro.core.links import LinkModel
from repro.fl import get_strategy
from repro.fl.strategies import StrategySpec, _STALENESS_FNS
from repro.sched import EventDrivenRuntime, FaultModel
from repro.sched.policies import AsyncFLEOPolicy, make_policy

from test_epoch_step import TinyFusedTrainer, W0

SIMKW = dict(duration_s=86400.0, train_time_s=300.0,
             use_model_bank=True, use_fused_step=True)
SLOW = LinkModel(rate_bps=10.0)          # 288-bit W0 -> 28.8 s per transfer


def _sim(name, event_driven, *, spec_kw=None, **kw):
    cfg = SimConfig(event_driven=event_driven, **{**SIMKW, **kw})
    spec = get_strategy(name)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    return FLSimulation(spec, TinyFusedTrainer(W0), None, cfg)


def _rows(hist):
    return [(r.epoch, round(r.time_s, 6), r.num_models,
             round(r.gamma, 6), r.stale_groups) for r in hist]


# ---- construction validation ------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(seed=-1), dict(loss_prob=1.5), dict(loss_prob=-0.1),
    dict(max_retries=-1), dict(retry_backoff_s=0.0),
    dict(eclipse_fraction=1.0), dict(eclipse_fraction=-0.2),
    dict(eclipse_period_s=0.0), dict(compute_rate_spread=-1.0),
    dict(compute_rates=()), dict(compute_rates=(1.0, 0.0)),
])
def test_fault_model_validation(kw):
    with pytest.raises(ValueError):
        FaultModel(**kw)


@pytest.mark.parametrize("kw", [
    dict(ps_channels=0), dict(ps_channels=-3), dict(max_in_flight=0),
    dict(group_timeouts=("bad",)), dict(group_timeouts=((0,),)),
    dict(group_timeouts=((0, -5.0),)), dict(group_timeouts=((0.5, 10.0),)),
    dict(staleness_fn="nope"), dict(agg_mode="typo"),
    dict(interval_s=0.0), dict(num_groups=0),
    dict(rx_backlog_threshold_s=-1.0), dict(rx_backlog_window_scale=0.0),
    dict(rx_backlog_window_scale=1.5),
])
def test_spec_validation_rejects(kw):
    """Malformed specs fail at construction with a clear ValueError, not
    deep in the runtime."""
    base = get_strategy("asyncfleo-gs")
    with pytest.raises(ValueError):
        dataclasses.replace(base, **kw)


def test_spec_validation_accepts_valid():
    spec = dataclasses.replace(
        get_strategy("asyncfleo-gs"), ps_channels=4, max_in_flight=3,
        group_timeouts=((-1, 900.0), (0, 1200.0)), staleness_fn="poly",
        rx_backlog_threshold_s=0.0, rx_backlog_window_scale=0.25)
    assert spec.ps_channels == 4


def test_staleness_fns_tables_in_sync():
    """strategies.py validates against a literal mirror of the canonical
    aggregation table (kept import-light) — they must not drift."""
    assert _STALENESS_FNS == STALENESS_FNS


# ---- staleness-function zoo -------------------------------------------------

def test_staleness_factor_zoo():
    # eq13: k_n / beta
    assert staleness_factor("eq13", 10, 7) == pytest.approx(0.7)
    assert staleness_factor("eq13", 10, -1) == 0.0       # never joined
    # constant: no mitigation
    assert staleness_factor("constant", 10, 0) == 1.0
    # hinge: flat 1 up to the breakpoint, then 1/(a*(d-b))
    assert staleness_factor("hinge", 6, 0) == 1.0        # d = 6 = b
    assert staleness_factor("hinge", 7, 0) == pytest.approx(1 / 10.0)
    assert staleness_factor("hinge", 16, 0) == pytest.approx(1 / 100.0)
    # poly: (1+d)^-a
    assert staleness_factor("poly", 0, 0) == 1.0
    assert staleness_factor("poly", 3, 0) == pytest.approx(0.5)
    # all zoo members give a fresh model (d=0) full weight and decay
    # monotonically with the gap
    for fn in ("constant", "hinge", "poly"):
        assert staleness_factor(fn, 5, 5) == 1.0
        vals = [staleness_factor(fn, b, 0) for b in range(0, 20)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))
    with pytest.raises(ValueError):
        staleness_factor("nope", 1, 0)


def _metas():
    return [SatelliteMeta(0, 100.0, (0, 0), 10.0, 5),     # fresh at beta=5
            SatelliteMeta(1, 100.0, (0, 0), 11.0, 2),     # stale
            SatelliteMeta(2, 50.0, (0, 0), 12.0, 0)]      # very stale


def test_asyncfleo_weights_staleness_fn():
    # per-model groups so the stale ones survive Alg. 2 selection (a
    # group with a fresh member discards its stale members)
    groups = {0: [0], 1: [1], 2: [2]}
    # eq13 explicitly == eq13 by default (the byte-identical contract)
    d0 = asyncfleo_weights(groups, _metas(), 5)
    d1 = asyncfleo_weights(groups, _metas(), 5, staleness_fn="eq13")
    np.testing.assert_array_equal(d0[1], d1[1])
    assert d0[2] == d1[2]
    # a zoo member changes the stale weighting but stays convex
    sel, w, gamma, info = asyncfleo_weights(groups, _metas(), 5,
                                            staleness_fn="poly")
    assert sel == [0, 1, 2]
    assert 0.0 < gamma <= 1.0
    assert w.sum() == pytest.approx(gamma)
    assert not np.allclose(w, d0[1])
    # constant == no mitigation: stale models keep pure size weights
    _, wc, gc, _ = asyncfleo_weights(groups, _metas(), 5,
                                     staleness_fn="constant")
    np.testing.assert_allclose(wc, gc * np.array([100, 100, 50.0]) / 250.0)


def test_staleness_fn_threads_through_simulation():
    """StrategySpec.staleness_fn reaches the committed gamma; eq13 (the
    default) is bit-identical to a spec that never heard of the field."""
    a = _sim("asyncfleo-twohap", True)
    b = _sim("asyncfleo-twohap", True, spec_kw=dict(staleness_fn="eq13"))
    c = _sim("asyncfleo-twohap", True, spec_kw=dict(staleness_fn="poly"))
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    hc = c.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))
    assert len(hc) == len(ha)        # the zoo member still runs to length


# ---- off-switch bit-parity (the CI-pinned contract) -------------------------

def test_fault_model_none_attaches_no_state():
    fls = _sim("asyncfleo-twohap", True)
    assert fls.fault is None and fls._train_scale is None


def test_null_fault_model_bit_identical():
    """fault_model=None and an all-off FaultModel() take identical code
    paths: same histories, same weights, under both drivers."""
    fm = FaultModel()
    assert fm.is_null
    for ed in (False, True):
        a = _sim("asyncfleo-twohap", ed)
        b = _sim("asyncfleo-twohap", ed, fault_model=fm)
        ha = a.run(W0, max_epochs=5)
        hb = b.run(W0, max_epochs=5)
        assert _rows(ha) == _rows(hb)
        np.testing.assert_array_equal(np.asarray(a._w_flat),
                                      np.asarray(b._w_flat))
        assert a._fused_prog.dispatches == b._fused_prog.dispatches


# ---- compute-rate heterogeneity ---------------------------------------------

def test_train_time_scale_shapes():
    fm = FaultModel(compute_rate_spread=2.0)
    s = fm.train_time_scale(40)
    assert s.shape == (40,) and (s >= 1.0).all() and (s <= 3.0).all()
    assert s.max() > 1.0
    np.testing.assert_array_equal(s, fm.train_time_scale(40))  # seeded
    assert FaultModel(compute_rate_spread=0.0).train_time_scale(40) is None
    ex = FaultModel(compute_rates=(1.0, 2.0, 3.0))
    np.testing.assert_array_equal(ex.train_time_scale(2), [1.0, 2.0])
    with pytest.raises(ValueError):
        ex.train_time_scale(5)           # fewer rates than satellites


def test_compute_spread_changes_timing_keeps_driver_parity():
    """Heterogeneous compute stretches TRAIN_DONE times (the history
    moves), but the epoch loop and the event runtime still agree exactly
    — both route through the ONE shared `_train_times`."""
    fm = FaultModel(compute_rate_spread=1.5, eclipse_fraction=0.2)
    base = _sim("asyncfleo-twohap", True).run(W0, max_epochs=4)
    a = _sim("asyncfleo-twohap", False, fault_model=fm)
    b = _sim("asyncfleo-twohap", True, fault_model=fm)
    ha = a.run(W0, max_epochs=4)
    hb = b.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches
    assert _rows(hb) != _rows(base)      # the faults actually bite


# ---- eclipse availability ---------------------------------------------------

def test_eclipse_masks_visibility():
    fm = FaultModel(eclipse_fraction=0.3)
    base = _sim("asyncfleo-twohap", True)
    ecl = _sim("asyncfleo-twohap", True, fault_model=fm)
    assert ecl.timeline.grid.sum() < base.timeline.grid.sum()
    # deterministic: same seed -> same mask
    ecl2 = _sim("asyncfleo-twohap", True, fault_model=fm)
    np.testing.assert_array_equal(ecl.timeline.grid, ecl2.timeline.grid)
    # availability_mask itself: each sat dark for ~the configured fraction
    mask = fm.availability_mask(np.arange(0.0, 54000.0, 10.0), 8)
    dark = 1.0 - mask.mean(axis=0)
    np.testing.assert_allclose(dark, 0.3, atol=0.02)
    assert FaultModel().availability_mask(np.zeros(3), 4) is None


# ---- lossy transfers: retry / backoff / drop --------------------------------

def test_transfer_fails_deterministic_schedule():
    fm = FaultModel(loss_prob=0.4)
    draws = [fm.transfer_fails(s, r, a)
             for s in range(8) for r in range(4) for a in range(3)]
    draws2 = [fm.transfer_fails(s, r, a)
              for s in range(8) for r in range(4) for a in range(3)]
    assert draws == draws2 and any(draws) and not all(draws)
    # keyed draws: a different seed gives a different schedule
    fm2 = FaultModel(seed=7, loss_prob=0.4)
    assert draws != [fm2.transfer_fails(s, r, a)
                     for s in range(8) for r in range(4) for a in range(3)]
    assert FaultModel(loss_prob=0.0).transfer_fails(0, 0, 0) is False
    assert FaultModel(loss_prob=1.0).transfer_fails(0, 0, 0) is True
    assert fm.retry_delay_s(0) == pytest.approx(120.0)
    assert fm.retry_delay_s(3) == pytest.approx(960.0)


def test_lossy_transfers_retry_and_recover():
    """30% loss with generous retries: failures and retransmissions show
    up in the telemetry, every epoch still commits, and the whole run is
    reproducible (the seeded schedule is independent of event order)."""
    fm = FaultModel(loss_prob=0.3, max_retries=5, retry_backoff_s=60.0)
    a = _sim("asyncfleo-twohap", True, fault_model=fm)
    rt = EventDrivenRuntime(a)
    ha = rt.run(W0, max_epochs=5)
    assert len(ha) == 5
    assert rt.stats["transfers_failed"] > 0
    assert rt.stats["transfer_retries"] > 0
    assert rt.events.counts["TRANSFER_FAILED"] == rt.stats["transfers_failed"]
    b = _sim("asyncfleo-twohap", True, fault_model=fm)
    rtb = EventDrivenRuntime(b)
    hb = rtb.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    assert rt.stats == rtb.stats
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))


def test_total_loss_drops_after_max_retries_and_terminates():
    """loss_prob=1: every chain burns its retries and drops; rounds
    resolve as 0-model commits (the on_expected_drop rescue) instead of
    hanging, and the run terminates at max_epochs."""
    fm = FaultModel(loss_prob=1.0, max_retries=1, retry_backoff_s=60.0)
    fls = _sim("asyncfleo-twohap", True, fault_model=fm)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=4)
    assert [r.num_models for r in hist] == [0, 0, 0, 0]
    assert rt.stats["dropped_after_max_retries"] > 0
    # every failed transfer either retried or dropped — nothing leaks
    assert rt.stats["transfers_failed"] == (
        rt.stats["transfer_retries"]
        + rt.stats["dropped_after_max_retries"]
        + rt.stats["dropped_unreachable"])


def test_sync_barrier_rescued_on_drops():
    """A barrier round whose transfers all drop must not stall until
    sync_stall_s — on_expected_drop fires the trigger as soon as nothing
    is left in flight."""
    fm = FaultModel(loss_prob=1.0, max_retries=0)
    fls = _sim("fedisl", True, fault_model=fm)
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=3)
    assert len(hist) == 3
    assert all(r.num_models == 0 for r in hist)
    assert rt.stats["dropped_after_max_retries"] > 0


def test_partial_loss_fewer_models_than_baseline():
    fm = FaultModel(loss_prob=0.5, max_retries=1, retry_backoff_s=600.0)
    base = _sim("asyncfleo-twohap", True).run(W0, max_epochs=4)
    rt = EventDrivenRuntime(_sim("asyncfleo-twohap", True, fault_model=fm))
    hist = rt.run(W0, max_epochs=4)
    n_base = sum(r.num_models for r in base)
    n_fault = sum(r.num_models for r in hist)
    assert 0 < n_fault < n_base
    assert rt.stats["dropped_after_max_retries"] > 0


def test_loss_requires_event_runtime():
    fm = FaultModel(loss_prob=0.2)
    fls = _sim("asyncfleo-twohap", False, fault_model=fm)
    with pytest.raises(ValueError, match="event-driven"):
        fls.run(W0, max_epochs=2)


def test_retries_reenter_channel_pools():
    """With finite ps_channels, retransmissions charge fresh rx grants:
    the lossy run books strictly more rx grants than the loss-free run
    of the same scenario."""
    kw = dict(link=SLOW, spec_kw=dict(ps_channels=2))
    a = _sim("asyncfleo-twohap", True, **kw)
    ra = EventDrivenRuntime(a)
    ra.run(W0, max_epochs=4)
    fm = FaultModel(loss_prob=0.4, max_retries=4, retry_backoff_s=60.0)
    b = _sim("asyncfleo-twohap", True, fault_model=fm, **kw)
    rb = EventDrivenRuntime(b)
    rb.run(W0, max_epochs=4)
    assert rb.stats["transfer_retries"] > 0
    assert (rb.contention_stats()["rx"]["grants"]
            > ra.contention_stats()["rx"]["grants"])


# ---- contention-aware trigger windows (off by default) ----------------------

def test_window_shrink_unit():
    """Backlog above the threshold scales the window; below leaves it
    untouched; threshold None is the bit-identical off switch."""
    fls = _sim("asyncfleo-twohap", True,
               spec_kw=dict(ps_channels=1, rx_backlog_threshold_s=10.0,
                            rx_backlog_window_scale=0.5))
    rt = EventDrivenRuntime(fls)
    pol = rt.policy
    assert isinstance(pol, AsyncFLEOPolicy)
    assert pol.rx_backlog_threshold_s == 10.0
    rnd = SimpleNamespace(sink=0, t_start=0.0, trigger_scheduled=None,
                          expected=[(1.0, 0, 0)], group_first={})
    w = rt.sim.agg_timeout_s
    assert pol.on_arrival(rt, rnd, 100.0) == pytest.approx(100.0 + w)
    fls.plan.contention.grant_rx(0, 50.0, 500.0)    # load the rx pool
    rnd.trigger_scheduled = None
    assert pol.on_arrival(rt, rnd, 100.0) == pytest.approx(100.0 + 0.5 * w)
    assert rt.stats["shrunk_windows"] == 1
    # default spec: the field stays None and split delegates to _trigger
    off = make_policy(get_strategy("asyncfleo-gs"))
    assert off.rx_backlog_threshold_s is None


def test_window_shrink_end_to_end():
    """Shrink enabled under heavy contention: the run completes, commits
    earlier-or-equal windows, and counts the shrinks."""
    base = _sim("asyncfleo-twohap", True, link=SLOW,
                spec_kw=dict(ps_channels=1))
    hb = base.run(W0, max_epochs=4)
    tight = _sim("asyncfleo-twohap", True, link=SLOW,
                 spec_kw=dict(ps_channels=1, rx_backlog_threshold_s=0.0,
                              rx_backlog_window_scale=0.25))
    rt = EventDrivenRuntime(tight)
    ht = rt.run(W0, max_epochs=4)
    assert len(ht) == 4
    assert rt.stats["shrunk_windows"] > 0
    assert ht[0].time_s <= hb[0].time_s    # first window can only shrink
