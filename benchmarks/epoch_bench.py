"""End-to-end epoch benchmark: fused epoch-step vs ModelBank vs legacy.

Measures, at constellation sizes S in {40, 200, 1000}:

* the server-side **aggregation + grouping segment** — from the trainer's
  stacked vmap output to the new global model — on both paths.  The legacy
  path pays the seed's per-epoch tax (device_get, per-satellite pytree
  unstack, per-leaf Python loops in grouping/aggregation); the ModelBank
  path keeps the (C, N) stack on device end to end.  Parity between the two
  global models is asserted (allclose, atol 1e-5).
* the vectorized **propagation timing segment** (downlink + uplink_many).
* the **end-to-end simulated epoch** wall time and sats/sec via
  ``FLSimulation`` with a noise trainer, in three modes: ``legacy``
  (host pytrees), ``bank`` (device-resident stack, chained dispatches) and
  ``fused`` (one donated jitted program per epoch, DESIGN.md §6) — plus a
  per-section host wall-time breakdown (timing / train / step / agg /
  group / eval seconds per epoch) so regressions are attributable.

Epoch timings are split into a first **warmup** epoch (tracing+compile;
reported separately) and the steady-state epochs that follow — the fused
program trades a slightly costlier compile for a much cheaper steady
state, which is what a multi-day simulation actually runs.

``--fail-if-slower`` exits nonzero when the fused steady-state epoch is
slower than legacy at any benchmarked S (the CI smoke gate).

Writes ``BENCH_epoch.json`` next to the repo root so successive PRs have a
perf trajectory.

Usage:  PYTHONPATH=src python benchmarks/epoch_bench.py [--sizes 40,200]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import SatelliteMeta
from repro.core.constellation import WalkerDelta, make_ps_nodes
from repro.core.grouping import GroupingState
from repro.core.links import LinkModel
from repro.core.modelbank import FlatSpec, ModelBank
from repro.core.propagation import PropagationModel
from repro.core.simulator import FLSimulation, SimConfig
from repro.core.topology import RingOfStars
from repro.core.visibility import VisibilityTimeline
from repro.fl.strategies import get_strategy

SATS_PER_ORBIT = 8
N_LAYERS = 8              # transformer-style pytree: the leaf count (not
D, FF = 24, 96            # just the param count) drives the legacy path's
VOCAB = 400               # per-leaf Python churn — ~67 leaves, ~66k params


def make_model(key):
    """LM-shaped federated model (mirrors the LMPool workload)."""
    leaves = {"embed": jax.random.normal(key, (VOCAB, D), jnp.float32) * 0.1}
    for i in range(N_LAYERS):
        k = jax.random.fold_in(key, i + 1)
        blk = {}
        for j, (name, shape) in enumerate([
                ("wq", (D, D)), ("wk", (D, D)), ("wv", (D, D)),
                ("wo", (D, D)), ("w1", (D, FF)), ("w2", (FF, D)),
                ("ln1", (D,)), ("ln2", (D,))]):
            blk[name] = jax.random.normal(jax.random.fold_in(k, j),
                                          shape, jnp.float32) * 0.1
        leaves[f"layer{i}"] = blk
    leaves["ln_f"] = jnp.ones((D,), jnp.float32)
    leaves["head"] = jax.random.normal(jax.random.fold_in(key, 99),
                                       (D, VOCAB), jnp.float32) * 0.1
    return leaves


def constellation_of(s: int) -> WalkerDelta:
    assert s % SATS_PER_ORBIT == 0
    return WalkerDelta(num_orbits=s // SATS_PER_ORBIT,
                       sats_per_orbit=SATS_PER_ORBIT, altitude_m=2000e3)


class NoiseTrainer:
    """'Training' = global model + a deterministic per-satellite
    perturbation, via one jitted vmap — a stand-in for the real pools: the
    bench measures the SERVER path (timing, grouping, aggregation, copies,
    dispatch discipline), so 'training' must be cheap and cost-identical
    across the legacy/bank/fused paths (a PRNG-heavy trainer makes every
    path converge to threefry throughput and hides the server costs this
    trajectory tracks).  Exposes all three trainer protocols."""

    def __init__(self, w0, scale: float = 0.05):
        self.spec = FlatSpec.of(w0)
        self._scale = scale

        def _perturb(flat, ids, seed):
            # distinct per-(sat, seed) models via a rank-1 shift: purely
            # memory-bound (no transcendentals — XLA CPU runs those
            # single-threaded and they would dominate every path equally,
            # hiding the server costs this bench compares)
            phase = (ids.astype(jnp.float32) * 0.7548777
                     + seed.astype(jnp.float32) * 0.1327) % 1.0
            return flat[None, :] * 0.95 + (scale * phase)[:, None]

        self._perturb = _perturb
        self._many = jax.jit(_perturb)

    def data_size(self, sat: int) -> int:
        return 100 + (sat % 7) * 10

    def epoch_inputs(self, ids_np):
        return None

    def epoch_train_fn(self):
        spec, perturb = self.spec, self._perturb

        def _fn(params, inputs, ids, seed):
            flat = spec.flatten(params)
            return perturb(flat, ids, seed), jnp.zeros(ids.shape[0])
        return _fn

    def train_many_stacked(self, sats, params, seed: int):
        from repro.fl.client import _pad_ids
        ids, n = _pad_ids(list(sats))          # bucketized: O(log S) traces
        flat = self.spec.flatten(params)
        stack = self._many(flat, jnp.asarray(ids),
                           jnp.uint32(np.uint32(seed)))[:n]
        return ModelBank(self.spec, stack), np.zeros(n)

    def train_many(self, sats, params, seed: int):
        bank, losses = self.train_many_stacked(sats, params, seed)
        return bank.to_pytrees(), losses         # the seed's per-epoch tax


def _timeit(fn, iters: int = 7) -> float:
    """Median of per-iteration wall times (robust on noisy shared CPUs)."""
    import gc
    fn()                                          # warmup / trace
    times = []
    for _ in range(iters):
        gc.collect()
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _make_metas(S: int, beta: int, rng) -> List[SatelliteMeta]:
    return [SatelliteMeta(s, 100.0 + (s % 7) * 10, (0.0, 0.0),
                          ts=float(s),
                          epoch=beta if rng.random() < 0.7
                          else int(rng.integers(0, beta)))
            for s in range(S)]


def bench_agg_grouping(S: int, beta: int = 4, seed: int = 0) -> Dict:
    """Stacked vs legacy server segment from the same trained stack."""
    key = jax.random.PRNGKey(seed)
    w0 = make_model(key)
    trainer = NoiseTrainer(w0)
    spec = trainer.spec
    bank, _ = trainer.train_many_stacked(list(range(S)), w0, seed=seed)
    jax.block_until_ready(bank.stack)
    rng = np.random.default_rng(seed)
    metas = _make_metas(S, beta, rng)
    orbit_of = np.arange(S) // SATS_PER_ORBIT
    num_orbits = S // SATS_PER_ORBIT

    # per-run state both paths get for free inside FLSimulation: the
    # grouping reference (set once at w0) and each path's natural base
    # representation (the simulator caches w_flat across epochs)
    ref_state = GroupingState(num_groups=3)
    ref_state.set_reference(w0)
    ref_np, ref_dev = ref_state.ref_flat, ref_state._ref_dev
    w0_flat = spec.flatten(w0)
    jax.block_until_ready(w0_flat)

    def run_path(stacked: bool):
        gs = GroupingState(ref_flat=ref_np, num_groups=3)
        gs._ref_dev = ref_dev
        groups: Dict[int, List[int]] = {}
        if stacked:
            models = bank
            orbit_indices = {o: list(np.flatnonzero(orbit_of == o))
                             for o in range(num_orbits)}
            orbit_group = gs.observe_orbits(orbit_indices, bank,
                                            [m.size for m in metas])
            for o, idxs in orbit_indices.items():
                groups.setdefault(orbit_group[o], []).extend(idxs)
        else:
            models = bank.to_pytrees()           # the seed's per-epoch tax
            for orbit in range(num_orbits):
                idxs = list(np.flatnonzero(orbit_of == orbit))
                gi = gs.observe_orbit(orbit, [models[j] for j in idxs],
                                      [metas[j].size for j in idxs])
                groups.setdefault(gi, []).extend(idxs)
        w_new, _info = agg.asyncfleo_aggregate(
            w0_flat if stacked else w0, groups, models, metas, beta)
        if stacked:
            w_new = spec.unflatten(w_new)
        jax.block_until_ready(w_new)
        return w_new

    w_legacy = run_path(False)
    w_bank = run_path(True)
    err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
              for a, b in zip(jax.tree_util.tree_leaves(w_legacy),
                              jax.tree_util.tree_leaves(w_bank)))
    assert err <= 1e-5, f"stacked/legacy parity violated: max|diff|={err}"

    # interleave the two paths so shared-host noise hits both equally;
    # medians of the paired samples give a stable ratio
    import gc
    t_l, t_b = [], []
    for _ in range(7):
        gc.collect()
        t0 = time.perf_counter()
        run_path(False)
        t_l.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_path(True)
        t_b.append(time.perf_counter() - t0)
    t_legacy, t_bank = float(np.median(t_l)), float(np.median(t_b))
    return {"S": S, "legacy_s": t_legacy, "bank_s": t_bank,
            "speedup": t_legacy / t_bank, "parity_max_abs_err": err}


def bench_propagation(S: int) -> Dict:
    c = constellation_of(S)
    tl = VisibilityTimeline(c, make_ps_nodes("twohap"), 6 * 3600.0, 30.0)
    topo = RingOfStars(c, tl.nodes, tl)
    prop = PropagationModel(topo, LinkModel())
    bits = 30e3 * 32

    t_down = _timeit(lambda: prop.downlink_times(0.0, bits, 0))
    recv = prop.downlink_times(0.0, bits, 0)
    sats = np.flatnonzero(np.isfinite(recv))
    t_up = _timeit(lambda: prop.uplink_many(sats, recv[sats] + 600.0, bits, 1))
    return {"S": S, "downlink_s": t_down,
            "uplink_many_s": t_up, "participants": int(len(sats))}


MODES = (("legacy", False, False), ("bank", True, False),
         ("fused", True, True))


def bench_epoch(S: int, epochs: int = 6) -> Dict:
    # 6 epochs: long enough that steady-state epochs (grouping known, no
    # distance block) outweigh the establishment epochs, as in a real
    # multi-day simulation; short enough for the CI smoke
    key = jax.random.PRNGKey(0)
    w0 = make_model(key)
    out = {"S": S}
    for label, use_bank, use_fused in MODES:
        trainer = NoiseTrainer(w0)        # jit/program caches live here
        per_epoch = []
        for _rep in range(2):             # rep 0 = cold (trace+compile)
            sim = SimConfig(duration_s=86400.0, dt_s=30.0,
                            train_time_s=300.0, use_model_bank=use_bank,
                            use_fused_step=use_fused)
            fls = FLSimulation(get_strategy("asyncfleo-twohap"),
                               trainer, None, sim,
                               constellation=constellation_of(S))
            t0 = time.perf_counter()
            hist = fls.run(w0, max_epochs=epochs)
            if getattr(fls, "_w_flat", None) is not None:
                jax.block_until_ready(fls._w_flat)   # drain in-flight work
            per_epoch.append((time.perf_counter() - t0)
                             / max(len(hist), 1))
        out[f"epoch_{label}_cold_s"] = per_epoch[0]
        out[f"epoch_{label}_s"] = per_epoch[1]
        out[f"sats_per_sec_{label}"] = S / per_epoch[1]
        # host wall-time attribution of the steady-state run, per epoch
        out[f"breakdown_{label}"] = {
            k: v / max(len(hist), 1)
            for k, v in fls.segment_seconds.items() if v > 0.0}
    out["epoch_speedup"] = out["epoch_legacy_s"] / out["epoch_bank_s"]
    out["epoch_speedup_fused"] = (out["epoch_legacy_s"]
                                  / out["epoch_fused_s"])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="40,200,1000",
                    help="comma-separated constellation sizes")
    ap.add_argument("--out", default="BENCH_epoch.json")
    ap.add_argument("--skip-epoch", action="store_true",
                    help="only the agg+grouping / propagation segments")
    ap.add_argument("--fail-if-slower", action="store_true",
                    help="exit 1 if the fused steady-state epoch is slower "
                         "than 0.9x legacy at any benchmarked S (CI gate; "
                         "the 10%% tolerance absorbs shared-runner noise)")
    args = ap.parse_args()
    try:
        sizes = [int(s) for s in args.sizes.split(",")]
    except ValueError:
        ap.error(f"--sizes must be comma-separated integers, got {args.sizes!r}")
    for s in sizes:
        if s <= 0 or s % SATS_PER_ORBIT:
            ap.error(f"--sizes entries must be positive multiples of "
                     f"{SATS_PER_ORBIT} (sats per orbit), got {s}")

    report = {"sizes": sizes, "agg_grouping": [], "propagation": [],
              "epoch": []}
    for S in sizes:
        r = bench_agg_grouping(S)
        print(f"S={S:5d} agg+grouping: legacy {r['legacy_s']*1e3:8.1f} ms  "
              f"bank {r['bank_s']*1e3:8.1f} ms  speedup {r['speedup']:.1f}x  "
              f"max_err {r['parity_max_abs_err']:.2e}")
        report["agg_grouping"].append(r)
        p = bench_propagation(S)
        print(f"S={S:5d} propagation:  downlink {p['downlink_s']*1e3:8.1f} ms"
              f"  uplink_many {p['uplink_many_s']*1e3:8.1f} ms")
        report["propagation"].append(p)
        if not args.skip_epoch:
            e = bench_epoch(S)
            print(f"S={S:5d} epoch e2e:    legacy {e['epoch_legacy_s']:6.2f} s"
                  f"  bank {e['epoch_bank_s']:6.2f} s  "
                  f"fused {e['epoch_fused_s']:6.2f} s  "
                  f"({e['sats_per_sec_fused']:.0f} sats/s, "
                  f"bank {e['epoch_speedup']:.1f}x, "
                  f"fused {e['epoch_speedup_fused']:.1f}x)")
            for label, _b, _f in MODES:
                bd = ", ".join(f"{k} {v*1e3:.1f}ms"
                               for k, v in e[f"breakdown_{label}"].items())
                print(f"        breakdown {label:6s}: {bd}")
            report["epoch"].append(e)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if args.fail_if_slower:
        slow = [e["S"] for e in report["epoch"]
                if e["epoch_speedup_fused"] < 0.9]
        if slow:
            raise SystemExit(
                f"fused e2e epoch slower than legacy at S={slow}")


if __name__ == "__main__":
    main()
