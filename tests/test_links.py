import numpy as np
import pytest

from repro.core.links import LinkModel, dbm_to_watt, dbi_to_linear, model_bits


def test_dbm():
    assert abs(dbm_to_watt(30.0) - 1.0) < 1e-9
    assert abs(dbm_to_watt(40.0) - 10.0) < 1e-8
    assert abs(dbi_to_linear(0.0) - 1.0) < 1e-12


def test_fspl_quadratic():
    lm = LinkModel()
    assert lm.fspl(2000e3) / lm.fspl(1000e3) == pytest.approx(4.0)


def test_snr_and_shannon_monotonic():
    lm = LinkModel()
    d = np.array([500e3, 1000e3, 2000e3, 4000e3])
    snrs = [lm.snr(x) for x in d]
    rates = [lm.shannon_rate(x) for x in d]
    assert all(a > b for a, b in zip(snrs, snrs[1:]))
    assert all(a > b for a, b in zip(rates, rates[1:]))
    assert all(r > 0 for r in rates)


def test_delays():
    lm = LinkModel()
    # paper setting: fixed 16 Mb/s
    assert lm.transmission_delay(16e6) == pytest.approx(1.0)
    assert lm.propagation_delay(299_792_458.0) == pytest.approx(1.0)
    total = lm.total_delay(16e6, 2000e3)
    assert total > lm.transmission_delay(16e6)


def test_model_bits():
    import numpy as np
    tree = {"a": np.zeros((10, 10)), "b": np.zeros((5,))}
    assert model_bits(tree) == 105 * 32


def test_fso_link():
    from repro.core.links import fso_link
    l = fso_link()
    # FSO moves a 3.2 Mb CNN model in microseconds vs 0.2 s at 16 Mb/s RF
    assert l.transmission_delay(3.2e6) < 1e-3
    assert LinkModel().transmission_delay(3.2e6) == pytest.approx(0.2)
    assert l.carrier_freq_hz > 1e14                 # optical


def test_busy_interval_edge_times():
    """Channel occupancy is the transmission time ONLY: propagation and
    processing delay the payload, not the transmitter (DESIGN.md §9)."""
    lm = LinkModel()
    t0, t1 = lm.busy_interval(100.0, 16e6)
    assert t0 == 100.0                       # starts exactly at t_start
    assert t1 - t0 == pytest.approx(lm.transmission_delay(16e6))
    # strictly shorter than the payload's end-to-end latency
    assert t1 - t0 < lm.total_delay(16e6, 2000e3)
    # zero-bit transfer: a zero-length interval anchored at t_start
    z0, z1 = lm.busy_interval(7.5, 0.0)
    assert z0 == z1 == 7.5
    # occupancy scales linearly with payload and inversely with rate
    a = lm.busy_interval(0.0, 32e6)
    b = lm.busy_interval(0.0, 16e6)
    assert a[1] == pytest.approx(2 * b[1])
    fast = LinkModel(rate_bps=32e6).busy_interval(0.0, 32e6)
    assert fast[1] == pytest.approx(b[1])
