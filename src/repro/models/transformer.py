"""Decoder/encoder transformer assembly with scan-over-layers.

Covers families: dense (llama/qwen/granite/starcoder), moe (+MLA for
DeepSeek-V2), vlm (prefix patch embeddings), audio (bidirectional encoder,
masked prediction).  SSM/hybrid live in rwkv.py / mamba.py and are assembled
in registry.py.

All layer stacks are ``jax.lax.scan`` over stacked params (leading ``L``
axis) with optional remat — this keeps HLO size and compile time O(1) in
depth, which matters for the 512-device dry-run on a CPU host.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE

MAX_POS_EMBED = 32768     # learned abs-pos table for non-RoPE encoders


def _stacked_init(fn, key, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(key, n))


def init_layer(key, cfg: ModelConfig, *, moe_layer: bool):
    ks = jax.random.split(key, 3)
    p = {"ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,))}
    if cfg.use_mla:
        p["attn"] = MOE.init_mla(ks[0], cfg)
    else:
        p["attn"] = L.init_attention(ks[0], cfg)
    if moe_layer:
        p["ffn"] = MOE.init_moe_ffn(ks[1], cfg)
    else:
        p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"embed": L.init_embedding(ks[0], cfg),
         "final_norm": jnp.ones((cfg.d_model,))}
    n_lead = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.num_layers - n_lead
    if n_lead:
        p["lead_layers"] = _stacked_init(
            functools.partial(init_layer, cfg=cfg, moe_layer=False), ks[1], n_lead)
    p["layers"] = _stacked_init(
        functools.partial(init_layer, cfg=cfg, moe_layer=cfg.is_moe), ks[2], n_scan)
    if not cfg.use_rope and cfg.is_encoder_only:
        p["pos_embed"] = L.embed_init(ks[3], (MAX_POS_EMBED, cfg.d_model))
    return p


def _layer_apply(lp, cfg: ModelConfig, x, positions, cache, *, moe_layer: bool,
                 window: int, impl: str, q_chunks: int = 1):
    h = L.rms_norm(x, lp["ln1"])
    if cfg.use_mla:
        att, new_cache = MOE.mla_attention(lp["attn"], cfg, h, positions, cache,
                                           window=window, q_chunks=q_chunks)
    else:
        att, new_cache = L.attention(lp["attn"], cfg, h, positions, cache,
                                     window=window, impl=impl,
                                     q_chunks=q_chunks)
    x = x + att
    h = L.rms_norm(x, lp["ln2"])
    if moe_layer:
        f, aux = MOE.moe_ffn(lp["ffn"], cfg, h)
    else:
        f, aux = L.mlp(lp["ffn"], h), jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


def _embed_inputs(params, cfg: ModelConfig, batch, dtype):
    """Returns (x (B,S,d), positions (B,S))."""
    if cfg.frontend == "audio_stub":
        x = batch["frame_embeds"].astype(dtype)      # conv frontend is a stub
    else:
        x = L.embed(params["embed"], cfg, batch["tokens"], dtype)
        if cfg.frontend == "vision_stub" and "prefix_embeds" in batch:
            x = jnp.concatenate([batch["prefix_embeds"].astype(dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if "pos_embed" in params:
        x = x + params["pos_embed"].astype(dtype)[positions]
    return x, positions


def forward(params, cfg: ModelConfig, batch, *, window: int = 0,
            impl: str = "xla", q_chunks: int = 1):
    """Full-sequence forward (train / prefill without cache).
    Returns (logits (B,S,V), aux_loss)."""
    dtype = jnp.dtype(cfg.dtype)
    x, positions = _embed_inputs(params, cfg, batch, dtype)

    aux_total = jnp.zeros((), jnp.float32)

    def make_body(moe_layer):
        def body(x, lp):
            x, _, aux = _layer_apply(lp, cfg, x, positions, None,
                                     moe_layer=moe_layer, window=window,
                                     impl=impl, q_chunks=q_chunks)
            return x, aux
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        return body

    if "lead_layers" in params:
        x, auxs = jax.lax.scan(make_body(False), x, params["lead_layers"])
        aux_total = aux_total + auxs.sum()
    x, auxs = jax.lax.scan(make_body(cfg.is_moe), x, params["layers"])
    aux_total = aux_total + auxs.sum()

    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["embed"], cfg, x)
    return logits, aux_total


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    """Stacked per-layer decode cache."""
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Lr = cfg.num_layers
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((Lr, batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((Lr, batch, cache_len, cfg.rope_head_dim), dtype),
            "index": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((Lr, batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((Lr, batch, cache_len, KV, hd), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def _split_cache(cache):
    idx = cache["index"]
    leaves = {k: v for k, v in cache.items() if k != "index"}
    return leaves, idx


def decode_step(params, cfg: ModelConfig, cache, tokens, *, window: int = 0):
    """One decode step. tokens: (B,1). Returns (logits (B,1,V), new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], cfg, tokens, dtype)
    leaves, idx = _split_cache(cache)
    positions = None   # per-layer attention derives positions from the index

    def body(x, inp):
        lp, cache_l = inp
        cache_l = dict(cache_l, index=idx)
        x, new_cache, _ = _layer_apply(
            lp, cfg, x, positions, cache_l,
            moe_layer=("router" in lp.get("ffn", {})), window=window, impl="xla")
        new_leaves = {k: v for k, v in new_cache.items() if k != "index"}
        return x, new_leaves

    if "lead_layers" in params:
        n_lead = jax.tree_util.tree_leaves(params["lead_layers"])[0].shape[0]
        lead_leaves = {k: v[:n_lead] for k, v in leaves.items()}
        rest_leaves = {k: v[n_lead:] for k, v in leaves.items()}
        x, new_lead = jax.lax.scan(body, x, (params["lead_layers"], lead_leaves))
        x, new_rest = jax.lax.scan(body, x, (params["layers"], rest_leaves))
        new_leaves = {k: jnp.concatenate([new_lead[k], new_rest[k]], axis=0)
                      for k in new_lead}
    else:
        x, new_leaves = jax.lax.scan(body, x, (params["layers"], leaves))

    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["embed"], cfg, x)
    new_cache = dict(new_leaves, index=idx + 1)
    return logits, new_cache
