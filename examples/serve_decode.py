"""Serving example: batched autoregressive decoding with KV/state caches.

Demonstrates the serve_step path the dry-run lowers for decode_32k /
long_500k — including the sliding-window ring-buffer cache (dense archs) and
O(1) recurrent state (RWKV/hybrid).

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-7b --tokens 32
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config, applicable, get_shape
from repro.models import registry as R


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args()

    full_cfg = get_config(args.arch)
    if not applicable(full_cfg, get_shape("decode_32k")):
        print(f"{args.arch} is encoder-only: no decode step (DESIGN.md)")
        return
    cfg = full_cfg.reduced().replace(remat=False, dtype="float32")
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    cache = R.init_cache(cfg, args.batch, args.cache_len, jnp.float32)

    step = jax.jit(lambda c, t: R.decode_step(params, cfg, c, t,
                                              window=args.window))
    toks = jnp.ones((args.batch, 1), jnp.int32)
    # prefill a short prompt token-by-token, then greedy-decode
    t0 = time.time()
    outs = []
    for i in range(args.tokens):
        logits, cache = step(cache, toks)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs.append(np.asarray(toks[:, 0]))
    dt = time.time() - t0
    gen = np.stack(outs, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{args.arch}: generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s on CPU, reduced config)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
