"""hubert-xlarge — encoder-only audio transformer. [arXiv:2106.07447]

Conv feature extractor is an ``audio_stub`` frontend (precomputed frame
embeddings); the 48-layer encoder + masked-prediction head are real.
vocab_size=504 is the k-means codebook size for masked-unit prediction.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False, use_rope=False,     # learned/conv pos — we use sinusoidal-free abs pos
    frontend="audio_stub",
    citation="arXiv:2106.07447",
)
