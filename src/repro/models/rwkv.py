"""RWKV6 ("Finch") — attention-free with data-dependent decay [arXiv:2404.05892].

Per layer: a time-mix block (token-shift interpolation with LoRA-produced
data-dependent mixing coefficients, data-dependent per-channel decay
``w = exp(-exp(w0 + lora(x)))``, WKV linear recurrence with bonus ``u``) and a
channel-mix block (squared-ReLU FFN with receptance gate).

Deviation noted in DESIGN.md: we use RMSNorm where upstream uses LayerNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import scan_ops

TM_LORA = 32     # time-mix lora rank (5 heads of it)
TD_LORA = 64     # decay lora rank


def init_layer(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.ssm_heads
    hd = d // H
    ks = jax.random.split(key, 16)
    return {
        "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
        # token-shift mixing
        "mu_base": jnp.zeros((d,)),
        "mu": jnp.zeros((5, d)),
        "tm_w1": L.dense_init(ks[0], (d, 5 * TM_LORA)),
        "tm_w2": L.dense_init(ks[1], (5, TM_LORA, d), in_axis_size=TM_LORA),
        # data-dependent decay
        "w0": jnp.full((d,), -0.6931),          # exp(-exp(w0)) ~ 0.5 halflife-ish
        "td_w1": L.dense_init(ks[2], (d, TD_LORA)),
        "td_w2": L.dense_init(ks[3], (TD_LORA, d), in_axis_size=TD_LORA),
        # projections
        "tm_wr": L.dense_init(ks[4], (d, d)),
        "tm_wk": L.dense_init(ks[5], (d, d)),
        "tm_wv": L.dense_init(ks[6], (d, d)),
        "tm_wg": L.dense_init(ks[7], (d, d)),
        "tm_wo": L.dense_init(ks[8], (d, d)),
        "u": jnp.zeros((H, hd)),                 # bonus ("time_faaaa")
        "gn_scale": jnp.ones((d,)), "gn_bias": jnp.zeros((d,)),
        # channel mix
        "cm_mu_r": jnp.zeros((d,)), "cm_mu_k": jnp.zeros((d,)),
        "cm_wr": L.dense_init(ks[9], (d, d)),
        "cm_wk": L.dense_init(ks[10], (d, cfg.d_ff)),
        "cm_wv": L.dense_init(ks[11], (cfg.d_ff, d), in_axis_size=cfg.d_ff),
    }


def _shift(x, prev):
    """Token shift: x_{t-1}, with ``prev`` (B,d) as the t=-1 value."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def time_mix(p, cfg: ModelConfig, x, prev_x, wkv_state, *, chunked=True,
             impl="jnp"):
    """x: (B,S,d). Returns (out, last_x (B,d), new_wkv_state)."""
    B, S, d = x.shape
    H = cfg.ssm_heads
    hd = d // H
    dt = x.dtype

    xs = _shift(x, prev_x)
    dx = xs - x
    xxx = x + dx * p["mu_base"].astype(dt)
    lora = jnp.tanh(xxx @ p["tm_w1"].astype(dt)).reshape(B, S, 5, TM_LORA)
    offs = jnp.einsum("bsfr,frd->fbsd", lora, p["tm_w2"].astype(dt))   # (5,B,S,d)
    mixed = x[None] + dx[None] * (p["mu"].astype(dt)[:, None, None] + offs)
    xw, xk, xv, xr, xg = mixed

    ww = p["w0"].astype(jnp.float32) + (jnp.tanh(xw @ p["td_w1"].astype(dt))
                                        @ p["td_w2"].astype(dt)).astype(jnp.float32)
    log_decay = -jnp.exp(ww)                                           # (B,S,d) <= 0

    r = (xr @ p["tm_wr"].astype(dt)).reshape(B, S, H, hd)
    k = (xk @ p["tm_wk"].astype(dt)).reshape(B, S, H, hd)
    v = (xv @ p["tm_wv"].astype(dt)).reshape(B, S, H, hd)
    g = xg @ p["tm_wg"].astype(dt)
    ld = log_decay.reshape(B, S, H, hd)

    scan = scan_ops.chunked_scan if chunked else scan_ops.recurrent_scan
    kw = dict(include_current=False, bonus=p["u"])
    if chunked:
        kw.update(chunk=min(cfg.chunk_size, S), impl=impl)
    y, new_state = scan(r, k, v, ld, wkv_state, **kw)

    y = L.group_norm_heads(y, p["gn_scale"].reshape(H, hd), p["gn_bias"].reshape(H, hd))
    y = y.reshape(B, S, d) * jax.nn.silu(g)
    return y @ p["tm_wo"].astype(dt), x[:, -1], new_state


def time_mix_step(p, cfg: ModelConfig, x, prev_x, wkv_state):
    """Single-token decode. x: (B,1,d)."""
    y, last_x, st = time_mix(p, cfg, x, prev_x, wkv_state, chunked=False)
    return y, last_x, st


def channel_mix(p, x, prev_x):
    dt = x.dtype
    xs = _shift(x, prev_x)
    dx = xs - x
    xr = x + dx * p["cm_mu_r"].astype(dt)
    xk = x + dx * p["cm_mu_k"].astype(dt)
    h = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["cm_wr"].astype(dt)) * (h @ p["cm_wv"].astype(dt))
    return out, x[:, -1]


def block(p, cfg: ModelConfig, x, state, *, impl="jnp"):
    """One RWKV layer. state = dict(tm_x, cm_x, wkv). Returns (x, new_state)."""
    h = L.rms_norm(x, p["ln1"])
    att, tm_x, wkv = time_mix(p, cfg, h, state["tm_x"], state["wkv"],
                              chunked=x.shape[1] > 1, impl=impl)
    x = x + att
    h = L.rms_norm(x, p["ln2"])
    ffn, cm_x = channel_mix(p, h, state["cm_x"])
    x = x + ffn
    return x, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}


def init_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    H = cfg.ssm_heads
    hd = d // H
    Lr = cfg.num_layers
    return {
        "tm_x": jnp.zeros((Lr, batch, d), dtype),
        "cm_x": jnp.zeros((Lr, batch, d), dtype),
        "wkv": jnp.zeros((Lr, batch, H, hd, hd), jnp.float32),
    }
