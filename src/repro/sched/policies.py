"""Pluggable aggregation-trigger policies for the event runtime.

A policy decides WHEN the sink PS aggregates; WHAT the update computes
(eqs. 4/13/14, the per-arrival EMA, the interval emulation) stays with the
strategy's ``agg_mode`` (`core/aggregation.epoch_weight_vector`), so a
policy is pure scheduling logic over a round's expected/observed arrivals:

* ``round_deadline``  — absolute TRIGGER_TIMEOUT to schedule when a round
  opens (the sync barrier's straggler stall; the idle timeout of a round
  that only drains carried stragglers), or None;
* ``on_arrival``      — absolute trigger time a MODEL_ARRIVAL should
  schedule (AsyncFLEO schedules first-arrival + idle timeout; the sync
  barrier fires when the last expected model lands; FedAsync fires on
  every arrival), or None;
* ``split``           — at trigger time, the (t_agg, used, late) partition
  of the round's arrivals.  AsyncFLEO and the sync barrier delegate to
  ``FLSimulation._trigger`` so the event runtime reproduces the epoch
  loop's aggregation instants *exactly* (the parity contract in
  tests/test_sched.py);
* ``round_complete``  — whether a commit closes the round (PS roles swap).

Policies are selected from the strategy table (`fl/strategies.py`,
``StrategySpec.sched_policy``): AsyncFLEO strategies map to the
idle-timeout policy, synchronous FedAvg baselines (ground-station FL as in
Razmi et al.) to the barrier, and the FedAsync-style ``fedasync`` /
``fedsat`` strategies to per-arrival aggregation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Arrival = Tuple[float, int, int]                 # (t_arrival, sat, bank row)


@dataclasses.dataclass
class AsyncFLEOPolicy:
    """AsyncFLEO (Alg. 2 trigger): the first arrival of a round opens a
    collection window of ``agg_timeout_s``; everything that lands inside
    aggregates in ONE fused dispatch, later arrivals carry over as
    stragglers.  ``min_models`` backstop handled by ``_trigger``."""
    name: str = "asyncfleo"

    def round_deadline(self, rt, rnd) -> Optional[float]:
        if rnd.expected:                 # first arrival opens the window
            return None
        return min(rnd.t_start + rt.sim.agg_timeout_s, rt.sim.duration_s)

    def on_arrival(self, rt, rnd, t: float) -> Optional[float]:
        if rnd.trigger_scheduled is None:
            return min(t + rt.sim.agg_timeout_s, rt.sim.duration_s)
        return None

    def split(self, rt, rnd, t_fired: float):
        return rt.fls._trigger(rnd.expected, rnd.t_start)

    def round_complete(self, rnd) -> bool:
        return True


@dataclasses.dataclass
class SyncBarrierPolicy:
    """Synchronous FedAvg barrier: aggregate when every expected model has
    arrived, or at the straggler stall ``sync_stall_s`` — whichever comes
    first (the GS-FedAvg baselines: fedisl / fedhap / Razmi-style
    ground-station FL)."""
    name: str = "sync"

    def round_deadline(self, rt, rnd) -> Optional[float]:
        if not rnd.expected:
            return rnd.t_start               # nothing to wait for
        return rnd.t_start + rt.sim.sync_stall_s

    def on_arrival(self, rt, rnd, t: float) -> Optional[float]:
        if rnd.arrived_count == len(rnd.expected):
            return t                         # barrier complete: fire now
        return None

    def split(self, rt, rnd, t_fired: float):
        return rt.fls._trigger(rnd.expected, rnd.t_start)

    def round_complete(self, rnd) -> bool:
        return True


@dataclasses.dataclass
class FedAsyncPolicy:
    """FedAsync-style immediate aggregation: every MODEL_ARRIVAL triggers
    its own (small) aggregation — the first one of a round consumes the
    fused training dispatch (remaining rows carry over as pending
    stragglers), later ones drain the carried matrix as they land.  The
    round closes after its last expected arrival."""
    name: str = "per_arrival"

    def round_deadline(self, rt, rnd) -> Optional[float]:
        if rnd.expected:
            return None
        return min(rnd.t_start + rt.sim.agg_timeout_s, rt.sim.duration_s)

    def on_arrival(self, rt, rnd, t: float) -> Optional[float]:
        return t

    def split(self, rt, rnd, t_fired: float):
        if not rnd.committed:
            used = [a for a in rnd.expected if a[0] <= t_fired]
            late = [a for a in rnd.expected if a[0] > t_fired]
            return t_fired, used, late
        return t_fired, [], []               # drain carried arrivals only

    def round_complete(self, rnd) -> bool:
        return rnd.arrived_count >= len(rnd.expected)


POLICIES = {
    "asyncfleo": AsyncFLEOPolicy,
    "sync": SyncBarrierPolicy,
    "per_arrival": FedAsyncPolicy,
}


def make_policy(spec, name: str = ""):
    """Policy for a strategy spec: the explicit ``spec.sched_policy`` when
    set, else derived — sync strategies get the barrier, ``per_arrival``
    aggregation gets FedAsync, everything else the AsyncFLEO window."""
    key = name or getattr(spec, "sched_policy", "")
    if not key:
        if spec.sync:
            key = "sync"
        elif spec.agg_mode == "per_arrival":
            key = "per_arrival"
        else:
            key = "asyncfleo"
    if key not in POLICIES:
        raise KeyError(f"unknown scheduler policy {key!r}; "
                       f"available: {sorted(POLICIES)}")
    return POLICIES[key]()
