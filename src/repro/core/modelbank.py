"""Device-resident stacked model storage (the ``ModelBank``).

The AsyncFLEO server path (grouping + staleness-discounted aggregation,
paper §IV-C) only ever needs models as *vectors*: Euclidean distances for
grouping (Fig. 5) and convex combinations for aggregation (eqs. 4/13/14).
The seed implementation nevertheless shuttled every trained model to host as
a pytree and back — O(S) full copies plus Python per-leaf loops per epoch.

``ModelBank`` keeps the whole client population as one stacked ``(C, N)``
float32 array on device from ``train_many`` output all the way through
grouping and aggregation.  A ``FlatSpec`` — built once per model structure
and cached — records how the pytree flattens into the ``N`` axis, so
pytrees only materialize when a caller explicitly asks (``to_pytrees`` /
``unflatten``), e.g. to feed the evaluator one global model per epoch.

Layout convention (see DESIGN.md §2): row ``c`` is client ``c``'s model;
columns are ``jax.tree_util.tree_leaves`` order, each leaf raveled
C-contiguously, concatenated.  All rows are float32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Cached flatten/unflatten recipe for one model structure."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]

    @property
    def num_params(self) -> int:
        return int(sum(self.sizes))

    # ---- construction ------------------------------------------------------

    @staticmethod
    def of(model) -> "FlatSpec":
        """Spec for ``model``'s structure (cached by treedef+shapes)."""
        leaves, treedef = jax.tree_util.tree_flatten(model)
        shapes = tuple(tuple(np.shape(l)) for l in leaves)
        key = (treedef, shapes)
        spec = _SPEC_CACHE.get(key)
        if spec is None:
            sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
            spec = FlatSpec(treedef, shapes, sizes)
            _SPEC_CACHE[key] = spec
        return spec

    # ---- flatten -----------------------------------------------------------

    def flatten(self, model) -> jnp.ndarray:
        """Pytree -> (N,) float32 device vector (one fused jitted call —
        per-leaf eager dispatch would cost ~0.1 ms x leaves per call)."""
        return _flatten_jit(self)(model)

    def flatten_stacked(self, stacked_model) -> jnp.ndarray:
        """Pytree whose leaves carry a shared leading axis C -> (C, N)."""
        leaves = jax.tree_util.tree_leaves(stacked_model)
        c = leaves[0].shape[0]
        return jnp.concatenate(
            [jnp.reshape(l, (c, -1)).astype(jnp.float32) for l in leaves],
            axis=1)

    # ---- unflatten ---------------------------------------------------------

    def unflatten(self, flat):
        """(N,) vector -> pytree of device arrays (no host copy)."""
        return _unflatten_jit(self)(jnp.asarray(flat))

    def unflatten_host(self, flat):
        """(N,) vector -> pytree of host numpy arrays (one device_get)."""
        flat = np.asarray(jax.device_get(flat))
        parts, off = [], 0
        for size, shape in zip(self.sizes, self.shapes):
            parts.append(flat[off:off + size].reshape(shape))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, parts)


_SPEC_CACHE: Dict[Any, FlatSpec] = {}
_UNFLATTEN_JIT: Dict[FlatSpec, Any] = {}


@jax.jit
def gather_rows(stack, idx):
    """Jitted row gather — noticeably faster than the eager `stack[idx]`
    dispatch path on CPU backends, and shape-cached like any jit."""
    return stack[idx]


def pad_bucket_ids(ids: Sequence[int]) -> Tuple[np.ndarray, int]:
    """Pad an index list to the next power-of-two bucket by repeating the
    first id, returning (padded int32 ids, true count).  Bucketing keeps
    jitted vmaps and row gathers at O(log S) distinct shapes as participant
    counts change; padded rows are computed and discarded (<2x bound)."""
    arr = np.asarray(list(ids), dtype=np.int32)
    n = len(arr)
    if n == 0:
        return arr, 0
    b = 1 << max(n - 1, 0).bit_length()
    if b > n:
        arr = np.concatenate([arr, np.full(b - n, arr[0], dtype=np.int32)])
    return arr, n


def flat_base(spec: FlatSpec, base):
    """Base model as a flat (N,) float32 device vector (None passes
    through); shared by the XLA and Pallas aggregation entry points."""
    if base is None:
        return None
    if getattr(base, "ndim", None) == 1:
        return jnp.asarray(base, jnp.float32)
    return spec.flatten(base)


@jax.jit
def flatten_tree(model):
    """Pytree -> (N,) float32 vector in the §2 layout.  Jitted when called
    eagerly; inlines when traced inside a larger program (the fused epoch
    step and custom ``epoch_train_fn`` implementations use it that way —
    structure-generic, jax.jit re-specializes per pytree structure)."""
    leaves = jax.tree_util.tree_leaves(model)
    return jnp.concatenate(
        [jnp.ravel(l).astype(jnp.float32) for l in leaves])


_flatten_tree = flatten_tree          # former private name


def _flatten_jit(spec: FlatSpec):
    del spec                     # flatten needs no spec; jit caches by tree
    return flatten_tree


def _unflatten_jit(spec: FlatSpec):
    fn = _UNFLATTEN_JIT.get(spec)
    if fn is None:
        def _unflatten(flat):
            parts, off = [], 0
            for size, shape in zip(spec.sizes, spec.shapes):
                parts.append(jnp.reshape(flat[off:off + size], shape))
                off += size
            return jax.tree_util.tree_unflatten(spec.treedef, parts)
        fn = _UNFLATTEN_JIT[spec] = jax.jit(_unflatten)
    return fn


@dataclasses.dataclass
class ModelBank:
    """C models held as one device-resident (C, N) float32 stack."""
    spec: FlatSpec
    stack: jnp.ndarray                 # (C, N) float32

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_pytrees(cls, models: Sequence) -> "ModelBank":
        spec = FlatSpec.of(models[0])
        return cls(spec, jnp.stack([spec.flatten(m) for m in models]))

    @classmethod
    def from_stacked_tree(cls, stacked_model) -> "ModelBank":
        """From a vmap output: pytree with shared leading client axis."""
        one = jax.tree_util.tree_map(lambda l: l[0], stacked_model)
        spec = FlatSpec.of(one)
        return cls(spec, spec.flatten_stacked(stacked_model))

    @classmethod
    def from_rows(cls, spec: FlatSpec, rows: Sequence) -> "ModelBank":
        """From per-client (N,) flat vectors (device or host)."""
        return cls(spec, jnp.stack([jnp.asarray(r) for r in rows]))

    # ---- views -------------------------------------------------------------

    def __len__(self) -> int:
        return int(self.stack.shape[0])

    @property
    def num_params(self) -> int:
        return int(self.stack.shape[1])

    def select(self, idx: Sequence[int]) -> "ModelBank":
        """Sub-bank of the given rows (device gather; no host copy)."""
        return ModelBank(self.spec,
                         gather_rows(self.stack,
                                     np.asarray(list(idx), dtype=np.int32)))

    def row(self, i: int) -> jnp.ndarray:
        return self.stack[i]

    # ---- explicit materialization -----------------------------------------

    def to_pytrees(self) -> List:
        """Materialize per-client host pytrees (single device_get)."""
        host = np.asarray(jax.device_get(self.stack))
        out = []
        for c in range(host.shape[0]):
            parts, off = [], 0
            for size, shape in zip(self.spec.sizes, self.spec.shapes):
                parts.append(host[c, off:off + size].reshape(shape))
                off += size
            out.append(jax.tree_util.tree_unflatten(self.spec.treedef, parts))
        return out

    def pytree(self, i: int):
        """Materialize one client's pytree (device arrays)."""
        return self.spec.unflatten(self.stack[i])
