"""FL training launcher — the production entrypoint for the paper's system.

    PYTHONPATH=src python -m repro.launch.fl_train \
        --strategy asyncfleo-hap --epochs 8 --target 0.8 \
        [--iid] [--dataset mnist|cifar] [--model cnn|mlp] \
        [--checkpoint out/server.npz] [--resume out/server.npz]

Runs the discrete-event constellation simulation with real JAX training and
checkpoints the PS state (global model + epoch + grouping) each epoch.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

import jax
import numpy as np

from repro.checkpoint import load_server_state, save_server_state
from repro.configs import CIFAR_CNN, CIFAR_MLP, MNIST_CNN, MNIST_MLP
from repro.core import FLSimulation, SimConfig, convergence_time, paper_constellation
from repro.data import class_conditional_images, iid_partition, paper_noniid_partition
from repro.fl import Evaluator, ImageClassifierPool, STRATEGIES, get_strategy
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="asyncfleo-hap",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--target", type=float, default=None)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--dataset", default="mnist", choices=["mnist", "cifar"])
    ap.add_argument("--model", default="cnn", choices=["cnn", "mlp"])
    ap.add_argument("--local-iters", type=int, default=30)
    ap.add_argument("--days", type=float, default=3.0)
    ap.add_argument("--separation", type=float, default=0.8)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    base = {("mnist", "cnn"): MNIST_CNN, ("mnist", "mlp"): MNIST_MLP,
            ("cifar", "cnn"): CIFAR_CNN, ("cifar", "mlp"): CIFAR_MLP}[
        (args.dataset, args.model)]
    cfg = dataclasses.replace(base, conv_channels=(8, 16)) \
        if args.model == "cnn" else base

    const = paper_constellation()
    imgs, labs = class_conditional_images(args.seed, 4000, size=cfg.image_size,
                                          channels=cfg.channels,
                                          separation=args.separation)
    ti, tl = class_conditional_images(args.seed + 99, 1000, size=cfg.image_size,
                                      channels=cfg.channels,
                                      separation=args.separation)
    shards = (iid_partition(labs, const.num_sats, args.seed) if args.iid
              else paper_noniid_partition(labs, const.orbit_ids(), args.seed))
    pool = ImageClassifierPool(cfg, imgs, labs, shards,
                               local_iters=args.local_iters)
    ev = Evaluator(cfg, ti, tl)

    if args.resume:
        w0, side = load_server_state(args.resume)
        print(f"resumed from {args.resume} at epoch {side['epoch']}")
    else:
        w0 = jax.device_get(cnn.init_params(jax.random.PRNGKey(args.seed), cfg))

    sim = FLSimulation(get_strategy(args.strategy), pool, ev,
                       SimConfig(duration_s=args.days * 86400.0,
                                 seed=args.seed))
    print(f"strategy={args.strategy} sats={const.num_sats} "
          f"iid={args.iid} dataset={args.dataset}/{args.model}")
    hist = sim.run(w0, max_epochs=args.epochs, target_accuracy=args.target)
    w_final = w0
    for r in hist:
        print(f"epoch {r.epoch:3d}  sim {r.time_s/3600:6.2f} h  "
              f"acc {r.accuracy:.4f}  models {r.num_models:2d}  "
              f"gamma {r.gamma:.2f}")
    if args.checkpoint and hist:
        os.makedirs(os.path.dirname(os.path.abspath(args.checkpoint)),
                    exist_ok=True)
        save_server_state(args.checkpoint, global_model=w_final,
                          epoch=hist[-1].epoch,
                          grouping=sim.grouping.groups)
        print(f"server state -> {args.checkpoint}")
    if args.target:
        conv = convergence_time(hist, args.target)
        print(f"convergence to {args.target}: "
              f"{conv/3600:.2f} h" if conv else "not reached")
    return 0


if __name__ == "__main__":
    sys.exit(main())
