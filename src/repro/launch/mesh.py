"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries the HAP-ring / data-parallel replication across pods
(DESIGN.md §3).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


def make_data_mesh():
    """1-D "data" mesh over every local device (trailing size-1 "model"
    axis so the shared rules resolve) — the layout the fused epoch program
    (``core/epoch_step.py``) shards the participant axis over.  On a
    single-device host this is the identity mesh: every shape and result
    stays bit-identical to the unsharded path."""
    return make_host_mesh(data=len(jax.devices()), model=1)


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
ICI_LINKS = 4                   # 2D torus on v5e: 4 links/chip
