"""internvl2-1b — VLM: InternViT (stub frontend) + InternLM2 LM backbone.

[arXiv:2404.16821] — the transformer backbone below is the Qwen2-0.5B-ish
InternLM2 decoder; the vision tower supplies 256 patch embeddings per image
via the ``vision_stub`` frontend (DESIGN.md carve-out).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    rope_theta=1_000_000.0,
    frontend="vision_stub", num_prefix_embeds=256,
    citation="arXiv:2404.16821",
)
