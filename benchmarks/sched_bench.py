"""Head-to-head convergence-delay benchmark under the event runtime.

The paper's headline (Table II / Fig. 6) is not an accuracy number but a
*delay* number: time-to-target-accuracy under asynchronous aggregation vs
the synchronous barrier.  This benchmark finally makes that comparison
runnable: the SAME constellation, contact plan and (deterministic,
fused-protocol) trainer run under each strategy's trigger policy in the
event-driven runtime (`sched/runtime.py`), and the simulated convergence
delay to a target accuracy is read off the shared history format with
``convergence_time``.

Per policy it records: simulated convergence delay (seconds), epochs to
target, fused dispatch counts, event counts, pipeline telemetry
(rounds opened / peak rounds in flight / cross-round straggler
adoptions), and host wall time; plus the compiled contact-plan summary
for the scenario.  The ``async_pipelined`` row runs the SAME AsyncFLEO
policy with up to 3 overlapping rounds in flight (DESIGN.md §8), so the
pipelined-vs-single-round delta is pure scheduling.  Results go to
``BENCH_sched.json`` (CI uploads it next to ``BENCH_epoch.json``; the
field-by-field schema is documented in ``benchmarks/README.md``).

``--fail-if-not-lower`` exits nonzero unless the AsyncFLEO policy's
convergence delay is strictly lower than the sync GS-FedAvg baseline's —
the acceptance gate for the paper's ordering — the pipelined row's is no
higher than single-round async, AND async still strictly beats sync in
the most bandwidth-constrained contention cell (``ps_channels=1`` at the
lowest swept rate): the ordering is a genuinely different claim once a
PS can no longer absorb every transfer at once.

The **contention sweep** (on by default, ``--skip-contention-sweep`` to
disable) re-runs the async / pipelined / sync head-to-head under finite
per-PS link capacity (DESIGN.md §9): every ``ps_channels`` in {1, 4, ∞}
crossed with a nominal and a bandwidth-constrained ``rate_bps``.  The
interesting row is the pipelined one — overlapping rounds share the
same PS pools, so the single-round-vs-pipelined delta shrinks (or
inverts) as channels get scarce, which the infinite-parallelism model
could never show.  ``--ps-channels`` additionally applies a channel
count to the four MAIN policy rows.

The **fault sweep** (on by default, ``--skip-fault-sweep`` to disable)
re-runs the AsyncFLEO row under injected faults (DESIGN.md §10): every
transfer-dropout probability in {0, 5%, 20%} crossed with a per-sat
compute-rate spread in {0, 1.0} and a staleness function in
{eq13, poly} — 12 cells, each carrying the retry telemetry
(transfers failed / retried / dropped after max retries) and the
realized compute-rate spread.  Under ``--fail-if-not-lower`` the
all-off cell (dropout 0, spread 0, eq13; ``fault_model=None``) must
match the main async row EXACTLY (the §10 off-switch parity pin), and
every dropout=20% cell must still reach the target accuracy.

Two §11 robustness cells ride along with the fault sweep: the
**defaults-parity row** re-runs the main async scenario with an
explicit ``FaultModel()`` (burst / outage / energy / adaptive-backoff
axes all at their defaults) and the gate requires it to match the
``fault_model=None`` row on every deterministic key, and the **outage
smoke cell** (``outage_smoke``) runs pipelined AsyncFLEO on the
two-HAP ring with one HAP dark for a contiguous 30% of the horizon —
the gate requires ring failover + lazy arrival reroutes to carry it to
the target anyway.

``--cnn-sats 200`` appends the accuracy-aware convergence-delay study:
the async / pipelined / sync head-to-head re-run with REAL federated CNN
training (non-IID class-conditional shards) at S >= 200, where the
measured delay includes genuine accuracy dynamics instead of the
deterministic proxy.

Usage:  PYTHONPATH=src python benchmarks/sched_bench.py [--target 0.9]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import FLSimulation, SimConfig, convergence_time
from repro.core.constellation import WalkerDelta
from repro.core.links import LinkModel
from repro.fl.strategies import get_strategy
from repro.obs import (DispatchProfiler, Tracer, add_runtime_tracks,
                       export_chrome, export_jsonl, validate_chrome_trace)
from repro.obs.trace import SPAN_ROUND
from repro.sched import EventDrivenRuntime

# async vs sync on the same constellation with the SAME PS placement
# (a single ground station, the Razmi-style GS-FL setup), plus the
# FedAsync per-arrival baseline for reference and the pipelined runtime
# (up to 3 overlapping rounds in flight, DESIGN.md §8) head-to-head
# against single-round async
POLICY_ROWS = (
    ("async_asyncfleo", "asyncfleo-gs"),
    ("async_pipelined", "asyncfleo-pipelined"),
    ("sync_gs_fedavg", "fedisl"),
    ("fedasync_per_arrival", "fedasync"),
)

# the bandwidth-constrained contention sweep (DESIGN.md §9): the same
# head-to-head under finite per-PS link capacity.  16 Mb/s is the paper's
# Table I evaluation rate (transfers are near-free there: the sweep's
# control); 3 kb/s makes one model transfer ~88 s, so a single-channel PS
# needs ~1 h of airtime to drain a 40-satellite round — the serialized
# transfers dominate the round and the 1.26x pipelining win inverts,
# while 4 channels (FedHAP-style collaborating capacity) restore it
CONTENTION_ROWS = POLICY_ROWS[:3]
CONTENTION_RATES = (16e6, 3e3)
CONTENTION_CHANNELS = (1, 4, None)         # None = infinite parallelism

# the robustness sweep (DESIGN.md §10): AsyncFLEO under injected faults.
# dropout x compute-rate spread x staleness function; the all-off cell
# (0, 0, eq13) runs with fault_model=None and must match the main async
# row EXACTLY — that equality is the off-switch parity pin the
# --fail-if-not-lower gate enforces
FAULT_DROPOUTS = (0.0, 0.05, 0.2)
FAULT_SPREADS = (0.0, 1.0)
FAULT_STALENESS = ("eq13", "poly")


# the deterministic fused-protocol testbed (trainer/evaluator/model) moved
# to `repro.sweep.testbed` so the batched sweep engine and this benchmark
# share ONE definition; re-exported here because tests and the CNN study
# import them from this module
from repro.sweep.testbed import (ConvergingTrainer, MeanDistanceEvaluator,
                                 make_model)


def _run_policy(name: str, strategy: str, w0, target: float,
                max_epochs: int, duration_s: float,
                ps_channels: Optional[int] = None,
                link: Optional[LinkModel] = None,
                fault=None, staleness_fn: str = "eq13",
                spec_kw: Optional[Dict] = None, tracer=None):
    """One benched run; returns (row, fls, rt, hist) so callers that
    need the live objects (the trace smoke cell) share the exact setup
    the plain rows use."""
    spec = get_strategy(strategy)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    if ps_channels is not None:
        spec = dataclasses.replace(spec, ps_channels=ps_channels)
    if staleness_fn != "eq13":
        spec = dataclasses.replace(spec, staleness_fn=staleness_fn)
    prof = DispatchProfiler()
    sim = SimConfig(duration_s=duration_s, dt_s=30.0, train_time_s=300.0,
                    use_model_bank=True, use_fused_step=True,
                    event_driven=True, link=link, fault_model=fault,
                    tracer=tracer, profiler=prof)
    fls = FLSimulation(spec, ConvergingTrainer(w0),
                       MeanDistanceEvaluator(), sim)
    rt = EventDrivenRuntime(fls)
    t0 = time.perf_counter()
    hist = rt.run(w0, max_epochs=max_epochs, target_accuracy=target)
    wall = time.perf_counter() - t0
    conv = convergence_time(hist, target)
    row = {
        "policy": name,
        "strategy": strategy,
        "trigger_policy": rt.policy.name,
        "target_accuracy": target,
        "convergence_delay_s": conv,
        "epochs_to_target": (len(hist) if conv is not None else None),
        "final_accuracy": float(hist[-1].accuracy) if hist else None,
        "aggregations": len(hist),
        "fused_dispatches": fls._fused_prog.dispatches,
        "fallback_dispatches": fls._fused_prog.fallback_dispatches,
        "event_counts": dict(rt.events.counts),
        "sched_stats": dict(rt.stats),
        "max_in_flight": rt.max_in_flight,
        "handoff_policy": rt.handoff.name,
        "ps_channels": ps_channels,
        "rate_bps": float((link or LinkModel()).rate_bps),
        "contention": rt.contention_stats(),
        "staleness_fn": staleness_fn,
        # fault/heterogeneity config + realized compute spread; the retry
        # telemetry (transfers_failed / transfer_retries / dropped_*) is
        # in sched_stats above
        "fault": None if fault is None else {
            "loss_prob": fault.loss_prob,
            "max_retries": fault.max_retries,
            "retry_backoff_s": fault.retry_backoff_s,
            "compute_rate_spread": fault.compute_rate_spread,
            "eclipse_fraction": fault.eclipse_fraction,
            "seed": fault.seed,
            "train_scale_min": (1.0 if fls._train_scale is None
                                else float(fls._train_scale.min())),
            "train_scale_max": (1.0 if fls._train_scale is None
                                else float(fls._train_scale.max())),
            # §11 degradation-and-recovery config (the realized outage /
            # energy / backoff telemetry is in sched_stats above)
            "burst_len_s": fault.burst_len_s,
            "loss_prob_bad": fault.loss_prob_bad,
            "loss_prob_good": fault.loss_prob_good,
            "ps_outages": (None if fault.ps_outages is None
                           else [list(iv) for iv in fault.ps_outages]),
            "ps_outage_fraction": fault.ps_outage_fraction,
            "battery_j": fault.battery_j,
            "adaptive_backoff": fault.adaptive_backoff,
        },
        "wall_s": wall,
        # reproducibility + wall-clock attribution (DESIGN.md §12): the
        # RNG seed this row trained under, and where the host time went —
        # cold trace+compile vs steady-state dispatch (obs/profile.py)
        "seed": int(sim.seed),
        "profile": prof.summary(),
        "plan": fls.plan.summary(),
    }
    return row, fls, rt, hist


def bench_policy(name: str, strategy: str, w0, target: float,
                 max_epochs: int, duration_s: float,
                 ps_channels: Optional[int] = None,
                 link: Optional[LinkModel] = None,
                 fault=None, staleness_fn: str = "eq13",
                 spec_kw: Optional[Dict] = None) -> Dict:
    row, _fls, _rt, _hist = _run_policy(
        name, strategy, w0, target, max_epochs, duration_s,
        ps_channels=ps_channels, link=link, fault=fault,
        staleness_fn=staleness_fn, spec_kw=spec_kw)
    return row


def trace_smoke(w0, target: float, max_epochs: int, duration_s: float,
                trace_out: str) -> Dict:
    """The observability smoke cell (DESIGN.md §12): run the pipelined
    AsyncFLEO row twice — once traced, once with ``tracer=None`` — and
    gate three claims before writing the trace artifact:

    1. **null-tracer bit-parity**: the traced run's history rows and
       final flat weights are bit-identical to the untraced run's;
    2. the exported Chrome trace-event JSON passes the schema validator
       (loads in Perfetto);
    3. the trace carries >= 1 ``round`` span per committed epoch.

    Writes ``trace_out`` (Chrome JSON, the CI artifact) plus the same
    buffer as JSONL next to it.  Raises SystemExit on any gate failure.
    """
    tracer = Tracer()
    _rowt, fls_t, rt_t, hist_t = _run_policy(
        "async_pipelined_traced", "asyncfleo-pipelined", w0, target,
        max_epochs, duration_s, tracer=tracer)
    _rowu, fls_u, _rt_u, hist_u = _run_policy(
        "async_pipelined", "asyncfleo-pipelined", w0, target,
        max_epochs, duration_s)

    def _rows(h):
        return [(r.epoch, r.time_s, r.accuracy, r.num_models, r.gamma)
                for r in h]

    if _rows(hist_t) != _rows(hist_u):
        raise SystemExit("tracer=None parity broken: traced history "
                         "differs from the untraced run")
    wt = np.asarray(fls_t._w_flat)
    wu = np.asarray(fls_u._w_flat)
    if wt.tobytes() != wu.tobytes():
        raise SystemExit("tracer=None parity broken: traced final "
                         "weights differ bitwise from the untraced run")

    add_runtime_tracks(tracer, rt_t)          # per-PS occupancy/outages
    obj = export_chrome(tracer, trace_out)
    errs = validate_chrome_trace(obj)
    if errs:
        raise SystemExit("exported trace failed Chrome-trace schema "
                         "validation: " + "; ".join(errs[:5]))
    round_spans = sum(1 for s in tracer.spans if s.name == SPAN_ROUND)
    if round_spans < len(hist_t):
        raise SystemExit(
            f"trace coverage broken: {round_spans} round spans for "
            f"{len(hist_t)} committed epochs")
    jsonl_out = trace_out.rsplit(".", 1)[0] + ".jsonl"
    lines = export_jsonl(tracer, jsonl_out)
    print(f"[trace] parity ok  {len(obj['traceEvents'])} events  "
          f"{round_spans} round spans / {len(hist_t)} epochs  "
          f"-> {trace_out} (+{jsonl_out}, {lines} lines)")
    return {"trace_path": trace_out, "jsonl_path": jsonl_out,
            "trace_events": len(obj["traceEvents"]),
            "round_spans": round_spans, "aggregations": len(hist_t),
            "tracer_null_parity": True}


def contention_sweep(w0, target: float, max_epochs: int,
                     duration_s: float) -> Dict:
    """The async / pipelined / sync head-to-head under finite per-PS link
    capacity: one cell per (rate_bps, ps_channels) with per-cell speedup
    ratios.  ``ps_channels=None`` cells are the infinite-parallelism
    control — bit-identical to the main rows at the same rate."""
    cells = []
    for rate in CONTENTION_RATES:
        link = LinkModel(rate_bps=rate)
        for k in CONTENTION_CHANNELS:
            cell = {"rate_bps": float(rate), "ps_channels": k, "rows": []}
            for name, strategy in CONTENTION_ROWS:
                r = bench_policy(name, strategy, w0, target, max_epochs,
                                 duration_s, ps_channels=k, link=link)
                cell["rows"].append(r)
            by = {r["policy"]: r["convergence_delay_s"]
                  for r in cell["rows"]}
            a, p, s = (by["async_asyncfleo"], by["async_pipelined"],
                       by["sync_gs_fedavg"])
            cell["async_vs_sync_speedup"] = (s / a if a and s else None)
            cell["pipelined_vs_async_speedup"] = (a / p if a and p else None)
            k_str = "inf" if k is None else str(k)
            print(f"[contention rate={rate:9.0f} k={k_str:>3s}] "
                  f"async {_h(a)} h  pipelined {_h(p)} h  sync {_h(s)} h  "
                  f"async/sync {cell['async_vs_sync_speedup'] or float('nan'):.1f}x  "
                  f"pipe/async {cell['pipelined_vs_async_speedup'] or float('nan'):.2f}x")
            cells.append(cell)
    return {"rates_bps": [float(r) for r in CONTENTION_RATES],
            "channels": list(CONTENTION_CHANNELS), "cells": cells}


def fault_sweep(w0, target: float, max_epochs: int, duration_s: float,
                ps_channels: Optional[int] = None) -> Dict:
    """AsyncFLEO convergence delay under injected faults: every dropout
    probability crossed with a compute-rate spread and a staleness
    function (12 cells).  Lossy cells retry with exponential backoff
    (max_retries=3, 120 s base), so moderate dropout costs delay rather
    than updates; the telemetry in each row's ``sched_stats`` records
    how many transfers failed / retried / dropped."""
    from repro.sched import FaultModel
    cells = []
    for drop in FAULT_DROPOUTS:
        for spread in FAULT_SPREADS:
            for sfn in FAULT_STALENESS:
                off = drop == 0.0 and spread == 0.0
                fm = None if off else FaultModel(
                    loss_prob=drop, compute_rate_spread=spread)
                r = bench_policy("async_asyncfleo", "asyncfleo-gs", w0,
                                 target, max_epochs, duration_s,
                                 ps_channels=ps_channels, fault=fm,
                                 staleness_fn=sfn)
                cell = {"dropout": drop, "compute_rate_spread": spread,
                        "staleness_fn": sfn, "row": r}
                st = r["sched_stats"]
                print(f"[fault drop={drop:4.2f} spread={spread:3.1f} "
                      f"{sfn:8s}] conv {_h(r['convergence_delay_s'])} h  "
                      f"failed {st['transfers_failed']:3d}  "
                      f"retried {st['transfer_retries']:3d}  "
                      f"dropped {st['dropped_after_max_retries']:3d}")
                cells.append(cell)
    return {"dropouts": list(FAULT_DROPOUTS),
            "compute_rate_spreads": list(FAULT_SPREADS),
            "staleness_fns": list(FAULT_STALENESS), "cells": cells}


def outage_smoke(w0, target: float, max_epochs: int,
                 duration_s: float) -> Dict:
    """The §11 PS-outage smoke cell: pipelined AsyncFLEO on the two-HAP
    ring with one HAP dark for a contiguous 30% of the horizon
    (explicit ``ps_outages``).  Ring failover + lazy arrival reroutes
    must carry the run to the target anyway — ``--fail-if-not-lower``
    gates on it converging.  The ``sched_stats`` telemetry
    (``sink_failovers`` / ``rerouted_arrivals`` / ``dropped_outage``)
    records how much recovery work that took."""
    from repro.sched import FaultModel
    # the dark window opens ~33 min in — right on top of the active
    # rounds (with the ring handoff, every other in-flight round is
    # sunk at PS 0 by then), not parked in the idle tail of the horizon
    dark = (0, 2000.0, 2000.0 + 0.3 * duration_s)
    fm = FaultModel(ps_outages=(dark,))
    r = bench_policy("async_pipelined_outage", "asyncfleo-twohap", w0,
                     target, max_epochs, duration_s, fault=fm,
                     spec_kw=dict(max_in_flight=3))
    st = r["sched_stats"]
    print(f"[outage ps=0 dark {dark[1] / 3600.0:.1f}-{dark[2] / 3600.0:.1f} h]"
          f" conv {_h(r['convergence_delay_s'])} h  "
          f"failovers {st['sink_failovers']:2d}  "
          f"rerouted {st['rerouted_arrivals']:3d}  "
          f"dropped {st['dropped_outage']:3d}")
    return {"ps_outages": [list(dark)], "row": r}


def scale_smoke(target: float, max_epochs: int, num_sats: int,
                num_ps: int, duration_s: float = 86400.0,
                dt_s: float = 30.0) -> Dict:
    """Mega-constellation scale cell (DESIGN.md §14): a Starlink-class
    S=10^4 shell over a P>=4 ``hapring`` of parameter servers compiles
    its contact plan through the SPARSE segment timeline (the dense
    (T, S, P) grid + (T, S, 3) positions would be gigabytes) and
    completes a ``max_epochs``-epoch event-driven run.  The row reports
    compile and run wall seconds separately; CI gates the total against
    an explicit budget (``--scale-budget-s``) so scale cannot rot."""
    spo = 250 if num_sats % 250 == 0 and num_sats >= 250 else num_sats
    cst = WalkerDelta(num_orbits=num_sats // spo, sats_per_orbit=spo,
                      altitude_m=550e3, inclination_deg=53.0)
    spec = dataclasses.replace(get_strategy("asyncfleo-gs"),
                               ps_scenario=f"hapring:{num_ps}")
    w0 = make_model()
    sim = SimConfig(duration_s=duration_s, dt_s=dt_s, train_time_s=300.0,
                    use_model_bank=True, use_fused_step=True,
                    event_driven=True, visibility="sparse")
    t0 = time.perf_counter()
    fls = FLSimulation(spec, ConvergingTrainer(w0),
                       MeanDistanceEvaluator(), sim, constellation=cst)
    rt = EventDrivenRuntime(fls)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    hist = rt.run(w0, max_epochs=max_epochs, target_accuracy=target)
    run_s = time.perf_counter() - t0
    row = {
        "num_sats": num_sats,
        "num_ps": num_ps,
        "duration_s": duration_s,
        "dt_s": dt_s,
        "visibility": "sparse",
        "epochs": len(hist),
        "final_accuracy": float(hist[-1].accuracy) if hist else None,
        "fused_dispatches": fls._fused_prog.dispatches,
        "event_counts": dict(rt.events.counts),
        "plan": fls.plan.summary(),
        "compile_wall_s": compile_s,
        "run_wall_s": run_s,
        "wall_s": compile_s + run_s,
    }
    print(f"scale smoke S={num_sats} P={num_ps}: compile {compile_s:.1f} s, "
          f"{len(hist)} epochs in {run_s:.1f} s, "
          f"{row['plan']['num_windows']} windows")
    return row


def _h(delay_s) -> str:
    return (f"{delay_s / 3600.0:6.2f}" if delay_s is not None
            else "  none")


def cnn_study(num_sats: int, target: float, max_epochs: int,
              duration_s: float) -> Dict:
    """Accuracy-aware convergence-delay study with the REAL CNN pools at
    S >= 200: the deterministic-trainer rows above isolate pure
    scheduling delay, this one re-runs the async / pipelined / sync
    head-to-head with actual federated CNN training on class-conditional
    image shards, so the measured delay includes genuine accuracy
    dynamics (staleness-discounted stale rounds really do contribute
    less).  Opt-in via ``--cnn-sats`` (minutes of wall time, not CI)."""
    import jax

    from repro.configs import MNIST_CNN
    from repro.core.constellation import WalkerDelta
    from repro.data import class_conditional_images, paper_noniid_partition
    from repro.fl import Evaluator, ImageClassifierPool
    from repro.models import cnn

    assert num_sats % 8 == 0, "num_sats must be a multiple of 8 (orbits)"
    const = WalkerDelta(num_orbits=num_sats // 8, sats_per_orbit=8,
                        altitude_m=2000e3, inclination_deg=80.0)
    cfg = dataclasses.replace(MNIST_CNN, conv_channels=(4, 8), hidden=32)
    imgs, labs = class_conditional_images(0, 3000, separation=1.2)
    ti, tl = class_conditional_images(99, 500, separation=1.2)
    shards = paper_noniid_partition(labs, const.orbit_ids(), 0)
    pool = ImageClassifierPool(cfg, imgs, labs, shards, local_iters=20,
                               lr=0.05)
    ev = Evaluator(cfg, ti, tl)
    w0 = jax.device_get(cnn.init_params(jax.random.PRNGKey(0), cfg))

    out = {"num_sats": num_sats, "target_accuracy": target, "rows": []}
    for name, strategy in (("async_asyncfleo", "asyncfleo-gs"),
                           ("async_pipelined", "asyncfleo-pipelined"),
                           ("sync_gs_fedavg", "fedisl")):
        sim = SimConfig(duration_s=duration_s, dt_s=30.0, train_time_s=300.0,
                        use_model_bank=True, use_fused_step=True,
                        event_driven=True)
        fls = FLSimulation(get_strategy(strategy), pool, ev, sim,
                           constellation=const)
        rt = EventDrivenRuntime(fls)
        # staleness-discounted pipelined rounds contribute smaller steps,
        # so the pipeline gets a proportionally larger epoch budget (it
        # fits them in less simulated time — that trade is the point)
        budget = max_epochs * (2 if strategy == "asyncfleo-pipelined"
                               else 1)
        t0 = time.perf_counter()
        hist = rt.run(w0, max_epochs=budget, target_accuracy=target)
        wall = time.perf_counter() - t0
        conv = convergence_time(hist, target)
        row = {
            "policy": name,
            "strategy": strategy,
            "convergence_delay_s": conv,
            "epochs_to_target": (len(hist) if conv is not None else None),
            "final_accuracy": float(hist[-1].accuracy) if hist else None,
            "aggregations": len(hist),
            "sched_stats": dict(rt.stats),
            "wall_s": wall,
        }
        out["rows"].append(row)
        conv_h = conv / 3600.0 if conv is not None else float("nan")
        acc = (row["final_accuracy"] if row["final_accuracy"] is not None
               else float("nan"))
        print(f"[cnn S={num_sats}] {name:18s}: "
              f"conv_delay {conv_h:8.2f} h"
              f"  aggs {len(hist)}  final_acc {acc:.3f}"
              f"  wall {wall:.1f} s")
    return out


def policy_sweep(w0, target: float, max_epochs: int, duration_s: float,
                 n_scenarios: int, ps_channels: Optional[int] = None) -> Dict:
    """Percentile-band Monte-Carlo sweep (DESIGN.md §13): the async /
    pipelined / sync head-to-head over ``n_scenarios`` seeds per policy,
    all 3 x n scenarios multiplexed through ONE DispatchBatcher so the
    whole sweep costs a handful of physical device programs.  Emits one
    band cell per policy (p10/p50/p90 over convergence delay, epochs to
    target, final accuracy, aggregations, plus the draw spec) and the
    sweep-wide dispatch economy (logical = what the same scenarios cost
    sequentially, a parity invariant; physical = programs actually
    launched, counted by the PR 8 DispatchProfiler).  Under
    ``--fail-if-not-lower`` the async<sync and pipelined<=async gates
    move onto the p50 band, and physical < logical is itself a gate."""
    from repro.sweep import (DispatchBatcher, ScenarioSpec, grid,
                             reduce_results, run_scenarios)
    seeds = list(range(n_scenarios))
    rows = POLICY_ROWS[:3]
    base = ScenarioSpec(duration_s=duration_s, dt_s=30.0,
                        train_time_s=300.0, ps_channels=ps_channels)
    specs = grid(base, strategy=[s for _, s in rows], seed=seeds)
    prof = DispatchProfiler()
    batcher = DispatchBatcher(mode="exact", profiler=prof)
    t0 = time.perf_counter()
    results = run_scenarios(specs, w0, batched=True, max_epochs=max_epochs,
                            target_accuracy=target, batcher=batcher)
    wall = time.perf_counter() - t0
    by_strategy: Dict[str, list] = {}
    for spec, res in zip(specs, results):
        by_strategy.setdefault(spec.strategy, []).append(res)
    cells = []
    for name, strategy in rows:
        rs = by_strategy[strategy]
        bands = reduce_results(rs)
        cells.append({
            "policy": name, "strategy": strategy,
            "n_scenarios": len(rs),
            "draw": {"kind": "grid", "axes": {"seed": seeds}},
            "bands": bands,
            "logical_dispatches": sum(r.dispatches + r.fallback_dispatches
                                      for r in rs),
        })
        band = bands["convergence_delay_s"]
        print(f"[sweep n={len(rs)}] {name:18s}: conv_delay p50 "
              f"{_h(band['p50'])} (p10 {_h(band['p10'])}, "
              f"p90 {_h(band['p90'])}, {band['n_failed']} failed)")
    logical = sum(r.dispatches + r.fallback_dispatches for r in results)
    print(f"[sweep] dispatch economy: {batcher.physical_dispatches} "
          f"physical vs {logical} logical "
          f"(max group {batcher.max_group})")
    return {
        "n_scenarios": len(specs), "target": target,
        "cells": cells,
        "dispatch_economy": {
            "logical_dispatches": logical,
            "physical_dispatches": batcher.physical_dispatches,
            "batcher": batcher.summary(),
            "profile": prof.summary(),
        },
        "wall_s": wall,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", type=float, default=0.9)
    ap.add_argument("--max-epochs", type=int, default=30)
    ap.add_argument("--days", type=float, default=3.0)
    ap.add_argument("--out", default="BENCH_sched.json")
    ap.add_argument("--fail-if-not-lower", action="store_true",
                    help="exit 1 unless AsyncFLEO's convergence delay is "
                         "strictly lower than the sync GS-FedAvg baseline, "
                         "the pipelined runtime's is no higher than "
                         "single-round async, and async still strictly "
                         "beats sync in the ps_channels=1 cell at the "
                         "lowest swept rate (unless the sweep is skipped)")
    ap.add_argument("--ps-channels", type=int, default=None,
                    help="finite per-PS link capacity for the MAIN policy "
                         "rows (StrategySpec.ps_channels; <=0 or omitted "
                         "= infinite parallelism)")
    ap.add_argument("--skip-contention-sweep", action="store_true",
                    help="skip the (rate_bps x ps_channels) contention "
                         "sweep cells")
    ap.add_argument("--skip-fault-sweep", action="store_true",
                    help="skip the (dropout x compute spread x staleness "
                         "fn) robustness sweep cells")
    ap.add_argument("--trace-out", default=None,
                    help="emit a Perfetto-loadable Chrome trace of the "
                         "pipelined async row to this path (plus JSONL "
                         "next to it) and gate tracer=None bit-parity, "
                         "trace schema validity, and >=1 round span per "
                         "committed epoch (DESIGN.md §12)")
    ap.add_argument("--cnn-sats", type=int, default=0,
                    help="also run the accuracy-aware CNN study at this "
                         "constellation size (>= 200 for the ROADMAP item; "
                         "0 = skip)")
    ap.add_argument("--cnn-target", type=float, default=0.55,
                    help="target test accuracy for the CNN study")
    ap.add_argument("--cnn-max-epochs", type=int, default=10)
    ap.add_argument("--scale-sats", type=int, default=0,
                    help="run the mega-constellation scale smoke cell at "
                         "this constellation size over a hapring of "
                         "--scale-ps parameter servers with sparse "
                         "contact compilation (DESIGN.md §14); 0 = skip")
    ap.add_argument("--scale-ps", type=int, default=4,
                    help="parameter servers in the scale cell's hapring")
    ap.add_argument("--scale-epochs", type=int, default=2,
                    help="event-driven epochs the scale cell must commit")
    ap.add_argument("--scale-budget-s", type=float, default=0.0,
                    help="explicit wall-clock budget for the scale cell "
                         "(compile + run); exceeded => exit 1, so scale "
                         "cannot rot (0 = report only, no gate)")
    ap.add_argument("--scale-only", action="store_true",
                    help="run ONLY the scale smoke cell (the CI scale "
                         "step: everything else lives in the main "
                         "benchmark invocation)")
    ap.add_argument("--sweep", type=int, default=0,
                    help="run the batched Monte-Carlo policy sweep with "
                         "this many seeds per policy cell (DESIGN.md "
                         "§13): p10/p50/p90 band rows land in the "
                         "report's 'sweep' section and, under "
                         "--fail-if-not-lower, the async<sync and "
                         "pipelined<=async gates move onto the p50 band "
                         "plus a physical<logical dispatch-economy gate; "
                         "0 = skip (single-seed gates)")
    args = ap.parse_args()

    if args.scale_only:
        if not args.scale_sats:
            raise SystemExit("--scale-only requires --scale-sats")
        row = scale_smoke(args.target, args.scale_epochs,
                          args.scale_sats, args.scale_ps)
        report = {"scale_smoke": row}
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")
        if row["epochs"] < args.scale_epochs:
            raise SystemExit(
                f"scale smoke committed only {row['epochs']} epochs "
                f"(expected {args.scale_epochs})")
        if args.scale_budget_s and row["wall_s"] > args.scale_budget_s:
            raise SystemExit(
                f"scale smoke wall clock {row['wall_s']:.1f} s exceeded "
                f"the {args.scale_budget_s:.0f} s budget "
                f"(S={args.scale_sats}, P={args.scale_ps})")
        return

    w0 = make_model()
    main_channels = (args.ps_channels if args.ps_channels
                     and args.ps_channels > 0 else None)
    report = {"target": args.target, "ps_channels": main_channels,
              "policies": []}
    for name, strategy in POLICY_ROWS:
        # per-arrival aggregations are single-model EMA steps, so FedAsync
        # needs ~participants-per-round more of them per unit of progress
        budget = (args.max_epochs * 20 if strategy == "fedasync"
                  else args.max_epochs)
        r = bench_policy(name, strategy, w0, args.target, budget,
                         args.days * 86400.0, ps_channels=main_channels)
        conv = r["convergence_delay_s"]
        print(f"{name:22s} ({strategy:13s}): conv_delay "
              f"{conv / 3600.0 if conv else float('nan'):8.2f} h  "
              f"epochs {r['epochs_to_target']}  "
              f"dispatches {r['fused_dispatches']}  wall {r['wall_s']:.2f} s")
        report["policies"].append(r)

    by_name = {r["policy"]: r for r in report["policies"]}
    a = by_name["async_asyncfleo"]["convergence_delay_s"]
    p = by_name["async_pipelined"]["convergence_delay_s"]
    s = by_name["sync_gs_fedavg"]["convergence_delay_s"]
    report["async_vs_sync_speedup"] = (s / a if a and s else None)
    report["pipelined_vs_async_speedup"] = (a / p if a and p else None)
    if report["async_vs_sync_speedup"]:
        print(f"async/sync convergence-delay speedup: "
              f"{report['async_vs_sync_speedup']:.1f}x")
    if report["pipelined_vs_async_speedup"]:
        print(f"pipelined/single-round async speedup: "
              f"{report['pipelined_vs_async_speedup']:.2f}x")

    if args.trace_out:
        report["trace_smoke"] = trace_smoke(
            w0, args.target, args.max_epochs, args.days * 86400.0,
            args.trace_out)

    if not args.skip_contention_sweep:
        report["contention_sweep"] = contention_sweep(
            w0, args.target, args.max_epochs, args.days * 86400.0)

    if not args.skip_fault_sweep:
        report["fault_sweep"] = fault_sweep(
            w0, args.target, args.max_epochs, args.days * 86400.0,
            ps_channels=main_channels)
        # §11 defaults bit-parity row: an EXPLICIT FaultModel() — every
        # new axis at its default — must reproduce the fault=None main
        # async row exactly (gated below)
        from repro.sched import FaultModel
        report["fault_defaults_parity"] = bench_policy(
            "async_fault_defaults", "asyncfleo-gs", w0, args.target,
            args.max_epochs, args.days * 86400.0,
            ps_channels=main_channels, fault=FaultModel())
        report["outage_smoke"] = outage_smoke(
            w0, args.target, args.max_epochs, args.days * 86400.0)

    if args.sweep:
        report["sweep"] = policy_sweep(
            w0, args.target, args.max_epochs, args.days * 86400.0,
            args.sweep, ps_channels=main_channels)

    if args.cnn_sats:
        report["cnn_study"] = cnn_study(args.cnn_sats, args.cnn_target,
                                        args.cnn_max_epochs,
                                        args.days * 86400.0)

    if args.scale_sats:
        report["scale_smoke"] = scale_smoke(
            args.target, args.scale_epochs, args.scale_sats, args.scale_ps)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if args.scale_sats:
        row = report["scale_smoke"]
        if row["epochs"] < args.scale_epochs:
            raise SystemExit(
                f"scale smoke committed only {row['epochs']} epochs "
                f"(expected {args.scale_epochs})")
        if args.scale_budget_s and row["wall_s"] > args.scale_budget_s:
            raise SystemExit(
                f"scale smoke wall clock {row['wall_s']:.1f} s exceeded "
                f"the {args.scale_budget_s:.0f} s budget")

    if args.fail_if_not_lower:
        if args.sweep:
            # distributional gates (DESIGN.md §13): with band rows
            # available, the async<sync and pipelined<=async orderings
            # gate on the MEDIAN over the seed draw instead of one seed
            bands = {c["policy"]: c["bands"]["convergence_delay_s"]
                     for c in report["sweep"]["cells"]}
            a50 = bands["async_asyncfleo"]["p50"]
            p50 = bands["async_pipelined"]["p50"]
            s50 = bands["sync_gs_fedavg"]["p50"]
            if a50 is None or s50 is None or not a50 < s50:
                raise SystemExit(
                    f"p50 async convergence delay ({a50}) not strictly "
                    f"lower than p50 sync ({s50}) over "
                    f"{report['sweep']['n_scenarios']} scenarios")
            if p50 is None or not p50 <= a50:
                raise SystemExit(
                    f"p50 pipelined convergence delay ({p50}) worse "
                    f"than p50 single-round async ({a50})")
            econ = report["sweep"]["dispatch_economy"]
            if not econ["physical_dispatches"] < econ["logical_dispatches"]:
                raise SystemExit(
                    f"sweep dispatch economy broken: "
                    f"{econ['physical_dispatches']} physical programs "
                    f"for {econ['logical_dispatches']} logical "
                    f"dispatches (batching bought nothing)")
        elif a is None or s is None or not a < s:
            raise SystemExit(
                f"async convergence delay ({a}) not strictly lower than "
                f"sync ({s})")
        if not args.sweep and (p is None or not p <= a):
            raise SystemExit(
                f"pipelined convergence delay ({p}) worse than "
                f"single-round async ({a})")
        if not args.skip_contention_sweep:
            # the paper-relevant NEW ordering: async must beat sync even
            # when a single-channel PS serializes every transfer at the
            # bandwidth-constrained rate (DESIGN.md §9)
            cell = next(c for c in report["contention_sweep"]["cells"]
                        if c["ps_channels"] == 1
                        and c["rate_bps"] == min(CONTENTION_RATES))
            by = {r["policy"]: r["convergence_delay_s"]
                  for r in cell["rows"]}
            ac, sc = by["async_asyncfleo"], by["sync_gs_fedavg"]
            if ac is None or sc is None or not ac < sc:
                raise SystemExit(
                    f"contended async convergence delay ({ac}) not "
                    f"strictly lower than contended sync ({sc}) at "
                    f"ps_channels=1, rate={min(CONTENTION_RATES)} bps")
        if not args.skip_fault_sweep:
            # off-switch parity pin (DESIGN.md §10): the all-off fault
            # cell must reproduce the main async row EXACTLY — the fault
            # layer with fault_model=None is bit-identical to not having
            # the layer at all
            null = next(c["row"] for c in report["fault_sweep"]["cells"]
                        if c["dropout"] == 0.0
                        and c["compute_rate_spread"] == 0.0
                        and c["staleness_fn"] == "eq13")
            ref = by_name["async_asyncfleo"]
            keys = ("convergence_delay_s", "epochs_to_target",
                    "final_accuracy", "aggregations", "fused_dispatches")
            drift = [k for k in keys if null[k] != ref[k]]
            if drift:
                raise SystemExit(
                    f"fault off-switch parity broken: null fault cell "
                    f"differs from the main async row on {drift}")
            # and the robustness claim: async still converges with one
            # transfer in five dropped (retry/backoff absorbs the loss)
            bad = [c for c in report["fault_sweep"]["cells"]
                   if c["dropout"] == max(FAULT_DROPOUTS)
                   and c["row"]["convergence_delay_s"] is None]
            if bad:
                raise SystemExit(
                    f"{len(bad)} dropout={max(FAULT_DROPOUTS)} fault "
                    f"cells failed to reach the target accuracy")
            # §11 defaults bit-parity gate: the explicit-FaultModel()
            # row (burst / outage / energy / adaptive-backoff axes all
            # at their defaults) must match the fault=None main async
            # row on every deterministic key — the new axes' off
            # switches are bit-exact, not just approximately quiet
            null_fm = report["fault_defaults_parity"]
            drift = [k for k in keys if null_fm[k] != ref[k]]
            if drift:
                raise SystemExit(
                    f"§11 defaults parity broken: explicit FaultModel() "
                    f"row differs from the main async row on {drift}")
            # §11 outage smoke gate: pipelined async must still reach
            # the target with one ring HAP dark for a contiguous 30% of
            # the horizon (ring failover + arrival reroutes)
            if report["outage_smoke"]["row"]["convergence_delay_s"] is None:
                raise SystemExit(
                    "outage smoke cell failed: pipelined async did not "
                    "reach the target with one PS dark 30% of the horizon")


if __name__ == "__main__":
    main()
