# The paper's primary contribution: AsyncFLEO's topology, propagation,
# grouping, staleness-discounted aggregation, and the discrete-event
# simulation that turns orbital mechanics into FL convergence times.
from repro.core.constellation import (
    WalkerDelta, GroundNode, paper_constellation, make_ps_nodes,
    R_EARTH, C_LIGHT,
)
from repro.core.visibility import VisibilityTimeline, elevation_deg, sat_los
from repro.core.links import LinkModel, model_bits
from repro.core.topology import RingOfStars
from repro.core.propagation import PropagationModel
from repro.core.grouping import GroupingState, group_by_gaps, model_distance
from repro.core.modelbank import FlatSpec, ModelBank
from repro.core.aggregation import (
    SatelliteMeta, fedavg, asyncfleo_aggregate, staleness_gamma, weighted_sum,
    dedup,
)
from repro.core.simulator import FLSimulation, SimConfig, EpochRecord, convergence_time
