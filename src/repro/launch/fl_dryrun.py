import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Dry-run of the constellation-parallel FL round (the paper's technique on
the TPU mesh, DESIGN.md §3): satellites on the data axis, J local SGD steps
each, ISL-ring ppermute propagation, staleness-weighted psum aggregation.

    PYTHONPATH=src python -m repro.launch.fl_dryrun [--multi-pod] \
        [--sats-per-device 1] [--out out.json]

The per-satellite model is the paper's CNN scaled to LLM-block size via the
qwen3-4b reduced config; the lowering proves the collective schedule of the
asynchronous aggregation is coherent at 256/512 chips.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.fl.sharded import make_fl_round
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import registry as R


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--sats-per-device", type=int, default=1)
    ap.add_argument("--local-iters", type=int, default=4)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_sat_devices = axis_sizes["data"] * axis_sizes.get("pod", 1)
    num_sats = n_sat_devices * args.sats_per_device

    cfg = get_config(args.arch).reduced().replace(
        remat=False, num_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 4, vocab_size=8192)

    def loss_fn(params, batch):
        loss, _ = R.train_loss(params, cfg, {"tokens": batch})
        return loss

    fl_round = make_fl_round(
        loss_fn, mesh, local_iters=args.local_iters, lr=0.01,
        pod_axis="pod" if args.multi_pod else None)

    p_spec = jax.eval_shape(lambda k: R.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    batches = jax.ShapeDtypeStruct(
        (num_sats, args.local_iters, args.batch, args.seq), jnp.int32)
    weights = jax.ShapeDtypeStruct((num_sats,), jnp.float32)

    t0 = time.time()
    lowered = jax.jit(fl_round).lower(p_spec, batches, weights)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    n_params = sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree_util.tree_leaves(p_spec))
    result = {
        "kind": "fl_round", "mesh_shape": list(mesh.devices.shape),
        "num_sats": num_sats, "local_iters": args.local_iters,
        "per_sat_params": n_params,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "collective_bytes": coll,
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
    }
    print(json.dumps(result))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
