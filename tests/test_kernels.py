"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_scan.ops import chunk_scan
from repro.kernels.chunk_scan.ref import chunk_scan_ref
from repro.kernels.fed_agg.ops import fed_agg, fed_agg_pytree
from repro.kernels.fed_agg.ref import fed_agg_flat_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pairwise_dist.ops import pairwise_dist, model_pairwise_dist
from repro.kernels.pairwise_dist.ref import pairwise_dist_sq_ref

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 2, 2, 64),
    (2, 256, 4, 2, 64),
    (1, 200, 4, 1, 32),      # non-multiple-of-block seq, strong GQA
    (2, 64, 8, 8, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 48), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = (jax.random.normal(ks[0], (B, S, H, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, KV, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, KV, hd)) * 0.5).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    kk, vv = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)

    def fl(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = attention_ref(fl(q), fl(kk), fl(vv), causal=causal, window=window)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# --------------------------------------------------------------------------
# chunk_scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,K,V,chunk", [
    (1, 64, 2, 8, 16, 16),
    (2, 128, 3, 16, 32, 32),
    (1, 96, 1, 4, 64, 32),
])
@pytest.mark.parametrize("mode", ["rwkv", "mamba"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_scan_sweep(B, T, H, K, V, chunk, mode, dtype):
    ks = jax.random.split(KEY, 7)
    r = (jax.random.normal(ks[0], (B, T, H, K)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (B, T, H, K)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (B, T, H, V)) * 0.3).astype(dtype)
    s0 = jax.random.normal(ks[3], (B, H, K, V)) * 0.1
    if mode == "rwkv":
        ld = -jax.random.uniform(ks[4], (B, T, H, K)) * 0.8
        u = jax.random.normal(ks[5], (H, K)) * 0.2
        kw = dict(include_current=False, bonus=u)
    else:
        ld = -jax.random.uniform(ks[4], (B, T, H)) * 0.8
        kw = dict(include_current=True)
    y, s_fin = chunk_scan(r, k, v, ld, s0, chunk=chunk, **kw)
    y_ref, s_ref = chunk_scan_ref(r, k, v, ld, s0, **kw)
    tol = 5e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol, rtol=0.1)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               atol=tol, rtol=0.1)


# --------------------------------------------------------------------------
# fed_agg
# --------------------------------------------------------------------------

@pytest.mark.parametrize("C,N", [(2, 100), (7, 10_000), (16, 2048), (3, 5000)])
@pytest.mark.parametrize("base_weight", [0.0, 0.35])
def test_fed_agg_sweep(C, N, base_weight):
    ks = jax.random.split(KEY, 3)
    stack = jax.random.normal(ks[0], (C, N))
    gamma = jax.random.uniform(ks[1], (C,)) / C
    base = jax.random.normal(ks[2], (N,))
    out = fed_agg(stack, gamma, base, base_weight)
    ref = fed_agg_flat_ref(stack, gamma, base, base_weight)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_fed_agg_pytree_matches_treemap():
    rng = np.random.default_rng(0)
    models = [{"a": rng.standard_normal((5, 3)).astype(np.float32),
               "b": rng.standard_normal((7,)).astype(np.float32)}
              for _ in range(4)]
    base = {"a": rng.standard_normal((5, 3)).astype(np.float32),
            "b": rng.standard_normal((7,)).astype(np.float32)}
    gamma = np.array([0.1, 0.2, 0.3, 0.1], np.float32)
    out = fed_agg_pytree(models, gamma, base, 0.3)
    expect_a = 0.3 * base["a"] + sum(g * m["a"] for g, m in zip(gamma, models))
    np.testing.assert_allclose(np.asarray(out["a"]), expect_a, atol=1e-5)


# --------------------------------------------------------------------------
# pairwise_dist
# --------------------------------------------------------------------------

@pytest.mark.parametrize("M,N", [(2, 50), (5, 9000), (8, 4096), (3, 4097)])
def test_pairwise_dist_sweep(M, N):
    x = jax.random.normal(jax.random.fold_in(KEY, N), (M, N))
    d = pairwise_dist(x, squared=True)
    ref = pairwise_dist_sq_ref(x)
    scale = float(jnp.maximum(ref.max(), 1.0))
    np.testing.assert_allclose(np.asarray(d) / scale, np.asarray(ref) / scale,
                               atol=1e-5)
    # diagonal ~ 0, symmetric
    assert float(jnp.abs(jnp.diagonal(d)).max()) / scale < 1e-4
    np.testing.assert_allclose(np.asarray(d), np.asarray(d).T, atol=1e-3)


def test_model_pairwise_dist():
    models = [{"w": np.full((3, 2), float(v), np.float32)} for v in (0, 1, 3)]
    d = model_pairwise_dist(models)
    np.testing.assert_allclose(np.asarray(d)[0, 1], np.sqrt(6.0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(d)[0, 2], np.sqrt(54.0), rtol=1e-5)
