import numpy as np
import pytest

from repro.core.constellation import (GroundNode, R_EARTH, WalkerDelta,
                                      make_ps_nodes, paper_constellation,
                                      OMEGA_EARTH)


def test_kepler_period():
    c = paper_constellation()
    # 2000 km LEO: ~127 min
    assert 120 * 60 < c.period_s < 135 * 60
    # v = sqrt(GM/r) ~ 6.9 km/s at 2000 km
    assert 6.5e3 < c.velocity < 7.2e3


def test_positions_on_sphere():
    c = paper_constellation()
    for t in [0.0, 1234.5, c.period_s * 1.37]:
        pos = c.positions(t)
        assert pos.shape == (40, 3)
        np.testing.assert_allclose(np.linalg.norm(pos, axis=-1),
                                   c.radius_m, rtol=1e-9)


def test_positions_periodicity():
    c = paper_constellation()
    np.testing.assert_allclose(c.positions(0.0), c.positions(c.period_s),
                               atol=1e-3)


def test_equal_spacing_in_orbit():
    c = paper_constellation()
    pos = c.positions(0.0)
    o0 = pos[:8]
    # adjacent satellites in one orbit are equally spaced (same chord)
    chords = [np.linalg.norm(o0[i] - o0[(i + 1) % 8]) for i in range(8)]
    np.testing.assert_allclose(chords, chords[0], rtol=1e-9)


def test_ground_node_rotates_with_earth():
    g = GroundNode("x", 37.95, -91.77, 0.0)
    p0 = g.position(0.0)
    day = 2 * np.pi / OMEGA_EARTH
    np.testing.assert_allclose(p0, g.position(day), atol=1e-3)
    assert np.linalg.norm(g.position(1000.0) - p0) > 1e3


def test_ground_node_radius():
    g = GroundNode("h", 0.0, 0.0, 20e3, kind="hap")
    np.testing.assert_allclose(np.linalg.norm(g.position(0.0)),
                               R_EARTH + 20e3, rtol=1e-12)


def test_ps_scenarios():
    assert len(make_ps_nodes("gs")) == 1
    assert len(make_ps_nodes("twohap")) == 2
    assert make_ps_nodes("gs-np")[0].lat_deg == 90.0
    assert make_ps_nodes("hap")[0].altitude_m == 20e3
    with pytest.raises(ValueError):
        make_ps_nodes("bogus")


def test_orbit_indexing():
    c = paper_constellation()
    assert c.orbit_of(0) == 0 and c.orbit_of(39) == 4
    assert list(c.orbit_ids()[:9]) == [0] * 8 + [1]
