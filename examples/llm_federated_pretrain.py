"""End-to-end driver (deliverable b): federated pretraining of a ~100M-param
transformer across the LEO constellation for a few hundred aggregate steps.

Each satellite holds a shard of a synthetic token stream; AsyncFLEO
orchestrates local AdamW training and staleness-discounted aggregation over
the real orbital timeline.  Any assigned architecture works via --arch
(reduced preset keeps it CPU-sized; ~100M via --layers/--d-model overrides).

    PYTHONPATH=src python examples/llm_federated_pretrain.py \
        --arch qwen3-4b --epochs 3 --sats 8
"""
import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import FLSimulation, SimConfig
from repro.core.constellation import WalkerDelta
from repro.data.synthetic import token_stream
from repro.fl import LMPool, get_strategy
from repro.models import registry as R


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--sats", type=int, default=8, help="satellites (1 orbit x N)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seqs-per-sat", type=int, default=32)
    ap.add_argument("--local-iters", type=int, default=4)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--size-mode", choices=["on_board", "trained"],
                    default="on_board",
                    help="what D_n the eq. 13/14 weights use: the full "
                         "on-board shard (paper) or the truncated count "
                         "the vmap trained on (DESIGN.md §3)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().replace(
        remat=False, dtype="float32",
        num_layers=args.layers if args.arch not in ("zamba2-2.7b",) else 4,
        d_model=args.d_model)
    n_params = None

    const = WalkerDelta(num_orbits=2, sats_per_orbit=args.sats // 2,
                        altitude_m=2000e3)
    toks = token_stream(0, args.sats * args.seqs_per_sat * args.seq,
                        cfg.vocab_size).reshape(-1, args.seq)
    shards = np.array_split(np.arange(len(toks)), const.num_sats)
    pool = LMPool(cfg, toks, shards, local_iters=args.local_iters,
                  batch_size=4, size_mode=args.size_mode)

    params = R.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"{args.arch} reduced: {n_params/1e6:.1f}M params, "
          f"{const.num_sats} satellites, {len(toks)} sequences")

    # evaluator: held-out perplexity
    import jax.numpy as jnp
    eval_toks = jnp.asarray(token_stream(7, 16 * args.seq,
                                         cfg.vocab_size).reshape(16, args.seq))

    def evaluator(p):
        loss, _ = R.train_loss(p, cfg, {"tokens": eval_toks})
        return float(-loss)            # higher is better for the simulator

    w0 = jax.device_get(params)
    sim = FLSimulation(get_strategy("asyncfleo-hap"), pool, evaluator,
                       SimConfig(duration_s=86400.0, train_time_s=300.0),
                       constellation=const)
    t0 = time.time()
    hist = sim.run(w0, max_epochs=args.epochs)
    for r in hist:
        print(f"epoch {r.epoch}  sim {r.time_s/3600:.2f}h  "
              f"eval_loss {-r.accuracy:.4f}  models {r.num_models}")
    total_steps = sum(r.num_models for r in hist) * args.local_iters
    print(f"aggregate local steps: {total_steps}  wall {time.time()-t0:.0f}s")
    assert np.isfinite(hist[-1].accuracy)
    print("OK: federated LM pretraining converging "
          f"(loss {-hist[0].accuracy:.3f} -> {-hist[-1].accuracy:.3f})")


if __name__ == "__main__":
    main()
