"""rwkv6-7b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=14336, vocab_size=65536,
    use_rope=False,
    ssm_heads=64, head_dim=64, chunk_size=128,
    citation="arXiv:2404.05892",
)
