"""Benchmark artifact IO: JSON results under benchmarks/artifacts/."""
from __future__ import annotations

import dataclasses
import json
import os
import time

ARTIFACT_DIR = os.environ.get(
    "REPRO_ARTIFACTS",
    os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "benchmarks", "artifacts"))


def _default(o):
    if dataclasses.is_dataclass(o):
        return dataclasses.asdict(o)
    if hasattr(o, "tolist"):
        return o.tolist()
    if hasattr(o, "item"):
        return o.item()
    return str(o)


def emit(name: str, payload) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump({"name": name, "ts": time.time(), "data": payload},
                  f, indent=1, default=_default)
    return path


def load(name: str):
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path) as f:
        return json.load(f)["data"]
