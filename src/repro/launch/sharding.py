"""Logical-axis sharding rules (MaxText-style, data not code).

Every parameter / cache / batch leaf is classified into a tuple of *logical*
dimension names by (leaf name, rank); a rules dict maps logical names to mesh
axes.  ``partition_spec`` additionally enforces divisibility — a dimension
that does not divide by its mesh-axis size is silently replicated (e.g.
starcoder2's kv_heads=2 or internvl2's 14 query heads on a 16-way model
axis), which keeps every (arch x mesh) combination lowerable without
per-arch special cases.

Rule sets are the main §Perf lever:
  BASE_RULES  — tensor parallelism on 'model', batch on ('pod','data').
  FSDP_RULES  — adds ZeRO-3-style parameter/optimizer sharding: the 'embed'
                dimension of weight matrices shards over 'data'.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Tuple[Optional[str], ...]

# ---- leaf classification ----------------------------------------------------

_NAME_RULES: Dict[Tuple[str, int], Logical] = {
    # embeddings / head
    ("embedding", 2): ("vocab", "embed"),
    ("unembed", 2): ("embed", "vocab"),
    ("pos_embed", 2): (None, "embed"),
    # attention (dense GQA)
    ("wq", 3): ("embed", "heads", "head"),
    ("wk", 3): ("embed", "kv_heads", "head"),
    ("wv", 3): ("embed", "kv_heads", "head"),
    ("wo", 3): ("heads", "head", "embed"),
    # MLP / MoE (routed-expert weights are named we* so the stacked dense
    # (layer, d, f) tensors never collide with the (expert, d, f) rule)
    ("w1", 2): ("embed", "mlp"),
    ("w3", 2): ("embed", "mlp"),
    ("w2", 2): ("mlp", "embed"),
    ("we1", 3): ("expert", "embed", "moe_mlp"),
    ("we3", 3): ("expert", "embed", "moe_mlp"),
    ("we2", 3): ("expert", "moe_mlp", "embed"),
    ("router", 2): ("embed", "expert"),
    # MLA
    ("w_dkv", 2): ("embed", "kv_lora"),
    ("w_kr", 2): ("embed", None),
    ("w_uk", 3): ("kv_lora", "heads", "head"),
    ("w_uv", 3): ("kv_lora", "heads", "head"),
    ("w_dq", 2): ("embed", "q_lora"),
    ("w_uq", 3): ("q_lora", "heads", "head"),
    # RWKV (time-mix projections are tm_w* to avoid dense-attention collisions)
    ("tm_wr", 2): ("embed", "inner"),
    ("tm_wg", 2): ("embed", "inner"),
    ("tm_wk", 2): ("embed", "inner"),
    ("tm_wv", 2): ("embed", "inner"),
    ("tm_wo", 2): ("inner", "embed"),
    ("tm_w1", 2): ("embed", None),
    ("tm_w2", 3): (None, None, "embed"),
    ("td_w1", 2): ("embed", None),
    ("td_w2", 2): (None, "embed"),
    ("cm_wk", 2): ("embed", "mlp"),
    ("cm_wv", 2): ("mlp", "embed"),
    ("cm_wr", 2): ("embed", "inner"),
    ("u", 2): ("heads", "head"),
    # Mamba
    ("in_proj", 2): ("embed", "inner"),
    ("conv_w", 2): (None, "inner"),
    ("out_proj", 2): ("inner", "embed"),
    # decode caches
    ("k", 5): ("layer", "batch", "kv_seq", "kv_heads", "head"),
    ("v", 5): ("layer", "batch", "kv_seq", "kv_heads", "head"),
    ("attn_k", 5): ("layer", "batch", "kv_seq", "kv_heads", "head"),
    ("attn_v", 5): ("layer", "batch", "kv_seq", "kv_heads", "head"),
    ("c_kv", 4): ("layer", "batch", "kv_seq", "kv_lora"),
    ("k_rope", 4): ("layer", "batch", "kv_seq", None),
    ("ssm", 5): ("layer", "batch", "heads", None, None),
    ("ssm", 6): ("layer", None, "batch", "heads", None, None),
    ("conv", 4): ("layer", "batch", None, "inner"),
    ("conv", 5): ("layer", None, "batch", None, "inner"),
    ("wkv", 5): ("layer", "batch", "heads", "head", None),
    ("tm_x", 3): ("layer", "batch", "embed"),
    ("cm_x", 3): ("layer", "batch", "embed"),
}


def classify_leaf(name: str, ndim: int) -> Logical:
    """Logical dims for a leaf; extra leading dims (layer stacking / optimizer
    slots) are padded with 'layer'/None on the left."""
    for extra in range(ndim + 1):
        rule = _NAME_RULES.get((name, ndim - extra))
        if rule is not None:
            return (None,) * extra + rule
    return (None,) * ndim


# ---- rules ------------------------------------------------------------------

BASE_RULES: Dict[str, object] = {
    "vocab": "model", "heads": "model", "kv_heads": "model", "mlp": "model",
    "moe_mlp": "model", "expert": "model", "inner": "model",
    "embed": None, "head": None, "kv_lora": None, "q_lora": None,
    "batch": ("pod", "data"), "seq": None, "kv_seq": "data", "layer": None,
}

FSDP_RULES = dict(BASE_RULES, embed="data")

RULE_SETS = {"base": BASE_RULES, "fsdp": FSDP_RULES}


def _mesh_axes(mesh: Mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def partition_spec(shape, logical: Logical, mesh: Mesh,
                   rules: Dict[str, object]) -> P:
    """Resolve logical dims to a PartitionSpec with divisibility checks and
    no mesh axis used twice."""
    sizes = _mesh_axes(mesh)
    used = set()
    out = []
    for dim, lg in zip(shape, logical):
        if lg is None or lg not in rules or rules[lg] is None:
            out.append(None)
            continue
        axes = rules[lg]
        if isinstance(axes, str):
            axes = (axes,)
        picked = []
        rem = dim
        for ax in axes:
            if ax not in sizes or ax in used:
                continue
            if rem % sizes[ax] != 0:
                continue
            picked.append(ax)
            used.add(ax)
            rem //= sizes[ax]
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def tree_shardings(tree, mesh: Mesh, rules: Dict[str, object]):
    """NamedShardings for an arbitrary params/cache/opt-state pytree.
    ``tree`` may hold arrays or ShapeDtypeStructs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = None
        for p in reversed(path):
            key = getattr(p, "key", None)
            if isinstance(key, str) and key not in ("m", "v", "mu"):
                name = key
                break
        name = name or ""
        logical = classify_leaf(name, len(leaf.shape))
        spec = partition_spec(leaf.shape, logical, mesh, rules)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out)


def batch_shardings(batch, mesh: Mesh, rules: Dict[str, object]):
    """Shardings for input batches: leading dim is 'batch', dim 1 is 'seq'."""
    def spec_for(leaf):
        logical: Logical = ("batch",) + ("seq",) + (None,) * (len(leaf.shape) - 2) \
            if len(leaf.shape) >= 2 else ("batch",) * len(leaf.shape)
        return NamedSharding(mesh, partition_spec(leaf.shape, logical, mesh, rules))
    return jax.tree.map(spec_for, batch)


def bank_sharding(mesh: Mesh) -> NamedSharding:
    """The federated model bank's (C, N) layout: the client/participant
    axis shards over "data", the flattened-parameter axis is replicated
    (each device owns whole rows — contractions reduce over C with one
    psum; see ``core/epoch_step.py``)."""
    return NamedSharding(mesh, P("data", None))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def sharded_fraction(tree, shardings) -> float:
    """Fraction of bytes that is sharded (diagnostic for rule coverage)."""
    total = 0
    sharded = 0
    for leaf, sh in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(
                            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += n
        if any(s is not None for s in sh.spec):
            sharded += n
    return sharded / max(total, 1)
