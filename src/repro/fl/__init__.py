from repro.fl.strategies import StrategySpec, STRATEGIES, get_strategy
from repro.fl.client import ImageClassifierPool, Evaluator, LMPool

__all__ = ["StrategySpec", "STRATEGIES", "get_strategy",
           "ImageClassifierPool", "Evaluator", "LMPool"]
