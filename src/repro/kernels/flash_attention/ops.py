"""Public API: GQA-aware flash attention over (B, S, H, hd) layout."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_flat


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0.
    KV heads are repeated to H before the kernel (optimization opportunity:
    group the grid by KV head instead — see EXPERIMENTS.md §Perf)."""
    if interpret is None:
        interpret = default_interpret()
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], hd)

    out = flash_attention_flat(flat(q), flat(k), flat(v), causal=causal,
                               window=window, interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
