"""Model propagation timing (paper §IV-B, Algorithm 1).

Downlink: the source HAP relays the global model around the HAP ring; every
HAP broadcasts to its visible satellites; visible satellites relay along the
intra-orbit ISL ring (two fronts, ceasing where they meet), so invisible
satellites start training with minimal delay.  Orbits with *no* visible
satellite wait for their next pass.

Uplink: a trained local model goes straight up if its satellite sees a HAP,
else it relays along the ring toward the nearest (eventually-)visible
orbit-mate; received sets are relayed along the HAP ring to the sink.

This module converts those rules into per-satellite receive/arrival *times*
(simulated seconds), which is everything the discrete-event simulator needs.
The hot paths are numpy-broadcast vectorized: ``downlink_times`` is one
min-plus relaxation over the (O, N, N) ring-hop grid and ``uplink_many``
times a whole participant set at once (per-satellite Python scans only
survive for the rare no-visibility fallbacks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.links import LinkModel
from repro.core.topology import RingOfStars

_UNREACH = 10 ** 9      # ring distance between different orbits


@dataclasses.dataclass
class PropagationModel:
    topo: RingOfStars
    link: LinkModel

    # ---- primitive hop delays ----------------------------------------------

    def isl_hop_delay(self, bits: float) -> float:
        return self.link.total_delay(bits, self.topo.isl_chord_m())

    def ihl_hop_delay(self, bits: float, a: int, b: int, t: float) -> float:
        return self.link.total_delay(bits, self.topo.ihl_distance(a, b, t))

    def sat_ps_delay(self, bits: float, sat: int, ps: int, t: float) -> float:
        return self.link.total_delay(bits, self.topo.sat_ps_distance(sat, ps, t))

    def ring_relay_delay(self, bits: float, src: int, dst: int, t0,
                         avoid=()):
        """Accumulated IHL delay along the *actual* shorter ring arc
        src -> dst: each successive HAP pair contributes its own delay,
        evaluated at the model's current arrival time.  ``t0`` may be a
        scalar or a vector of per-model send times.

        ``avoid`` (default empty — identical behavior) lists HAPs the
        relay may not transit (e.g. PSs inside an outage window,
        DESIGN.md §11): the relay takes the other ring arc when the
        shorter arc's interior is blocked, and returns +inf when both
        arcs are (the model cannot reach ``dst`` right now)."""
        if avoid:
            path = self.topo.ring_path_via(src, dst, avoid)
            if path is None:
                return np.full_like(np.asarray(t0, np.float64), np.inf) \
                    if np.ndim(t0) else np.inf
        else:
            path = self.topo.ring_path(src, dst)
        t = np.asarray(t0, dtype=np.float64)
        for a, b in zip(path, path[1:]):
            t = t + self.link.total_delay(bits, self.topo.ihl_distance(a, b, t))
        return t - np.asarray(t0, dtype=np.float64)

    # ---- downlink (Alg. 1 lines 2-10) ---------------------------------------

    def hap_receive_times(self, t0: float, bits: float, source: int) -> np.ndarray:
        """Time each HAP holds the global model after the ring relay (walks
        the successive ring pairs, not ``hops x`` one endpoint-pair delay)."""
        H = self.topo.num_ps
        out = np.full(H, float(t0))
        for h in range(H):
            out[h] = t0 + self.ring_relay_delay(bits, source, h, t0)
        return out

    def downlink_times(self, t0: float, bits: float, source: int = 0,
                       contention=None) -> np.ndarray:
        """Per-satellite time of receiving the global model (Alg. 1).
        Vectorized: star broadcasts are per-HAP distance vectors; the ISL
        relay is one broadcast min-plus over the ring-hop matrix.

        ``contention`` (a `sched/contacts.ContentionModel`, optional)
        charges one transmit-channel grant per PS->sat model copy — each
        HAP unicasts the global model to every satellite in its star, so
        finite ``ps_channels`` serialize those transfers per busy interval
        (the transmission time) instead of the pure delay formula.  Every
        *visible* satellite is charged, even one a scheduler will not
        recruit: it still receives the copy and seeds the intra-orbit
        relay for its orbit-mates (Alg. 1 broadcasts unconditionally).
        The ISL relay onward is satellite-to-satellite and the HAP ring a
        dedicated trunk: neither is charged (DESIGN.md §9)."""
        topo = self.topo
        O = topo.constellation.num_orbits
        N = topo.constellation.sats_per_orbit
        S = topo.constellation.num_sats
        recv = np.full(S, np.inf)
        hap_t = self.hap_receive_times(t0, bits, source)

        # star broadcast from each HAP to its visible satellites
        if contention is None:
            for h in range(topo.num_ps):
                vis = topo.star_members(h, hap_t[h])
                if len(vis) == 0:
                    continue
                cand = hap_t[h] + self.link.total_delay(
                    bits, topo.sat_ps_distances(vis, h, hap_t[h]))
                recv[vis] = np.minimum(recv[vis], cand)
        else:
            # per-transfer tx grants (FIFO by request time across HAPs);
            # a queued grant shifts the copy by (start - request), which
            # is exactly 0.0 when the channel is free, so uncontended
            # results stay bit-identical to the vectorized branch
            ps_ids, reqs, frees, sat_ids = [], [], [], []
            for h in range(topo.num_ps):
                vis = topo.star_members(h, hap_t[h])
                if len(vis) == 0:
                    continue
                free = hap_t[h] + self.link.total_delay(
                    bits, topo.sat_ps_distances(vis, h, hap_t[h]))
                free = np.broadcast_to(np.asarray(free, np.float64),
                                       (len(vis),))
                ps_ids.extend([h] * len(vis))
                reqs.extend([hap_t[h]] * len(vis))
                frees.extend(free.tolist())
                sat_ids.extend(int(s) for s in vis)
            if sat_ids:
                t_t = self.link.transmission_delay(bits)
                starts = contention.grant_tx_many(ps_ids, reqs, t_t)
                cand = (np.asarray(frees)
                        + (starts - np.asarray(reqs, np.float64)))
                np.minimum.at(recv, sat_ids, cand)

        # intra-orbit ISL relay from the seeded (visible) satellites:
        # recv[o,i] = min_j recv[o,j] + ringd[j,i] * hop, all orbits at once
        hop = self.isl_hop_delay(bits)
        ringd = topo.isl_ring_distance_matrix()
        recv_on = recv.reshape(O, N)
        relay = (recv_on[:, :, None] + ringd[None] * hop).min(axis=1)
        recv_on = np.minimum(recv_on, relay)

        # orbits with no visible satellite now: wait for the next pass
        for orbit in np.flatnonzero(~np.isfinite(recv_on).any(axis=1)):
            sats = topo.orbit_sats(orbit)
            t_vis, seed = topo.timeline.next_orbit_visible(sats, t0)
            if t_vis is None:
                continue                 # never visible within horizon
            ps = topo.visible_ps_of(seed, t_vis)
            ps0 = ps[0] if ps else 0
            t_seed = (max(t_vis, hap_t[ps0])
                      + self.sat_ps_delay(bits, seed, ps0, t_vis))
            if contention is not None:
                req = max(t_vis, hap_t[ps0])
                start = contention.grant_tx(
                    ps0, req, self.link.transmission_delay(bits))
                t_seed += start - req
            recv_on[orbit] = np.minimum(recv_on[orbit],
                                        t_seed + ringd[seed - sats[0]] * hop)
        return recv_on.reshape(S)

    # ---- uplink (Alg. 1 lines 11-22) ----------------------------------------

    def uplink_many(self, sats: Sequence[int], t_done, bits: float,
                    sink: int, contention=None) -> Tuple[np.ndarray,
                                                         np.ndarray]:
        """Vectorized uplink timing for a whole participant set.

        Returns (arrival times at the sink HAP, first-receiving HAP id) as
        (P,) arrays; inf / -1 where a model never reaches a HAP.

        ``contention`` charges one receive-channel grant per model at its
        first-receiving HAP, held for the transmission time: a PS with
        finite ``ps_channels`` serializes simultaneous uplinks instead of
        absorbing them all at once (DESIGN.md §9).  The onward HAP-ring
        relay to the sink is a dedicated trunk and is not charged.
        """
        topo, tl = self.topo, self.topo.timeline
        sats = np.atleast_1d(np.asarray(sats, dtype=np.int64))
        t_done = np.broadcast_to(np.asarray(t_done, dtype=np.float64),
                                 sats.shape).copy()
        P = len(sats)
        hop = self.isl_hop_delay(bits)
        N = topo.constellation.sats_per_orbit
        ringd = topo.isl_ring_distance_matrix()
        ti = np.clip(np.round(t_done / tl.dt_s).astype(np.int64), 0,
                     len(tl.times) - 1)

        t_at = np.full(P, np.inf)          # arrival at the first HAP
        hap = np.full(P, -1, dtype=np.int64)

        # --- direct: the satellite sees a HAP at t_done ---------------------
        vis = tl.visible_rows(ti, sats)                          # (P, H)
        direct = vis.any(axis=1)
        if direct.any():
            di = np.flatnonzero(direct)
            hsel = np.argmax(vis[di], axis=1)
            for h in np.unique(hsel):
                m = di[hsel == h]
                d = topo.sat_ps_distances(sats[m], int(h), t_done[m])
                t_at[m] = t_done[m] + self.link.total_delay(bits, d)
                hap[m] = h

        # --- relay: a currently visible orbit-mate exists -------------------
        rest = np.flatnonzero(~direct)
        if len(rest):
            orb = sats[rest] // N
            mates = orb[:, None] * N + np.arange(N)[None, :]     # (Q, N)
            mate_vis = tl.visible_rows(ti[rest][:, None], mates)  # (Q, N, H)
            mate_any = mate_vis.any(axis=2)                      # (Q, N)
            has_mate = mate_any.any(axis=1)
            if has_mate.any():
                q = np.flatnonzero(has_mate)
                rd = ringd[sats[rest[q]] % N]                    # (|q|, N)
                rdm = np.where(mate_any[q], rd, _UNREACH)
                jstar = np.argmin(rdm, axis=1)
                s_star = mates[q, jstar]
                d_hops = rdm[np.arange(len(q)), jstar]
                t_arrive = t_done[rest[q]] + d_hops * hop
                hsel = np.argmax(mate_vis[q, jstar, :], axis=1)
                for h in np.unique(hsel):
                    m = hsel == h
                    rows = rest[q[m]]
                    d = topo.sat_ps_distances(s_star[m], int(h), t_arrive[m])
                    t_at[rows] = t_arrive[m] + self.link.total_delay(bits, d)
                    hap[rows] = h

            # --- wait: whole orbit invisible; relay pre-positions -----------
            for qi in np.flatnonzero(~has_mate):
                p = rest[qi]
                t_vis, s_star = tl.next_orbit_visible(
                    topo.orbit_sats(int(sats[p] // N)), float(t_done[p]))
                if t_vis is None:
                    continue
                d = topo.isl_ring_distance(int(sats[p]), int(s_star))
                t_ready = max(t_done[p] + d * hop, t_vis)
                vis2 = topo.visible_ps_of(s_star, t_vis)
                h = vis2[0] if vis2 else 0
                t_at[p] = t_ready + self.sat_ps_delay(bits, s_star, h, t_ready)
                hap[p] = h

        # --- receive contention at the first HAP ----------------------------
        if contention is not None:
            okc = np.flatnonzero(np.isfinite(t_at))
            if len(okc):
                t_t = self.link.transmission_delay(bits)
                # the PS starts receiving at (unconstrained completion -
                # transmission time); a queued grant shifts completion by
                # (start - request), exactly 0.0 when a channel is free
                req = t_at[okc] - t_t
                starts = contention.grant_rx_many(hap[okc], req, t_t)
                t_at[okc] += starts - req

        # --- HAP ring relay to the sink (walks the actual ring path) --------
        out = np.full(P, np.inf)
        ok = np.isfinite(t_at)
        for h in np.unique(hap[ok]):
            m = ok & (hap == h)
            out[m] = t_at[m] + self.ring_relay_delay(bits, int(h), sink,
                                                     t_at[m])
        return out, hap

    def uplink(self, sat: int, t_done: float, bits: float, sink: int,
               contention=None) -> Tuple[float, int]:
        """Arrival time of sat's local model at the *sink* HAP, and the HAP
        that first received it (scalar convenience over ``uplink_many``;
        this single-transfer shape is what the event runtime's
        lossy-transfer retries re-time — each retransmission is a fresh
        uplink, and a fresh rx grant when ``contention`` is given).

        The fault layer (sched/faults.py) never appears here explicitly:
        eclipse windows are ANDed into the visibility grid before the
        plan compiles, so all uplink routing (direct / relay / wait)
        already avoids dark satellites."""
        t_arr, haps = self.uplink_many([sat], [t_done], bits, sink,
                                       contention=contention)
        return float(t_arr[0]), int(haps[0])
