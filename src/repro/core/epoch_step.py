"""One fused, buffer-donated device program per simulated epoch.

PR 1 made the server tensor work device-resident, but the epoch hot path
still issued a *chain* of small dispatches — the training vmap, a flatten,
per-segment grouping contractions, per-segment aggregation contractions, an
unflatten — with host sync points in between (``np.asarray(losses)``, the
blocking evaluator).  On CPU that chain is dominated by dispatch overhead;
on accelerators it wastes the async queue.

``EpochStepProgram`` fuses the whole epoch into ONE jitted XLA program
(DESIGN.md §6):

    in :  w_flat (N,) [donated], carry (L, N) stragglers, per-participant
          batch inputs, participant ids, epoch seed, aggregation weight
          vectors over bank/carry rows, base weight, new-orbit partial-
          model row weights + segment ids, grouping reference (N,)
    out:  new_w_flat (N,), bank stack (C, N), new-orbit distances (K,),
          per-participant losses (C,)

Inside the program: ``w_flat`` is unflattened (on device), the pool's
training vmap runs over the participant axis, the trained stack is formed,
the new global model is one ``base_w * w + wv_bank @ stack +
wv_carry @ carry`` contraction, and grouping distances for new orbits are
``|| segment_sum(w_row * rows) - ref ||`` over the same stack — a
segment-sum rather than a dense (K, C) GEMM because each bank row feeds at
most one new orbit (O(C*N), not O(K*C*N); at S=1000 with 125 fresh orbits
that is a 125x FLOP difference).  Because every per-model
weight is host *metadata* math (eqs. 13/14 need sizes/staleness, not
tensors), the weight vectors are program inputs — the one case where they
depend on a tensor result (a *new* orbit arriving while *stale* models are
pending, so group membership depends on this epoch's distances) falls back
to two dispatches (train+distances, then the contraction), counted in
``fallback_dispatches``.

``donate_argnums`` donates the global model buffer so XLA writes the new
global model into it in place — the simulator never touches the donated
buffer again.  The carried-stragglers matrix is NOT donated: it has no
same-shape output for XLA to reuse (donating it only triggers the
"unusable donation" warning), and keeping it alive lets the rare
two-dispatch fallback contract over it without a re-gather.

Mesh-awareness: with a ``jax.sharding.Mesh`` carrying a ``"data"`` axis,
the (C, N) bank and the participant batch shard their leading axis over
"data" (``NamedSharding``), and the bank contraction runs as an explicit
``shard_map`` psum so multi-device hosts scale the participant dimension.
A single-device (identity) mesh — or ``mesh=None`` — leaves every shape
and result bit-identical to the unsharded path, keeping CPU tests
unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.modelbank import FlatSpec

# Straggler matrices are padded up to at least this many rows so the fused
# program keeps one trace across the common 0..4-straggler epochs.
CARRY_MIN_ROWS = 4


def next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def carry_capacity(n: int) -> int:
    """Row capacity for a carried-stragglers matrix of ``n`` live rows."""
    return max(CARRY_MIN_ROWS, next_pow2(max(n, 1)))


def _data_axis_size(mesh: Optional[Mesh]) -> int:
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    return int(dict(zip(mesh.axis_names, mesh.devices.shape))["data"])


def bank_sharding(mesh: Mesh) -> NamedSharding:
    """The (C, N) bank layout: participants over "data", params replicated
    (the shared rule lives in ``launch/sharding.py``)."""
    from repro.launch.sharding import bank_sharding as _bs
    return _bs(mesh)


def sharded_contract(w: jnp.ndarray, stack: jnp.ndarray,
                     mesh: Mesh) -> jnp.ndarray:
    """(C,) @ (C, N) with the C axis sharded over "data": each device
    contracts its local rows, one psum combines the partials."""
    from repro.shard_compat import shard_map

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("data"), P("data", None)),
                       out_specs=P(None), check_vma=False)
    def _contract(w_loc, s_loc):
        return jax.lax.psum(w_loc @ s_loc, "data")

    return _contract(w, stack)


def _constrain_batch(inputs, mesh: Mesh, ndata: int):
    """Shard every batch leaf's leading (participant) axis over "data"."""
    def _c(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] % ndata == 0:
            spec = P("data", *([None] * (leaf.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec))
        return leaf
    return jax.tree.map(_c, inputs)


@dataclasses.dataclass
class EpochStepProgram:
    """The per-epoch fused program for one (FlatSpec, trainer) pair.

    ``train_fn(params, inputs, ids, seed) -> (stacked_models, losses)`` must
    be traceable; ``stacked_models`` is either a pytree whose leaves carry a
    leading participant axis (a vmap output) or already a flat (C, N) stack.
    """
    spec: FlatSpec
    train_fn: Callable[..., Tuple[Any, jnp.ndarray]]
    mesh: Optional[Mesh] = None
    donate: bool = True
    use_kernel: bool = False           # fed_agg Pallas contraction (below)
    # host-side dispatch timing (obs/profile.DispatchProfiler); None (the
    # default) takes the exact pre-existing path — no timing, no overhead
    profiler: Optional[Any] = None

    dispatches: int = 0                # fused one-dispatch epochs
    fallback_dispatches: int = 0       # epochs that needed train+agg split
    batched_dispatches: int = 0        # scenario-batched physical dispatches

    def __post_init__(self):
        donate = (0,) if self.donate else ()
        self._step = jax.jit(self._trace, donate_argnums=donate,
                             static_argnums=(10, 11))
        self._batched_fns = {}         # (mode,) -> jitted scenario-batched fn

    # ---- traced body -------------------------------------------------------

    def _trace(self, w_flat, carry, inputs, ids, seed,
               wv_bank, wv_carry, base_w, dw_row, dw_seg, kpad,
               blocked_m, dw_carry, ref):
        mesh, ndata = self.mesh, _data_axis_size(self.mesh)
        sharded = ndata > 1 and int(ids.shape[0]) % ndata == 0
        if sharded:
            inputs = _constrain_batch(inputs, mesh, ndata)
        params = self.spec.unflatten(w_flat)
        stacked, losses = self.train_fn(params, inputs, ids, seed)
        stack = (stacked if getattr(stacked, "ndim", None) == 2
                 else self.spec.flatten_stacked(stacked))
        if sharded:
            # the shard_map psum keeps the XLA contraction — the Pallas
            # kernel is single-device (per-shard pallas_call under
            # shard_map is future work; the flag is ignored here)
            stack = jax.lax.with_sharding_constraint(
                stack, bank_sharding(mesh))
            bank_term = sharded_contract(wv_bank, stack, mesh)
            new_w = base_w * w_flat + bank_term + wv_carry @ carry
        elif self.use_kernel:
            # route eq. 14 through the fed_agg Pallas kernel, inlined into
            # the fused program: the bank pass folds in the (donated) base
            # model, the carry pass accumulates onto its output
            from repro.kernels.fed_agg import ops as agg_ops
            new_w = agg_ops.fed_agg(stack, wv_bank, w_flat, base_w)
            new_w = agg_ops.fed_agg(carry, wv_carry, new_w, 1.0)
        else:
            new_w = base_w * w_flat + wv_bank @ stack + wv_carry @ carry
        if kpad:
            c, n = stack.shape
            if blocked_m:
                # new orbits own contiguous equal row blocks (the common
                # full-participation layout): one O(C*N) blocked einsum
                pm = jnp.einsum("km,kmn->kn",
                                dw_row.reshape(kpad, blocked_m),
                                stack.reshape(kpad, blocked_m, n))
            else:
                # general layout: one-hot the segment ids into a dense
                # (kpad+1, C) weight matrix on device and GEMM (the +1
                # dump row also keeps XLA CPU off its pathological
                # 1-row-dot fusion)
                w_mat = (jax.nn.one_hot(dw_seg, kpad + 1,
                                        dtype=jnp.float32).T
                         * dw_row[None, :])
                pm = (w_mat @ stack)[:kpad]
            pm = pm + dw_carry @ carry
            dists = jnp.linalg.norm(pm - ref[None, :], axis=1)
        else:
            dists = jnp.zeros((0,), jnp.float32)
        return new_w, stack, dists, losses

    # ---- scenario batch axis (DESIGN.md §13) -------------------------------

    def _unrolled(self, w_stack, carry, inputs, ids, seeds,
                  wv_bank, wv_carry, base_w, dw_row, dw_seg, kpad,
                  blocked_m, dw_carry, ref):
        """B per-scenario epochs as ONE program, bit-exact per scenario.

        A traced Python loop (unrolled at jit time) over the scenario axis:
        each iteration is *the same* ``_trace`` computation graph the solo
        path jits, so XLA sees B independent copies of the identical HLO and
        every per-scenario output is bitwise what the sequential run
        produces.  ``jax.vmap`` would be one batched GEMM instead of B —
        faster, but its batched ``dot_general`` reduces in a different
        order, so it is NOT bit-exact (~1e-6 on new_w on CPU); that is the
        opt-in ``mode="vmap"`` below, never the parity default.
        """
        outs = []
        for i in range(w_stack.shape[0]):
            inp = (None if inputs is None
                   else jax.tree.map(lambda l: l[i], inputs))
            outs.append(self._trace(
                w_stack[i], carry[i], inp, ids[i], seeds[i],
                wv_bank[i], wv_carry[i], base_w[i], dw_row[i], dw_seg[i],
                kpad, blocked_m, dw_carry[i], ref[i]))
        return tuple(jnp.stack(parts) for parts in zip(*outs))

    def batched_step(self, w_stack, carry, inputs, ids, seeds,
                     wv_bank, wv_carry, base_w, dw_row, dw_seg, kpad: int,
                     blocked_m: int, dw_carry, ref, *,
                     mode: str = "exact", fallback: bool = False):
        """Dispatch B scenarios' epochs as one physical program.

        Every array carries a leading scenario axis B (batch leaves of
        ``inputs`` too; ``inputs=None`` stays None); ``kpad``/``blocked_m``
        are static and shared — the DispatchBatcher only groups requests
        with identical static signatures.  The stacked ``w_stack`` is
        donated (it is a fresh buffer the batcher built; the per-scenario
        flats it was stacked from stay alive).  Returns lazy
        (B, ...)-leading outputs; callers slice per scenario.
        """
        if self.mesh is not None or self.use_kernel:
            raise ValueError("scenario batching supports the plain XLA "
                             "path only (mesh=None, use_kernel=False); "
                             "route mesh/kernel programs solo")
        if mode not in ("exact", "vmap"):
            raise ValueError(f"unknown scenario batch mode {mode!r}")
        key = (mode, inputs is None)
        fn = self._batched_fns.get(key)
        if fn is None:
            donate = (0,) if self.donate else ()
            if mode == "exact":
                fn = jax.jit(self._unrolled, donate_argnums=donate,
                             static_argnums=(10, 11))
            else:
                in_axes = (0, 0, (None if inputs is None else 0), 0, 0,
                           0, 0, 0, 0, 0, None, None, 0, 0)
                fn = jax.jit(jax.vmap(self._trace, in_axes=in_axes),
                             donate_argnums=donate, static_argnums=(10, 11))
            self._batched_fns[key] = fn
        self.batched_dispatches += 1
        args = (w_stack, carry, inputs, ids, seeds, wv_bank, wv_carry,
                base_w, dw_row, dw_seg, int(kpad), int(blocked_m),
                dw_carry, ref)
        prof = self.profiler
        if prof is None:
            return fn(*args)
        sig = ("batched", mode, int(w_stack.shape[0]),
               int(carry.shape[1]), int(ids.shape[1]), int(kpad),
               int(blocked_m), bool(fallback))
        t0 = prof.timer()
        out = fn(*args)
        if prof.block:
            jax.block_until_ready(out)
        prof.record(sig, bool(fallback), prof.timer() - t0)
        return out

    # ---- dispatch ----------------------------------------------------------

    def step(self, w_flat, carry, inputs, ids_np: np.ndarray, seed: int,
             wv_bank: np.ndarray, wv_carry: np.ndarray, base_w: float,
             dw_row: np.ndarray, dw_seg: np.ndarray, kpad: int,
             blocked_m: int, dw_carry: np.ndarray, ref,
             *, fallback: bool = False):
        """Dispatch one epoch.  All returned values are lazy device arrays —
        nothing here blocks; callers block only on what they record.

        ``w_flat`` is consumed (donated): pass a buffer you will not
        reuse.  ``wv_*`` / ``dw_*`` / ``base_w`` are host metadata (numpy);
        ``ids_np`` is the padded participant id vector.  ``dw_row``/
        ``dw_seg`` give each bank row its partial-model weight and its
        new-orbit segment (``kpad`` = dump id, static; pow2-bucketed so
        trace count stays O(log orbits)); ``blocked_m`` > 0 (static)
        asserts segment k owns exactly rows [k*m, (k+1)*m) and selects the
        blocked einsum.  The returned distances carry ``kpad`` entries of
        which the first K are real.
        """
        if fallback:
            self.fallback_dispatches += 1
        else:
            self.dispatches += 1
        prof = self.profiler
        if prof is None:
            return self._step(
                w_flat, carry, inputs,
                jnp.asarray(ids_np, jnp.int32), np.uint32(seed),
                jnp.asarray(np.asarray(wv_bank, np.float32)),
                jnp.asarray(np.asarray(wv_carry, np.float32)),
                np.float32(base_w),
                jnp.asarray(np.asarray(dw_row, np.float32)),
                jnp.asarray(np.asarray(dw_seg, np.int32)),
                int(kpad), int(blocked_m),
                jnp.asarray(np.asarray(dw_carry, np.float32)),
                ref)
        # the static dispatch signature: everything that forces a new jit
        # trace — array shapes (carry rows, participant count), the static
        # args and the fallback split.  First-seen = trace+compile.
        sig = (int(carry.shape[0]), int(len(ids_np)), int(kpad),
               int(blocked_m), bool(fallback))
        t0 = prof.timer()
        out = self._step(
            w_flat, carry, inputs,
            jnp.asarray(ids_np, jnp.int32), np.uint32(seed),
            jnp.asarray(np.asarray(wv_bank, np.float32)),
            jnp.asarray(np.asarray(wv_carry, np.float32)),
            np.float32(base_w),
            jnp.asarray(np.asarray(dw_row, np.float32)),
            jnp.asarray(np.asarray(dw_seg, np.int32)),
            int(kpad), int(blocked_m),
            jnp.asarray(np.asarray(dw_carry, np.float32)),
            ref)
        if prof.block:
            jax.block_until_ready(out)
        prof.record(sig, bool(fallback), prof.timer() - t0)
        return out


def make_epoch_program(trainer, params, mesh: Optional[Mesh] = None,
                       *, donate: bool = True,
                       use_kernel: bool = False) -> Optional[EpochStepProgram]:
    """Build (or reuse) the fused program for a trainer exposing the
    fused-epoch protocol (``epoch_train_fn`` + ``epoch_inputs``); None
    otherwise.  Programs are cached on the trainer so repeated simulations
    with the same trainer share jit traces and compiled executables."""
    fn = getattr(trainer, "epoch_train_fn", None)
    if fn is None or not hasattr(trainer, "epoch_inputs"):
        return None
    spec = FlatSpec.of(params)
    cache = getattr(trainer, "_epoch_programs", None)
    if cache is None:
        cache = {}
        try:
            trainer._epoch_programs = cache
        except AttributeError:        # trainer forbids attributes: no reuse
            pass
    key = (spec, mesh, donate, use_kernel)   # Mesh is hashable; id() could
    prog = cache.get(key)                    # collide
    if prog is None:
        prog = cache[key] = EpochStepProgram(spec, fn(), mesh=mesh,
                                             donate=donate,
                                             use_kernel=use_kernel)
    return prog
