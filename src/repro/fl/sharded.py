"""Constellation-parallel FL runtime (beyond-paper; DESIGN.md §3).

The paper simulates satellites sequentially on one machine.  On a TPU mesh we
map AsyncFLEO's communication pattern onto collectives and run the *whole
constellation* in parallel:

  * satellites live on the ``data`` axis (stacked leading param axis);
  * each satellite runs J local SGD steps on its own shard (eq. 3), all
    satellites simultaneously — one ``shard_map``;
  * **intra-orbit ISL ring → ``jax.lax.ppermute``**: the model-propagation
    step exchanges parameters with ring neighbors (paper Alg. 1);
  * **aggregation (eq. 14) → weighted ``psum``**: the staleness-discounted
    convex combination is a single fused all-reduce, with per-satellite
    weights (gamma split) computed from metadata — the paper's sink-HAP
    reduction becomes a collective;
  * on the multi-pod mesh the ``pod`` axis is the HAP ring: a final psum over
    ``pod`` mirrors the source→sink IHL relay.

This is the module the dry-run lowers as ``fl_step`` and the third §Perf
hillclimb target.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.shard_compat import shard_map

from repro.optim import sgd, apply_updates


def _local_train(loss_fn, params, batch, *, local_iters: int, lr: float):
    """J local SGD steps (paper eq. 3) for ONE satellite."""
    opt = sgd(lr)
    state = opt.init(params)

    def step(carry, xs):
        params, state = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, xs)
        upd, state = opt.update(grads, state, params)
        return (apply_updates(params, upd), state), loss

    minibatches = batch      # (J, ...) leading local-iteration axis per leaf
    (params, _), losses = jax.lax.scan(step, (params, state), minibatches)
    return params, losses.mean()


def make_fl_round(loss_fn: Callable, mesh: Mesh, *, local_iters: int = 4,
                  lr: float = 0.01, sat_axis: str = "data",
                  pod_axis: Optional[str] = None):
    """Build the sharded FL round:

        fl_round(global_params, stacked_batches, weights)
            -> (new_global_params, mean_loss)

    ``stacked_batches`` leaves: (num_sats, J, ...) — satellite axis sharded
    over ``sat_axis`` (and ``pod_axis`` if given).  ``weights``: (num_sats,)
    staleness-discounted aggregation weights, summing to gamma; the global
    update is w' = (1-gamma) w + sum_n p_n w_n as one weighted psum.
    """
    axes = (pod_axis, sat_axis) if pod_axis else (sat_axis,)

    def per_shard(global_params, batches, weights):
        # batches leaves: (local_sats, J, ...); weights: (local_sats, 1)
        train = functools.partial(_local_train, loss_fn,
                                  local_iters=local_iters, lr=lr)
        local_params, losses = jax.vmap(train, in_axes=(None, 0))(
            global_params, batches)

        # --- model propagation: ISL ring exchange (Alg. 1) ---------------
        # each shard passes its trained models to the next ring neighbor so
        # a straggler's neighbor holds a fresh copy (fault tolerance); the
        # received copy participates at zero weight unless enabled.
        n_shards = mesh.devices.shape[mesh.axis_names.index(sat_axis)]
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        relayed = jax.tree.map(
            lambda a: jax.lax.ppermute(a, sat_axis, perm), local_params)
        del relayed   # timing/fault-tolerance path; aggregation uses psum

        # --- aggregation: weighted psum (eq. 14) --------------------------
        w = weights[:, None]

        def agg(leaf, g_leaf):
            contrib = jnp.tensordot(weights.astype(jnp.float32),
                                    leaf.astype(jnp.float32), axes=1)
            total = jax.lax.psum(contrib, axes)
            gamma = jax.lax.psum(jnp.sum(weights.astype(jnp.float32)), axes)
            return ((1.0 - gamma) * g_leaf.astype(jnp.float32)
                    + total).astype(g_leaf.dtype)

        new_global = jax.tree.map(agg, local_params, global_params)
        mean_loss = jax.lax.pmean(losses.mean(), axes)
        return new_global, mean_loss

    batch_spec = P(axes if len(axes) > 1 else axes[0])
    fl_round = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), batch_spec, batch_spec),
        out_specs=(P(), P()),
        check_vma=False)
    return fl_round
