"""Roofline analysis from dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds-per-step:

    compute    = FLOPs          / (chips * 197e12)        [bf16 MXU peak]
    memory     = HBM bytes      / (chips * 819e9)
    collective = collective B   / (chips * 4 * 50e9)      [v5e: 4 ICI links]

FLOP/byte accounting: XLA's ``cost_analysis()`` counts a ``scan`` body ONCE
regardless of trip count (verified empirically — see EXPERIMENTS.md §Dry-run),
so for scan-over-layers models we use an analytic estimator for total
compute/memory (standard 6ND-style accounting, matmul-dominated and exact to
first order) and report the HLO numbers alongside.  Collective bytes come
from the compiled HLO (outside the scan body collectives appear per-step;
in-scan collectives are scaled by trip count analytically where flagged).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the ratio
MODEL_FLOPS / total_flops shows how much compiled compute is "useful".
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.configs import ARCHS, SHAPES, LONG_CONTEXT_WINDOW, get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, ICI_LINKS,
                               PEAK_FLOPS_BF16)
from repro.launch.steps import cache_len_for, window_for


# --------------------------------------------------------------------------
# analytic FLOPs / bytes (documented estimator; scan-body undercount fix)
# --------------------------------------------------------------------------

def _attention_flops_fwd(cfg: ModelConfig, B: int, S: int, kv_len: int) -> float:
    """Score + AV matmul FLOPs, full (unmasked) as XLA computes them."""
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
        hd = cfg.resolved_head_dim
        return 4.0 * B * S * kv_len * cfg.num_heads * hd * n_attn
    hd = (cfg.nope_head_dim + cfg.rope_head_dim) if cfg.use_mla \
        else cfg.resolved_head_dim
    return 4.0 * B * S * kv_len * cfg.num_heads * hd * cfg.num_layers


def _ssm_flops_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.family == "ssm":
        H = cfg.ssm_heads
        hd = cfg.ssm_head_dim or cfg.d_model // H
        K = V = hd
        nl = cfg.num_layers
    elif cfg.family == "hybrid":
        H = cfg.ssm_heads
        hd = cfg.ssm_head_dim or cfg.d_model // H
        K, V = cfg.ssm_state, hd
        nl = cfg.num_layers
    else:
        return 0.0
    # chunked scan: intra (2*S*Lc*K + 2*S*Lc*V) + carry (4*S*K*V) per head
    Lc = cfg.chunk_size
    per_tok = 2.0 * Lc * K + 2.0 * Lc * V + 4.0 * K * V
    return B * S * H * per_tok * nl


def _moe_capacity_extra(cfg: ModelConfig, T: float, capacity_factor: float) -> float:
    """Routed-expert matmuls run at capacity C = T*k*cf/E per expert, so
    their FLOPs scale by cf relative to the exact-top-k accounting baked
    into N_active (cf=1).  Extra (or saved) FLOPs = 2*T*(cf-1)*routed."""
    if not cfg.is_moe:
        return 0.0
    f = cfg.moe_d_ff or cfg.d_ff
    n_moe = cfg.num_layers - cfg.first_dense_layers
    routed = 3.0 * cfg.d_model * f * cfg.top_k * n_moe
    return 2.0 * T * (capacity_factor - 1.0) * routed


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig, *, q_chunks: int = 1,
                   capacity_factor: float = None, remat: bool = None) -> float:
    B, S = shape.global_batch, shape.seq_len
    N_act = cfg.active_param_count()
    window = window_for(cfg, shape)
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    use_remat = cfg.remat if remat is None else remat
    # chunked causal prefill: query chunk i attends to keys [0,(i+1)S/n)
    attn_scale = (q_chunks + 1) / (2.0 * q_chunks) if q_chunks > 1 else 1.0
    if shape.kind == "train":
        T = B * S
        fwd = (2.0 * N_act * T
               + attn_scale * _attention_flops_fwd(cfg, B, S, min(S, window or S))
               + _ssm_flops_fwd(cfg, B, S)
               + _moe_capacity_extra(cfg, T, cf))
        total = 3.0 * fwd                # fwd + 2x bwd
        if use_remat:
            total += fwd                 # full remat recomputes the forward
        return total
    if shape.kind == "prefill":
        T = B * S
        return (2.0 * N_act * T
                + attn_scale * _attention_flops_fwd(cfg, B, S, min(S, window or S))
                + _ssm_flops_fwd(cfg, B, S)
                + _moe_capacity_extra(cfg, T, cf))
    # decode: one token per sequence; attention over the cache
    kv_len = cache_len_for(cfg, shape)
    return (2.0 * N_act * B
            + _attention_flops_fwd(cfg, B, 1, kv_len)
            + _ssm_flops_fwd(cfg, B, 1)
            + _moe_capacity_extra(cfg, B, cf))


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """HBM traffic per step (global, all chips): parameters + optimizer
    state + activations + decode cache, to first order."""
    B, S = shape.global_batch, shape.seq_len
    N = cfg.param_count()
    N_act = cfg.active_param_count()
    d = cfg.d_model
    act_bytes_per_tok = 2.0 * d * cfg.num_layers * 2     # resid+hidden, bf16
    if shape.kind == "train":
        # params read f32 (master) + grads write/read + adam m,v read/write
        param_traffic = N * (4 + 4 + 4 + 4 * 4)
        act = B * S * act_bytes_per_tok * (2 if cfg.remat else 1)
        return param_traffic + act
    if shape.kind == "prefill":
        return N * 2 + B * S * act_bytes_per_tok
    # decode: active params + full cache read + one-token activations
    cl = cache_len_for(cfg, shape)
    if cfg.use_mla:
        cache = B * cl * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2 * cfg.num_layers
    elif cfg.family == "ssm":
        H = cfg.ssm_heads
        hd = cfg.ssm_head_dim or d // H
        cache = B * H * hd * hd * 4 * cfg.num_layers
    elif cfg.family == "hybrid":
        H = cfg.ssm_heads
        hd = cfg.ssm_head_dim or d // H
        n_attn = cfg.num_layers // cfg.attn_every
        cache = (B * H * cfg.ssm_state * hd * 4 * cfg.num_layers
                 + B * cl * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2 * n_attn)
    else:
        cache = B * cl * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2 * cfg.num_layers
    return N_act * 2 + cache + B * act_bytes_per_tok


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The headline 6ND (dense) / 6·N_active·D (MoE) number."""
    B, S = shape.global_batch, shape.seq_len
    T = B * S if shape.kind != "decode" else B
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * cfg.active_param_count() * T


# --------------------------------------------------------------------------
# terms
# --------------------------------------------------------------------------

def roofline_terms(entry: Dict) -> Dict:
    """entry: one dry-run JSON record -> roofline report row."""
    cfg = get_config(entry["arch"])
    shape = get_shape(entry["shape"])
    chips = entry["num_devices"]
    fl = analytic_flops(cfg, shape,
                        q_chunks=entry.get("q_chunks", 1),
                        capacity_factor=entry.get("capacity_factor"),
                        remat=entry.get("remat"))
    hbm = analytic_hbm_bytes(cfg, shape)
    coll = float(entry.get("collective_bytes", {}).get("total", 0.0))

    t_compute = fl / (chips * PEAK_FLOPS_BF16)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = coll / (chips * ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        "arch": entry["arch"], "shape": entry["shape"],
        "mesh": "x".join(map(str, entry["mesh_shape"])),
        "rules": entry.get("rules", "base"),
        "chips": chips,
        "analytic_flops": fl, "analytic_hbm_bytes": hbm,
        "collective_bytes": coll,
        "hlo_flops": entry.get("flops", -1),
        "hlo_bytes": entry.get("bytes_accessed", -1),
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": round(mf / fl, 4) if fl else 0.0,
        "step_time_bound_s": round(max(terms.values()), 6),
    }


def load_and_analyze(paths) -> list:
    rows = []
    for p in paths:
        with open(p) as f:
            data = json.load(f)
        for entry in (data if isinstance(data, list) else [data]):
            if entry.get("skipped") or "error" in entry:
                rows.append({"arch": entry.get("arch"), "shape": entry.get("shape"),
                             "skipped": True,
                             "reason": entry.get("reason", entry.get("error", ""))})
                continue
            rows.append(roofline_terms(entry))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    rows = load_and_analyze(args.paths)
    cols = ["arch", "shape", "mesh", "rules", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio"]
    print(",".join(cols))
    for r in rows:
        if r.get("skipped"):
            print(f"{r['arch']},{r['shape']},skipped: {r['reason']}")
            continue
        print(",".join(str(r.get(c, "")) for c in cols))
    return rows


if __name__ == "__main__":
    main()
