"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED variant (2 layers, d_model<=256, <=4 experts) runs one forward and
one real train step on CPU with finite loss and correct shapes; decode-capable
archs also run a serve step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable, get_shape
from repro.launch.steps import make_optimizer, make_train_step
from repro.launch.train import make_batch
from repro.models import registry as R

ALL_ARCHS = sorted(ARCHS)


def _reduced(arch):
    return ARCHS[arch].reduced().replace(remat=False, dtype="float32")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _reduced(arch)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, seed=0)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    logits, aux = R.apply(params, cfg, batch)
    S_out = S + (cfg.num_prefix_embeds if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = _reduced(arch)
    opt = make_optimizer(1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    batch = make_batch(cfg, 2, 32, seed=0)
    params2, opt_state2, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(params2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_serve_step_if_applicable(arch):
    cfg = _reduced(arch)
    shape = get_shape("decode_32k")
    if not applicable(ARCHS[arch], shape):
        pytest.skip("encoder-only: no decode step (DESIGN.md)")
    B, CL = 2, 16
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    cache = R.init_cache(cfg, B, CL, jnp.float32)
    toks = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = R.decode_step(params, cfg, cache, toks)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache index advanced
    if "index" in cache2:
        assert int(cache2["index"]) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_positive(arch):
    n = ARCHS[arch].param_count()
    na = ARCHS[arch].active_param_count()
    assert n > 0 and 0 < na <= n
    if ARCHS[arch].is_moe:
        assert na < n


def test_param_counts_match_cards():
    """Full-size parameter counts are in the right ballpark of the model
    cards (within ~45% — tokenizer/head details differ)."""
    expect = {"llama3-8b": 8.0e9, "qwen3-4b": 4.0e9, "starcoder2-3b": 3.0e9,
              "granite-8b": 8.0e9, "rwkv6-7b": 7.0e9, "zamba2-2.7b": 2.7e9,
              "deepseek-v2-236b": 236e9, "hubert-xlarge": 1.0e9,
              "internvl2-1b": 0.8e9}
    for arch, n_exp in expect.items():
        n = ARCHS[arch].param_count()
        assert 0.5 * n_exp < n < 1.8 * n_exp, (arch, n, n_exp)


def test_kimi_is_about_1t():
    n = ARCHS["kimi-k2-1t-a32b"].param_count()
    assert 0.6e12 < n < 1.5e12
    na = ARCHS["kimi-k2-1t-a32b"].active_param_count()
    assert na < 0.1 * n            # strongly sparse
