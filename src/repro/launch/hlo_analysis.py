"""Post-compile HLO analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` has FLOPs and bytes but no collective traffic, so we
parse the optimized HLO text and sum output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Shapes like ``bf16[8,1024,128]`` are parsed from the op result type;
tuple results (e.g. fused all-reduces) contribute every element.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind total bytes (output sizes) + op counts."""
    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op lines look like:  %name = bf16[...] all-reduce(...), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", stripped)
        if not m:
            continue
        type_str, opname = m.group(1), m.group(2)
        for coll in _COLLECTIVES:
            if opname == coll or opname.startswith(coll + "-start"):
                out[coll] += _shape_bytes(type_str)
                counts[coll] += 1
                break
    result = dict(out)
    result["_counts"] = dict(counts)
    result["total"] = int(sum(v for k, v in out.items()))
    return result


def remat_duplication(hlo_text: str) -> float:
    """Crude remat indicator: ratio of dot/convolution ops to unique ones by
    shape signature (duplicate compute from rematerialization shows up as
    repeated identical op types)."""
    dots = re.findall(r"=\s*[^ ]+\s+dot\(", hlo_text)
    return float(len(dots))
