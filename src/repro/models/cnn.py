"""The paper's FL client models (§V-A): small CNN and MLP classifiers.

These are the networks AsyncFLEO trains on-board each satellite (MNIST /
CIFAR-10, 10 classes).  Pure-functional JAX, params as dict pytrees so the
FL aggregation layer treats them identically to the large archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_models import SmallNetConfig
from repro.models.layers import dense_init


def init_params(key, cfg: SmallNetConfig):
    ks = jax.random.split(key, 6)
    if cfg.kind == "mlp":
        d_in = cfg.image_size * cfg.image_size * cfg.channels
        return {
            "w1": dense_init(ks[0], (d_in, cfg.hidden)),
            "b1": jnp.zeros((cfg.hidden,)),
            "w2": dense_init(ks[1], (cfg.hidden, cfg.hidden)),
            "b2": jnp.zeros((cfg.hidden,)),
            "w3": dense_init(ks[2], (cfg.hidden, cfg.num_classes)),
            "b3": jnp.zeros((cfg.num_classes,)),
        }
    c1, c2 = cfg.conv_channels
    # two 3x3 convs with 2x2 pooling each
    flat = (cfg.image_size // 4) * (cfg.image_size // 4) * c2
    return {
        "conv1": dense_init(ks[0], (3, 3, cfg.channels, c1), in_axis_size=9 * cfg.channels),
        "bc1": jnp.zeros((c1,)),
        "conv2": dense_init(ks[1], (3, 3, c1, c2), in_axis_size=9 * c1),
        "bc2": jnp.zeros((c2,)),
        "w1": dense_init(ks[2], (flat, cfg.hidden)),
        "b1": jnp.zeros((cfg.hidden,)),
        "w2": dense_init(ks[3], (cfg.hidden, cfg.num_classes)),
        "b2": jnp.zeros((cfg.num_classes,)),
    }


def _conv(x, w, b):
    """3x3 SAME conv as im2col + matmul (XLA:CPU convolutions are slow and
    compile slowly under vmap+grad; shifted-slice matmuls hit the fast Eigen
    GEMM path instead — same math)."""
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    xp = jnp.pad(x, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
    patches = jnp.stack([xp[:, i:i + H, j:j + W, :]
                         for i in range(kh) for j in range(kw)], axis=3)
    y = jnp.einsum("bhwkc,kco->bhwo",
                   patches, w.reshape(kh * kw, Cin, Cout))
    return jax.nn.relu(y + b)


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(params, cfg: SmallNetConfig, images):
    """images: (B, H, W, C) float32 in [0,1]. Returns logits (B, classes)."""
    if cfg.kind == "mlp":
        x = images.reshape(images.shape[0], -1)
        x = jax.nn.relu(x @ params["w1"] + params["b1"])
        x = jax.nn.relu(x @ params["w2"] + params["b2"])
        return x @ params["w3"] + params["b3"]
    x = _conv(images, params["conv1"], params["bc1"])
    x = _pool(x)
    x = _conv(x, params["conv2"], params["bc2"])
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["w1"] + params["b1"])
    return x @ params["w2"] + params["b2"]


def loss_fn(params, cfg: SmallNetConfig, images, labels):
    logits = apply(params, cfg, images)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


def accuracy(params, cfg: SmallNetConfig, images, labels):
    logits = apply(params, cfg, images)
    return (jnp.argmax(logits, -1) == labels).mean()
