"""Summarize an exported scheduler trace (DESIGN.md §12).

Consumes either artifact `obs/export.py` writes — the Chrome
trace-event JSON (``sched_bench.py --trace-out``) or the JSONL next to
it — and prints three terminal views:

* **round waterfall**: one line per round, in open order, with an ASCII
  timeline bar over the run horizon plus the phase durations (recruit /
  transfers / trigger window) and per-round arrival / retry / drop
  counts;
* **per-PS utilization**: reserved channel-seconds per PS and direction
  (from the §9 pools' ``channel_busy`` spans) and outage darkness (§11
  ``outage`` spans), as fractions of the horizon;
* **retry/backoff histograms**: transfer failures by attempt number and
  the applied retry delays (AIMD or exponential) bucketed into a text
  histogram.

Usage:  PYTHONPATH=src python benchmarks/trace_report.py trace.json
        PYTHONPATH=src python benchmarks/trace_report.py trace.jsonl
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from typing import Dict, List

from repro.obs.trace import (EV_ARRIVAL, EV_DROP, EV_TRANSFER_FAILED,
                             EV_TRANSFER_RETRY, SPAN_CHANNEL, SPAN_OUTAGE,
                             SPAN_RECRUIT, SPAN_ROUND, SPAN_TRANSFERS,
                             SPAN_TRIGGER, Instant, Span, Tracer)

_US = 1e6


def load_trace(path: str) -> Tracer:
    """Rebuild a Tracer buffer from either export format (sniffed by
    content, not extension: JSONL lines start with ``{"kind"``)."""
    t = Tracer()
    with open(path) as f:
        head = f.read(16)
        f.seek(0)
        if head.lstrip().startswith('{"kind"'):
            for line in f:
                if not line.strip():
                    continue
                d = json.loads(line)
                if d["kind"] == "span":
                    t.spans.append(Span(d["name"], d["track"],
                                        d["t_start"], d["t_end"],
                                        d.get("args", {})))
                else:
                    t.instants.append(Instant(d["name"], d["track"],
                                              d["t"], d.get("args", {})))
            return t
        obj = json.load(f)
    names: Dict[int, str] = {}
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev["tid"]] = ev["args"]["name"]
    for ev in obj["traceEvents"]:
        track = names.get(ev.get("tid"), str(ev.get("tid")))
        if ev.get("ph") == "X":
            t0 = ev["ts"] / _US
            t.spans.append(Span(ev["name"], track, t0,
                                t0 + ev["dur"] / _US, ev.get("args", {})))
        elif ev.get("ph") in ("i", "I"):
            t.instants.append(Instant(ev["name"], track, ev["ts"] / _US,
                                      ev.get("args", {})))
    return t


def _horizon(t: Tracer) -> float:
    ends = [s.t_end for s in t.spans] + [i.t for i in t.instants]
    return max(ends) if ends else 1.0


def _bar(t0: float, t1: float, horizon: float, width: int = 48) -> str:
    a = int(round(width * t0 / horizon))
    b = max(a + 1, int(round(width * t1 / horizon)))
    return "." * a + "#" * (b - a) + "." * max(0, width - b)


def round_waterfall(t: Tracer, width: int = 48) -> List[str]:
    horizon = _horizon(t)
    by_track: Dict[str, Dict[str, Span]] = defaultdict(dict)
    for s in t.spans:
        if s.name in (SPAN_ROUND, SPAN_RECRUIT, SPAN_TRANSFERS,
                      SPAN_TRIGGER):
            by_track[s.track][s.name] = s
    counts: Dict[str, Dict[str, int]] = defaultdict(
        lambda: defaultdict(int))
    for i in t.instants:
        counts[i.track][i.name] += 1
    out = [f"# round waterfall  (horizon {horizon / 3600.0:.2f} h, "
           f"bar width {width})",
           f"{'round':>8s} {'open_h':>7s} {'dur_h':>6s} "
           f"{'recr_h':>6s} {'xfer_h':>6s} {'trig_h':>6s} "
           f"{'arr':>4s} {'rty':>4s} {'drop':>4s}  timeline"]
    rounds = sorted((tr for tr in by_track if SPAN_ROUND in by_track[tr]),
                    key=lambda tr: by_track[tr][SPAN_ROUND].t_start)
    for tr in rounds:
        ph = by_track[tr]
        rs = ph[SPAN_ROUND]

        def _d(name):
            s = ph.get(name)
            return f"{s.duration / 3600.0:6.2f}" if s else "     -"

        c = counts[tr]
        out.append(
            f"{tr.split()[-1]:>8s} {rs.t_start / 3600.0:7.2f} "
            f"{rs.duration / 3600.0:6.2f} {_d(SPAN_RECRUIT)} "
            f"{_d(SPAN_TRANSFERS)} {_d(SPAN_TRIGGER)} "
            f"{c[EV_ARRIVAL]:4d} {c[EV_TRANSFER_RETRY]:4d} "
            f"{c[EV_DROP]:4d}  "
            f"{_bar(rs.t_start, rs.t_end, horizon, width)}")
    return out


def ps_utilization(t: Tracer) -> List[str]:
    horizon = _horizon(t)
    busy: Dict[tuple, float] = defaultdict(float)
    dark: Dict[str, float] = defaultdict(float)
    for s in t.spans:
        if s.name == SPAN_CHANNEL:
            busy[(s.track, s.args.get("direction", "?"))] += s.duration
        elif s.name == SPAN_OUTAGE:
            dark[s.track] += s.duration
    out = ["# per-PS utilization (reserved channel-seconds / horizon)"]
    if not busy and not dark:
        out.append("  (no contention or outage tracks in this trace — "
                   "run with ps_channels / ps_outages set)")
        return out
    for (track, direction), b in sorted(busy.items()):
        out.append(f"  {track:>6s} {direction}: busy {b:10.1f} s  "
                   f"({b / horizon:6.1%} of horizon)")
    for track, d in sorted(dark.items()):
        out.append(f"  {track:>6s} outage: dark {d:10.1f} s  "
                   f"({d / horizon:6.1%} of horizon)")
    return out


def retry_report(t: Tracer, buckets: int = 8) -> List[str]:
    fails = [i for i in t.instants if i.name == EV_TRANSFER_FAILED]
    retries = [i for i in t.instants if i.name == EV_TRANSFER_RETRY]
    drops = [i for i in t.instants if i.name == EV_DROP]
    out = [f"# retries: {len(fails)} transfer failures, "
           f"{len(retries)} retries, {len(drops)} drops"]
    by_attempt: Dict[int, int] = defaultdict(int)
    for i in fails:
        by_attempt[int(i.args.get("attempt", 0))] += 1
    for a in sorted(by_attempt):
        out.append(f"  attempt {a}: {'#' * by_attempt[a]} "
                   f"({by_attempt[a]})")
    delays = sorted(float(i.args["delay_s"]) for i in retries
                    if "delay_s" in i.args)
    if delays:
        lo, hi = delays[0], delays[-1]
        span = (hi - lo) or 1.0
        hist = [0] * buckets
        for d in delays:
            hist[min(buckets - 1, int(buckets * (d - lo) / span))] += 1
        out.append(f"# applied retry delays  (min {lo:.0f} s, "
                   f"max {hi:.0f} s)")
        for k, n in enumerate(hist):
            a = lo + span * k / buckets
            b = lo + span * (k + 1) / buckets
            out.append(f"  [{a:7.0f}, {b:7.0f}) s: {'#' * n} ({n})")
    by_reason: Dict[str, int] = defaultdict(int)
    for i in drops:
        by_reason[i.args.get("reason", "?")] += 1
    for reason, n in sorted(by_reason.items()):
        out.append(f"  dropped ({reason}): {n}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON or JSONL from "
                                  "sched_bench.py --trace-out")
    ap.add_argument("--width", type=int, default=48,
                    help="waterfall bar width in characters")
    args = ap.parse_args()
    t = load_trace(args.trace)
    print(f"loaded {args.trace}: {len(t.spans)} spans, "
          f"{len(t.instants)} instants, {len(t.tracks())} tracks\n")
    for line in round_waterfall(t, args.width):
        print(line)
    print()
    for line in ps_utilization(t):
        print(line)
    print()
    for line in retry_report(t):
        print(line)


if __name__ == "__main__":
    main()
