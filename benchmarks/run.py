"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV blocks per benchmark.  Quick mode
(default) runs reduced epoch counts so the whole suite finishes on a CPU
container; --full reproduces the complete sweeps (see EXPERIMENTS.md for
archived full results).  The roofline block reads any dry-run artifacts in
benchmarks/artifacts/dryrun*.json.
"""
from __future__ import annotations

import glob
import os
import sys
import time


def _banner(name):
    print(f"\n### {name}")


def main() -> None:
    full = "--full" in sys.argv or os.environ.get("BENCH_FULL") == "1"

    t0 = time.time()
    _banner("kernels (paper has no kernel table; supports §Perf)")
    from benchmarks import kernels_bench
    kernels_bench.main()

    _banner("table2_fig6: SOTA comparison, non-IID MNIST-like + CNN")
    from benchmarks import table2
    out = table2.run(max_epochs=16 if full else 12,
                     schemes=None if full else
                     ["fedisl-ideal", "fedhap",
                      "asyncfleo-hap", "asyncfleo-twohap"])
    print("scheme,best_acc,conv_time_h,epochs")
    for r in out["rows"]:
        print(f"{r['scheme']},{r['best_acc']},{r['conv_time_h']},{r['epochs']}")
    print(f"speedup_vs_slowest_sync,{out['speedup_vs_slowest_sync']}")
    from repro.benchmarks_io import emit
    emit("table2_quick" if not full else "table2", out)

    _banner("fig7: MNIST settings sweep (IID/non-IID x CNN/MLP x PS)")
    from benchmarks import fig7_mnist
    out7 = fig7_mnist.run("mnist", quick=not full,
                          max_epochs=12 if full else 12)
    print("iid,model,scheme,best_acc,final_time_h")
    for r in out7["rows"]:
        print(f"{r['iid']},{r['model']},{r['scheme']},{r['best_acc']},{r['final_time_h']}")
    emit("fig7_mnist", out7)

    _banner("fig8: CIFAR-like settings sweep")
    from benchmarks import fig8_cifar
    out8 = fig8_cifar.run(quick=not full, max_epochs=12 if full else 12)
    print("iid,model,scheme,best_acc,final_time_h")
    for r in out8["rows"]:
        print(f"{r['iid']},{r['model']},{r['scheme']},{r['best_acc']},{r['final_time_h']}")
    emit("fig8_cifar", out8)

    _banner("ablations (beyond-paper): AsyncFLEO component contributions")
    from benchmarks import ablations
    outa = ablations.run(max_epochs=12)
    print("variant,best_acc,final_time_h,epochs,mean_gamma")
    for r in outa["rows"]:
        print(f"{r['variant']},{r['best_acc']},{r['final_time_h']},"
              f"{r['epochs']},{r['mean_gamma']}")
    emit("ablations", outa)

    _banner("roofline: dry-run artifacts")
    from benchmarks import roofline
    arts = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "artifacts", "dryrun*.json")))
    if arts:
        roofline.main(arts)
    else:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --arch all --shape all "
              "--out benchmarks/artifacts/dryrun_base.json` first")

    print(f"\n# total bench wall: {time.time()-t0:.0f}s (full={full})")


if __name__ == "__main__":
    main()
