"""SGD(+momentum) and AdamW over parameter pytrees.

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``; apply with
:func:`apply_updates`.  States are pytrees -> shardable by the same logical
rules as params (FSDP shards optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": jax.tree.map(jnp.zeros_like, params)}
        return {}

    def update(grads, state, params=None):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            return jax.tree.map(lambda m: -lr * m, mu), {"mu": mu}
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)

        def upd(m_, v_, p):
            u = -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)
        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)
