"""Pluggable fault-injection / heterogeneity layer (DESIGN.md §10-§11).

The simulator's robustness story used to ride on geometry alone: every
satellite trained at the same speed, no transfer was ever lost, and no
satellite ever powered down.  ``FaultModel`` makes the missing failure
axes first-class, following FLGo's ``system_simulator`` shape
(pluggable availability / latency / dropout state on a shared clock):

* **compute-rate heterogeneity** — per-satellite multipliers that
  stretch local-training time (and therefore every ``TRAIN_DONE``
  instant): ``train_time_scale`` draws a seeded spread in
  ``[1, 1 + compute_rate_spread]`` (or takes explicit per-sat rates).
  Threaded through `FLSimulation._train_times`, the ONE shared timing
  helper of the epoch loop and the event runtime, so driver parity is
  preserved under heterogeneity.
* **eclipse / duty-cycle availability** — ``availability_mask`` returns
  a (T, S) boolean that is ANDed into ``VisibilityTimeline.grid`` at
  simulator construction: a satellite in its (seeded-phase, periodic)
  eclipse window is simply not visible to any PS, so every downstream
  rule — contact windows, downlink stars, ISL relay seeds, uplink
  direct/relay/wait — routes around it without special cases.
* **lossy transfers** — ``transfer_fails`` is a *deterministic* seeded
  Bernoulli draw per (satellite, round, attempt): the event runtime
  turns a failed sat->PS model transfer into a ``TRANSFER_FAILED``
  event at the would-be arrival instant and re-times the retransmission
  from ``t + retry_backoff_s * 2**attempt`` through the contact plan
  (which charges a fresh rx-channel grant — retries re-enter the
  `ChannelPool`), up to ``max_retries`` attempts; grants of retries
  that can never complete are rolled back via the existing
  snapshot/restore machinery.  Loss requires the event runtime — the
  epoch loop cannot express retries and refuses to run with
  ``loss_prob > 0``.
* **correlated / bursty loss (§11)** — real Satcom channels fade in
  bursts (rain fade, scintillation), not i.i.d. coin flips.
  ``burst_len_s > 0`` switches ``transfer_fails`` to a two-state
  Gilbert–Elliott block-fading channel per (sat, PS) link: time is cut
  into windows of ``burst_len_s`` seconds, each window's good/bad state
  is a pure seeded draw keyed on ``(seed, sat, ps, window)`` with bad
  probability ``loss_prob`` (so the long-run loss rate matches the
  i.i.d. knob), and attempts inside a bad window fail with
  ``loss_prob_bad`` (default 1.0: the whole burst shares its fate —
  retries that land inside the same window all fail) vs
  ``loss_prob_good`` in good windows (default 0.0).  Consecutive bad
  windows happen by chance, so the mean bad dwell is
  ``burst_len_s / (1 - loss_prob)``.  ``burst_len_s=0`` is bit-identical
  to the PR 6 i.i.d. draw (off-switch contract).
* **PS / HAP outages (§11)** — ``ps_outages`` (explicit intervals)
  and/or ``ps_outage_fraction`` (seeded periodic windows, the eclipse
  mirror for the server side) declare when a parameter server is dark.
  ``outage_intervals`` compiles them into a validated, merged schedule
  (`OutageSchedule`), ``outage_mask`` is ANDed into the visibility grid
  (a dark PS has no sat contacts), and the event runtime adds
  ``PS_DOWN`` / ``PS_UP`` events with ring-failover recovery semantics
  (see DESIGN.md §11 and `sched/runtime.py`).
* **energy budgets (§11)** — ``battery_j`` attaches per-satellite
  battery state (`EnergyState`): local training drains
  ``train_energy_j``, every transmit attempt drains ``tx_energy_j``,
  and the battery recharges at ``recharge_w`` watts scaled by the
  sunlit duty cycle ``1 - eclipse_fraction``.  A depleted satellite
  defers its uplink to the first affordable instant (energy as a
  consumable, not just the availability mask).  ``battery_j=None``
  attaches no state at all.

Every draw is a pure function of ``(seed, domain tag, ids...)``
— no global RNG state — so a fault schedule is reproducible across
runs and independent of event-processing order.

**Off-switch contract**: ``SimConfig.fault_model=None`` attaches no
state at all, and a default ``FaultModel()`` (every axis off) takes the
identical code paths — both are bit-identical to the fault-free
simulator (tests/test_faults.py pins this).  Each new axis has its own
independent off-switch: ``burst_len_s=0`` keeps the i.i.d. draw,
``ps_outages=None`` + ``ps_outage_fraction=0`` attach no outage
schedule, ``battery_j=None`` attaches no energy state, and
``adaptive_backoff=False`` keeps the blind exponential backoff.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

# domain-separation tags so the fault axes never share a stream
_TAG_COMPUTE = 0xC0
_TAG_ECLIPSE = 0xEC
_TAG_LOSS = 0xF417
_TAG_BURST = 0xB5
_TAG_OUTAGE = 0x0A6E


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Declarative fault / heterogeneity scenario (all axes off by
    default; validated at construction).

    ``compute_rate_spread=s`` draws per-sat training-time multipliers
    uniformly in ``[1, 1+s]`` (0 = homogeneous); ``compute_rates``
    overrides with explicit multipliers.  ``eclipse_fraction=f`` makes
    each satellite unavailable for a fraction ``f`` of every
    ``eclipse_period_s`` window (seeded per-sat phase).  ``loss_prob``
    is the per-attempt Bernoulli loss of a sat->PS model transfer (in
    burst mode, the stationary bad-window probability); ``max_retries``
    bounds retransmissions and ``retry_backoff_s`` is the base of the
    exponential backoff (attempt k waits ``retry_backoff_s * 2**k``).

    §11 axes: ``burst_len_s`` switches the loss draw to a Gilbert–
    Elliott block-fading channel per (sat, PS) link with per-window
    failure probabilities ``loss_prob_bad`` / ``loss_prob_good``;
    ``ps_outages`` / ``ps_outage_fraction`` declare PS dark windows;
    ``battery_j`` attaches per-sat energy budgets; ``adaptive_backoff``
    replaces the blind exponential backoff with an AIMD delay driven by
    the sink pool's observed queue wait, capped at
    ``retry_backoff_cap_s``."""
    seed: int = 0
    # heterogeneity
    compute_rate_spread: float = 0.0
    compute_rates: Optional[Tuple[float, ...]] = None
    # eclipse / duty cycle
    eclipse_fraction: float = 0.0
    eclipse_period_s: float = 5400.0
    # lossy transfers
    loss_prob: float = 0.0
    max_retries: int = 3
    retry_backoff_s: float = 120.0
    # correlated / bursty loss (Gilbert–Elliott block fading, §11)
    burst_len_s: float = 0.0           # 0 = i.i.d. draw (bit-identical)
    loss_prob_bad: float = 1.0         # attempt failure prob in a bad window
    loss_prob_good: float = 0.0        # attempt failure prob in a good window
    # PS / HAP outages (§11)
    ps_outages: Optional[Tuple[Tuple[int, float, float], ...]] = None
    ps_outage_fraction: float = 0.0    # seeded periodic dark fraction per PS
    ps_outage_period_s: float = 21600.0
    # energy budgets (§11)
    battery_j: Optional[float] = None  # None = no energy state at all
    train_energy_j: float = 50.0       # drained per local-training round
    tx_energy_j: float = 5.0           # drained per transmit attempt
    recharge_w: float = 1.0            # sunlit recharge rate (W = J/s)
    initial_charge: float = 1.0        # starting charge as a capacity fraction
    # adaptive retry backoff (§11)
    adaptive_backoff: bool = False
    retry_backoff_cap_s: float = 3840.0

    def __post_init__(self):
        if int(self.seed) < 0:
            raise ValueError(f"FaultModel.seed must be >= 0, got {self.seed}")
        if self.compute_rate_spread < 0.0:
            raise ValueError("FaultModel.compute_rate_spread must be >= 0, "
                             f"got {self.compute_rate_spread}")
        if self.compute_rates is not None:
            rates = tuple(float(r) for r in self.compute_rates)
            if not rates or min(rates) <= 0.0:
                raise ValueError("FaultModel.compute_rates must be a "
                                 "non-empty tuple of positive multipliers, "
                                 f"got {self.compute_rates!r}")
            object.__setattr__(self, "compute_rates", rates)
        if not 0.0 <= self.eclipse_fraction < 1.0:
            raise ValueError("FaultModel.eclipse_fraction must be in "
                             f"[0, 1), got {self.eclipse_fraction}")
        if self.eclipse_period_s <= 0.0:
            raise ValueError("FaultModel.eclipse_period_s must be > 0, "
                             f"got {self.eclipse_period_s}")
        if not 0.0 <= self.loss_prob <= 1.0:
            raise ValueError("FaultModel.loss_prob must be in [0, 1], "
                             f"got {self.loss_prob}")
        if int(self.max_retries) < 0:
            raise ValueError("FaultModel.max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.retry_backoff_s <= 0.0:
            raise ValueError("FaultModel.retry_backoff_s must be > 0, "
                             f"got {self.retry_backoff_s}")
        if self.burst_len_s < 0.0:
            raise ValueError("FaultModel.burst_len_s must be >= 0, "
                             f"got {self.burst_len_s}")
        for name in ("loss_prob_bad", "loss_prob_good"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultModel.{name} must be in [0, 1], "
                                 f"got {v}")
        if self.ps_outages is not None:
            ivs = []
            for entry in self.ps_outages:
                try:
                    ps, start, end = entry
                except (TypeError, ValueError):
                    raise ValueError(
                        "FaultModel.ps_outages entries must be "
                        f"(ps, start_s, end_s) triples, got {entry!r}")
                if int(ps) < 0:
                    raise ValueError("FaultModel.ps_outages PS index must "
                                     f"be >= 0, got {ps}")
                if not 0.0 <= float(start) < float(end):
                    raise ValueError(
                        "FaultModel.ps_outages intervals need "
                        f"0 <= start < end, got ({start}, {end})")
                ivs.append((int(ps), float(start), float(end)))
            object.__setattr__(self, "ps_outages", tuple(ivs))
        if not 0.0 <= self.ps_outage_fraction < 1.0:
            raise ValueError("FaultModel.ps_outage_fraction must be in "
                             f"[0, 1), got {self.ps_outage_fraction}")
        if self.ps_outage_period_s <= 0.0:
            raise ValueError("FaultModel.ps_outage_period_s must be > 0, "
                             f"got {self.ps_outage_period_s}")
        if self.battery_j is not None and self.battery_j <= 0.0:
            raise ValueError("FaultModel.battery_j must be > 0 (or None), "
                             f"got {self.battery_j}")
        for name in ("train_energy_j", "tx_energy_j", "recharge_w"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"FaultModel.{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        if not 0.0 <= self.initial_charge <= 1.0:
            raise ValueError("FaultModel.initial_charge must be in [0, 1], "
                             f"got {self.initial_charge}")
        if self.retry_backoff_cap_s < self.retry_backoff_s:
            raise ValueError(
                "FaultModel.retry_backoff_cap_s must be >= retry_backoff_s, "
                f"got {self.retry_backoff_cap_s} < {self.retry_backoff_s}")
        # per-instance memo for eclipse phases (keyed by num_sats); not a
        # dataclass field, so equality/hash/replace are unaffected
        object.__setattr__(self, "_phase_memo", {})

    # ---- derived state (pure functions of the frozen config) ---------------

    @property
    def is_null(self) -> bool:
        """True when every fault axis is off — a null model must be
        bit-identical to ``fault_model=None`` (the off-switch contract)."""
        return (self.compute_rate_spread == 0.0
                and self.compute_rates is None
                and self.eclipse_fraction == 0.0
                and self.loss_prob == 0.0
                and not self.has_burst
                and not self.has_outages
                and not self.has_energy)

    @property
    def has_burst(self) -> bool:
        """True when the Gilbert–Elliott burst channel is on."""
        return self.burst_len_s > 0.0

    @property
    def has_loss(self) -> bool:
        """True when any transfer-loss axis (i.i.d. or burst) is on."""
        return self.loss_prob > 0.0 or self.has_burst

    @property
    def has_outages(self) -> bool:
        """True when any PS-outage axis is configured."""
        return bool(self.ps_outages) or self.ps_outage_fraction > 0.0

    @property
    def has_energy(self) -> bool:
        """True when per-sat energy budgets are on."""
        return self.battery_j is not None

    def train_time_scale(self, num_sats: int) -> Optional[np.ndarray]:
        """Per-satellite training-time multipliers (>= 1 under a spread),
        or None when homogeneous — callers then keep the scalar
        ``train_time_s`` math, bit-identical to the fault-free path."""
        if self.compute_rates is not None:
            if len(self.compute_rates) != num_sats:
                raise ValueError(
                    f"FaultModel.compute_rates has {len(self.compute_rates)} "
                    f"entries but the constellation has {num_sats} satellites")
            return np.asarray(self.compute_rates, np.float64)
        if self.compute_rate_spread <= 0.0:
            return None
        rng = np.random.default_rng((self.seed, _TAG_COMPUTE))
        return 1.0 + self.compute_rate_spread * rng.random(num_sats)

    def _eclipse_phases(self, num_sats: int) -> np.ndarray:
        """Seeded per-sat eclipse phases, memoised per constellation size
        (the mask and the point query must agree exactly)."""
        memo = self._phase_memo
        phase = memo.get(num_sats)
        if phase is None:
            rng = np.random.default_rng((self.seed, _TAG_ECLIPSE))
            phase = rng.random(num_sats) * self.eclipse_period_s
            memo[num_sats] = phase
        return phase

    def availability_mask(self, times: np.ndarray,
                          num_sats: int) -> Optional[np.ndarray]:
        """(T, S) bool — True where a satellite is powered/available.
        None when eclipse modelling is off (no grid mutation at all).
        Each satellite is dark for ``eclipse_fraction`` of every
        ``eclipse_period_s`` window, at a seeded per-sat phase."""
        if self.eclipse_fraction <= 0.0:
            return None
        phase = self._eclipse_phases(num_sats)                    # (S,)
        dark = self.eclipse_fraction * self.eclipse_period_s
        rel = (np.asarray(times, np.float64)[:, None] + phase[None, :]) \
            % self.eclipse_period_s
        return rel >= dark

    def sat_available_at(self, sat: int, t: float, num_sats: int) -> bool:
        """Point query of the eclipse availability mask: is ``sat``
        sunlit/powered at instant ``t``?  Exactly the
        ``availability_mask`` formula, so a True here matches a True in
        the grid (used by fault-aware participant selection)."""
        if self.eclipse_fraction <= 0.0:
            return True
        phase = self._eclipse_phases(num_sats)
        dark = self.eclipse_fraction * self.eclipse_period_s
        rel = (float(t) + phase[int(sat)]) % self.eclipse_period_s
        return bool(rel >= dark)

    def in_bad_window(self, sat: int, ps: int, t: float) -> bool:
        """Gilbert–Elliott channel state of the (sat, ps) link at ``t``:
        True in a bad (fading) window.  Pure function of
        ``(seed, sat, ps, window)`` — independent of query order."""
        if not self.has_burst:
            return False
        window = int(float(t) // self.burst_len_s)
        rng = np.random.default_rng(
            (self.seed, _TAG_BURST, int(sat), int(ps), window))
        return bool(rng.random() < self.loss_prob)

    def transfer_fails(self, sat: int, round_idx: int, attempt: int,
                       ps: int = 0, t: float = 0.0) -> bool:
        """Deterministic loss draw for one transfer attempt.

        With ``burst_len_s=0`` (default) this is the PR 6 i.i.d.
        Bernoulli keyed on (seed, sat, round, attempt) — ``ps`` and
        ``t`` are ignored, so the schedule is bit-identical.  With
        ``burst_len_s > 0`` the (sat, ps) link's Gilbert–Elliott window
        state at the attempt instant ``t`` picks the failure
        probability (``loss_prob_bad`` / ``loss_prob_good``); the
        per-attempt sub-draw is keyed on
        (seed, sat, ps, window, round, attempt).  Either way the result
        is a pure function of the key — independent of event-processing
        order and reproducible across runs."""
        if not self.has_burst:
            if self.loss_prob <= 0.0:
                return False
            if self.loss_prob >= 1.0:
                return True
            rng = np.random.default_rng(
                (self.seed, _TAG_LOSS, int(sat), int(round_idx),
                 int(attempt)))
            return bool(rng.random() < self.loss_prob)
        p = (self.loss_prob_bad if self.in_bad_window(sat, ps, t)
             else self.loss_prob_good)
        if p <= 0.0:
            return False
        if p >= 1.0:
            return True
        window = int(float(t) // self.burst_len_s)
        rng = np.random.default_rng(
            (self.seed, _TAG_LOSS, int(sat), int(ps), window,
             int(round_idx), int(attempt)))
        return bool(rng.random() < p)

    def retry_delay_s(self, attempt: int) -> float:
        """Exponential backoff before retransmission ``attempt + 1``."""
        return float(self.retry_backoff_s * (2.0 ** int(attempt)))

    # ---- PS outages (§11) --------------------------------------------------

    def outage_intervals(self, num_ps: int, duration_s: float) \
            -> Tuple[Tuple[int, float, float], ...]:
        """Compile the configured PS outages into explicit
        ``(ps, start_s, end_s)`` intervals clipped to ``[0, duration_s)``:
        the explicit ``ps_outages`` (validated against ``num_ps`` here,
        like ``compute_rates`` at ``train_time_scale`` time) plus the
        seeded periodic windows from ``ps_outage_fraction`` (dark for
        that fraction of every ``ps_outage_period_s``, at a seeded
        per-PS phase — the server-side eclipse mirror)."""
        out: List[Tuple[int, float, float]] = []
        if self.ps_outages:
            for ps, start, end in self.ps_outages:
                if ps >= num_ps:
                    raise ValueError(
                        f"FaultModel.ps_outages names PS {ps} but the "
                        f"topology has {num_ps} parameter servers")
                s, e = max(0.0, start), min(end, duration_s)
                if e > s:
                    out.append((ps, s, e))
        if self.ps_outage_fraction > 0.0:
            period = self.ps_outage_period_s
            dark = self.ps_outage_fraction * period
            rng = np.random.default_rng((self.seed, _TAG_OUTAGE))
            phase = rng.random(num_ps) * period
            for ps in range(num_ps):
                k_max = int((duration_s + phase[ps]) // period)
                for k in range(k_max + 1):
                    s = k * period - phase[ps]
                    e = s + dark
                    s, e = max(0.0, s), min(e, duration_s)
                    if e > s:
                        out.append((ps, s, e))
        out.sort()
        return tuple(out)

    def outage_mask(self, times: np.ndarray, num_ps: int,
                    duration_s: float) -> Optional[np.ndarray]:
        """(T, P) bool — True where a parameter server is up.  None when
        no outage axis is configured (no grid mutation at all).  ANDed
        into ``VisibilityTimeline.grid`` at simulator construction: a
        dark PS simply has no sat contacts, so every downstream timing
        rule routes around it."""
        ivs = self.outage_intervals(num_ps, duration_s)
        if not ivs:
            return None
        t = np.asarray(times, np.float64)
        avail = np.ones((t.shape[0], num_ps), bool)
        for ps, s, e in ivs:
            avail[(t >= s) & (t < e), ps] = False
        return avail


class OutageSchedule:
    """Compiled per-PS outage intervals with pure point/next queries.

    Built once at simulator construction from
    ``FaultModel.outage_intervals`` (merged, sorted, disjoint per PS);
    every query is a pure function of the schedule and the query
    instant, so runtime recovery decisions are independent of
    event-processing order.  The half-open convention matches the grid
    mask: a PS is down on ``[start, end)`` and up again AT ``end``."""

    def __init__(self, intervals: Sequence[Tuple[int, float, float]],
                 num_ps: int):
        self.num_ps = int(num_ps)
        by: List[List[Tuple[float, float]]] = [[] for _ in range(self.num_ps)]
        for ps, s, e in intervals:
            by[int(ps)].append((float(s), float(e)))
        self._starts: List[List[float]] = []
        self._ends: List[List[float]] = []
        for ivs in by:
            merged: List[Tuple[float, float]] = []
            for s, e in sorted(ivs):
                if merged and s <= merged[-1][1]:
                    merged[-1] = (merged[-1][0], max(merged[-1][1], e))
                else:
                    merged.append((s, e))
            self._starts.append([s for s, _ in merged])
            self._ends.append([e for _, e in merged])

    def events(self) -> List[Tuple[int, float, float]]:
        """Merged ``(ps, start, end)`` intervals, for PS_DOWN / PS_UP
        event scheduling and telemetry."""
        return [(ps, s, e)
                for ps in range(self.num_ps)
                for s, e in zip(self._starts[ps], self._ends[ps])]

    def down_at(self, ps: int, t: float) -> bool:
        """True when ``ps`` is dark at instant ``t``."""
        starts = self._starts[ps]
        i = bisect.bisect_right(starts, float(t)) - 1
        return i >= 0 and float(t) < self._ends[ps][i]

    def next_up(self, ps: int, t: float) -> float:
        """First instant >= ``t`` at which ``ps`` is up (``t`` itself
        when it already is)."""
        starts = self._starts[ps]
        i = bisect.bisect_right(starts, float(t)) - 1
        if i >= 0 and float(t) < self._ends[ps][i]:
            return float(self._ends[ps][i])
        return float(t)

    def all_down_at(self, t: float) -> bool:
        """True when EVERY parameter server is dark at ``t`` (the total
        outage the runtime's horizon clamp guards against)."""
        return all(self.down_at(ps, t) for ps in range(self.num_ps))

    def next_any_up(self, t: float) -> float:
        """First instant >= ``t`` at which at least one PS is up.
        Finite for any finite schedule (every interval ends)."""
        if not self.all_down_at(t):
            return float(t)
        return min(self.next_up(ps, t) for ps in range(self.num_ps))

    def down_set(self, t: float) -> set:
        """The set of PSs dark at ``t`` (for relay-path avoidance)."""
        return {ps for ps in range(self.num_ps) if self.down_at(ps, t)}


class EnergyState:
    """Per-satellite battery bookkeeping (runtime-only consumable state,
    DESIGN.md §11).

    Charge is advanced lazily in closed form at each query instant:
    ``charge(t) = min(cap, charge + rate * (t - t_last))`` with the
    mean-field recharge rate ``recharge_w * (1 - eclipse_fraction)``
    (the sunlit duty cycle), so no per-dt integration loop is needed.
    ``try_drain`` commits a withdrawal; ``time_to_afford`` answers when
    a withdrawal first becomes affordable (None if it never does —
    zero recharge or a cost above capacity).  ``snapshot``/``restore``
    mirror the §9 channel-pool rollback for aborted speculative opens."""

    def __init__(self, fault: FaultModel, num_sats: int):
        self.cap = float(fault.battery_j)
        self.rate_w = float(fault.recharge_w) * \
            (1.0 - float(fault.eclipse_fraction))
        self.train_j = float(fault.train_energy_j)
        self.tx_j = float(fault.tx_energy_j)
        self.charge = np.full(num_sats, self.cap * float(fault.initial_charge),
                              np.float64)
        self.t_last = np.zeros(num_sats, np.float64)
        self.drained_j = 0.0
        self.drains = 0

    def _advance(self, sat: int, t: float) -> None:
        dt = float(t) - self.t_last[sat]
        if dt > 0.0:
            self.charge[sat] = min(self.cap, self.charge[sat]
                                   + self.rate_w * dt)
            self.t_last[sat] = float(t)

    def level(self, sat: int, t: float) -> float:
        """Battery charge (J) of ``sat`` at instant ``t``."""
        self._advance(sat, t)
        return float(self.charge[sat])

    def try_drain(self, sat: int, t: float, joules: float) -> bool:
        """Withdraw ``joules`` at ``t`` if affordable; False otherwise
        (no partial drains)."""
        self._advance(sat, t)
        if self.charge[sat] + 1e-9 < joules:
            return False
        self.charge[sat] = max(0.0, self.charge[sat] - joules)
        self.drained_j += float(joules)
        self.drains += 1
        return True

    def time_to_afford(self, sat: int, t: float,
                       joules: float) -> Optional[float]:
        """First instant >= ``t`` at which ``sat`` can afford ``joules``
        (``t`` itself when it already can); None when it never will."""
        self._advance(sat, t)
        deficit = float(joules) - self.charge[sat]
        if deficit <= 0.0:
            return float(t)
        if self.rate_w <= 0.0 or float(joules) > self.cap + 1e-9:
            return None
        return float(t) + deficit / self.rate_w

    def snapshot(self):
        return (self.charge.copy(), self.t_last.copy(),
                self.drained_j, self.drains)

    def restore(self, snap) -> None:
        charge, t_last, drained_j, drains = snap
        self.charge = charge.copy()
        self.t_last = t_last.copy()
        self.drained_j = drained_j
        self.drains = drains
