"""Observability subsystem (repro/obs, DESIGN.md §12).

Covers: Tracer span/instant bookkeeping and the NullTracer off-switch,
the bounded deterministic Histogram, the MetricRegistry-backed
StatsView compat layer (key-for-key against the registry snapshot),
``contention_stats()`` on a fresh runtime, the pinned ``tracer=None``
bit-parity contract, Chrome trace-event export + validation, JSONL
round-tripping through the trace_report CLI loader, and the host-side
dispatch profiler's cold-vs-steady split.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core.links import LinkModel
from repro.fl import get_strategy
from repro.obs import (NULL_TRACER, DispatchProfiler, Histogram,
                       MetricRegistry, StatsView, Tracer,
                       add_runtime_tracks, export_chrome, export_jsonl,
                       validate_chrome_trace)
from repro.obs.trace import (EV_COMMIT, EV_DISPATCH, EV_TRANSFER_RETRY,
                             EV_TRIGGER, SPAN_CHANNEL, SPAN_OUTAGE,
                             SPAN_ROUND)
from repro.sched import EventDrivenRuntime, FaultModel

from benchmarks.trace_report import (load_trace, ps_utilization,
                                     retry_report, round_waterfall)
from test_epoch_step import TinyFusedTrainer, W0

SIMKW = dict(duration_s=86400.0, train_time_s=300.0,
             use_model_bank=True, use_fused_step=True)
PIPE = dict(max_in_flight=3, handoff_policy="next_contact")


def _sim(name, *, spec_kw=None, **kw):
    cfg = SimConfig(event_driven=True, **{**SIMKW, **kw})
    spec = get_strategy(name)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    return FLSimulation(spec, TinyFusedTrainer(W0), None, cfg)


def _rows(hist):
    return [(r.epoch, r.time_s, r.num_models, r.gamma, r.stale_groups)
            for r in hist]


# ---- Tracer / NullTracer ----------------------------------------------------

def test_tracer_span_lifecycle():
    t = Tracer()
    h = t.begin("round", 10.0, track="round 0", source=1)
    t.instant("MODEL_ARRIVAL", 12.0, track="round 0", sat=3)
    t.end(h, 20.0, committed=True)
    t.span("recruit", 10.0, 11.0, track="round 0")
    assert len(t.spans) == 2 and len(t.instants) == 1
    s = t.spans[0]
    assert (s.name, s.t_start, s.t_end) == ("round", 10.0, 20.0)
    assert s.args == {"source": 1, "committed": True}
    assert s.duration == 10.0
    # track order is first-appearance; unknown handle / clamp are benign
    assert t.tracks() == ["round 0"]
    t.end(999, 5.0)
    h2 = t.begin("x", 50.0)
    t.end(h2, 40.0)                       # t_end clamped to t_start
    assert t.spans[-1].t_end == 50.0


def test_tracer_close_open_spans():
    t = Tracer()
    t.begin("round", 0.0, track="round 0")
    t.begin("round", 5.0, track="round 1")
    t.close_open_spans(30.0)
    assert [s.t_end for s in t.spans] == [30.0, 30.0]
    assert t.tracks() == ["round 0", "round 1"]
    t.clear()
    assert not t.spans and not t.instants and not t.tracks()


def test_null_tracer_is_inert():
    nt = NULL_TRACER
    assert nt.enabled is False
    h = nt.begin("round", 0.0, track="round 0", junk=1)
    assert h == -1
    nt.end(h, 1.0)
    nt.instant("x", 2.0)
    nt.span("y", 0.0, 1.0)
    nt.close_open_spans(3.0)
    assert not hasattr(nt, "spans")       # __slots__: no buffers at all


# ---- Histogram / MetricRegistry / StatsView ---------------------------------

def test_histogram_bounded_with_exact_aggregates():
    h = Histogram("w", max_samples=64)
    n = 10_000
    for i in range(n):
        h.observe(float(i))
    assert len(h.samples) <= 64           # decimated, never unbounded
    s = h.summary()
    assert s["count"] == n                # aggregates stay exact
    assert s["sum"] == pytest.approx(n * (n - 1) / 2)
    assert (s["min"], s["max"]) == (0.0, float(n - 1))
    # percentiles come from the retained (stride-decimated) sample set:
    # uniform data keeps them within a stride of the true quantile
    assert s["p50"] == pytest.approx(n / 2, rel=0.05)
    assert s["p95"] == pytest.approx(0.95 * n, rel=0.05)
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_histogram_empty_and_validation():
    h = Histogram("w")
    assert h.summary() == {"count": 0, "sum": 0.0, "min": None,
                           "max": None, "p50": None, "p95": None,
                           "p99": None}
    assert h.percentile(50.0) is None
    with pytest.raises(ValueError):
        Histogram("w", max_samples=1)


def test_stats_view_is_a_mutable_mapping_over_the_registry():
    reg = MetricRegistry()
    st = StatsView(reg, counter_keys=("a", "b"), histogram_keys=("h",))
    assert st["a"] == 0 and "a" in st
    st["a"] += 2
    st["b"] = 5
    assert reg.counter("a").value == 2.0 and st["b"] == 5
    assert isinstance(st["a"], int)       # integer counters render as int
    reg.observe("h", 1.5)
    assert st["h"]["count"] == 1          # histogram key -> summary dict
    with pytest.raises(TypeError):
        st["h"] = []                      # histograms are not assignable
    with pytest.raises(TypeError):
        del st["a"]
    st["new_key"] = 3                     # unknown keys become counters
    assert reg.counter("new_key").value == 3.0
    assert set(dict(st)) == {"a", "b", "h", "new_key"}


def test_runtime_stats_view_matches_registry_snapshot():
    """satellite (c): the compat dict and the registry are one store —
    every key the view exposes reads back the registry's value."""
    fm = FaultModel(loss_prob=0.3, max_retries=2, adaptive_backoff=True)
    fls = _sim("asyncfleo-twohap", fault_model=fm, spec_kw=PIPE)
    rt = EventDrivenRuntime(fls)
    rt.run(W0, max_epochs=5)
    st = dict(rt.stats)
    assert st["transfers_failed"] > 0
    assert st["backoff_delays_s"]["count"] == st["transfer_retries"]
    for key, val in st.items():
        assert rt.metrics.get(key) == val
    assert rt.stats.registry is rt.metrics


def test_contention_stats_on_fresh_runtime():
    """satellite (c): telemetry is well-formed before any event runs —
    zero grants, empty queue-wait histogram — and None without a model."""
    fls = _sim("asyncfleo-twohap", spec_kw=dict(ps_channels=4))
    rt = EventDrivenRuntime(fls)          # no run()
    st = rt.contention_stats()
    assert st["ps_channels"] == 4
    for side in ("tx", "rx"):
        assert st[side]["grants"] == 0
        assert st[side]["queue_wait_s"] == 0.0
        assert st[side]["queue_wait_hist"]["count"] == 0
        assert st[side]["queue_wait_hist"]["p95"] is None
    bare = EventDrivenRuntime(_sim("asyncfleo-twohap"))
    assert bare.contention_stats() is None


# ---- tracer=None bit-parity (pinned) ----------------------------------------

def test_null_tracer_bit_parity_pinned():
    """The §12 off-switch contract: a traced run and a tracer=None run
    of the same contended, faulty, pipelined scenario produce
    bit-identical histories and final weights."""
    fm = FaultModel(loss_prob=0.3, max_retries=2)
    kw = dict(fault_model=fm, link=LinkModel(rate_bps=10.0))
    sk = {**PIPE, "ps_channels": 1}
    plain = _sim("asyncfleo-twohap", spec_kw=sk, **kw)
    traced = _sim("asyncfleo-twohap", tracer=Tracer(), spec_kw=sk, **kw)
    rt_p = EventDrivenRuntime(plain)
    rt_t = EventDrivenRuntime(traced)
    hp = rt_p.run(W0, max_epochs=6)
    ht = rt_t.run(W0, max_epochs=6)
    assert _rows(hp) == _rows(ht)
    assert (np.asarray(plain._w_flat).tobytes()
            == np.asarray(traced._w_flat).tobytes())
    assert dict(rt_p.stats) == dict(rt_t.stats)
    assert rt_p.tracer is NULL_TRACER and not rt_p.tracer.enabled
    assert len(rt_t.tracer.spans) > 0


# ---- traced run -> Chrome export -> report ----------------------------------

def _traced_run(max_epochs=5):
    fm = FaultModel(loss_prob=0.3, max_retries=2, ps_outage_fraction=0.1)
    fls = _sim("asyncfleo-twohap", tracer=Tracer(), fault_model=fm,
               link=LinkModel(rate_bps=10.0),
               spec_kw={**PIPE, "ps_channels": 1})
    rt = EventDrivenRuntime(fls)
    hist = rt.run(W0, max_epochs=max_epochs)
    return fls, rt, hist


def test_traced_run_exports_valid_chrome_trace(tmp_path):
    fls, rt, hist = _traced_run()
    tracer = rt.tracer
    round_spans = [s for s in tracer.spans if s.name == SPAN_ROUND]
    assert len(round_spans) >= len(hist)  # >=1 round span per epoch
    for name in (EV_TRIGGER, EV_DISPATCH, EV_COMMIT):
        assert sum(i.name == name for i in tracer.instants) >= len(hist)
    assert any(i.name == EV_TRANSFER_RETRY for i in tracer.instants)
    add_runtime_tracks(tracer, rt)
    assert any(s.name == SPAN_CHANNEL for s in tracer.spans)
    assert any(s.name == SPAN_OUTAGE for s in tracer.spans)

    path = tmp_path / "trace.json"
    obj = export_chrome(tracer, str(path))
    assert validate_chrome_trace(obj) == []
    assert validate_chrome_trace(json.loads(path.read_text())) == []
    # ps tracks come first in the pid/tid layout, then rounds in order
    names = [e["args"]["name"] for e in obj["traceEvents"]
             if e.get("ph") == "M"]
    ps = [n for n in names if n.startswith("ps ")]
    assert names[:len(ps)] == sorted(ps)


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": {}}) != []
    bad_ph = {"traceEvents": [{"ph": "Z", "name": "x", "pid": 0,
                               "tid": 0, "ts": 0.0}]}
    assert any("ph" in e for e in validate_chrome_trace(bad_ph))
    neg_dur = {"traceEvents": [{"ph": "X", "name": "x", "pid": 0,
                                "tid": 0, "ts": 0.0, "dur": -1.0}]}
    assert validate_chrome_trace(neg_dur) != []


def test_jsonl_and_chrome_roundtrip_through_trace_report(tmp_path):
    fls, rt, hist = _traced_run()
    add_runtime_tracks(rt.tracer, rt)
    jpath, cpath = tmp_path / "t.jsonl", tmp_path / "t.json"
    n = export_jsonl(rt.tracer, str(jpath))
    export_chrome(rt.tracer, str(cpath))
    assert n == len(rt.tracer.spans) + len(rt.tracer.instants)
    a, b = load_trace(str(cpath)), load_trace(str(jpath))
    for t in (a, b):
        assert len(t.spans) == len(rt.tracer.spans)
        assert len(t.instants) == len(rt.tracer.instants)
        assert sorted(t.tracks()) == sorted(rt.tracer.tracks())
    wf = round_waterfall(a)
    assert len(wf) - 2 == sum(s.name == SPAN_ROUND for s in a.spans)
    util = "\n".join(ps_utilization(a))
    assert "busy" in util and "outage" in util
    assert "retries" in retry_report(a)[0]


# ---- dispatch profiler ------------------------------------------------------

def test_dispatch_profiler_cold_vs_steady_unit():
    p = DispatchProfiler()
    p.trigger()
    p.record((4, 2, 2, 0, False), False, 0.50)   # cold: new signature
    p.record((4, 2, 2, 0, False), False, 0.01)   # steady: cache hit
    p.record((4, 3, 4, 0, True), True, 0.40)     # cold again + fallback
    s = p.summary()
    assert s["dispatches"] == 3 and s["cold_dispatches"] == 2
    assert s["fallback_dispatches"] == 1
    assert s["compile_s"] == pytest.approx(0.90)
    assert s["dispatch_s"] == pytest.approx(0.01)
    assert s["dispatches_per_trigger"] == 3.0
    p.reset()
    assert p.summary()["dispatches"] == 0


def test_dispatch_profiler_wired_through_fused_commits():
    prof = DispatchProfiler()
    fls = _sim("asyncfleo-twohap", profiler=prof, spec_kw=PIPE)
    hist = EventDrivenRuntime(fls).run(W0, max_epochs=6)
    s = prof.summary()
    assert s["triggers"] == len(hist)
    assert s["dispatches"] >= len(hist)
    assert 0 < s["cold_dispatches"] <= s["dispatches"]
    assert s["compile_s"] + s["dispatch_s"] > 0.0
    # profiler off: the program must shed the hook between runs
    fls2 = _sim("asyncfleo-twohap", spec_kw=PIPE)
    EventDrivenRuntime(fls2).run(W0, max_epochs=2)
    assert fls2._fused_prog.profiler is None
