"""Expert-parallel MoE with explicit all-to-all (beyond-paper, shard_map).

The GSPMD path (models/moe.py) lets XLA choose the collective schedule for
the sort-based dispatch.  This module is the hand-scheduled production
alternative: tokens are sequence-sharded over the ``model`` axis, each rank
owns E/n_ranks experts, and dispatch/return are two explicit
``jax.lax.all_to_all`` collectives — the schedule used by Switch/GShard-class
systems and the pattern AsyncFLEO's ring-of-stars maps onto when satellites
hold expert shards (DESIGN.md §3).

Layout inside shard_map (per (data, model) device):
  x_loc   : (T_loc, d)        tokens of my sequence shard
  we*_loc : (E_loc, d, f)     my experts
  send    : (n_ranks, C, d)   capacity-C buckets per destination rank
  recv    = all_to_all(send)  tokens routed to my experts from every rank
  y       = expert matmuls    (n_ranks*C tokens through E_loc experts)
  return  = all_to_all(y)     back to the token owners, combined by gate.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.shard_compat import shard_map


def ep_capacity(tokens_local: int, top_k: int, n_ranks: int,
                factor: float) -> int:
    c = int(math.ceil(tokens_local * top_k * factor / n_ranks))
    return max(8, -(-c // 8) * 8)


def moe_ffn_ep_local(p_local, cfg: ModelConfig, x_loc, *, axis_name: str,
                     n_ranks: int, capacity_factor: float = None):
    """Body to run inside shard_map.  x_loc: (T_loc, d) this rank's tokens;
    p_local leaves are the LOCAL expert shards (E_loc, d, f); the router is
    replicated.  Returns (out (T_loc, d), aux)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    T_loc, d = x_loc.shape
    E, k = cfg.num_experts, cfg.top_k
    E_loc = E // n_ranks
    dt = x_loc.dtype

    logits = (x_loc @ p_local["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                       # (T_loc, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T_loc * k)
    aux = E * jnp.sum(me * ce)
    aux = jax.lax.pmean(aux, axis_name)

    C = ep_capacity(T_loc, k, n_ranks, capacity_factor)
    flat_ids = ids.reshape(-1)                                # (T_loc*k,)
    dest_rank = flat_ids // E_loc
    # position within destination-rank bucket via stable sort by rank
    sort_idx = jnp.argsort(dest_rank, stable=True)
    sorted_rank = dest_rank[sort_idx]
    start = jnp.searchsorted(sorted_rank, jnp.arange(n_ranks), side="left")
    pos = jnp.arange(T_loc * k) - start[sorted_rank]
    tok = sort_idx // k
    valid = pos < C
    slot = jnp.where(valid, sorted_rank * C + pos, n_ranks * C)

    send_x = jnp.zeros((n_ranks * C + 1, d), dt).at[slot].set(x_loc[tok])
    send_eid = jnp.full((n_ranks * C + 1,), 0, jnp.int32).at[slot].set(
        flat_ids[sort_idx] % E_loc)
    send_x = send_x[:-1].reshape(n_ranks, C, d)
    send_eid = send_eid[:-1].reshape(n_ranks, C)

    # ---- dispatch: tokens travel to their experts' rank -------------------
    recv_x = jax.lax.all_to_all(send_x, axis_name, 0, 0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, axis_name, 0, 0, tiled=False)
    rx = recv_x.reshape(n_ranks * C, d)
    reid = recv_eid.reshape(n_ranks * C)

    # local per-expert compute via one-hot masking over E_loc (E_loc is
    # small per rank; (E_loc, nC, d) buffers stay VMEM/HBM friendly)
    onehot = jax.nn.one_hot(reid, E_loc, dtype=dt)            # (nC, E_loc)
    xe = jnp.einsum("td,te->etd", rx, onehot)                 # (E_loc, nC, d)
    a = jnp.einsum("etd,edf->etf", xe, p_local["we1"].astype(dt))
    b = jnp.einsum("etd,edf->etf", xe, p_local["we3"].astype(dt))
    ye = jnp.einsum("etf,efd->etd", jax.nn.silu(a) * b, p_local["we2"].astype(dt))
    y = jnp.einsum("etd,te->td", ye, onehot)                  # (nC, d)

    # ---- return trip ------------------------------------------------------
    y_send = y.reshape(n_ranks, C, d)
    y_back = jax.lax.all_to_all(y_send, axis_name, 0, 0, tiled=False)
    y_flat = y_back.reshape(n_ranks * C, d)

    gate_sorted = gate.reshape(-1)[sort_idx].astype(dt)
    contrib = y_flat[jnp.where(valid, slot, 0)] * jnp.where(valid, gate_sorted,
                                                            0.0)[:, None]
    out = jnp.zeros((T_loc, d), dt).at[tok].add(contrib)

    if "shared" in p_local:
        out = out + L.mlp(p_local["shared"], x_loc)
    return out, aux


def make_ep_moe_layer(cfg: ModelConfig, mesh, *, axis_name: str = "model",
                      capacity_factor: float = None):
    """Returns moe(params, x (B,S,d)) -> (out, aux) wrapping shard_map.

    params: full (unsharded-view) moe params; shard_map slices experts onto
    ranks via in_specs; x is sequence-sharded over ``axis_name`` inside."""
    from jax.sharding import PartitionSpec as P
    n_ranks = mesh.devices.shape[mesh.axis_names.index(axis_name)]

    body = functools.partial(moe_ffn_ep_local, cfg=cfg, axis_name=axis_name,
                             n_ranks=n_ranks, capacity_factor=capacity_factor)

    def local_fn(p_local, x_loc):
        B_loc, S_loc, d = x_loc.shape
        out, aux = body(p_local, x_loc=x_loc.reshape(B_loc * S_loc, d))
        return out.reshape(B_loc, S_loc, d), aux

    expert_spec = P(axis_name)
    p_specs = {
        "router": P(),
        "we1": expert_spec, "we3": expert_spec, "we2": expert_spec,
    }

    def moe(params, x):
        p_specs_full = dict(p_specs)
        if "shared" in params:
            p_specs_full["shared"] = jax.tree.map(lambda _: P(), params["shared"])
        mapped = shard_map(
            local_fn, mesh=mesh,
            in_specs=(p_specs_full, P("data", axis_name, None)),
            out_specs=(P("data", axis_name, None), P()),
            check_vma=False)
        return mapped(params, x)

    return moe
