"""Pytree checkpointing (npz + json treedef — no orbax in this container).

Flat-key layout: each leaf saved under its '/'-joined key path; the treedef
is reconstructed from the key paths, so arbitrary nested dict/list pytrees of
arrays round-trip.  FL server state (global model + metadata + grouping)
uses the same primitive.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez_compressed(path, **_flatten_with_paths(tree))


def load_pytree(path: str):
    with np.load(path, allow_pickle=False) as z:
        return _unflatten({k: z[k] for k in z.files})


def save_server_state(path: str, *, global_model, epoch: int,
                      grouping=None, metadata=None) -> None:
    save_pytree(path, {"global_model": global_model})
    side = {"epoch": int(epoch),
            "grouping": grouping if grouping is not None else [],
            "metadata": metadata if metadata is not None else {}}
    with open(path + ".json", "w") as f:
        json.dump(side, f)


def load_server_state(path: str):
    tree = load_pytree(path)
    with open(path + ".json") as f:
        side = json.load(f)
    return tree["global_model"], side
