"""PS link-capacity / contention subsystem (sched/contacts.ContentionModel,
DESIGN.md §9).

Covers: ChannelPool grant semantics (serialization, parallel channels,
FIFO by request time, gap backfilling, backlog, snapshot/restore), the
off-switch parity contract (ps_channels=None attaches no model;
ps_channels large enough to never queue is bit-identical to None), the
epoch-loop-vs-runtime parity with contention ON (both drivers share the
plan's pools), cross-round serialization degrading the pipelined
runtime, rollback of aborted speculative opens, the NextContactHandoff
occupancy tie-break, and telemetry.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig
from repro.core.links import LinkModel
from repro.fl import get_strategy
from repro.sched import ContentionModel, EventDrivenRuntime
from repro.sched.contacts import ChannelPool
from repro.sched.policies import NextContactHandoff

from test_epoch_step import TinyFusedTrainer, W0

SIMKW = dict(duration_s=86400.0, train_time_s=300.0,
             use_model_bank=True, use_fused_step=True)
# W0 is 9 params = 288 bits; at 10 b/s one transfer holds a channel for
# 28.8 s — long enough that a 40-satellite round must serialize visibly
SLOW = LinkModel(rate_bps=10.0)


def _sim(name, event_driven, *, spec_kw=None, **kw):
    cfg = SimConfig(event_driven=event_driven, **{**SIMKW, **kw})
    spec = get_strategy(name)
    if spec_kw:
        spec = dataclasses.replace(spec, **spec_kw)
    return FLSimulation(spec, TinyFusedTrainer(W0), None, cfg)


def _rows(hist):
    return [(r.epoch, round(r.time_s, 6), r.num_models,
             round(r.gamma, 6), r.stale_groups) for r in hist]


# ---- ChannelPool / ContentionModel unit semantics ---------------------------

def test_busy_interval_is_transmission_time_only():
    """The channel-occupancy interval of a transfer is its transmission
    time, not its end-to-end delay: propagation + processing delay the
    payload, not the transmitter (DESIGN.md §9)."""
    t0, t1 = SLOW.busy_interval(100.0, 288.0)
    assert t0 == 100.0
    assert t1 - t0 == pytest.approx(SLOW.transmission_delay(288.0))
    assert t1 - t0 < SLOW.total_delay(288.0, 500e3)


def test_single_channel_serializes_fifo():
    p = ChannelPool(1, 1)
    assert p.grant(0, 0.0, 10.0) == 0.0
    assert p.grant(0, 0.0, 10.0) == 10.0     # queued behind the first
    assert p.grant(0, 5.0, 10.0) == 20.0     # and behind the second
    assert p.grant(0, 40.0, 10.0) == 40.0    # free again
    assert p.grants == 4
    assert p.queue_wait_s == pytest.approx(10.0 + 15.0)
    assert p.busy_s[0] == pytest.approx(40.0)


def test_parallel_channels_and_infinite():
    p = ChannelPool(1, 2)
    assert [p.grant(0, 0.0, 10.0) for _ in range(3)] == [0.0, 0.0, 10.0]
    inf = ChannelPool(1, None)
    assert [inf.grant(0, t, 100.0) for t in (0.0, 1.0, 2.0)] == [0.0, 1.0,
                                                                 2.0]
    assert inf.grants == 3                    # telemetry still counted


def test_gap_backfill_between_reservations():
    """A far-future reservation must not lock the idle gap before it —
    the cross-round case where a straggler's slot is granted hours ahead
    at round open."""
    p = ChannelPool(1, 1)
    assert p.grant(0, 10000.0, 10.0) == 10000.0
    assert p.grant(0, 0.0, 10.0) == 0.0       # backfills the idle gap
    assert p.grant(0, 9995.0, 10.0) == 10010.0   # gap too small: queues
    assert p.backlog(0, 9000.0) == pytest.approx(30.0 - 10.0)


def test_grant_many_fifo_by_request_time():
    c = ContentionModel(2, 1)
    starts = c.grant_rx_many([0, 0, 1], [5.0, 0.0, 3.0], 10.0)
    # the t=0 request is granted first (FIFO by request time), so the
    # t=5 request queues behind it; PS 1 is an independent pool
    np.testing.assert_allclose(starts, [10.0, 0.0, 3.0])
    assert c.rx.queue_wait_s == pytest.approx(5.0)


def test_snapshot_restore_rolls_back_grants():
    c = ContentionModel(1, 1)
    c.grant_tx(0, 0.0, 10.0)
    snap = c.snapshot()
    c.grant_tx(0, 0.0, 10.0)
    c.grant_rx(0, 0.0, 10.0)
    c.restore(snap)
    assert c.tx.grants == 1 and c.rx.grants == 0
    assert c.grant_tx(0, 0.0, 10.0) == 10.0   # only the first grant stands


def test_stats_shape():
    c = ContentionModel(2, 4)
    c.grant_tx(1, 0.0, 50.0)
    s = c.stats(100.0)
    assert s["ps_channels"] == 4
    assert s["tx"]["grants"] == 1
    assert s["tx"]["busy_s"] == [0.0, 50.0]
    assert s["tx"]["utilization"][1] == pytest.approx(50.0 / 400.0)
    assert s["rx"]["grants"] == 0


# ---- the off-switch parity contract ----------------------------------------

def test_ps_channels_none_attaches_no_model():
    fls = _sim("asyncfleo-twohap", True)
    assert fls.spec.ps_channels is None
    assert fls.plan.contention is None        # zero contention state


def test_huge_channel_count_bit_identical_to_off():
    """ps_channels large enough that no transfer ever queues must leave
    every aggregation instant and the final weights bit-identical to the
    no-contention path — the contended code path itself is time-neutral
    when channels are free (the §9 off-switch parity contract)."""
    a = _sim("asyncfleo-twohap", True, link=SLOW)
    b = _sim("asyncfleo-twohap", True, link=SLOW,
             spec_kw=dict(ps_channels=10 ** 6))
    ha = a.run(W0, max_epochs=5)
    hb = b.run(W0, max_epochs=5)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_array_equal(np.asarray(a._w_flat),
                                  np.asarray(b._w_flat))


def test_parity_epoch_loop_vs_runtime_with_contention_on():
    """Contention is physics, not policy: with the SAME finite channel
    count the fused epoch loop and the event runtime still agree exactly
    (both route timing through the shared plan's pools in the same
    order)."""
    kw = dict(link=SLOW, spec_kw=dict(ps_channels=1))
    a = _sim("asyncfleo-twohap", False, **kw)
    b = _sim("asyncfleo-twohap", True, **kw)
    ha = a.run(W0, max_epochs=4)
    hb = b.run(W0, max_epochs=4)
    assert _rows(ha) == _rows(hb)
    np.testing.assert_allclose(np.asarray(a._w_flat), np.asarray(b._w_flat),
                               atol=1e-5)
    assert a._fused_prog.dispatches == b._fused_prog.dispatches


# ---- contention actually binds ----------------------------------------------

def test_single_channel_serializes_a_round():
    """k=1 with slow links: the same scenario converges strictly later
    than uncontended, and the pools report queue waits."""
    a = _sim("asyncfleo-twohap", True, link=SLOW)
    b = _sim("asyncfleo-twohap", True, link=SLOW,
             spec_kw=dict(ps_channels=1))
    ha = a.run(W0, max_epochs=5)
    rb = EventDrivenRuntime(b)
    hb = rb.run(W0, max_epochs=5)
    assert hb[-1].time_s > ha[-1].time_s
    st = rb.contention_stats()
    assert st["ps_channels"] == 1
    assert st["rx"]["grants"] > 0 and st["tx"]["grants"] > 0
    assert st["rx"]["queue_wait_s"] > 0.0
    assert max(st["rx"]["utilization"]) > 0.0


def test_cross_round_contention_degrades_pipelining():
    """The §9 headline: overlapping rounds share the same per-PS pools,
    so the pipelined runtime loses (part of) its win under k=1 — the
    free lunch max_in_flight>1 got from infinite parallelism is gone."""
    pipe = dict(max_in_flight=3, handoff_policy="next_contact")
    free = _sim("asyncfleo-twohap", True, link=SLOW, spec_kw=pipe)
    hf = free.run(W0, max_epochs=8)
    tight = _sim("asyncfleo-twohap", True, link=SLOW,
                 spec_kw={**pipe, "ps_channels": 1})
    rt = EventDrivenRuntime(tight)
    ht = rt.run(W0, max_epochs=8)
    assert len(hf) == len(ht) == 8
    assert ht[-1].time_s > hf[-1].time_s
    assert rt.contention_stats()["rx"]["queue_wait_s"] > 0.0


def test_mid_batch_snapshot_restore_interleaved_rounds():
    """§10 rollback point: a snapshot taken mid-batch — after round A's
    first grant, with round B's grants interleaved on both pools before
    the rollback — must restore exactly the prefix state, and the same
    snapshot object must survive several restores (restore copies again,
    so a retry loop can roll back repeatedly from one checkpoint)."""
    c = ContentionModel(2, 1)
    assert c.grant_rx(0, 0.0, 10.0) == 0.0        # round A, transfer 1
    snap = c.snapshot()
    # everything after the checkpoint: A's second transfer, round B's
    # grants on the other PS and on A's own pools
    assert c.grant_rx(0, 5.0, 10.0) == 10.0       # A queues behind A
    assert c.grant_tx(1, 0.0, 10.0) == 0.0        # B: tx on the other PS
    assert c.grant_rx(0, 12.0, 10.0) == 20.0      # B: queues behind both
    assert c.grant_tx(0, 3.0, 10.0) == 3.0        # B: tx on A's PS
    c.restore(snap)
    assert (c.tx.grants, c.rx.grants) == (0, 1)
    assert c.tx.res == snap[0].res and c.rx.res == snap[1].res
    # re-grants see the prefix occupancy, not the rolled-back one
    assert c.grant_rx(0, 5.0, 10.0) == 10.0
    assert c.grant_tx(0, 3.0, 10.0) == 3.0
    # reusable snapshot: a second restore discards the re-grants too
    c.restore(snap)
    assert (c.tx.grants, c.rx.grants) == (0, 1)
    assert c.rx.intervals(0) == [(0, 0.0, 10.0)]
    assert c.tx.intervals(0) == []


def test_aborted_speculative_open_rolls_back_grants():
    """A speculative open that recruits nobody (everyone busy) must leave
    the channel pools exactly as it found them — no occupancy ghosts from
    rounds that never ran."""
    fls = _sim("asyncfleo-twohap", True, link=SLOW,
               spec_kw=dict(max_in_flight=3, handoff_policy="next_contact",
                            ps_channels=1))
    rt = EventDrivenRuntime(fls)
    rt.bits, rt.prog, _stacked = fls._init_run(W0)
    rt.max_epochs = 5
    rt.target = None
    ctn = fls.plan.contention
    before = (ctn.tx.grants, ctn.rx.grants, ctn.snapshot())
    rt._busy_until[:] = 1e9               # every satellite mid-training
    assert rt._start_round(100.0, 0, pipelined=True) is None
    assert (ctn.tx.grants, ctn.rx.grants) == before[:2]
    assert ctn.tx.res == before[2][0].res and ctn.rx.res == before[2][1].res


# ---- handoff occupancy tie-break -------------------------------------------

def test_next_contact_handoff_breaks_ties_by_occupancy():
    """Two PSs with identical next-contact times (degenerate all-visible
    plan): the source tie breaks toward the PS with the lower pending tx
    backlog; without any backlog the lowest id wins (the historical
    argmin)."""
    fls = _sim("asyncfleo-twohap", True,
               spec_kw=dict(handoff_policy="next_contact", ps_channels=1))
    fls.timeline.grid[:] = True
    rt = EventDrivenRuntime(fls)
    hand = NextContactHandoff()
    assert hand.next_round(rt, None, 0.0)[0] == 0
    fls.plan.contention.grant_tx(0, 0.0, 5000.0)   # load PS 0's tx pool
    src, sink = hand.next_round(rt, None, 0.0)
    assert src == 1
    fls.plan.contention.grant_rx(1, 0.0, 5000.0)   # and PS 1's rx pool
    src, sink = hand.next_round(rt, None, 0.0)
    assert (src, sink) == (1, 0)          # sink tie-break consults rx
