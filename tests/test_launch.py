"""Launch-layer logic: specs, windowing, HLO parsing, roofline estimators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.roofline import (analytic_flops, analytic_hbm_bytes,
                                 model_flops, roofline_terms)
from repro.configs import ARCHS, SHAPES, applicable, get_config, get_shape
from repro.configs.base import LONG_CONTEXT_WINDOW
from repro.launch.hlo_analysis import collective_bytes, _shape_bytes
from repro.launch.specs import cache_specs, input_specs, param_specs
from repro.launch.steps import cache_len_for, window_for


def test_window_only_for_long_dense():
    qwen = get_config("qwen3-4b")
    rwkv = get_config("rwkv6-7b")
    assert window_for(qwen, get_shape("long_500k")) == LONG_CONTEXT_WINDOW
    assert window_for(qwen, get_shape("decode_32k")) == 0
    assert window_for(rwkv, get_shape("long_500k")) == 0     # SSM: native
    assert window_for(get_config("deepseek-v2-236b"), get_shape("long_500k")) \
        == LONG_CONTEXT_WINDOW                               # MLA is attention


def test_cache_len_ring_buffer():
    qwen = get_config("qwen3-4b")
    assert cache_len_for(qwen, get_shape("long_500k")) == LONG_CONTEXT_WINDOW
    assert cache_len_for(qwen, get_shape("decode_32k")) == 32768


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_all_pairs(arch, shape):
    cfg, sc = get_config(arch), get_shape(shape)
    specs = input_specs(cfg, sc)
    assert isinstance(specs, dict) and specs
    for leaf in jax.tree_util.tree_leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert all(d > 0 for d in leaf.shape)
    if sc.kind == "decode":
        assert specs["tokens"].shape == (sc.global_batch, 1)
        if applicable(cfg, sc):
            cache = cache_specs(cfg, sc)
            assert jax.tree_util.tree_leaves(cache)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_abstract(arch):
    specs = param_specs(get_config(arch))
    for leaf in jax.tree_util.tree_leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)   # never allocated


def test_collective_parser():
    hlo = """
  %ag = bf16[8,128] all-gather(%x), replica_groups={}
  %ar.1 = f32[256] all-reduce(%y), to_apply=%sum
  %rs = (f32[16,16], f32[4]) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[2,2] collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[8,8] dot(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 16 * 16 * 4 + 4 * 4
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(v for k, v in out.items()
                               if k not in ("total", "_counts"))


def test_shape_bytes_tuple():
    assert _shape_bytes("(bf16[4,4], f32[2])") == 32 + 8
    assert _shape_bytes("pred[100]") == 100


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-236b", "rwkv6-7b",
                                  "zamba2-2.7b"])
def test_analytic_flops_sane(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if not applicable(cfg, shape):
            continue
        fl = analytic_flops(cfg, shape)
        hb = analytic_hbm_bytes(cfg, shape)
        mf = model_flops(cfg, shape)
        assert fl > 0 and hb > 0 and mf > 0
        assert mf <= fl * 1.01, (arch, shape.name)   # useful <= total


def test_q_chunks_reduces_attention_flops():
    cfg = get_config("deepseek-v2-236b")
    shape = get_shape("prefill_32k")
    base = analytic_flops(cfg, shape)
    chunked = analytic_flops(cfg, shape, q_chunks=8)
    assert chunked < base
    # the reduction is bounded by the attention share and the (n+1)/2n factor
    assert chunked > base * 0.4


def test_capacity_factor_scales_expert_flops():
    cfg = get_config("kimi-k2-1t-a32b")
    shape = get_shape("train_4k")
    lo = analytic_flops(cfg, shape, capacity_factor=1.0)
    hi = analytic_flops(cfg, shape, capacity_factor=2.0)
    assert hi > lo


def test_roofline_terms_from_entry():
    entry = {"arch": "qwen3-4b", "shape": "train_4k", "num_devices": 256,
             "mesh_shape": [16, 16], "collective_bytes": {"total": 1e9},
             "flops": 1e12, "bytes_accessed": 1e10}
    r = roofline_terms(entry)
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["compute_s"] > 0 and 0 < r["useful_ratio"] <= 1.0
