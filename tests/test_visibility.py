import numpy as np

from repro.core.constellation import (GroundNode, R_EARTH, WalkerDelta,
                                      make_ps_nodes, paper_constellation)
from repro.core.visibility import (VisibilityTimeline, elevation_deg,
                                   is_visible, sat_los)


def test_elevation_zenith():
    gnd = np.array([R_EARTH, 0.0, 0.0])
    sat = np.array([R_EARTH + 2000e3, 0.0, 0.0])
    assert abs(elevation_deg(sat, gnd) - 90.0) < 1e-6


def test_elevation_horizon_negative():
    gnd = np.array([R_EARTH, 0.0, 0.0])
    sat = np.array([-(R_EARTH + 2000e3), 0.0, 0.0])   # opposite side
    assert elevation_deg(sat, gnd) < 0


def test_sat_los_earth_block():
    a = np.array([R_EARTH + 500e3, 0.0, 0.0])
    b = -a                                             # straight through Earth
    assert not sat_los(a, b)
    c = np.array([0.0, R_EARTH + 500e3, 0.0])          # quarter arc: grazing ok
    assert sat_los(a, np.array([R_EARTH + 2000e3, 1e6, 0.0]))
    assert sat_los(np.array([R_EARTH + 2000e3, 0, 0]),
                   np.array([R_EARTH + 2000e3, 1e5, 0]))


def test_timeline_grid_and_queries():
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes("hap"), 6 * 3600.0, 10.0)
    assert tl.grid.shape[1] == 40 and tl.grid.shape[2] == 1
    # every satellite should see the HAP at some point within 6h? not all —
    # but at least SOME satellite does.
    assert tl.grid.any()
    t_vis = tl.next_visible_time(0, 0.0)
    if t_vis is not None:
        assert tl.visible(t_vis)[0, 0]
    t, sat = tl.next_orbit_visible(range(8), 0.0)
    if t is not None:
        assert 0 <= sat < 8
        assert tl.visible(t)[sat].any()


def test_visibility_fraction_reasonable():
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes("hap"), 86400.0, 30.0)
    fr = np.mean([tl.visibility_fraction(s) for s in range(40)])
    # LEO satellite sees one mid-latitude HAP a few % of the time
    assert 0.005 < fr < 0.5


def test_next_visible_after_never_visible():
    """Equatorial constellation + polar GS: no satellite is EVER visible —
    next_visible_after must report (inf, -1), not crash or wrap."""
    c = WalkerDelta(num_orbits=1, sats_per_orbit=4, inclination_deg=0.0)
    tl = VisibilityTimeline(c, [GroundNode("GS-NP", 90.0, 0.0, 0.0)],
                            3600.0, 10.0)
    assert not tl.grid.any()
    tv, ps = tl.next_visible_after([0, 1, 2, 3], 0.0)
    assert not np.isfinite(tv).any()
    assert (ps == -1).all()
    assert tl.next_visible_time(0, 0.0) is None


def test_next_visible_after_past_horizon():
    """Queries beyond the precomputed horizon clamp to the final grid row:
    visible-at-the-end satellites report the last sample time, everyone
    else (inf, -1)."""
    c = paper_constellation()
    tl = VisibilityTimeline(c, make_ps_nodes("twohap"), 6 * 3600.0, 30.0)
    tv, ps = tl.next_visible_after(np.arange(c.num_sats),
                                   tl.duration_s * 10.0)
    last = tl.grid[-1]
    for s in range(c.num_sats):
        if last[s].any():
            assert tv[s] == tl.times[-1]
            assert ps[s] == int(np.argmax(last[s]))
        else:
            assert not np.isfinite(tv[s])
            assert ps[s] == -1
    # scalar query form agrees
    t_clamped = tl.next_visible_time(0, tl.duration_s * 10.0)
    assert t_clamped is None or t_clamped == tl.times[-1]


def test_hap_sees_similar_or_more_than_gs():
    """The paper's rationale: HAP at 20 km has slightly better visibility.
    At a fixed 10-degree minimum elevation the geometric gain is tiny, so we
    assert near-parity (the elevation advantage shows up at the horizon and
    is sub-percent at dt=30 s sampling)."""
    c = paper_constellation()
    tl_gs = VisibilityTimeline(c, make_ps_nodes("gs"), 86400.0, 30.0)
    tl_hap = VisibilityTimeline(c, make_ps_nodes("hap"), 86400.0, 30.0)
    assert tl_hap.grid.sum() > tl_gs.grid.sum()     # horizon-dip advantage
