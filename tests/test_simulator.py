"""Simulator behaviour with a stub trainer (no real ML — fast)."""
import numpy as np
import pytest

from repro.core import FLSimulation, SimConfig, convergence_time
from repro.core.simulator import EpochRecord
from repro.fl import get_strategy


class StubTrainer:
    """Each 'training' nudges a scalar toward 1.0 — convergence is visible
    in the evaluator without real ML."""

    def data_size(self, sat):
        return 100

    def train_many(self, sats, params, seed):
        out = [{"w": params["w"] + 0.3 * (1.0 - params["w"])} for _ in sats]
        return out, np.zeros(len(sats))


def evaluator(params):
    return float(1.0 - abs(1.0 - params["w"].mean()))


W0 = {"w": np.zeros((4,), np.float32)}
SIMCFG = SimConfig(duration_s=86400.0, train_time_s=300.0)


@pytest.mark.parametrize("name", ["asyncfleo-hap", "asyncfleo-twohap",
                                  "fedhap", "fedsat", "fedspace",
                                  "fedisl-ideal"])
def test_strategies_run_and_progress(name):
    sim = FLSimulation(get_strategy(name), StubTrainer(), evaluator, SIMCFG)
    hist = sim.run(W0, max_epochs=4)
    assert len(hist) >= 1
    assert all(isinstance(r, EpochRecord) for r in hist)
    # monotonically advancing simulated time
    times = [r.time_s for r in hist]
    assert all(b >= a for a, b in zip(times, times[1:]))
    # the stub converges toward accuracy 1
    assert hist[-1].accuracy > hist[0].accuracy - 1e-6


def test_async_epochs_faster_than_sync():
    """The paper's core claim at system level: async epoch cadence beats the
    sync barrier (which waits for stragglers)."""
    h_async = FLSimulation(get_strategy("asyncfleo-hap"), StubTrainer(),
                           evaluator, SIMCFG).run(W0, max_epochs=3)
    h_sync = FLSimulation(get_strategy("fedhap"), StubTrainer(),
                          evaluator, SIMCFG).run(W0, max_epochs=3)
    assert h_async[0].time_s < h_sync[0].time_s


def test_two_haps_no_slower_than_one():
    h1 = FLSimulation(get_strategy("asyncfleo-hap"), StubTrainer(),
                      evaluator, SIMCFG).run(W0, max_epochs=3)
    h2 = FLSimulation(get_strategy("asyncfleo-twohap"), StubTrainer(),
                      evaluator, SIMCFG).run(W0, max_epochs=3)
    assert h2[-1].time_s <= h1[-1].time_s * 1.5


def test_convergence_time_helper():
    hist = [EpochRecord(0, 100.0, 0.5, 4, 1.0, 0),
            EpochRecord(1, 200.0, 0.9, 4, 1.0, 0)]
    assert convergence_time(hist, 0.8) == 200.0
    assert convergence_time(hist, 0.95) is None


def test_convergence_time_edge_cases():
    assert convergence_time([], 0.5) is None          # empty history
    hist = [EpochRecord(0, 50.0, float("nan"), 1, 1.0, 0),
            EpochRecord(1, 100.0, 0.9, 1, 1.0, 0),
            EpochRecord(2, 150.0, 0.95, 1, 1.0, 0)]
    # NaN accuracy rows never satisfy the target (NaN >= x is False)
    assert convergence_time(hist, 0.8) == 100.0
    # exactly-at-target counts (>=), and the FIRST crossing wins
    assert convergence_time(hist, 0.9) == 100.0
    # target met by the very first record
    assert convergence_time(hist[2:], 0.9) == 150.0
    # non-monotone accuracy: first crossing still wins
    dip = [EpochRecord(0, 10.0, 0.9, 1, 1.0, 0),
           EpochRecord(1, 20.0, 0.4, 1, 1.0, 0)]
    assert convergence_time(dip, 0.85) == 10.0


def test_target_accuracy_stops_early():
    sim = FLSimulation(get_strategy("asyncfleo-hap"), StubTrainer(),
                       evaluator, SIMCFG)
    hist = sim.run(W0, max_epochs=10, target_accuracy=0.9)
    assert hist[-1].accuracy >= 0.9
    assert len(hist) < 10


def test_no_grouping_ablation_runs():
    import dataclasses
    spec = dataclasses.replace(get_strategy("asyncfleo-hap"), grouping=False)
    sim = FLSimulation(spec, StubTrainer(), evaluator, SIMCFG)
    hist = sim.run(W0, max_epochs=3)
    assert len(hist) >= 1


def test_fso_link_speeds_transmission_not_visibility():
    """FSO (100 Gb/s) vs RF (16 Mb/s): transmission delay vanishes but epoch
    cadence stays visibility-dominated — the system's real bottleneck."""
    from repro.core.links import fso_link
    import dataclasses
    cfg_fso = dataclasses.replace(SIMCFG, link=fso_link())
    h_rf = FLSimulation(get_strategy("asyncfleo-hap"), StubTrainer(),
                        evaluator, SIMCFG).run(W0, max_epochs=2)
    h_fso = FLSimulation(get_strategy("asyncfleo-hap"), StubTrainer(),
                         evaluator, cfg_fso).run(W0, max_epochs=2)
    assert h_fso[0].time_s <= h_rf[0].time_s
    # visibility dominates: FSO saves < 20% of the first-epoch latency
    assert h_fso[0].time_s > 0.5 * h_rf[0].time_s
