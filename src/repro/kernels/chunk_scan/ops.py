"""Public API for chunk_scan: (B,T,H,·) layout plumbing around the kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.chunk_scan.kernel import chunk_scan_flat
from repro.models.scan_ops import _prep_decay


def chunk_scan(r, k, v, log_decay, state0=None, *, include_current=True,
               bonus=None, chunk: int = 64,
               interpret: Optional[bool] = None):
    """Same contract as models.scan_ops.chunked_scan (B,T,H,·)."""
    if interpret is None:
        interpret = default_interpret()
    B, T, H, K = r.shape
    V = v.shape[-1]
    ld = _prep_decay(log_decay, K)

    def flat(x):                       # (B,T,H,X) -> (B*H, T, X)
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, x.shape[-1])

    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if state0 is None
          else state0.astype(jnp.float32)).reshape(B * H, K, V)
    u = (jnp.zeros((H, K), jnp.float32) if bonus is None
         else bonus.astype(jnp.float32))
    u = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)

    y, s_fin = chunk_scan_flat(flat(r), flat(k), flat(v), flat(ld), s0, u,
                               include_current=include_current,
                               chunk=min(chunk, T), interpret=interpret)
    y = y.reshape(B, H, T, V).transpose(0, 2, 1, 3)
    return y.astype(v.dtype), s_fin.reshape(B, H, K, V)
