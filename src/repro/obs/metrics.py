"""Metric registry: counters, gauges, bounded histograms (DESIGN.md §12).

The registry is the single backing store for what used to be ad-hoc
telemetry dicts: `EventDrivenRuntime.stats` becomes a
:class:`StatsView` over a :class:`MetricRegistry` (the old dict keys
keep working, read and write), and `ChannelPool` queue-wait telemetry
feeds a histogram so `contention` bench blocks report percentiles, not
just totals.

Histograms are **bounded and deterministic**: the sample buffer keeps
every ``stride``-th observation and, on reaching ``max_samples``,
decimates itself (drop every other retained sample, double the stride)
— no RNG, so two identical runs summarize identically, and memory is
O(max_samples) no matter how many observations arrive.  ``count`` /
``sum`` / ``min`` / ``max`` stay exact; p50/p95/p99 are computed over
the retained samples.
"""
from __future__ import annotations

import dataclasses
from collections.abc import MutableMapping
from typing import Dict, Iterator, List, Optional, Sequence


@dataclasses.dataclass
class Counter:
    """Monotonic count (resettable only via the registry)."""
    name: str
    value: float = 0

    def inc(self, amount: float = 1) -> None:
        self.value += amount


@dataclasses.dataclass
class Gauge:
    """Last-written value (e.g. peak in-flight depth via ``set_max``)."""
    name: str
    value: float = 0

    def set(self, value: float) -> None:
        self.value = value

    def set_max(self, value: float) -> None:
        if value > self.value:
            self.value = value


class Histogram:
    """Bounded deterministic histogram with exact count/sum/min/max.

    Keeps at most ``max_samples`` observations for percentile
    estimation by stride-decimation: observation ``i`` is retained iff
    ``i % stride == 0``, and when the buffer fills the stride doubles
    and every other retained sample is dropped.  Early observations are
    never privileged over late ones beyond the uniform stride, and no
    randomness is involved.
    """

    def __init__(self, name: str, max_samples: int = 1024):
        if max_samples < 2:
            raise ValueError("max_samples must be >= 2")
        self.name = name
        self.max_samples = int(max_samples)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._samples: List[float] = []
        self._stride = 1

    def observe(self, value: float) -> None:
        v = float(value)
        if self.count == 0 or v < self.min:
            self.min = v
        if self.count == 0 or v > self.max:
            self.max = v
        if self.count % self._stride == 0:
            if len(self._samples) >= self.max_samples:
                self._samples = self._samples[::2]
                self._stride *= 2
            if self.count % self._stride == 0:
                self._samples.append(v)
        self.count += 1
        self.sum += v

    def percentile(self, q: float) -> Optional[float]:
        """Linear-interpolated percentile over the retained samples
        (None when empty).  ``q`` in [0, 100]."""
        if not self._samples:
            return None
        xs = sorted(self._samples)
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> Dict:
        """JSON-serializable summary (min/max/percentiles None when
        empty) — the compat-view representation of histogram stats."""
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }

    @property
    def samples(self) -> Sequence[float]:
        return tuple(self._samples)


class MetricRegistry:
    """Flat namespace of counters, gauges, and histograms.

    ``counter``/``gauge``/``histogram`` are get-or-create; ``inc`` /
    ``set_gauge`` / ``observe`` are the write shorthands call sites
    use.  ``snapshot`` renders everything to plain JSON-serializable
    values (histograms as their summary dict)."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    # ---- get-or-create -----------------------------------------------------

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, max_samples: int = 1024) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, max_samples)
        return h

    # ---- write shorthands --------------------------------------------------

    def inc(self, name: str, amount: float = 1) -> None:
        self.counter(name).inc(amount)

    def set_gauge(self, name: str, value: float) -> None:
        self.gauge(name).set(value)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    # ---- read --------------------------------------------------------------

    def get(self, name: str):
        """The rendered value of a metric by name (counters/gauges →
        number, histograms → summary dict); KeyError when absent."""
        if name in self.counters:
            return self.counters[name].value
        if name in self.gauges:
            return self.gauges[name].value
        if name in self.histograms:
            return self.histograms[name].summary()
        raise KeyError(name)

    def __contains__(self, name: str) -> bool:
        return (name in self.counters or name in self.gauges
                or name in self.histograms)

    def names(self) -> List[str]:
        return (list(self.counters) + list(self.gauges)
                + list(self.histograms))

    def snapshot(self) -> Dict:
        return {n: self.get(n) for n in self.names()}


class StatsView(MutableMapping):
    """The legacy ``runtime.stats`` dict as a live view over a registry.

    Existing call sites keep working unchanged — ``stats[k] += 1``,
    ``stats.get(k, 0)``, ``dict(stats)``, ``json.dump`` — but every
    read reflects the registry, so the dict and the registry can never
    drift.  Keys listed in ``histogram_keys`` render as histogram
    summary dicts (bounded; the fix for the unbounded
    ``backoff_delays_s`` list) and reject writes; integer-like counter
    values render as ``int`` so JSON artifacts keep their old shape.
    Unknown-key writes create counters, so policy hooks that invent
    keys (e.g. ``shrunk_windows``) still work.
    """

    def __init__(self, registry: MetricRegistry,
                 counter_keys: Sequence[str] = (),
                 histogram_keys: Sequence[str] = ()):
        self._registry = registry
        self._histogram_keys = tuple(histogram_keys)
        for k in counter_keys:
            registry.counter(k)
        for k in histogram_keys:
            registry.histogram(k)

    @property
    def registry(self) -> MetricRegistry:
        return self._registry

    def _render(self, key: str):
        v = self._registry.get(key)
        if isinstance(v, float) and v.is_integer() \
                and key not in self._registry.gauges:
            return int(v)
        return v

    def __getitem__(self, key: str):
        if key not in self._registry:
            raise KeyError(key)
        return self._render(key)

    def __setitem__(self, key: str, value) -> None:
        if key in self._histogram_keys:
            raise TypeError(
                f"{key!r} is histogram-backed; use "
                f"registry.observe({key!r}, v) instead of assignment")
        if key in self._registry.gauges:
            self._registry.gauges[key].set(value)
        else:
            self._registry.counter(key).value = value

    def __delitem__(self, key: str) -> None:
        raise TypeError("StatsView keys cannot be deleted")

    def __iter__(self) -> Iterator[str]:
        return iter(self._registry.names())

    def __len__(self) -> int:
        return len(self._registry.names())

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"
