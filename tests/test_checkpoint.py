import numpy as np
import pytest

from repro.checkpoint import (load_pytree, load_server_state, save_pytree,
                              save_server_state)


def test_pytree_roundtrip(tmp_path):
    tree = {"layers": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                       "b": np.zeros((4,), np.float32)},
            "embed": {"table": np.ones((7, 2), np.float32)}}
    p = str(tmp_path / "ckpt.npz")
    save_pytree(p, tree)
    back = load_pytree(p)
    np.testing.assert_array_equal(back["layers"]["w"], tree["layers"]["w"])
    np.testing.assert_array_equal(back["embed"]["table"], tree["embed"]["table"])


def test_server_state_roundtrip(tmp_path):
    model = {"w": np.full((2, 2), 3.0, np.float32)}
    p = str(tmp_path / "server.npz")
    save_server_state(p, global_model=model, epoch=7,
                      grouping=[[0, 1], [2]], metadata={"5": 3})
    m2, side = load_server_state(p)
    np.testing.assert_array_equal(m2["w"], model["w"])
    assert side["epoch"] == 7
    assert side["grouping"] == [[0, 1], [2]]


def test_bf16_leaves_roundtrip(tmp_path):
    import jax.numpy as jnp
    tree = {"w": np.asarray(jnp.ones((3,), jnp.bfloat16))}
    p = str(tmp_path / "bf16.npz")
    save_pytree(p, tree)
    back = load_pytree(p)
    assert back["w"].shape == (3,)
