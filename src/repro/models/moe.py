"""Mixture-of-Experts FFN (token-choice top-k, sort-based dispatch) and
Multi-head Latent Attention (MLA, DeepSeek-V2 style).

MoE dispatch is the production sort-based formulation: assignments are sorted
by expert id, placed into a per-expert capacity buffer ``(E, C, d)`` via
scatter, batched expert matmuls run as a single ``ecd,edf->ecf`` einsum
(expert axis tensor-shardable), and results are combined by weighted
scatter-add.  Tokens beyond capacity are dropped (standard on TPU); the
router aux loss keeps load balanced.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

CAPACITY_FACTOR = 1.25


def moe_capacity(tokens: int, num_experts: int, top_k: int,
                 factor: float = CAPACITY_FACTOR) -> int:
    c = int(math.ceil(tokens * top_k * factor / num_experts))
    return max(8, -(-c // 8) * 8)     # round up to 8 for TPU-friendly tiles


def init_moe_ffn(key, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, E)),
        "we1": L.dense_init(ks[1], (E, d, f), in_axis_size=d),
        "we3": L.dense_init(ks[2], (E, d, f), in_axis_size=d),
        "we2": L.dense_init(ks[3], (E, f, d), in_axis_size=f),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, f * cfg.num_shared_experts)
    return p


def moe_ffn(p, cfg: ModelConfig, x, *, capacity_factor: float = None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    dt = x.dtype
    xf = x.reshape(T, d)
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor

    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                             # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                    # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    C = moe_capacity(T, E, k, capacity_factor)
    flat_ids = ids.reshape(-1)                                      # (T*k,)
    sort_idx = jnp.argsort(flat_ids, stable=True)                   # (T*k,)
    sorted_eids = flat_ids[sort_idx]
    start = jnp.searchsorted(sorted_eids, jnp.arange(E), side="left")
    pos_in_expert = jnp.arange(T * k) - start[sorted_eids]
    tok = sort_idx // k                                             # source token
    valid = pos_in_expert < C
    dest = jnp.where(valid, sorted_eids * C + pos_in_expert, E * C)  # drop slot

    buf = jnp.zeros((E * C + 1, d), dt).at[dest].set(xf[tok])
    h = buf[: E * C].reshape(E, C, d)
    a = jnp.einsum("ecd,edf->ecf", h, p["we1"].astype(dt))
    b = jnp.einsum("ecd,edf->ecf", h, p["we3"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(a) * b, p["we2"].astype(dt))
    y = y.reshape(E * C, d)

    gate_sorted = gate.reshape(-1)[sort_idx].astype(dt)
    contrib = y[jnp.where(valid, dest, 0)] * jnp.where(valid, gate_sorted, 0.0)[:, None]
    out = jnp.zeros((T, d), dt).at[tok].add(contrib)

    if "shared" in p:
        out = out + L.mlp(p["shared"], xf)
    return out.reshape(B, S, d), aux


def moe_ffn_reference(p, cfg: ModelConfig, x):
    """Oracle: per-token dense loop over all experts (tiny configs only)."""
    B, S, d = x.shape
    dt = x.dtype
    xf = x.reshape(-1, d)
    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    # all-experts dense compute (E, T, d) — fine at smoke scale
    a = jnp.einsum("td,edf->etf", xf, p["we1"].astype(dt))
    b = jnp.einsum("td,edf->etf", xf, p["we3"].astype(dt))
    y = jnp.einsum("etf,efd->etd", jax.nn.silu(a) * b, p["we2"].astype(dt))
    onehot = jax.nn.one_hot(ids, cfg.num_experts, dtype=jnp.float32)  # (T,k,E)
    w = jnp.einsum("tk,tke->te", gate, onehot).astype(dt)             # (T,E)
    out = jnp.einsum("te,etd->td", w, y)
    if "shared" in p:
        out = out + L.mlp(p["shared"], xf)
    return out.reshape(B, S, d)


# ==========================================================================
# MLA — multi-head latent attention (DeepSeek-V2)
# ==========================================================================

def init_mla(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": L.dense_init(ks[0], (d, r_kv)),                 # x -> latent
        "w_kr": L.dense_init(ks[1], (d, dr)),                    # x -> shared rope key
        "w_uk": L.dense_init(ks[2], (r_kv, H, dn), in_axis_size=r_kv),
        "w_uv": L.dense_init(ks[3], (r_kv, H, dn), in_axis_size=r_kv),
        "wo": L.dense_init(ks[4], (H, dn, d), in_axis_size=H * dn),
        "kv_norm": jnp.ones((r_kv,)),
    }
    if r_q:
        p["w_dq"] = L.dense_init(ks[5], (d, r_q))
        p["w_uq"] = L.dense_init(ks[6], (r_q, H, dn + dr), in_axis_size=r_q)
        p["q_norm"] = jnp.ones((r_q,))
    else:
        p["wq"] = L.dense_init(ks[7], (d, H, dn + dr))
    return p


def _mla_queries(p, cfg: ModelConfig, x, positions):
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    dt = x.dtype
    if cfg.q_lora_rank:
        cq = L.rms_norm(x @ p["w_dq"].astype(dt), p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p, cfg: ModelConfig, x, positions, cache=None, *, window: int = 0,
                  q_chunks: int = 1):
    """MLA block.  Prefill/train: expanded form.  Decode: absorbed form over a
    latent cache of ``(c_kv, k_rope)`` — O(S·(r_kv+dr)) per step, the MLA win.

    ``q_chunks > 1`` enables chunked causal prefill: query chunk i only
    attends to keys [0, (i+1)*S/n), cutting score/AV matmul FLOPs to
    (n+1)/2n of the full rectangle — the §Perf lever for compute-bound
    long-prefill (structural, exact, no approximation).
    Returns (out, new_cache_or_None)."""
    B, S, d = x.shape
    H = cfg.num_heads
    dn, dr, r_kv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    dt = x.dtype
    scale = 1.0 / math.sqrt(dn + dr)

    if cache is not None and positions is None:
        positions = jnp.broadcast_to(cache["index"][None, None], (B, S))
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)
    c_kv = L.rms_norm(x @ p["w_dkv"].astype(dt), p["kv_norm"])      # (B,S,r_kv)
    k_rope = L.apply_rope(x @ p["w_kr"].astype(dt), positions, cfg.rope_theta)

    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(dt))

        def attend(qn, qr, qpos, kn, kr, kpos):
            scores = (jnp.einsum("bqhk,bshk->bhqs", qn, kn)
                      + jnp.einsum("bqhk,bsk->bhqs", qr, kr))
            scores = scores.astype(jnp.float32) * scale
            bias = L._mask_bias(qpos, kpos, True, window, jnp.float32)
            scores = scores + bias.reshape(
                bias.shape[:-2] + (1,) * (scores.ndim - bias.ndim) + bias.shape[-2:])
            probs = jax.nn.softmax(scores, axis=-1).astype(dt)
            return probs

        if q_chunks > 1 and S % q_chunks == 0:
            cs = S // q_chunks
            outs = []
            for i in range(q_chunks):
                hi = (i + 1) * cs
                probs = attend(q_nope[:, i * cs:hi], q_rope[:, i * cs:hi],
                               positions[..., i * cs:hi],
                               k_nope[:, :hi], k_rope[:, :hi],
                               positions[..., :hi])
                outs.append(jnp.einsum("bhqs,bshk->bqhk", probs, v[:, :hi]))
            out = jnp.concatenate(outs, axis=1)
        else:
            probs = attend(q_nope, q_rope, positions, k_nope, k_rope, positions)
            out = jnp.einsum("bhqs,bshk->bqhk", probs, v)
        new_cache = None
    else:
        # ---- absorbed decode: scores via latent, never expand K/V ----------
        cache_len = cache["c_kv"].shape[1]
        idx = cache["index"]
        slot = jnp.mod(idx, cache_len)
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, slot, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, slot, 0))
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c, "index": idx + 1}

        slots = jnp.arange(cache_len)
        written = jnp.minimum(idx + 1, cache_len)
        age = jnp.mod(slot - slots, cache_len)
        k_pos = jnp.where(age < written, idx - age, 10**9)
        k_pos = jnp.broadcast_to(k_pos, (B, cache_len))
        q_pos = jnp.broadcast_to(jnp.asarray(idx)[None], (B, 1))

        # absorb: q_lat = q_nope @ W_uk  -> (B,1,H,r_kv)
        q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["w_uk"].astype(dt))
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_c.astype(dt))
                  + jnp.einsum("bqhk,bsk->bhqs", q_rope, kr_c.astype(dt)))
        scores = scores.astype(jnp.float32) * scale
        bias = L._mask_bias(q_pos, k_pos, True, window, jnp.float32)
        scores = scores + bias[:, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ckv_c.astype(dt))
        out = jnp.einsum("bqhr,rhk->bqhk", out_lat, p["w_uv"].astype(dt))

    out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
    return out, new_cache
