"""Pure-JAX pytree optimizers (no optax in this container)."""
from repro.optim.optimizers import (
    Optimizer, sgd, adamw, apply_updates, global_norm, clip_by_global_norm,
)

__all__ = ["Optimizer", "sgd", "adamw", "apply_updates", "global_norm",
           "clip_by_global_norm"]
