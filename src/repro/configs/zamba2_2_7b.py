"""zamba2-2.7b — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

54 Mamba2 layers, d_model 2560, ssm_state 64; a single *shared* attention+MLP
block (32 heads) is invoked every 6 mamba layers (same weights each call).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_heads=40, ssm_head_dim=128,    # d_inner = 2*d_model
    chunk_size=128, attn_every=6,
    citation="arXiv:2411.15242",
)
