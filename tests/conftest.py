import os
import sys

# Tests see the host's real single device — the 512-device flag is set ONLY
# inside launch/dryrun.py (its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks pkg
